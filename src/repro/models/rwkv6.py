"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus squared-ReLU channel-mix.

Time-mix recurrence per head (N = head dim, state S in R^{NxN}):

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(-exp(wlog_t))

with token-shift DDLERP inputs and LoRA-generated per-channel decay wlog_t.
Train lowering uses the same exact parallel-over-chunks scheme as mamba.py:
zero-init within-chunk scan + cross-chunk state propagation + closed-form
boundary correction  y_t += (r_t * P_{t-1})^T S_start  (P = cumprod of w).
No log-space/overflow tricks are needed because all factors are <= 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 64
LORA_MIX = 32
LORA_DECAY = 64
N_MIX = 5  # r, k, v, g, w


def _token_shift(x, last):
    """x: (B,S,D); last: (B,D) previous token (zeros at sequence start)."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _ddlerp(x, prev, p):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,g,w)."""
    xx = prev - x
    base = x + xx * p["mu_base"]
    k5 = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["mix_a"]))  # (B,S,5*32)
    b, s, _ = x.shape
    k5 = k5.reshape(b, s, N_MIX, LORA_MIX)
    dyn = jnp.einsum("bsfr,frd->bsfd", k5, p["mix_b"])  # (B,S,5,D)
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (p["mu_five"] + dyn)
    return [mixed[:, :, i, :] for i in range(N_MIX)]


def _wkv_chunked(r, k, v, w, u, s0, unroll=1):
    """r/k/v/w: (B,S,H,N); u: (H,N); s0: (B,H,N,N). Exact chunked WKV.

    Returns (y (B,S,H,N), s_final)."""
    b, s, h, n = r.shape
    nc = max(1, s // CHUNK)
    lc = s // nc
    assert nc * lc == s
    rs = r.reshape(b, nc, lc, h, n)
    ks = k.reshape(b, nc, lc, h, n)
    vs = v.reshape(b, nc, lc, h, n)
    ws = w.reshape(b, nc, lc, h, n)

    def step(state, t):
        r_t, k_t, v_t, w_t = t  # each (B, nc, H, N)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,nc,H,N,N)
        y = jnp.einsum("bchi,bchij->bchj", r_t, state + u[:, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    s_zero = jnp.zeros((b, nc, h, n, n), r.dtype)
    s_last, y0 = jax.lax.scan(
        step,
        s_zero,
        (
            rs.transpose(2, 0, 1, 3, 4),
            ks.transpose(2, 0, 1, 3, 4),
            vs.transpose(2, 0, 1, 3, 4),
            ws.transpose(2, 0, 1, 3, 4),
        ),
        unroll=unroll,
    )
    y0 = y0.transpose(1, 2, 0, 3, 4)  # (B, nc, lc, H, N)

    p_cum = jnp.cumprod(ws, axis=2)  # (B,nc,lc,H,N) — prod of w_1..t
    p_full = p_cum[:, :, -1]

    def cross(state, t):
        p_c, m_c = t
        return p_c[..., :, None] * state + m_c, state

    s_fin, s_starts = jax.lax.scan(
        cross, s0, (p_full.transpose(1, 0, 2, 3), s_last.transpose(1, 0, 2, 3, 4)),
        unroll=unroll,
    )
    s_starts = s_starts.swapaxes(0, 1)  # (B,nc,H,N,N)

    # y_t uses S_{t-1}: correction factor is P_{t-1} (exclusive cumprod).
    p_excl = jnp.concatenate(
        [jnp.ones_like(p_cum[:, :, :1]), p_cum[:, :, :-1]], axis=2
    )
    y_corr = jnp.einsum("bclhi,bchij->bclhj", rs * p_excl, s_starts)
    y = (y0 + y_corr).reshape(b, s, h, n)
    return y, s_fin


def _group_norm(y, gamma, beta, eps=64e-5):
    """Per-head LayerNorm (RWKV 'ln_x'). y: (B,S,H,N); gamma/beta: (H*N,)."""
    b, s, h, n = y.shape
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = ((y32 - mu) ** 2).mean(-1, keepdims=True)
    yn = ((y32 - mu) * jax.lax.rsqrt(var + eps)).reshape(b, s, h * n)
    return (yn * gamma + beta).astype(y.dtype)


def time_mix(x, p, cfg, state=None):
    """RWKV-6 attention substitute. state: {'shift': (B,D), 'wkv': (B,H,N,N)}."""
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    shift_in = state["shift"] if state is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, shift_in)
    xr, xk, xv, xg, xw = _ddlerp(x, prev, p)

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b, s, h, n)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b, s, h, n)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b, s, h, n)
    g = jnp.einsum("bsd,de->bse", xg, p["w_g"])
    wlog = p["w_base"] + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_a"])), p["decay_b"]
    )
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))).astype(x.dtype).reshape(b, s, h, n)
    u = p["u"].reshape(h, n)

    s0 = state["wkv"] if state is not None else jnp.zeros((b, h, n, n), x.dtype)
    if s == 1:  # decode fast path
        kv = k[:, 0, :, :, None] * v[:, 0, :, None, :]
        y = jnp.einsum("bhi,bhij->bhj", r[:, 0], s0 + u[:, :, None] * kv)[:, None]
        y = y.reshape(b, 1, h, n)
        s_fin = w[:, 0, :, :, None] * s0 + kv
    else:
        # inner scans stay While-loops even in analysis mode (see dryrun).
        y, s_fin = _wkv_chunked(r, k, v, w, u, s0)

    y = _group_norm(y, p["ln_x_g"], p["ln_x_b"])
    out = jnp.einsum("bsd,de->bse", y * jax.nn.silu(g), p["w_o"])
    new_state = {"shift": x[:, -1, :], "wkv": s_fin}
    return out, new_state


def channel_mix(x, p, cfg, state=None):
    """RWKV-6 FFN: squared-ReLU with token shift. state: {'shift': (B,D)}."""
    b, s, d = x.shape
    shift_in = state["shift"] if state is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, shift_in)
    xx = prev - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"])) * kv
    return out, {"shift": x[:, -1, :]}
