"""Model zoo foundation: config, parameter pytrees, logical sharding axes.

Every architecture is described by one `ModelConfig`. Parameters are built as
*stacked* pytrees: layers are grouped into repeating periods (dense LMs have
period 1; Jamba has period 8; Llama-3.2-Vision has period 5) and each leaf
carries a leading `groups` dimension so the forward pass is a single
`lax.scan` — HLO size is O(1) in depth, which is what makes 72-layer/398B
configs lower+compile in the 512-device dry-run.

Each parameter leaf has a parallel *logical axes* annotation (a tuple of axis
names like ("layers", "embed", "heads")); `repro.distributed.sharding` maps
logical axes onto the production mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    gated_mlp: bool = True  # SwiGLU vs plain MLP
    mlp_act: str = "gelu"  # non-gated MLP activation: gelu | relu2
    rope_theta: float = 1e4
    sliding_window: int = 0  # 0 -> full attention
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # every k-th layer position is MoE (within a period)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # hybrid / ssm
    attn_every: int = 0  # jamba: one attention layer per this many layers
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # vlm
    cross_attn_every: int = 0  # one cross-attn layer per this many layers
    n_img_tokens: int = 0
    # audio
    n_codebooks: int = 0
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    # dry-run analysis mode: fully unroll every lax.scan so XLA cost analysis
    # (which visits While bodies once) counts true totals. Never used for the
    # memory pass or real execution.
    scan_unroll: bool = False
    flash_chunk: int = 1024  # q/kv chunk for flash-style attention
    kv_quant: bool = False  # int8 KV cache (+per-token scales) for decode

    @property
    def unroll(self):
        return True if self.scan_unroll else 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        """Layers per scan step (the repeating block pattern)."""
        if self.family == "hybrid":
            return self.attn_every  # e.g. jamba: 8 (1 attn : 7 mamba)
        if self.family == "vlm":
            return self.cross_attn_every  # e.g. 5 (4 self + 1 cross)
        if self.n_experts and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, pos: int) -> dict:
        """Describe period position `pos`: mixer type + ffn type."""
        if self.family == "hybrid":
            mixer = "attn" if pos == self.attn_every // 2 else "mamba"
            ffn = "moe" if (pos % 2 == 1) else "mlp"
        elif self.family == "vlm":
            mixer = "cross" if pos == self.period - 1 else "attn"
            ffn = "mlp"
        elif self.family == "ssm":
            mixer, ffn = "rwkv", "rwkv_cm"
        elif self.family == "moe":
            mixer = "attn"
            ffn = "moe" if (pos % self.moe_every == self.moe_every - 1) else "mlp"
        else:
            mixer, ffn = "attn", "mlp"
        return {"mixer": mixer, "ffn": ffn}

    def param_count(self) -> tuple[int, int]:
        """(total, active-per-token) parameter counts, computed analytically."""
        total = active = 0
        for pos in range(self.period):
            kind = self.layer_kind(pos)
            t, a = _layer_params(self, kind)
            total += t * self.n_groups
            active += a * self.n_groups
        emb = self.vocab * self.d_model * max(1, self.n_codebooks or 1)
        head = 0 if self.tie_embeddings else self.vocab * self.d_model * max(
            1, self.n_codebooks or 1
        )
        total += emb + head
        active += emb + head
        if self.cross_attn_every:
            pass  # cross-attn weights counted in _layer_params
        return total, active


def _layer_params(cfg: ModelConfig, kind: dict) -> tuple[int, int]:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    t = a = 0
    if kind["mixer"] in ("attn", "cross"):
        qkv = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        t += qkv
        a += qkv
    elif kind["mixer"] == "mamba":
        di, ds = cfg.d_inner, cfg.d_state
        m = d * 2 * di + di * cfg.d_conv + di * (2 * ds + math.ceil(d / 16)) + di * d + di
        t += m
        a += m
    elif kind["mixer"] == "rwkv":
        n = 5 * d * d + d * 64 * 2  # r/k/v/g/o projections + lora adapters (approx)
        t += n
        a += n
    if kind["ffn"] == "moe":
        per_exp = (3 if cfg.gated_mlp else 2) * d * f
        t += cfg.n_experts * per_exp + d * cfg.n_experts
        a += cfg.top_k * per_exp + d * cfg.n_experts
        if cfg.shared_expert:
            t += per_exp
            a += per_exp
    elif kind["ffn"] == "rwkv_cm":
        n = d * int(3.5 * d) * 2
        t += n
        a += n
    else:
        per = (3 if cfg.gated_mlp else 2) * d * f
        t += per
        a += per
    return t, a


# ---------------------------------------------------------------------------
# Parameter tree construction. Leaves are `Spec(shape, logical_axes, init)`;
# `materialize` turns a spec tree into arrays, `struct` into ShapeDtypeStructs.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple  # logical axis names, same length as shape
    init: str = "normal"  # normal | zeros | ones | decay
    scale: float = 1.0


def spec_tree_map(fn, tree):
    return jax.tree_util.tree_map(
        fn, tree, is_leaf=lambda x: isinstance(x, Spec)
    )


def materialize(spec_tree, key, dtype):
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, Spec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            a = jnp.zeros(s.shape, dtype)
        elif s.init == "ones":
            a = jnp.ones(s.shape, dtype)
        elif s.init == "decay":  # rwkv/mamba decay logits: small negatives
            a = jnp.linspace(-6.0, -0.5, num=int(np.prod(s.shape))).reshape(s.shape).astype(dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            a = (jax.random.normal(k, s.shape) * (s.scale / math.sqrt(fan_in))).astype(dtype)
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


def struct(spec_tree, dtype):
    return spec_tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree
    )


def axes_tree(spec_tree):
    return spec_tree_map(lambda s: s.axes, spec_tree)
