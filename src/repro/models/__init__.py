from repro.models import base, lm, layers, mamba, moe, rwkv6
from repro.models.base import ModelConfig
