"""Selective SSM (Mamba-1) layer for the Jamba hybrid.

Train-time lowering is *parallel-over-chunks, sequential-within-chunk*: the
sequence is split into chunks of `CHUNK`; a lax.scan runs the exact recurrence
inside each chunk with zero initial state (vmapped over chunks, so chunks run
in parallel), a second cheap lax.scan propagates chunk-boundary states, and a
closed-form correction adds the boundary state's contribution:

    h_t = P_{1..t} * h_start + h0_t          (P = cumprod of per-step decay)
    y_t = C_t . h_t = y0_t + C_t . (P_t * h_start)

This is numerically exact (no log-space tricks), keeps the per-step working
set at (B, CHUNK, d_inner, d_state) — d_inner is TP-sharded — and compiles to
two While ops regardless of sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 64


def _conv_causal(x, w, b):
    """Depthwise causal conv. x: (B,S,di), w: (di, K), b: (di,)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[:, i]
    return out + b


def _ssm_scan_chunked(decay, inp, c_coef, h0, unroll=1):
    """decay/inp: (B,S,di,ds); c_coef: (B,S,ds); h0: (B,di,ds).

    Returns (y (B,S,di), h_final (B,di,ds)).
    """
    b, s, di, ds = decay.shape
    nc = max(1, s // CHUNK)
    lc = s // nc
    assert nc * lc == s, (s, CHUNK)
    dc = decay.reshape(b, nc, lc, di, ds)
    ic = inp.reshape(b, nc, lc, di, ds)
    cc = c_coef.reshape(b, nc, lc, ds)

    # Within-chunk scan with zero init (vmapped over B and chunks via batching
    # dims on the scan body's operands; scan is over the time axis).
    def step(h, t):
        d_t, i_t = t  # (B, nc, di, ds)
        h = d_t * h + i_t
        return h, h

    h_zero = jnp.zeros((b, nc, di, ds), decay.dtype)
    h_last, hs = jax.lax.scan(
        step,
        h_zero,
        (dc.transpose(2, 0, 1, 3, 4), ic.transpose(2, 0, 1, 3, 4)),
        unroll=unroll,
    )
    # hs: (lc, B, nc, di, ds) — zero-init within-chunk states h0_t
    y0 = jnp.einsum("lbcdk,bclk->bcld", hs, cc.transpose(0, 1, 2, 3))

    # Cross-chunk state propagation: h_start_{c+1} = P_c * h_start_c + M_c
    p_cum = jnp.cumprod(dc, axis=2)  # (B, nc, lc, di, ds)
    p_full = p_cum[:, :, -1]  # (B, nc, di, ds)

    def cross(h, t):
        p_c, m_c = t
        return p_c * h + m_c, h

    h_fin, h_starts = jax.lax.scan(
        cross,
        h0,
        (p_full.transpose(1, 0, 2, 3), h_last.transpose(1, 0, 2, 3)),
        unroll=unroll,
    )
    h_starts = h_starts.swapaxes(0, 1)  # (B, nc, di, ds): state entering chunk c
    # Correction: y_t += C_t . (P_t * h_start_c)
    y_corr = jnp.einsum("bcldk,bcdk,bclk->bcld", p_cum, h_starts, cc)
    y = (y0 + y_corr).reshape(b, s, di)
    return y, h_fin


def mamba_layer(x, p, cfg, state=None):
    """x: (B, S, D). state: None (train/prefill from scratch) or dict with
    'conv' (B, d_conv-1, di) and 'ssm' (B, di, ds) for chunk-wise/decode use.

    Returns (out (B,S,D), new_state).
    """
    b, s, d = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)  # (B,S,di)

    if state is not None:
        conv_in = jnp.concatenate([state["conv"], xs], axis=1)
        new_conv = conv_in[:, -(cfg.d_conv - 1) :, :]
        xs_c = _conv_causal(conv_in, p["conv_w"], p["conv_b"])[:, cfg.d_conv - 1 :, :]
    else:
        pad = max(0, (cfg.d_conv - 1) - s)
        new_conv = jnp.pad(xs, ((0, 0), (pad, 0), (0, 0)))[:, -(cfg.d_conv - 1) :, :]
        xs_c = _conv_causal(xs, p["conv_w"], p["conv_b"])
    xs_c = jax.nn.silu(xs_c)

    dbc = jnp.einsum("bsi,ie->bse", xs_c, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    delta, bmat, cmat = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", delta, p["dt_proj"]) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32)).astype(x.dtype)  # (di, ds)

    decay = jnp.exp(delta[..., None] * a)  # (B,S,di,ds)
    inp = (delta * xs_c)[..., None] * bmat[:, :, None, :]  # (B,S,di,ds)

    h0 = state["ssm"] if state is not None else jnp.zeros((b, di, ds), x.dtype)
    if s == 1:  # decode fast path: one recurrence step, no chunking
        h = decay[:, 0] * h0 + inp[:, 0]
        y = jnp.einsum("bdk,bk->bd", h, cmat[:, 0])[:, None, :]
        h_fin = h
    else:
        # inner scans stay While-loops even in analysis mode; the dry-run
        # adds their FLOPs analytically (see launch/dryrun.py ssm_correction).
        y, h_fin = _ssm_scan_chunked(decay, inp, cmat, h0)

    y = y + xs_c * p["d_skip"]
    out = jnp.einsum("bsi,id->bsd", y * jax.nn.silu(z), p["out_proj"])
    return out, {"conv": new_conv, "ssm": h_fin}
