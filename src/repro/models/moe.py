"""Mixture-of-Experts with FLOP-exact, batch-grouped sort dispatch.

Dense "compute every expert" MoE inflates HLO FLOPs by E/top_k; GShard-style
one-hot einsum dispatch costs O(T*E*C*D) FLOPs which dominates the expert
FFNs at large T. We use sort-based dispatch instead — argsort token->expert
assignments, rank within expert segments, gather into an (E, C) slot grid,
batched expert einsum, scatter-add combine — so compiled FLOPs ~= active
expert FLOPs (the 6*N_active*D quantity).

Crucially the dispatch is *grouped by batch row* (sequence): each row sorts
only its own S tokens, so under pjit the sort/gather stay local to the data
shard that owns the row — a global-token argsort would force GSPMD to
all-gather the entire (1M-token, d_model) activation tensor (measured: 5.8
TiB/chip on jamba train_4k). Capacity is per (row, expert): C = S*k/E*cf,
the standard GShard grouping. For decode (S == 1) the whole batch forms one
group — B tokens are trivially cheap to sort globally.

Expert weights are (E, D, F) with E on the "experts" logical axis -> "model"
mesh axis (EP); the xe regroup to expert-major lowers to all-to-all over the
EP axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def route_topk(x, router_w, n_experts, top_k):
    """x: (..., D). Returns (expert_idx (..., k), probs (..., k), logits)."""
    logits = jnp.einsum(
        "...d,de->...e", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(probs_full, top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)  # renormalize
    return idx, probs.astype(x.dtype), logits


def sort_dispatch(expert_idx, n_experts, capacity):
    """(T, k) expert assignments -> (E*C,) slot->token-slot mapping.

    Returns (slot_src, slot_valid, kept). Used per dispatch group (vmapped)."""
    t, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k) - first
    kept_sorted = rank < capacity
    slot_of_sorted = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    dest = jnp.where(kept_sorted, slot_of_sorted, n_experts * capacity)
    slot_src = jnp.full((n_experts * capacity,), t * k, jnp.int32)
    slot_src = slot_src.at[dest].set(order.astype(jnp.int32), mode="drop")
    slot_valid = slot_src < t * k
    kept = jnp.zeros((t * k,), bool).at[order].set(kept_sorted)
    return slot_src, slot_valid, kept


def _expert_ffn(xe, p, cfg):
    """xe: (G, E, C, D) -> (G, E, C, D) through the per-expert (Sw)MLP."""
    if cfg.gated_mlp:
        gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w1"]))
        up = jnp.einsum("gecd,edf->gecf", xe, p["w3"])
        return jnp.einsum("gecf,efd->gecd", gate * up, p["w2"])
    h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["w1"]))
    return jnp.einsum("gecf,efd->gecd", h, p["w2"])


def moe_ffn(x, p, cfg):
    """x: (B, S, D) -> (B, S, D). p: router (D,E), w1/w3 (E,D,F), w2 (E,F,D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if s == 1:  # decode: one global group over the (small) batch
        xg = x.reshape(1, b, d)
    else:  # train/prefill: one group per batch row (sequence)
        xg = x  # (B, S, D) — groups are rows
    g, t = xg.shape[0], xg.shape[1]
    capacity = int(max(1, round(t * k / e * cfg.capacity_factor)))

    expert_idx, probs, router_logits = route_topk(xg, p["router"], e, k)
    slot_src, slot_valid, _ = jax.vmap(
        lambda idx: sort_dispatch(idx, e, capacity)
    )(expert_idx)

    tok_of_slot = jnp.minimum(slot_src // k, t - 1)  # (G, E*C)
    xe = jnp.take_along_axis(xg, tok_of_slot[..., None], axis=1)  # (G, E*C, D)
    xe = xe * slot_valid[..., None].astype(xe.dtype)
    xe = xe.reshape(g, e, capacity, d)

    ye = _expert_ffn(xe, p, cfg)  # (G, E, C, D)

    prob_flat = probs.reshape(g, t * k)
    safe_src = jnp.minimum(slot_src, t * k - 1)
    w_slot = jnp.where(
        slot_valid, jnp.take_along_axis(prob_flat, safe_src, axis=1), 0.0
    )  # (G, E*C)
    y_flat = ye.reshape(g, e * capacity, d) * w_slot[..., None].astype(ye.dtype)

    def combine(y_row, tok_row, valid_row):
        return jnp.zeros((t, d), y_row.dtype).at[tok_row].add(
            jnp.where(valid_row[:, None], y_row, 0.0)
        )

    out = jax.vmap(combine)(y_flat, tok_of_slot, slot_valid)  # (G, T, D)

    if cfg.shared_expert:
        gate = jax.nn.silu(jnp.einsum("gtd,df->gtf", xg, p["shared_w1"]))
        up = jnp.einsum("gtd,df->gtf", xg, p["shared_w3"])
        out = out + jnp.einsum("gtf,fd->gtd", gate * up, p["shared_w2"])

    aux = load_balance_loss(router_logits.reshape(b * s, e), expert_idx.reshape(b * s, k), e)
    return out.reshape(b, s, d), aux


def load_balance_loss(router_logits, expert_idx, n_experts):
    """Switch-style auxiliary load-balancing loss."""
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(expert_idx.reshape(-1, expert_idx.shape[-1])[:, 0],
                             n_experts, dtype=probs.dtype)
    ce = one_hot.mean(0)
    return n_experts * jnp.sum(me * ce)
