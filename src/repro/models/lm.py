"""Unified decoder LM: parameter construction + train/prefill/decode forwards
for all assigned families (dense, MoE, SSM/RWKV6, hybrid/Jamba, audio, VLM).

Layers are stacked over `cfg.n_groups` repeating period-groups and executed
with one `lax.scan`, so the lowered HLO is O(1) in depth. Parameter leaves are
`base.Spec`s carrying logical sharding axes ("layers", "embed", "heads",
"ffn", "experts", "vocab"), mapped to the mesh by repro.distributed.sharding.

The cross-entropy is computed in sequence chunks (lax.scan) against the
(vocab-sharded) unembedding so full (B, S, V) logits never materialise —
at 151k vocab and 1M-token batches that is the difference between 300 TB of
logits and a 100 MB working set.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import base, layers, mamba, moe, rwkv6
from repro.models.base import ModelConfig, Spec

REMAT_POLICIES = {
    None: None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def _norm_spec(cfg, d=None):
    d = d or cfg.d_model
    p = {"gamma": Spec((d,), ("embed",), "ones")}
    if cfg.norm_type == "layernorm":
        p["beta"] = Spec((d,), ("embed",), "zeros")
    return p


def _attn_spec(cfg):
    d, hd = cfg.d_model, cfg.hd
    # Head-granular TP constraint: "heads:<n>" only shards if n % model == 0.
    # Sharding the flattened H*hd dim when H doesn't divide splits heads
    # across devices; the q reshape then forces GSPMD into partial shardings
    # whose attention scores all-reduce at (B,H,S,S) scale (measured 10.7
    # GiB/op on llama4 train). Non-divisible head counts replicate instead.
    qh = f"heads:{cfg.n_heads}"
    kh = f"heads:{cfg.n_kv_heads}"
    p = {
        "wq": Spec((d, cfg.n_heads * hd), ("embed", qh)),
        "wk": Spec((d, cfg.n_kv_heads * hd), ("embed", kh)),
        "wv": Spec((d, cfg.n_kv_heads * hd), ("embed", kh)),
        "wo": Spec((cfg.n_heads * hd, d), (qh, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Spec((cfg.n_heads * hd,), (qh,), "zeros")
        p["bk"] = Spec((cfg.n_kv_heads * hd,), (kh,), "zeros")
        p["bv"] = Spec((cfg.n_kv_heads * hd,), (kh,), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = Spec((hd,), (None,), "ones")
        p["k_norm"] = Spec((hd,), (None,), "ones")
    return p


def _mlp_spec(cfg):
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "w1": Spec((d, f), ("embed", "ffn")),
        "w2": Spec((f, d), ("ffn", "embed")),
    }
    if cfg.gated_mlp:
        p["w3"] = Spec((d, f), ("embed", "ffn"))
    return p


def _moe_spec(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": Spec((d, e), ("embed", None)),
        "w1": Spec((e, d, f), ("experts", "embed", "ffn")),
        "w2": Spec((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.gated_mlp:
        p["w3"] = Spec((e, d, f), ("experts", "embed", "ffn"))
    if cfg.shared_expert:
        p["shared_w1"] = Spec((d, f), ("embed", "ffn"))
        p["shared_w3"] = Spec((d, f), ("embed", "ffn"))
        p["shared_w2"] = Spec((f, d), ("ffn", "embed"))
    return p


def _mamba_spec(cfg):
    d, di, ds, k = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dtr = max(1, d // 16)
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "ffn")),
        "conv_w": Spec((di, k), ("ffn", None), scale=0.5),
        "conv_b": Spec((di,), ("ffn",), "zeros"),
        "x_proj": Spec((di, dtr + 2 * ds), ("ffn", None)),
        "dt_proj": Spec((dtr, di), (None, "ffn")),
        "dt_bias": Spec((di,), ("ffn",), "zeros"),
        "a_log": Spec((di, ds), ("ffn", None), "decay"),
        "d_skip": Spec((di,), ("ffn",), "ones"),
        "out_proj": Spec((di, d), ("ffn", "embed")),
    }


def _rwkv_tm_spec(cfg):
    d = cfg.d_model
    rh = f"heads:{d // cfg.rwkv_head_dim}"
    return {
        "mu_base": Spec((d,), ("embed",), "zeros"),
        "mix_a": Spec((d, rwkv6.N_MIX * rwkv6.LORA_MIX), ("embed", None)),
        "mix_b": Spec((rwkv6.N_MIX, rwkv6.LORA_MIX, d), (None, None, "embed")),
        "mu_five": Spec((rwkv6.N_MIX, d), (None, "embed"), "zeros"),
        "w_r": Spec((d, d), ("embed", rh)),
        "w_k": Spec((d, d), ("embed", rh)),
        "w_v": Spec((d, d), ("embed", rh)),
        "w_g": Spec((d, d), ("embed", rh)),
        "w_o": Spec((d, d), (rh, "embed")),
        "w_base": Spec((d,), (rh,), "decay"),
        "decay_a": Spec((d, rwkv6.LORA_DECAY), ("embed", None)),
        "decay_b": Spec((rwkv6.LORA_DECAY, d), (None, rh)),
        "u": Spec((d,), (rh,), "zeros"),
        "ln_x_g": Spec((d,), (rh,), "ones"),
        "ln_x_b": Spec((d,), (rh,), "zeros"),
    }


def _rwkv_cm_spec(cfg):
    d, f = cfg.d_model, cfg.d_ff
    rh = f"heads:{d // cfg.rwkv_head_dim}"
    return {
        "mu_k": Spec((d,), ("embed",), "zeros"),
        "mu_r": Spec((d,), ("embed",), "zeros"),
        "w_k": Spec((d, f), ("embed", "ffn")),
        "w_v": Spec((f, d), ("ffn", "embed")),
        "w_r": Spec((d, d), ("embed", rh)),
    }


def _layer_spec(cfg, pos):
    kind = cfg.layer_kind(pos)
    p = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg)}
    if kind["mixer"] in ("attn", "cross"):
        p["attn"] = _attn_spec(cfg)
        if kind["mixer"] == "cross":
            p["gate_attn"] = Spec((1,), (None,), "zeros")
            p["gate_ffn"] = Spec((1,), (None,), "zeros")
    elif kind["mixer"] == "mamba":
        p["mamba"] = _mamba_spec(cfg)
    elif kind["mixer"] == "rwkv":
        p["tm"] = _rwkv_tm_spec(cfg)
    if kind["ffn"] == "moe":
        p["moe"] = _moe_spec(cfg)
    elif kind["ffn"] == "rwkv_cm":
        p["cm"] = _rwkv_cm_spec(cfg)
    else:
        p["mlp"] = _mlp_spec(cfg)
    return p


def _stack(spec, g):
    """Prepend the scan (groups) dimension to every leaf."""
    return base.spec_tree_map(
        lambda s: Spec((g,) + s.shape, ("layers",) + s.axes, s.init, s.scale), spec
    )


def init_specs(cfg: ModelConfig):
    blocks = {
        f"p{j}": _stack(_layer_spec(cfg, j), cfg.n_groups) for j in range(cfg.period)
    }
    if cfg.n_codebooks:
        embed = Spec((cfg.n_codebooks, cfg.vocab, cfg.d_model), (None, "vocab", "embed"))
        head = Spec((cfg.n_codebooks, cfg.d_model, cfg.vocab), (None, "embed", "vocab"))
    else:
        embed = Spec((cfg.vocab, cfg.d_model), ("vocab", "embed"))
        head = Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    tree = {"embed": embed, "blocks": blocks, "final_norm": _norm_spec(cfg)}
    if not cfg.tie_embeddings:
        tree["lm_head"] = head
    return tree


def init_params(cfg: ModelConfig, key):
    return base.materialize(init_specs(cfg), key, cfg.param_dtype)


def param_struct(cfg: ModelConfig):
    return base.struct(init_specs(cfg), cfg.param_dtype)


def logical_axes(cfg: ModelConfig):
    return base.axes_tree(init_specs(cfg))


def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """Exact (total, active) parameter counts from the spec tree."""
    leaves = jax.tree_util.tree_leaves(
        init_specs(cfg), is_leaf=lambda x: isinstance(x, Spec)
    )
    total = active = 0
    for s in leaves:
        n = int(np.prod(s.shape))
        total += n
        if "experts" in s.axes and len(s.shape) >= 4:
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return total, active


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, img_tokens: int = 0):
    """Zero-initialised decode cache, one slot per period position per group."""
    g, dt = cfg.n_groups, cfg.compute_dtype
    cache: dict[str, Any] = {}
    for j in range(cfg.period):
        kind = cfg.layer_kind(j)
        c: dict[str, Any] = {}
        if kind["mixer"] == "attn":
            s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            kv_dt = jnp.int8 if cfg.kv_quant else dt
            c["k"] = jnp.zeros((g, batch, s, cfg.n_kv_heads, cfg.hd), kv_dt)
            c["v"] = jnp.zeros((g, batch, s, cfg.n_kv_heads, cfg.hd), kv_dt)
            if cfg.kv_quant:
                c["kv_scale"] = jnp.zeros((g, batch, s, cfg.n_kv_heads, 2), jnp.float32)
        elif kind["mixer"] == "cross":
            t = img_tokens or cfg.n_img_tokens
            c["k"] = jnp.zeros((g, batch, t, cfg.n_kv_heads, cfg.hd), dt)
            c["v"] = jnp.zeros((g, batch, t, cfg.n_kv_heads, cfg.hd), dt)
        elif kind["mixer"] == "mamba":
            c["conv"] = jnp.zeros((g, batch, cfg.d_conv - 1, cfg.d_inner), dt)
            c["ssm"] = jnp.zeros((g, batch, cfg.d_inner, cfg.d_state), dt)
        elif kind["mixer"] == "rwkv":
            n = cfg.rwkv_head_dim
            c["shift_tm"] = jnp.zeros((g, batch, cfg.d_model), dt)
            c["wkv"] = jnp.zeros((g, batch, cfg.d_model // n, n, n), dt)
            c["shift_cm"] = jnp.zeros((g, batch, cfg.d_model), dt)
        cache[f"p{j}"] = c
    return cache


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _quant_kv(k, v):
    """(B,S,H,hd) -> int8 planes + per-(token,head) scales (B,S,H,2)."""
    ks = jnp.max(jnp.abs(k).astype(jnp.float32), axis=-1, keepdims=True) / 127.0
    vs = jnp.max(jnp.abs(v).astype(jnp.float32), axis=-1, keepdims=True) / 127.0
    ks = jnp.maximum(ks, 1e-9)
    vs = jnp.maximum(vs, 1e-9)
    kq = jnp.clip(jnp.round(k.astype(jnp.float32) / ks), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v.astype(jnp.float32) / vs), -127, 127).astype(jnp.int8)
    return kq, vq, jnp.concatenate([ks, vs], axis=-1)


def _dequant_kv(kq, vq, scale, dtype):
    k = kq.astype(dtype) * scale[..., 0:1].astype(dtype)
    v = vq.astype(dtype) * scale[..., 1:2].astype(dtype)
    return k, v


def _attn_block(x, p, cfg, *, mode, cache, pos, img=None, cross=False):
    b, s, _ = x.shape
    h = layers.apply_norm(x, p["ln1"], cfg.norm_type)
    if cross:
        q, _, _ = layers.qkv_proj(h, p["attn"], cfg)
        new_cache = cache
        if mode == "decode":
            k, v = cache["k"], cache["v"]
        else:
            hi = img.astype(x.dtype)
            bi, si, _ = hi.shape
            k = jnp.einsum("bsd,dh->bsh", hi, p["attn"]["wk"]).reshape(
                bi, si, cfg.n_kv_heads, cfg.hd
            )
            v = jnp.einsum("bsd,dh->bsh", hi, p["attn"]["wv"]).reshape(
                bi, si, cfg.n_kv_heads, cfg.hd
            )
            if cfg.qk_norm:
                k = layers.rms_norm(k, p["attn"]["k_norm"])
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
        if mode == "decode":
            out = layers.decode_attention(q, k, v, k.shape[1])
        elif s >= 2048:  # chunk the q axis: (S x n_img_tokens) scores are huge
            out = layers.flash_attention(
                q, k, v, causal=False, q_chunk=cfg.flash_chunk,
                kv_chunk=k.shape[1], unroll=cfg.unroll,
            )
        else:
            out = layers.full_attention(q, k, v, causal=False)
        out = layers.out_proj(out, p["attn"]) * jnp.tanh(p["gate_attn"])
        x = x + out
        h2 = layers.apply_norm(x, p["ln2"], cfg.norm_type)
        x = x + layers.mlp(h2, p["mlp"], cfg) * jnp.tanh(p["gate_ffn"])
        return x, new_cache, 0.0

    q, k, v = layers.qkv_proj(h, p["attn"], cfg)
    if mode == "decode":
        # pos is a scalar (whole batch at one position, the historical path)
        # or a (B,) vector (continuous batching: every lane decodes its own
        # position). The scalar path is kept byte-for-byte so existing
        # fixed-batch rollouts stay bit-identical.
        pos_v = jnp.asarray(pos)
        positions = jnp.full((b, 1), pos) if pos_v.ndim == 0 else pos_v[:, None]
    elif mode == "chunk":
        # Chunked prefill / speculative verify (DESIGN.md §16): s new tokens
        # per lane starting at per-lane cache position pos0 = pos.
        pos_v = jnp.asarray(pos)
        base = pos_v if pos_v.ndim else jnp.full((b,), pos, jnp.int32)
        positions = base[:, None] + jnp.arange(s)[None, :]
    else:
        positions = jnp.arange(s)[None, :]
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    w = cfg.sliding_window
    if mode == "decode":
        smax = cache["k"].shape[1]
        # SWA caches are ring buffers of size `window`: slot = pos % smax.
        slot = pos_v % smax if w else jnp.minimum(pos_v, smax - 1)
        if pos_v.ndim:
            # per-lane slot: vmap the row update over the batch axis
            upd = jax.vmap(
                lambda c, x, s_: jax.lax.dynamic_update_slice_in_dim(c, x, s_, 0)
            )
        else:
            upd = lambda c, x, s_: jax.lax.dynamic_update_slice_in_dim(c, x, s_, 1)
        if cfg.kv_quant:
            kq, vq, sc = _quant_kv(k, v)
            ck = upd(cache["k"], kq, slot)
            cv = upd(cache["v"], vq, slot)
            csc = upd(cache["kv_scale"], sc, slot)
            new_cache = {"k": ck, "v": cv, "kv_scale": csc}
            kd, vd = _dequant_kv(ck, cv, csc, cfg.compute_dtype)
        else:
            ck = upd(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), slot)
            new_cache = {"k": ck, "v": cv}
            kd, vd = ck, cv
        cur = jnp.minimum(pos_v + 1, smax) if w else pos_v + 1
        out = layers.decode_attention(q, kd, vd, cur)
    elif mode == "chunk":
        # Same cache-write + attend-the-cache structure as decode, vmapped
        # over lanes with an s-row window; restricted to the paged-KV config
        # class (all-attn, no SWA ring, no quantized cache) the scheduler
        # already requires via configs.shapes.supports_paged_kv.
        assert not w and not cfg.kv_quant, (
            "chunk mode requires a paged-KV-compatible config"
        )
        upd = jax.vmap(
            lambda c, u, s_: jax.lax.dynamic_update_slice_in_dim(c, u, s_, 0)
        )
        ck = upd(cache["k"], k.astype(cache["k"].dtype), base)
        cv = upd(cache["v"], v.astype(cache["v"].dtype), base)
        new_cache = {"k": ck, "v": cv}
        out = layers.chunk_attention(q, ck, cv, base)
    else:
        if mode == "prefill":
            smax = cache["k"].shape[1]
            ks = k[:, -smax:, :, :]
            vs = v[:, -smax:, :, :]
            if w and s >= smax:
                # Keep ring positions consistent: seq position q lives at
                # slot q % smax for later decode steps.
                ks = jnp.roll(ks, s % smax, axis=1)
                vs = jnp.roll(vs, s % smax, axis=1)
            if cfg.kv_quant:
                kq, vq, sc = _quant_kv(ks, vs)
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, 1)
                csc = jax.lax.dynamic_update_slice_in_dim(cache["kv_scale"], sc, 0, 1)
                new_cache = {"k": ck, "v": cv, "kv_scale": csc}
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks.astype(cache["k"].dtype), 0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs.astype(cache["v"].dtype), 0, 1)
                new_cache = {"k": ck, "v": cv}
        if w and s > w:
            out = layers.banded_attention(
                q, k, v, window=w, q_chunk=cfg.flash_chunk, unroll=cfg.unroll
            )
        elif s >= 4096:
            out = layers.flash_attention(
                q, k, v, causal=True, q_chunk=cfg.flash_chunk,
                kv_chunk=cfg.flash_chunk, unroll=cfg.unroll,
            )
        else:
            out = layers.full_attention(q, k, v, causal=True, window=w)
    x = x + layers.out_proj(out, p["attn"])

    h2 = layers.apply_norm(x, p["ln2"], cfg.norm_type)
    if "moe" in p:
        y, aux = moe.moe_ffn(h2, p["moe"], cfg)
    else:
        y, aux = layers.mlp(h2, p["mlp"], cfg), 0.0
    return x + y, new_cache, aux


def _mamba_block(x, p, cfg, *, mode, cache):
    h = layers.apply_norm(x, p["ln1"], cfg.norm_type)
    state = None
    if mode == "decode":
        state = {"conv": cache["conv"], "ssm": cache["ssm"]}
    y, new_state = mamba.mamba_layer(h, p["mamba"], cfg, state)
    x = x + y
    new_cache = cache
    if mode in ("decode", "prefill"):
        new_cache = {"conv": new_state["conv"].astype(cache["conv"].dtype),
                     "ssm": new_state["ssm"].astype(cache["ssm"].dtype)}
    h2 = layers.apply_norm(x, p["ln2"], cfg.norm_type)
    if "moe" in p:
        y, aux = moe.moe_ffn(h2, p["moe"], cfg)
    else:
        y, aux = layers.mlp(h2, p["mlp"], cfg), 0.0
    return x + y, new_cache, aux


def _rwkv_block(x, p, cfg, *, mode, cache):
    h = layers.apply_norm(x, p["ln1"], cfg.norm_type)
    st = None
    if mode == "decode":
        st = {"shift": cache["shift_tm"], "wkv": cache["wkv"]}
    y, tm_state = rwkv6.time_mix(h, p["tm"], cfg, st)
    x = x + y
    h2 = layers.apply_norm(x, p["ln2"], cfg.norm_type)
    st2 = {"shift": cache["shift_cm"]} if mode == "decode" else None
    y2, cm_state = rwkv6.channel_mix(h2, p["cm"], cfg, st2)
    x = x + y2
    new_cache = cache
    if mode in ("decode", "prefill"):
        new_cache = {
            "shift_tm": tm_state["shift"].astype(x.dtype),
            "wkv": tm_state["wkv"].astype(x.dtype),
            "shift_cm": cm_state["shift"].astype(x.dtype),
        }
    return x, new_cache, 0.0


def _group_body(x, pgroup, cfg, *, mode, cache_group, pos, img):
    """One scan step: run the `period` layers of a group."""
    aux_total = 0.0
    new_cache = {}
    for j in range(cfg.period):
        kind = cfg.layer_kind(j)
        p = pgroup[f"p{j}"]
        c = cache_group.get(f"p{j}", {}) if cache_group is not None else {}
        if kind["mixer"] in ("attn", "cross"):
            x, nc, aux = _attn_block(
                x, p, cfg, mode=mode, cache=c, pos=pos, img=img,
                cross=kind["mixer"] == "cross",
            )
        elif kind["mixer"] == "mamba":
            x, nc, aux = _mamba_block(x, p, cfg, mode=mode, cache=c)
        else:
            x, nc, aux = _rwkv_block(x, p, cfg, mode=mode, cache=c)
        new_cache[f"p{j}"] = nc
        aux_total = aux_total + aux
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _embed(params, tokens, cfg):
    if cfg.n_codebooks:
        # tokens: (B, K, S); sum the K codebook embeddings (MusicGen).
        parts = [
            jnp.take(params["embed"][k], tokens[:, k], axis=0)
            for k in range(cfg.n_codebooks)
        ]
        x = sum(parts)
        s = tokens.shape[-1]
        x = x + _sinusoid(s, cfg.d_model, x.dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(cfg.compute_dtype)


def _sinusoid(s, d, dtype, offset=0):
    # offset may be a traced scalar (decode step): keep arange static.
    pos = (jnp.arange(s, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)[None]


def _unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        e = params["embed"]
        return e.swapaxes(-1, -2) if cfg.n_codebooks else e.T
    return params["lm_head"]


def forward(params, tokens, cfg: ModelConfig, *, img=None, cache=None,
            pos=0, mode="train", remat=None):
    """Shared backbone. Returns (hidden (B,S,D), new_cache, aux_loss)."""
    x = _embed(params, tokens, cfg)
    if cfg.n_codebooks and mode == "decode":
        # decode-time positional: replace the offset-0 sinusoid added in _embed
        x = x - _sinusoid(1, cfg.d_model, x.dtype, offset=0) + _sinusoid(
            1, cfg.d_model, x.dtype, offset=pos
        )

    body = functools.partial(_group_body, cfg=cfg, mode=mode, pos=pos, img=img)
    zero = jnp.zeros((), jnp.float32)

    if cache is None:  # train: no cache threading
        empty = {f"p{j}": {} for j in range(cfg.period)}

        def step(carry, pg):
            h, aux = carry
            h, _, a = body(h, pg, cache_group=empty)
            return (h, aux + a), None

        if remat is not None:
            step = jax.checkpoint(step, policy=REMAT_POLICIES[remat])
        (x, aux), _ = jax.lax.scan(step, (x, zero), params["blocks"], unroll=cfg.unroll)
        new_cache = None
    else:

        def step(carry, xs):
            h, aux = carry
            pg, cg = xs
            h, nc, a = body(h, pg, cache_group=cg)
            return (h, aux + a), nc

        if remat is not None:
            step = jax.checkpoint(step, policy=REMAT_POLICIES[remat])
        (x, aux), new_cache = jax.lax.scan(
            step, (x, zero), (params["blocks"], cache), unroll=cfg.unroll
        )

    x = layers.apply_norm(x, params["final_norm"], cfg.norm_type)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Losses / entry points
# ---------------------------------------------------------------------------
def chunked_xent(hidden, unembed, labels, chunk=512, unroll=1):
    """Cross-entropy without materialising (B, S, V) logits.

    hidden: (B, S, D); unembed: (D, V); labels: (B, S) int32 (-1 = masked).
    Scans over S chunks; each step computes (B, chunk, V) logits in f32.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)  # (nc, B, c, D)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def step(acc, xs):
        h, lab = xs
        logits = jnp.einsum("bcd,dv->bcv", h.astype(jnp.float32), unembed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        loss_sum, n = acc
        return (loss_sum + jnp.sum((lse - gold) * mask), n + mask.sum()), None

    (loss_sum, n), _ = jax.lax.scan(step, (0.0, 0.0), (hs, ls), unroll=unroll)
    return loss_sum / jnp.maximum(n, 1.0)


def train_loss(params, batch, cfg: ModelConfig, remat="full"):
    """batch: {tokens, labels[, img]}. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    hidden, _, aux = forward(
        params, tokens, cfg, img=batch.get("img"), mode="train", remat=remat
    )
    un = _unembed_matrix(params, cfg)
    if cfg.n_codebooks:
        losses = [
            chunked_xent(hidden, un[k], batch["labels"][:, k], unroll=cfg.unroll)
            for k in range(cfg.n_codebooks)
        ]
        ce = sum(losses) / cfg.n_codebooks
    else:
        ce = chunked_xent(hidden, un, batch["labels"], unroll=cfg.unroll)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def sequence_logits(params, tokens, cfg: ModelConfig, *, img=None):
    """Teacher-forced per-position logits for a fixed token sequence.

    The paired clean-vs-faulty eval path (core/campaign.py, DESIGN.md §15):
    feeding the *same* ``tokens`` (B, S) through clean and fault-injected
    params gives position-aligned (B, S, V) f32 logits whose KL / NLL deltas
    are well-defined — unlike comparing logits along each model's own greedy
    rollout, which diverges after the first mismatched token. Runs the full
    causal train-mode forward (no cache), so ECC-protected ``EccWeight``
    leaves decode through the scrub-on-read matmul path exactly as serving
    does. Not implemented for multi-codebook (audio) heads.
    """
    assert not cfg.n_codebooks, "sequence_logits: single-codebook LMs only"
    hidden, _, _ = forward(params, tokens, cfg, img=img, mode="train")
    un = _unembed_matrix(params, cfg)
    return jnp.einsum(
        "bsd,dv->bsv", hidden.astype(jnp.float32), un.astype(jnp.float32)
    )


def prefill(params, tokens, cfg: ModelConfig, cache, *, img=None):
    """Process a prompt, fill the cache. Returns (last-token logits, cache)."""
    hidden, new_cache, _ = forward(
        params, tokens, cfg, img=img, cache=cache, mode="prefill", remat="full"
    )
    last = hidden[:, -1]
    un = _unembed_matrix(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bd,kdv->bkv", last.astype(jnp.float32), un.astype(jnp.float32))
    else:
        logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32), un.astype(jnp.float32))
    return logits, new_cache


def greedy_decode_loop(params, tok0, cfg: ModelConfig, cache, start_pos, n_steps: int,
                       *, img=None):
    """Greedy-decode ``n_steps`` tokens after ``tok0`` with one ``lax.scan``.

    The per-token Python loop dispatches one jitted computation per token;
    under a scan the whole rollout lowers to a single device program (O(1)
    dispatch, DESIGN.md §2). ``start_pos`` may be a traced scalar so prompt
    length never forces a retrace. Token-identical to stepping
    ``decode_step`` in Python (tested).

    Returns (tokens (B, n_steps) int32, final cache).
    """

    def step(carry, i):
        tok, c = carry
        logits, c = decode_step(params, tok, cfg, c, start_pos + i, img=img)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, c), nxt

    (_, cache), toks = jax.lax.scan(
        step, (tok0, cache), jnp.arange(n_steps, dtype=jnp.int32)
    )
    return jnp.swapaxes(toks[..., 0], 0, 1), cache


def decode_step(params, tokens, cfg: ModelConfig, cache, pos, *, img=None):
    """One decode step. tokens: (B, 1) or (B, K, 1). pos: scalar int32 —
    0-based position of the token being processed — or a (B,) int32 vector
    giving every batch lane its own position (continuous batching)."""
    hidden, new_cache, _ = forward(
        params, tokens, cfg, img=img, cache=cache, pos=pos, mode="decode"
    )
    last = hidden[:, -1]
    un = _unembed_matrix(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bd,kdv->bkv", last.astype(jnp.float32), un.astype(jnp.float32))
    else:
        logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32), un.astype(jnp.float32))
    return logits, new_cache


def chunk_step(params, tokens, cfg: ModelConfig, cache, pos0):
    """Chunked prefill (DESIGN.md §16): process ``tokens`` (B, S) whose
    cache positions start at per-lane ``pos0`` ((B,) or scalar int32),
    writing their K/V into the cache. Returns (last-token logits (B, V),
    new cache) — token-identical to feeding the S tokens through
    ``decode_step`` one at a time (the per-position contractions are the
    same; tested)."""
    assert not cfg.n_codebooks, "chunk_step: single-codebook LMs only"
    hidden, new_cache, _ = forward(
        params, tokens, cfg, cache=cache, pos=pos0, mode="chunk"
    )
    last = hidden[:, -1]
    un = _unembed_matrix(params, cfg)
    logits = jnp.einsum(
        "bd,dv->bv", last.astype(jnp.float32), un.astype(jnp.float32)
    )
    return logits, new_cache


def chunk_logits(params, tokens, cfg: ModelConfig, cache, pos0):
    """Like :func:`chunk_step` but returning the full (B, S, V) logits —
    the speculative-decode verify block scores every drafted token against
    the target model in one dispatch (DESIGN.md §16)."""
    assert not cfg.n_codebooks, "chunk_logits: single-codebook LMs only"
    hidden, new_cache, _ = forward(
        params, tokens, cfg, cache=cache, pos=pos0, mode="chunk"
    )
    un = _unembed_matrix(params, cfg)
    logits = jnp.einsum(
        "bsd,dv->bsv", hidden.astype(jnp.float32), un.astype(jnp.float32)
    )
    return logits, new_cache
