"""Shared transformer layers: norms, RoPE, GQA/cross attention, MLPs.

Attention comes in four structurally different lowerings (not just masks),
because the roofline of each shape class differs:

  * `full_attention`    — direct einsum, used when S is small (train_4k).
  * `flash_attention`   — doubly-chunked online-softmax scan (prefill_32k):
                          O(S^2) FLOPs but O(S * chunk) memory.
  * `banded_attention`  — sliding-window prefill: per q-chunk a gathered KV
                          band, O(S * window) FLOPs (mixtral long-context).
  * `decode_attention`  — one token vs. a (possibly sequence-sharded) KV
                          cache; softmax reductions over the sharded S axis
                          lower to tiny all-reduces (flash-decoding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

NEG_INF = -1e30


def _linear(x, w):
    """Dense or ECC-protected linear: dispatch on the parameter type.

    `EccWeight` leaves route through the SECDED read path (the paper's
    technique as a first-class feature); plain arrays use an einsum.
    """
    if isinstance(w, kops.EccWeight):
        return kops.ecc_matmul(x, w, fuse=w.fuse).astype(x.dtype)
    return jnp.einsum("...d,df->...f", x, w)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv).astype(dt) * gamma


def layer_norm(x, gamma, beta, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


def apply_norm(x, p, norm_type):
    if norm_type == "layernorm":
        return layer_norm(x, p["gamma"], p["beta"])
    return rms_norm(x, p["gamma"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(hd, theta):
    return theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)  # (hd/2,)


def apply_rope(x, positions, theta):
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention bodies
# ---------------------------------------------------------------------------
def _group_q(q, hkv):
    """(B, S, H, Dh) -> (B, S, Hkv, R, Dh): grouped-query layout.

    Used on the DECODE path only, where the KV cache is sequence-sharded: a
    broadcast+reshape of sharded KV would force a full cache all-gather.
    On train/prefill paths KV is replicated over the model axis, so the
    opposite layout wins: repeat KV locally (free broadcast) and keep the
    full q-head dim, which shards 16-way even when n_kv_heads < mesh model
    size (kv=8/4 archs).
    """
    b, s, h, dh = q.shape
    return q.reshape(b, s, hkv, h // hkv, dh)


def _repeat_kv(k, n_rep):
    """Local repeat of replicated KV heads (no collective when k is
    replicated over the model axis — train/prefill only)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def full_attention(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, Dh), k/v: (B, Skv, Hkv, Dh). Direct einsum path."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(
        dh
    ).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = kpos <= qpos if causal else jnp.ones((sq, skv), bool)
    if window:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention(q, k, v, *, causal=True, q_chunk=1024, kv_chunk=1024, unroll=1):
    """Doubly-chunked online-softmax attention (pure JAX flash-style).

    Memory: O(B * H * q_chunk * kv_chunk) per step instead of O(S^2).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    qs = q.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(b, nk, kv_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kv_chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_qc):
        qi, qc = qi_qc  # qc: (B, q_chunk, H, Dh)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, q_chunk, h, dh), jnp.float32)

        def kv_step(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None] + (skv - sq)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p.astype(qc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0), (jnp.arange(nk), ks, vs), unroll=unroll
        )
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs), unroll=unroll)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def banded_attention(q, k, v, *, window, q_chunk=1024, unroll=1):
    """Sliding-window causal attention with an explicit gathered KV band.

    For each q chunk [t, t+C) only KV [t-window, t+C) can be attended; we
    dynamic-slice that band so FLOPs are O(S * (window + C)), not O(S^2).
    """
    b, sq, h, dh = q.shape
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    q_chunk = min(q_chunk, sq)
    assert sq % q_chunk == 0
    band = window + q_chunk
    # Left-pad KV by `window` so every band slice is in range.
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    nq = sq // q_chunk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qs = q.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_qc):
        qi, qc = qi_qc
        start = qi * q_chunk  # band begins at (start - window) in unpadded coords
        kc = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
        qpos = jnp.arange(q_chunk)[:, None] + window  # position within band
        kpos = jnp.arange(band)[None, :]
        valid = (kpos <= qpos) & (kpos > qpos - window) & (kpos + start >= window)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", p, vc)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs), unroll=unroll)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def decode_attention(q, k_cache, v_cache, cur_len, *, window=0):
    """One-token attention against a KV cache.

    q: (B, 1, H, Dh); caches: (B, S_max, Hkv, Dh); cur_len: () or (B,) int32 —
    number of valid cache entries (including the token being decoded); a (B,)
    vector gives every lane its own depth (continuous batching mixes requests
    at different positions in one batch).
    Softmax reductions over the cache S axis work transparently when S is
    sequence-sharded (flash-decoding lowers to tiny all-reduces).
    """
    b, _, h, dh = q.shape
    smax = k_cache.shape[1]
    hkv = k_cache.shape[2]
    qg = _group_q(q, hkv)  # (B, 1, Hkv, R, Dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache).astype(jnp.float32) / jnp.sqrt(
        dh
    ).astype(jnp.float32)
    kpos = jnp.arange(smax)[None, None, None, None, :]
    cur_len = jnp.asarray(cur_len)
    if cur_len.ndim:
        cur_len = cur_len.reshape(b, 1, 1, 1, 1)
    valid = kpos < cur_len
    if window:
        valid = valid & (kpos >= cur_len - window)
    s = jnp.where(valid, s, NEG_INF)
    # Softmax + weighted-sum reductions run over the (sequence-sharded) cache
    # axis: GSPMD lowers them to tiny max/sum/partial-out all-reduces — this
    # IS flash-decoding, derived by the partitioner.
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v_cache)
    return out.reshape(b, 1, h, dh)


def chunk_attention(q, k_cache, v_cache, pos0, *, window=0):
    """Multi-token attention against a KV cache (chunked prefill and the
    speculative verify block, DESIGN.md §16).

    q: (B, Sq, H, Dh) — Sq new tokens whose K/V were already written into the
    cache; caches: (B, S_max, Hkv, Dh); pos0: () or (B,) int32 — the cache
    position of the chunk's *first* token per lane. Token i of the chunk
    attends kpos <= pos0 + i, so for Sq == 1 this is exactly
    ``decode_attention(q, k, v, cur_len=pos0 + 1)``: the same grouped-query
    einsum contracting the same axes per position, which is what keeps the
    chunked path bit-identical to the step-by-step decode path.
    """
    b, sq, h, dh = q.shape
    smax = k_cache.shape[1]
    hkv = k_cache.shape[2]
    qg = _group_q(q, hkv)  # (B, Sq, Hkv, R, Dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache).astype(jnp.float32) / jnp.sqrt(
        dh
    ).astype(jnp.float32)
    kpos = jnp.arange(smax)[None, None, None, None, :]
    qpos = jnp.asarray(pos0).reshape(-1, 1, 1, 1, 1) + jnp.arange(sq).reshape(
        1, 1, 1, sq, 1
    )
    valid = kpos <= qpos
    if window:
        valid = valid & (kpos > qpos - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v_cache)
    return out.reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# Projections / MLP
# ---------------------------------------------------------------------------
def qkv_proj(x, p, cfg):
    """x: (B, S, D) -> q (B,S,H,Dh), k/v (B,S,Hkv,Dh)."""
    b, s, _ = x.shape
    q = _linear(x, p["wq"])
    k = _linear(x, p["wk"])
    v = _linear(x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def out_proj(attn_out, p):
    b, s = attn_out.shape[:2]
    return _linear(attn_out.reshape(b, s, -1), p["wo"])


_ACTS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron/minitron MLP
}


def mlp(x, p, cfg):
    if cfg.gated_mlp:
        gate = jax.nn.silu(_linear(x, p["w1"]))
        up = _linear(x, p["w3"])
        return _linear(gate * up, p["w2"])
    h = _ACTS[cfg.mlp_act](_linear(x, p["w1"]))
    return _linear(h, p["w2"])
