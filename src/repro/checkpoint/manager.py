"""Sharded checkpointing: atomic, resharding-on-load, optional SECDED planes.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json        # tree structure, shapes, dtypes, ecc flag
        leaf_00000.npy ...   # one file per pytree leaf
        leaf_00000.ecc.npz   # (optional) SECDED planes: lo/hi/parity
    ckpt_dir/LATEST          # text file with the newest step (atomic rename)

Fault-tolerance semantics follow the paper's fault classes: with `ecc=True`
every leaf is stored with Hsiao(72,64) planes; on load, single-bit storage
corruption is CORRECTED transparently, multi-bit corruption is DETECTED and
raises (the trainer then falls back to the previous checkpoint) — exactly the
CORRECTED/DETECTED split of the BRAM controller, applied to the long-lived
memory of a 1000-node training run.

Resharding: leaves are saved as full (host-replicated) arrays and re-placed
with `jax.device_put(leaf, sharding)` on load, so a checkpoint written on a
(16,16) mesh restores onto (2,16,16), (4,8) or a single device unchanged —
this is the elastic-rescale path.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

from repro.core import ecc, quantize


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, ecc_protect: bool = False, keep: int = 3):
    """Atomically write one checkpoint; prunes old ones beyond `keep`."""
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:06d}")
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "ecc": ecc_protect,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        if ecc_protect:
            lo, hi, nbytes = quantize.array_to_words_np(arr)
            parity = np.asarray(ecc.encode_np(lo, hi))
            np.savez(
                os.path.join(tmp, f"leaf_{i:05d}.ecc.npz"),
                parity=parity, nbytes=nbytes,
            )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"), os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep)


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:06d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    return [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.startswith(".")
    ]


def latest_step(ckpt_dir: str):
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


class CheckpointCorruption(RuntimeError):
    """Raised when ECC DETECTS uncorrectable corruption in a leaf."""


def _verify_and_correct(arr: np.ndarray, eccf: str) -> np.ndarray:
    z = np.load(eccf)
    parity = z["parity"]
    nbytes = int(z["nbytes"])
    lo, hi, nb = quantize.array_to_words_np(arr)
    assert nb == nbytes
    import jax.numpy as jnp

    lo2, hi2, status = ecc.decode(jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(parity))
    status = np.asarray(status)
    if (status == ecc.STATUS_DETECTED).any():
        raise CheckpointCorruption(
            f"{int((status == ecc.STATUS_DETECTED).sum())} uncorrectable words"
        )
    if (status == ecc.STATUS_CORRECTED).any():
        fixed = np.asarray(
            quantize.words_to_array(lo2, hi2, nbytes, arr.shape, arr.dtype)
        )
        return fixed
    return arr


def load(ckpt_dir: str, step: int, like, shardings=None):
    """Load into the structure of `like`; device_put with `shardings` if given."""
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), "tree structure mismatch"
    out = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
    )
    for i, (ref, shard) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        eccf = os.path.join(path, f"leaf_{i:05d}.ecc.npz")
        if manifest["ecc"] and os.path.exists(eccf):
            arr = _verify_and_correct(arr, eccf)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
