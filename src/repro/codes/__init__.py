"""Pluggable ECC codec subsystem (DESIGN.md §12).

Registered codes over 64-bit data words, weakest to strongest:

  ``parity65``  1 check bit   detect-only (odd-weight faults)
  ``secded72``  8 check bits  Hsiao SECDED — the paper's built-in BRAM ECC
  ``ileave88``  24 check bits 4-way interleaved SECDED — corrects bursts <= 4
  ``dected79``  15 check bits shortened extended BCH DEC-TED — corrects
                any 2 random flips, detects any 3

``get(name)`` returns the (cached) Codec instance; ``names()`` lists the
registry. The generalized Pallas kernels (kernels/inject_scrub.py,
kernels/paged_gather.py), the plane arenas (core/planestore.py,
core/kvpages.py) and the rail controller's escalation ladder
(core/controller.py) are all parameterized by these names.
"""

# Import order fixes the registry order (weakest -> strongest).
from repro.codes import parity, secded, interleaved, dected  # noqa: F401, I001
from repro.codes.base import (
    DEFAULT_CODEC,
    N_DATA,
    STATUS_CLEAN,
    STATUS_CORRECTED,
    STATUS_DETECTED,
    Codec,
    get,
    names,
)

__all__ = [
    "Codec",
    "DEFAULT_CODEC",
    "N_DATA",
    "STATUS_CLEAN",
    "STATUS_CORRECTED",
    "STATUS_DETECTED",
    "get",
    "names",
]
