"""4-way bit-interleaved SECDED (88,64): burst faults become per-codeword
singles.

Four independent Hsiao(22,16) SECDED subcodes protect the 64-bit word with
the physical bit lanes interleaved: data bit ``j`` belongs to subcode
``j % 4`` (sub-bit ``j // 4``) and check-plane bit ``b`` to subcode
``b % 4`` (sub-check ``b // 4``). Any burst of up to 4 *adjacent* flipped
bits therefore lands at most one flip in each subcode and is fully
corrected — the mitigation style evaluated for flash-based-FPGA BRAMs in
arXiv:1507.05740, where undervolting/radiation upsets cluster in physically
adjacent cells. Random coverage sits between SECDED and DEC-TED: two random
flips are corrected iff they land in different subcodes (~3/4 of the time
over the 88-bit codeword) and are *detected* otherwise, so the code is
never worse than SECDED on doubles.

The syndrome factors into four 6-bit sub-syndromes, so classification runs
the subcode's 64-entry LUT four times as compare/select chains — the dense
2^24 global table is never materialised (``lut_status is None``; the numpy
oracle is the factored decode below).
"""

from __future__ import annotations

import functools

import numpy as np

from repro.codes import base
from repro.codes.base import N_DATA, Codec, register
from repro.codes.secded import build_hsiao

N_WAYS = 4
SUB_DATA = 16
SUB_CHECK = 6
N_CHECK = N_WAYS * SUB_CHECK  # 24


def _sub_positions(s: int) -> np.ndarray:
    """Global data-bit indices owned by subcode ``s`` (sub-bit order)."""
    return np.arange(SUB_DATA) * N_WAYS + s


@functools.lru_cache(maxsize=None)
def build_interleaved() -> dict:
    sub = build_hsiao(SUB_DATA, SUB_CHECK)
    mask_lo = np.zeros(N_CHECK, dtype=np.uint32)
    mask_hi = np.zeros(N_CHECK, dtype=np.uint32)
    for b in range(N_CHECK):
        s, r = b % N_WAYS, b // N_WAYS
        for d, j in enumerate(_sub_positions(s)):
            if (int(sub["data_cols"][d]) >> r) & 1:
                if j < 32:
                    mask_lo[b] |= np.uint32(1 << j)
                else:
                    mask_hi[b] |= np.uint32(1 << (j - 32))
    return {
        "sub": sub,
        "mask_lo": mask_lo,
        "mask_hi": mask_hi,
    }


class InterleavedCodec(Codec):
    name = "ileave88"
    n_check = N_CHECK
    corrects_random = 1
    detects_random = 2
    corrects_burst = N_WAYS
    sure_correct = 2  # <=2 random flips: corrected (split) or detected (same sub)

    def __init__(self):
        code = build_interleaved()
        self.mask_lo = code["mask_lo"]
        self.mask_hi = code["mask_hi"]
        self._sub_cols = code["sub"]["data_cols"]  # (16,) sub data columns
        # 2^24 dense tables are deliberately not built:
        self.lut_status = None
        self.lut_flip_lo = None
        self.lut_flip_hi = None
        self.lut_flip_check = None

    # ------------------------------------------------------------------ jnp
    def classify_jnp(self, synd, want_flips: bool = True, luts: tuple = ()):
        import jax.numpy as jnp

        u32 = jnp.uint32
        flip_lo = jnp.zeros_like(synd)
        flip_hi = jnp.zeros_like(synd)
        flip_check = jnp.zeros_like(synd)
        any_detect = jnp.zeros_like(synd, dtype=jnp.bool_)
        any_correct = jnp.zeros_like(synd, dtype=jnp.bool_)
        for s in range(N_WAYS):
            sub_synd = jnp.zeros_like(synd)
            for r in range(SUB_CHECK):
                sub_synd = sub_synd | (((synd >> (N_WAYS * r + s)) & u32(1)) << r)
            matched = jnp.zeros_like(synd, dtype=jnp.bool_)
            for d in range(SUB_DATA):
                m = sub_synd == u32(int(self._sub_cols[d]))
                matched = matched | m
                if want_flips:
                    j = d * N_WAYS + s
                    if j < 32:
                        flip_lo = jnp.where(m, flip_lo | u32(1 << j), flip_lo)
                    else:
                        flip_hi = jnp.where(m, flip_hi | u32(1 << (j - 32)), flip_hi)
            for r in range(SUB_CHECK):
                m = sub_synd == u32(1 << r)
                matched = matched | m
                if want_flips:
                    flip_check = jnp.where(
                        m, flip_check | u32(1 << (N_WAYS * r + s)), flip_check
                    )
            sub_clean = sub_synd == u32(0)
            any_detect = any_detect | (~sub_clean & ~matched)
            any_correct = any_correct | matched
        status = jnp.where(
            any_detect,
            jnp.int32(base.STATUS_DETECTED),
            jnp.where(
                any_correct,
                jnp.int32(base.STATUS_CORRECTED),
                jnp.int32(base.STATUS_CLEAN),
            ),
        )
        return flip_lo, flip_hi, flip_check, status

    # ---------------------------------------------------------- numpy oracle
    def decode_np(self, lo: np.ndarray, hi: np.ndarray, check: np.ndarray):
        lo = np.asarray(lo, np.uint32)
        hi = np.asarray(hi, np.uint32)
        synd = (
            self.encode_np(lo, hi).astype(np.uint32)
            ^ np.asarray(check).astype(np.uint32)
        )
        sub_lut = build_hsiao(SUB_DATA, SUB_CHECK)["syndrome_lut"]
        out_lo, out_hi = lo.copy(), hi.copy()
        any_detect = np.zeros(synd.shape, bool)
        any_correct = np.zeros(synd.shape, bool)
        for s in range(N_WAYS):
            sub_synd = np.zeros(synd.shape, np.int64)
            for r in range(SUB_CHECK):
                sub_synd |= ((synd >> np.uint32(N_WAYS * r + s)) & 1).astype(
                    np.int64
                ) << r
            action = sub_lut[sub_synd]
            any_detect |= action == -2  # secded.LUT_DETECT
            any_correct |= action >= 0
            databit = (action >= 0) & (action < SUB_DATA)
            j = np.clip(action, 0, SUB_DATA - 1) * N_WAYS + s
            out_lo ^= np.where(databit & (j < 32), np.uint32(1) << (j % 32), 0).astype(
                np.uint32
            )
            out_hi ^= np.where(databit & (j >= 32), np.uint32(1) << (j % 32), 0).astype(
                np.uint32
            )
        status = np.where(
            any_detect,
            base.STATUS_DETECTED,
            np.where(any_correct, base.STATUS_CORRECTED, base.STATUS_CLEAN),
        ).astype(np.int32)
        return out_lo, out_hi, status


@register("ileave88")
def _ileave88() -> InterleavedCodec:
    return InterleavedCodec()
