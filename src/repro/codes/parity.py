"""Parity-only detect (65,64): one check bit, zero correction.

The cheapest scheme in the ladder — 1.6% redundancy vs SECDED's 12.5% —
and the paper's implicit no-ECC-with-detection baseline: any odd number of
flipped bits raises the (uncorrectable) detect flag, every even-weight
fault aliases silently. Useful as the low end of the coverage/overhead
trade-off curve and as the degenerate case that keeps the codec interface
honest (``corrects_random == 0``: the classify path must never flip a bit).
"""

from __future__ import annotations

import numpy as np

from repro.codes import base
from repro.codes.base import Codec, build_luts, register


class ParityCodec(Codec):
    name = "parity65"
    n_check = 1
    corrects_random = 0
    detects_random = 1
    corrects_burst = 0
    sure_correct = 0

    def __init__(self):
        # The single check bit folds the whole 64-bit word.
        self.mask_lo = np.array([0xFFFFFFFF], dtype=np.uint32)
        self.mask_hi = np.array([0xFFFFFFFF], dtype=np.uint32)
        luts = build_luts(self.n_check, [])  # nothing is correctable
        self.lut_status = luts["lut_status"]
        self.lut_flip_lo = luts["lut_flip_lo"]
        self.lut_flip_hi = luts["lut_flip_hi"]
        self.lut_flip_check = luts["lut_flip_check"]

    def classify_jnp(self, synd, want_flips: bool = True, luts: tuple = ()):
        import jax.numpy as jnp

        z = jnp.zeros_like(synd)
        status = jnp.where(
            synd == jnp.uint32(0),
            jnp.int32(base.STATUS_CLEAN),
            jnp.int32(base.STATUS_DETECTED),
        )
        return z, z, z, status


@register("parity65")
def _parity65() -> ParityCodec:
    return ParityCodec()
