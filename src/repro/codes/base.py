"""Pluggable ECC codec abstraction + registry (DESIGN.md §12).

Every registered code protects one 64-bit data word (the BRAM word geometry
shared by the whole repo: lo/hi uint32 data planes) with ``n_check`` check
bits stored in a parallel check plane. A ``Codec`` carries:

  * the parity-check matrix in *systematic* form — check bit ``r`` is the
    XOR-fold of the data word masked by (``mask_lo[r]``, ``mask_hi[r]``);
    the check positions themselves are identity columns, so the syndrome is
    simply ``recomputed_check XOR stored_check``;
  * a syndrome classification into NONE / CORRECTED / DETECTED plus the
    correction flip masks, exposed two ways: dense numpy lookup tables
    (``lut_status`` / ``lut_flip_*``, the host oracle) and a jnp
    ``classify_jnp`` usable inside Pallas kernel bodies.  Codecs whose
    correctable-syndrome set is small evaluate the LUT as unrolled
    compare/select chains (gather-free, the TPU-friendly form the SECDED
    kernels always used); multi-bit correctors gather from the dense table.
  * coverage guarantees (``corrects_random`` / ``detects_random`` /
    ``corrects_burst``) that the telemetry tallies, the hypothesis property
    tests, and the controller escalation ladder consume.

The numpy and jnp paths are required to be bit-identical (property-tested in
tests/test_codecs.py); the jnp path is required to be safe to trace inside a
Pallas kernel body (elementwise ops + at most a small-table gather).
"""

from __future__ import annotations

import functools

import numpy as np

N_DATA = 64

# The scheme everything defaults to: the paper's built-in BRAM SECDED. The
# single source of truth — configs/shapes.py, core/planestore.py and the
# controller all import it.
DEFAULT_CODEC = "secded72"

STATUS_CLEAN = 0
STATUS_CORRECTED = 1
STATUS_DETECTED = 2


def parity32_np(v: np.ndarray) -> np.ndarray:
    """Bitwise XOR-fold of each uint32 lane -> {0, 1} uint32."""
    v = v.astype(np.uint32)
    v = v ^ (v >> np.uint32(16))
    v = v ^ (v >> np.uint32(8))
    v = v ^ (v >> np.uint32(4))
    v = v ^ (v >> np.uint32(2))
    v = v ^ (v >> np.uint32(1))
    return v & np.uint32(1)


def parity32_jnp(v):
    import jax.numpy as jnp

    v = v ^ (v >> 16)
    v = v ^ (v >> 8)
    v = v ^ (v >> 4)
    v = v ^ (v >> 2)
    v = v ^ (v >> 1)
    return v & jnp.uint32(1)


class Codec:
    """One registered ECC scheme over 64-bit data words.

    Subclasses set the class attributes below and (optionally) override
    ``classify_jnp`` with a gather-free compare-chain form; the base class
    provides systematic encode (shared by every linear code here), the dense
    LUT host decode, and a dense-LUT jnp classify.
    """

    name: str
    n_check: int
    # guaranteed behaviour under k random / burst-of-k adjacent bit flips
    corrects_random: int
    detects_random: int
    corrects_burst: int
    # flips <= sure_correct and status == CORRECTED implies the delivered
    # data is genuinely restored (drives the telemetry "corrected" lane)
    sure_correct: int

    # systematic H: check bit r = parity(lo & mask_lo[r]) ^ parity(hi & mask_hi[r])
    mask_lo: np.ndarray  # (n_check,) uint32
    mask_hi: np.ndarray  # (n_check,) uint32

    # dense syndrome tables, length 2**n_check (None when the syndrome space
    # is too large to materialise — the codec must then override classify)
    lut_status: np.ndarray | None
    lut_flip_lo: np.ndarray | None
    lut_flip_hi: np.ndarray | None
    lut_flip_check: np.ndarray | None

    # ------------------------------------------------------------------ meta
    @property
    def n_bits(self) -> int:
        return N_DATA + self.n_check

    @property
    def check_dtype(self):
        """Storage dtype of the check plane (uint8 up to 8 check bits)."""
        return np.uint8 if self.n_check <= 8 else np.uint32

    @property
    def overhead(self) -> float:
        """Redundancy: check bits per data bit."""
        return self.n_check / N_DATA

    @property
    def exact_tallies(self) -> bool:
        """Whether the telemetry kernels must compare the correction against
        the injected mask to count genuine corrections (any codec that can
        correct more than a single random bit), instead of the cheap
        single-flip formula that is exact for SEC-class codes."""
        return self.corrects_random > 1 or self.corrects_burst > 1

    # ---------------------------------------------------------------- encode
    def encode_np(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """Check plane for uint32 data planes; returns ``check_dtype``."""
        lo = np.asarray(lo, np.uint32)[..., None]
        hi = np.asarray(hi, np.uint32)[..., None]
        bits = parity32_np(lo & self.mask_lo) ^ parity32_np(hi & self.mask_hi)
        weights = (np.uint32(1) << np.arange(self.n_check, dtype=np.uint32))
        return (bits * weights).sum(-1).astype(self.check_dtype)

    def encode_jnp(self, lo, hi):
        """Check plane as a uint32 tensor (callers cast to ``check_dtype``
        when storing). Elementwise — safe inside Pallas kernel bodies."""
        import jax.numpy as jnp

        c = jnp.zeros_like(lo)
        for r in range(self.n_check):
            mlo = jnp.uint32(int(self.mask_lo[r]))
            mhi = jnp.uint32(int(self.mask_hi[r]))
            bit = parity32_jnp((lo & mlo) ^ (hi & mhi))
            c = c | (bit << r)
        return c

    def syndrome_jnp(self, lo, hi, check):
        import jax.numpy as jnp

        return self.encode_jnp(lo, hi) ^ check.astype(jnp.uint32)

    # -------------------------------------------------------------- classify
    def lut_input_arrays(self) -> tuple:
        """Dense tables a Pallas kernel must receive as *explicit inputs*
        (Pallas rejects captured array constants): (status, flip_lo,
        flip_hi, flip_check). Empty for codecs whose classify is pure
        compare/select chains."""
        if self.lut_status is None:
            return ()
        if self.classify_jnp.__func__ is not Codec.classify_jnp:
            return ()  # chain-classify override: tables are the host oracle only
        return (
            self.lut_status,
            self.lut_flip_lo,
            self.lut_flip_hi,
            self.lut_flip_check,
        )

    def classify_jnp(self, synd, want_flips: bool = True, luts: tuple = ()):
        """Syndrome plane -> (flip_lo, flip_hi, flip_check, status).

        Default: dense-LUT gather (used by multi-bit correctors whose
        correctable set is too large to unroll). ``want_flips=False`` skips
        the flip gathers for telemetry-only callers. Inside a Pallas kernel
        body, pass the loaded ``lut_input_arrays`` tensors as ``luts``;
        outside, the tables are materialised as jnp constants.
        """
        import jax.numpy as jnp

        if not luts:
            assert self.lut_status is not None, self.name
            luts = tuple(
                jnp.asarray(t)
                for t in (
                    self.lut_status,
                    self.lut_flip_lo,
                    self.lut_flip_hi,
                    self.lut_flip_check,
                )
            )
        status_t, flip_lo_t, flip_hi_t, flip_check_t = luts
        s = synd.astype(jnp.int32)
        status = jnp.take(status_t, s)
        if not want_flips:
            z = jnp.zeros_like(synd)
            return z, z, z, status
        flip_lo = jnp.take(flip_lo_t, s)
        flip_hi = jnp.take(flip_hi_t, s)
        flip_check = jnp.take(flip_check_t, s)
        return flip_lo, flip_hi, flip_check, status

    def decode_jnp(self, lo, hi, check):
        """(lo', hi', status) with correctable errors fixed — jnp path."""
        synd = self.syndrome_jnp(lo, hi, check)
        flip_lo, flip_hi, _, status = self.classify_jnp(synd)
        return lo ^ flip_lo, hi ^ flip_hi, status

    # ---------------------------------------------------------- numpy oracle
    def decode_np(self, lo: np.ndarray, hi: np.ndarray, check: np.ndarray):
        """Host oracle decode via the dense syndrome tables."""
        assert self.lut_status is not None, self.name
        lo = np.asarray(lo, np.uint32)
        hi = np.asarray(hi, np.uint32)
        synd = (
            self.encode_np(lo, hi).astype(np.uint32) ^ np.asarray(check).astype(np.uint32)
        ).astype(np.int64)
        return (
            lo ^ self.lut_flip_lo[synd],
            hi ^ self.lut_flip_hi[synd],
            self.lut_status[synd].astype(np.int32),
        )


# ---------------------------------------------------------------------------
# LUT construction helper shared by the concrete codecs
# ---------------------------------------------------------------------------
def build_luts(n_check: int, patterns) -> dict:
    """Dense syndrome tables from (syndrome, flip_lo, flip_hi, flip_check)
    correctable patterns. Asserts every correctable syndrome is distinct —
    the constructive proof that the code corrects its advertised set."""
    size = 1 << n_check
    status = np.full(size, STATUS_DETECTED, np.int32)
    flip_lo = np.zeros(size, np.uint32)
    flip_hi = np.zeros(size, np.uint32)
    flip_check = np.zeros(size, np.uint32)
    status[0] = STATUS_CLEAN
    for synd, flo, fhi, fch in patterns:
        assert synd != 0, "correctable pattern aliases to the zero syndrome"
        assert status[synd] == STATUS_DETECTED, (
            f"syndrome collision at {synd:#x}: two correctable patterns"
        )
        status[synd] = STATUS_CORRECTED
        flip_lo[synd] = flo
        flip_hi[synd] = fhi
        flip_check[synd] = fch
    return {
        "lut_status": status,
        "lut_flip_lo": flip_lo,
        "lut_flip_hi": flip_hi,
        "lut_flip_check": flip_check,
    }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_FACTORIES: dict[str, callable] = {}


def register(name: str):
    """Decorator: register a zero-arg codec factory under ``name``."""

    def deco(factory):
        _FACTORIES[name] = functools.lru_cache(maxsize=None)(factory)
        return factory

    return deco


def get(name: str) -> Codec:
    try:
        return _FACTORIES[name]()
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; registered: {sorted(_FACTORIES)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(_FACTORIES)
