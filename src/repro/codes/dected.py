"""DEC-TED (79,64): double-error-correcting, triple-error-detecting BCH.

Construction: the binary primitive BCH(127, 113, d=5) over GF(2^7)
(primitive polynomial x^7 + x^3 + 1), extended with an overall parity row
(d=6) and shortened to 64 data bits. The parity-check matrix column of
position ``i`` is [alpha^i | alpha^{3i} | 1] (7 + 7 + 1 = 15 rows); any
five columns are linearly independent, so

  * every 1- and 2-bit error pattern has a distinct non-zero syndrome
    (corrected via the dense LUT — 3160 correctable syndromes out of 2^15,
    far too many for the compare-chain form the SEC codes use), and
  * every 3-bit pattern's syndrome differs from all of those
    (3 + 2 < d = 6), so triples are always flagged DETECTED.

The matrix is put in systematic form (check positions = identity columns)
by Gaussian elimination over GF(2), so the shared ``Codec`` encode/syndrome
machinery applies unchanged; ``build_luts`` then *proves* the distinctness
claims constructively — a syndrome collision anywhere raises at build time.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.codes.base import N_DATA, Codec, build_luts, register

N_CHECK = 15
N_POS = N_DATA + N_CHECK  # 79 codeword bits after shortening

_GF_POLY = 0x89  # x^7 + x^3 + 1, primitive over GF(2^7)
_GF_ORDER = 127


def _gf_powers() -> list[int]:
    """alpha^0 .. alpha^126 as 7-bit field elements."""
    out, x = [], 1
    for _ in range(_GF_ORDER):
        out.append(x)
        x <<= 1
        if x & 0x80:
            x ^= 0x80 | (_GF_POLY & 0x7F)
    return out


def _gf2_inv(mat: np.ndarray) -> np.ndarray:
    """Invert a square GF(2) matrix (bool); raises if singular."""
    m = mat.shape[0]
    aug = np.concatenate([mat.copy(), np.eye(m, dtype=bool)], axis=1)
    for col in range(m):
        piv = next((r for r in range(col, m) if aug[r, col]), None)
        assert piv is not None, "check-position submatrix is singular"
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        for r in range(m):
            if r != col and aug[r, col]:
                aug[r] ^= aug[col]
    return aug[:, m:]


@functools.lru_cache(maxsize=None)
def build_dected() -> dict:
    """Systematic H, per-position syndromes, and the dense correction LUTs."""
    alpha = _gf_powers()
    # Raw H over the first 79 positions of the extended, shortened code.
    h = np.zeros((N_CHECK, N_POS), dtype=bool)
    for i in range(N_POS):
        col = alpha[i] | (alpha[(3 * i) % _GF_ORDER] << 7) | (1 << 14)
        for r in range(N_CHECK):
            h[r, i] = (col >> r) & 1
    # Systematise: make the last 15 positions the check bits (identity).
    t = _gf2_inv(h[:, N_DATA:])
    h_sys = (t.astype(np.uint8) @ h.astype(np.uint8)) % 2
    assert np.array_equal(h_sys[:, N_DATA:], np.eye(N_CHECK, dtype=np.uint8))

    mask_lo = np.zeros(N_CHECK, dtype=np.uint32)
    mask_hi = np.zeros(N_CHECK, dtype=np.uint32)
    for d in range(N_DATA):
        for r in range(N_CHECK):
            if h_sys[r, d]:
                if d < 32:
                    mask_lo[r] |= np.uint32(1 << d)
                else:
                    mask_hi[r] |= np.uint32(1 << (d - 32))

    # Per-position syndrome + flip-mask triples.
    synd = np.zeros(N_POS, dtype=np.int64)
    flips = np.zeros((N_POS, 3), dtype=np.uint32)  # (flip_lo, flip_hi, flip_check)
    for p in range(N_POS):
        if p < N_DATA:
            synd[p] = sum(int(h_sys[r, p]) << r for r in range(N_CHECK))
            flips[p, 0 if p < 32 else 1] = np.uint32(1 << (p % 32))
        else:
            synd[p] = 1 << (p - N_DATA)
            flips[p, 2] = np.uint32(1 << (p - N_DATA))

    patterns = [(int(synd[p]), *flips[p]) for p in range(N_POS)]
    for p in range(N_POS):
        for q in range(p + 1, N_POS):
            patterns.append(
                (
                    int(synd[p] ^ synd[q]),
                    flips[p, 0] ^ flips[q, 0],
                    flips[p, 1] ^ flips[q, 1],
                    flips[p, 2] ^ flips[q, 2],
                )
            )
    luts = build_luts(N_CHECK, patterns)  # raises on any syndrome collision
    return {
        "mask_lo": mask_lo,
        "mask_hi": mask_hi,
        "position_syndromes": synd,
        **luts,
    }


class DectedCodec(Codec):
    """Shortened extended BCH: corrects any 1-2 flips, detects any 3."""

    name = "dected79"
    n_check = N_CHECK
    corrects_random = 2
    detects_random = 3
    corrects_burst = 2
    sure_correct = 2

    def __init__(self):
        code = build_dected()
        self.mask_lo = code["mask_lo"]
        self.mask_hi = code["mask_hi"]
        self.lut_status = code["lut_status"]
        self.lut_flip_lo = code["lut_flip_lo"]
        self.lut_flip_hi = code["lut_flip_hi"]
        self.lut_flip_check = code["lut_flip_check"]
        # classify_jnp: inherited dense-LUT gather (the correctable set has
        # 3160 members — unrolled compares are not an option here).


@register("dected79")
def _dected79() -> DectedCodec:
    return DectedCodec()
