"""Hsiao odd-weight-column SECDED codes, behind the Codec interface.

The (72,64) instance is the Xilinx 7-series BRAM built-in ECC the paper
evaluates (UG473) and was historically constructed in ``repro.core.hsiao``;
that module is now a thin re-export of the tables built here. The
construction is deterministic and unchanged: every column of the
``n_check x n_bits`` parity-check matrix is distinct and odd-weight, the
check positions use the weight-1 identity columns, and the data positions
take all weight-3 columns first, then greedily pick higher-weight columns
to keep row weights balanced (minimum hardware XOR-tree depth).

``build_hsiao`` generalises the same procedure to any (n_data, n_check)
with enough odd-weight columns — the 4-way interleaved codec reuses it for
its Hsiao(22,16) subcode.

Decode classification (syndrome s = stored_check XOR recomputed_check):
  s == 0                 -> NONE       (no error, or an aliasing >=4-bit error)
  s == a data column     -> CORRECTED  (flip that data bit)
  s == a check column    -> CORRECTED  (check-bit error; data untouched)
  otherwise              -> DETECTED   (uncorrectable; includes all 2-bit
                                        errors: XOR of two odd columns is even)
"""

from __future__ import annotations

import functools

import numpy as np

from repro.codes import base
from repro.codes.base import N_DATA, Codec, build_luts, register

N_PARITY = 8
N_BITS = N_DATA + N_PARITY  # 72-bit codeword

# Sentinel values in the (historical) syndrome action table.
LUT_CLEAN = -1  # syndrome 0
LUT_DETECT = -2  # uncorrectable (even-weight or unused odd syndrome)
# 0..63   -> flip that data bit
# 64..71  -> parity bit (64 + r) had the error; data is fine.


def _popcount(x: int) -> int:
    return bin(x).count("1")


@functools.lru_cache(maxsize=None)
def build_hsiao(n_data: int, n_check: int) -> dict:
    """Deterministic Hsiao construction for an (n_data + n_check, n_data)
    SECDED code. Returns data/parity columns, encode masks over the data
    word (lo/hi uint32 halves), the historical action LUT, and row weights.
    """
    # Candidate data columns: odd weight >= 3, grouped by weight ascending.
    chosen: list[int] = []
    row_weight = np.zeros(n_check, dtype=np.int64)

    def add(c: int) -> None:
        chosen.append(c)
        for r in range(n_check):
            row_weight[r] += (c >> r) & 1

    for w in range(3, n_check + 1, 2):
        cands = [c for c in range(1 << n_check) if _popcount(c) == w]
        need = n_data - len(chosen)
        if need == 0:
            break
        if len(cands) <= need:
            for c in cands:
                add(c)
            continue
        # Greedily pick the remainder keeping row weights balanced.
        for _ in range(need):
            best, best_key = None, None
            for c in cands:
                if c in chosen:
                    continue
                trial = row_weight.copy()
                for r in range(n_check):
                    trial[r] += (c >> r) & 1
                key = (int(trial.max()), int(trial.var() * 1e6), c)
                if best_key is None or key < best_key:
                    best, best_key = c, key
            add(best)
    assert len(chosen) == n_data, (
        f"not enough odd-weight {n_check}-bit columns for {n_data} data bits"
    )

    col_dtype = np.uint8 if n_check <= 8 else np.uint32
    data_cols = np.array(chosen, dtype=col_dtype)
    parity_cols = np.array([1 << r for r in range(n_check)], dtype=col_dtype)
    assert len(set(chosen) | set(int(c) for c in parity_cols)) == n_data + n_check

    # Encode masks: check bit r covers data bit d iff bit r of data_cols[d].
    mask_lo = np.zeros(n_check, dtype=np.uint32)
    mask_hi = np.zeros(n_check, dtype=np.uint32)
    for d in range(n_data):
        col = int(data_cols[d])
        for r in range(n_check):
            if (col >> r) & 1:
                if d < 32:
                    mask_lo[r] |= np.uint32(1 << d)
                else:
                    mask_hi[r] |= np.uint32(1 << (d - 32))

    # Historical action table (syndrome -> data bit / parity bit / sentinel).
    lut = np.full(1 << n_check, LUT_DETECT, dtype=np.int32)
    lut[0] = LUT_CLEAN
    for d in range(n_data):
        lut[int(data_cols[d])] = d
    for r in range(n_check):
        lut[1 << r] = n_data + r

    return {
        "data_cols": data_cols,
        "parity_cols": parity_cols,
        "mask_lo": mask_lo,
        "mask_hi": mask_hi,
        "syndrome_lut": lut,
        "row_weight": row_weight,
    }


@functools.lru_cache(maxsize=None)
def build_code() -> dict:
    """The Hsiao(72,64) tables (historical entry point, re-exported by
    ``repro.core.hsiao``)."""
    return build_hsiao(N_DATA, N_PARITY)


CODE = build_code()
DATA_COLS: np.ndarray = CODE["data_cols"]
MASK_LO: np.ndarray = CODE["mask_lo"]
MASK_HI: np.ndarray = CODE["mask_hi"]
SYNDROME_LUT: np.ndarray = CODE["syndrome_lut"]


class SecdedCodec(Codec):
    """Hsiao SECDED(72,64): corrects any single, detects any double."""

    name = "secded72"
    n_check = N_PARITY
    corrects_random = 1
    detects_random = 2
    corrects_burst = 1
    sure_correct = 1

    def __init__(self):
        code = build_code()
        self.mask_lo = code["mask_lo"]
        self.mask_hi = code["mask_hi"]
        patterns = []
        for d in range(N_DATA):
            flo = np.uint32(1 << d) if d < 32 else np.uint32(0)
            fhi = np.uint32(1 << (d - 32)) if d >= 32 else np.uint32(0)
            patterns.append((int(code["data_cols"][d]), flo, fhi, np.uint32(0)))
        for r in range(self.n_check):
            patterns.append((1 << r, np.uint32(0), np.uint32(0), np.uint32(1 << r)))
        luts = build_luts(self.n_check, patterns)
        self.lut_status = luts["lut_status"]
        self.lut_flip_lo = luts["lut_flip_lo"]
        self.lut_flip_hi = luts["lut_flip_hi"]
        self.lut_flip_check = luts["lut_flip_check"]

    def classify_jnp(self, synd, want_flips: bool = True, luts: tuple = ()):
        # Gather-free syndrome resolution: the correctable set is only 72
        # syndromes, so the LUT is evaluated as unrolled compare/select
        # chains — exactly the form the SECDED Pallas kernels always lowered
        # to (bit-identical op graph, so the CI perf gate sees no change).
        import jax.numpy as jnp

        u32 = jnp.uint32
        flip_lo = jnp.zeros_like(synd)
        flip_hi = jnp.zeros_like(synd)
        flip_check = jnp.zeros_like(synd)
        matched = jnp.zeros_like(synd, dtype=jnp.bool_)
        for d in range(N_DATA):
            col = u32(int(DATA_COLS[d]))
            m = synd == col
            matched = matched | m
            if want_flips:
                if d < 32:
                    flip_lo = jnp.where(m, flip_lo | u32(1 << d), flip_lo)
                else:
                    flip_hi = jnp.where(m, flip_hi | u32(1 << (d - 32)), flip_hi)
        for r in range(self.n_check):
            m = synd == u32(1 << r)
            matched = matched | m  # check-bit error: data fine
            if want_flips:
                flip_check = jnp.where(m, flip_check | u32(1 << r), flip_check)
        clean = synd == u32(0)
        status = jnp.where(
            clean,
            jnp.int32(base.STATUS_CLEAN),
            jnp.where(
                matched,
                jnp.int32(base.STATUS_CORRECTED),
                jnp.int32(base.STATUS_DETECTED),
            ),
        )
        return flip_lo, flip_hi, flip_check, status


@register("secded72")
def _secded72() -> SecdedCodec:
    return SecdedCodec()
