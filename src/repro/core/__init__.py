"""Core library: the paper's contribution as composable JAX modules.

Built-in-ECC-under-undervolting for ML memory systems:
  * `hsiao` / `ecc`    — Hsiao(72,64) SECDED code (Xilinx BRAM geometry)
  * `voltage`          — calibrated fault-rate + power models (VC707/KC705-A/B)
  * `faultsim`         — per-bitcell failure-threshold field (FIP by construction)
  * `memory`           — EccMemoryDomain: SECDED-protected array storage
  * `controller`       — DED-canary runtime undervolting controller
  * `telemetry`        — CORRECTED / DETECTED / SILENT fault accounting
  * `quantize`         — int8 + 64-bit word packing (BRAM word geometry)
  * `scenario`         — burst-fault shapes, environment matrix, aging drift
  * `campaign`         — accuracy-under-undervolt divergence scoring + harness
"""

from repro.core import (
    campaign,
    controller,
    ecc,
    faultsim,
    hsiao,
    memory,
    quantize,
    scenario,
    telemetry,
    voltage,
)
from repro.core.campaign import CampaignSpec, DivergenceReport, run_campaign
from repro.core.controller import (
    RAIL_POLICIES,
    EscalationPolicy,
    MeshRailController,
    MultiRailController,
    UndervoltController,
)
from repro.core.faultsim import FaultField, FlipMasks
from repro.core.memory import EccMemoryDomain
from repro.core.scenario import ENVIRONMENTS, BurstProfile, EnvironmentProfile
from repro.core.telemetry import DomainFaultStats, FaultStats, ShardFaultStats
from repro.core.voltage import PLATFORMS, PlatformProfile

__all__ = [
    "campaign", "controller", "ecc", "faultsim", "hsiao", "memory",
    "quantize", "scenario", "telemetry", "voltage", "CampaignSpec",
    "DivergenceReport", "run_campaign", "EscalationPolicy",
    "MeshRailController", "MultiRailController", "RAIL_POLICIES",
    "UndervoltController", "FaultField", "FlipMasks", "EccMemoryDomain",
    "DomainFaultStats", "FaultStats", "ShardFaultStats", "PLATFORMS",
    "PlatformProfile", "ENVIRONMENTS", "BurstProfile", "EnvironmentProfile",
]
