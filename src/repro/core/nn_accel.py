"""The paper's §IV case study: an NN accelerator whose weights live in
ECC-protected, undervolted on-chip memory.

Faithful reproduction of the FPGA methodology ([16]'s mapping):
  * int8 fixed-point weights packed 8-per-64-bit-codeword into BRAM geometry,
  * the rail undervolted from V_nom toward V_crash injects bit faults into
    the stored planes (parity bits included),
  * every inference reads weights through the SECDED path — here the fused
    Pallas decode-matmul kernel (`kernels/ecc_matmul`), the TPU-native
    equivalent of the BRAM hard-core ECC port,
  * classification error vs. voltage, with and without ECC, reproduces
    paper Fig. 3; power comes from the calibrated Table-I model.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import voltage as vmod
from repro.core.faultsim import FaultField
from repro.core.planestore import PlaneStore, leaf_seed
from repro.core.telemetry import FaultStats
from repro.kernels import ops as kops


@dataclasses.dataclass
class _Layer:
    w: jnp.ndarray  # float32 trained weight (K, N)
    b: jnp.ndarray  # float32 bias (N,)
    enc: kops.EccWeight | None = None  # clean encoded planes
    faulty: kops.EccWeight | None = None  # planes at current rail voltage
    field: FaultField | None = None


class EccMLP:
    """MLP classifier with SECDED-protected int8 weights (paper's accelerator)."""

    def __init__(
        self, layer_sizes, platform: str = "vc707", seed: int = 0,
        mask_source: str = "host",
    ):
        self.sizes = tuple(layer_sizes)
        self.platform = vmod.PLATFORMS[platform]
        self.seed = seed
        self.mask_source = mask_source
        self.layers: list[_Layer] = []
        self.voltage = self.platform.v_nom
        self.ecc_enabled = True
        self.stats = FaultStats()
        key = jax.random.PRNGKey(seed)
        for i, (k, n) in enumerate(zip(self.sizes[:-1], self.sizes[1:])):
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (k, n)) * (2.0 / np.sqrt(k))
            self.layers.append(_Layer(w=w, b=jnp.zeros((n,))))

    # -- float training (host-side, plain JAX) --------------------------------
    def _forward_f32(self, params, x):
        h = x
        for i, (w, b) in enumerate(params):
            h = h @ w + b
            if i < len(self.sizes) - 2:
                h = jax.nn.relu(h)
        return h

    def train(self, xs, ys, steps=600, batch=128, lr=3e-3, seed=0):
        params = [(l.w, l.b) for l in self.layers]

        def loss_fn(params, xb, yb):
            logits = self._forward_f32(params, xb)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(lse - gold)

        @jax.jit
        def step_fn(params, xb, yb):
            loss, g = jax.value_and_grad(loss_fn)(params, xb, yb)
            params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
            return params, loss

        rng = np.random.Generator(np.random.Philox(key=(seed, 0x7281)))
        n = xs.shape[0]
        loss = None
        for _ in range(steps):
            idx = rng.integers(0, n, size=batch)
            params, loss = step_fn(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        for l, (w, b) in zip(self.layers, params):
            l.w, l.b = w, b
        self.store()  # quantize + encode into the memory domain
        return float(loss)

    # -- memory domain ---------------------------------------------------------
    def store(self):
        """Quantize weights to int8 and SECDED-encode them (write to 'BRAM')."""
        for i, l in enumerate(self.layers):
            l.enc = kops.pack_ecc_weights(l.w)
            l.field = FaultField(
                self.platform, l.enc.lo.size, seed=leaf_seed(self.seed, f"layer{i}")
            )
        self._store = PlaneStore(
            [l.enc for l in self.layers],
            [f"layer{i}" for i in range(len(self.layers))],
            self.platform,
            seed=self.seed,
            mask_source=self.mask_source,
        )
        self.set_voltage(self.voltage, self.ecc_enabled)

    def set_voltage(self, v: float, ecc: bool = True, batched: bool = True):
        """Move the rail; regenerate the faulty view of every plane.

        batched=True: one fused inject+scrub launch over the whole arena;
        batched=False: the historical per-leaf reference loop (bit-identical,
        kept for parity tests and the voltage_sweep benchmark baseline).
        """
        self.voltage = float(v)
        self.ecc_enabled = ecc
        if batched:
            leaves, stats = self._store.set_voltage(v, ecc=ecc)
            for l, faulty in zip(self.layers, leaves):
                l.faulty = faulty
            self.stats = stats
            return
        agg = FaultStats()
        for l in self.layers:
            masks = l.field.masks(v)
            lo, hi, par = kops.inject(
                l.enc.lo, l.enc.hi, l.enc.parity,
                jnp.asarray(masks.lo.reshape(l.enc.lo.shape)),
                jnp.asarray(masks.hi.reshape(l.enc.hi.shape)),
                jnp.asarray(masks.parity.reshape(l.enc.parity.shape)),
            )
            if not ecc:
                # ECC disabled: all 18 bits are data in the real BRAM; we
                # emulate by making the decoder a no-op (parity recomputed on
                # the faulty data => syndrome 0, faults flow through).
                par = kops.encode(lo, hi)
            faulty = dataclasses.replace(l.enc, lo=lo, hi=hi, parity=par)
            status = np.asarray(kops.scrub(faulty))
            agg.accumulate(FaultStats.from_decode(status, masks.flip_counts()))
            l.faulty = faulty
        self.stats = agg

    # -- inference through the ECC read path -----------------------------------
    def predict(self, xs: np.ndarray, fuse: bool = True) -> np.ndarray:
        h = jnp.asarray(xs)
        for i, l in enumerate(self.layers):
            h = kops.ecc_matmul(h, l.faulty, fuse=fuse) + l.b
            if i < len(self.sizes) - 2:
                h = jax.nn.relu(h)
        return np.asarray(jnp.argmax(h, axis=-1))

    def error_rate(self, xs, ys, fuse: bool = True) -> float:
        pred = self.predict(xs, fuse=fuse)
        return float((pred != ys).mean())

    def power_w(self) -> float:
        return vmod.accelerator_power(self.voltage, ecc=self.ecc_enabled)

    def bram_power_w(self) -> float:
        return vmod.bram_power(self.voltage, ecc=self.ecc_enabled)
