"""Fault telemetry: ECC outcomes vs. ground truth (paper Fig. 1/2 counters)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ecc

# Field order of the device-side counter vector produced by the fused
# inject_scrub kernel (kernels/inject_scrub.py). `words` is not reduced on
# device — the caller knows the store size.
COUNTER_FIELDS = (
    "clean", "corrected", "detected", "silent",
    "words_1bit", "words_2bit", "words_multi", "faulty_bits",
)


@dataclasses.dataclass
class FaultStats:
    """Aggregated per-read fault statistics for one memory domain."""

    words: int = 0
    clean: int = 0  # syndrome 0, no ground-truth flips
    corrected: int = 0  # ECC corrected a genuine single-bit fault
    detected: int = 0  # ECC raised the uncorrectable (DED) flag
    silent: int = 0  # >=2 flips that ECC mis-corrected or aliased to clean
    # ground-truth fault classes (paper's correctable/detectable/undetectable)
    words_1bit: int = 0
    words_2bit: int = 0
    words_multi: int = 0
    faulty_bits: int = 0

    def accumulate(self, other: "FaultStats") -> None:
        """Add ``other``'s counters into ``self``, in place.

        Deliberately returns None: the old ``merge`` name looked like a pure
        combinator but mutated the receiver, so call sites could silently
        alias the accumulator. Use ``FaultStats.summed`` for a pure merge.
        """
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    @classmethod
    def summed(cls, stats) -> "FaultStats":
        """Pure merge: a fresh FaultStats totalling an iterable of stats."""
        out = cls()
        for s in stats:
            out.accumulate(s)
        return out

    @property
    def faulty_words(self) -> int:
        return self.words_1bit + self.words_2bit + self.words_multi

    def coverage(self) -> dict:
        """Fractions of faulty words by ECC outcome (paper's >90% / 7% split)."""
        n = max(self.faulty_words, 1)
        return {
            "correctable": self.corrected / n,
            "detectable": self.detected / n,
            "silent": self.silent / n,
        }

    @classmethod
    def from_counters(cls, counters, words: int) -> "FaultStats":
        """Build stats from the fused kernel's device-reduced counter vector."""
        c = np.asarray(counters).reshape(-1)
        assert c.size >= len(COUNTER_FIELDS), c.shape
        return cls(words=int(words), **{
            f: int(c[i]) for i, f in enumerate(COUNTER_FIELDS)
        })

    def counters(self) -> np.ndarray:
        """Inverse of from_counters (testing / serialization)."""
        return np.array([getattr(self, f) for f in COUNTER_FIELDS], np.int64)

    @classmethod
    def from_counter_matrix(
        cls, counters, names, words_by_domain
    ) -> "DomainFaultStats":
        """Build per-domain stats from the kernel's (n_domains, 8+) counter
        block (row order == ``names`` == the store's domain order)."""
        c = np.asarray(counters)
        assert c.shape[0] == len(names) and c.shape[1] >= len(COUNTER_FIELDS), c.shape
        return DomainFaultStats(
            {
                d: cls.from_counters(c[i], words=words_by_domain[d])
                for i, d in enumerate(names)
            }
        )

    @classmethod
    def from_decode(cls, status: np.ndarray, flip_counts: np.ndarray) -> "FaultStats":
        """Build stats from per-word ECC status codes + ground-truth flip counts."""
        status = np.asarray(status).reshape(-1)
        flips = np.asarray(flip_counts).reshape(-1)
        corrected_true = (status == ecc.STATUS_CORRECTED) & (flips == 1)
        detected = status == ecc.STATUS_DETECTED
        silent = (flips >= 2) & ~detected
        # A 1-flip word always syndromes to its column => corrected; a 0-flip
        # word always syndromes to 0 => clean. Anything else is silent risk.
        return cls(
            words=int(status.size),
            clean=int(((status == ecc.STATUS_CLEAN) & (flips == 0)).sum()),
            corrected=int(corrected_true.sum()),
            detected=int(detected.sum()),
            silent=int(silent.sum()),
            words_1bit=int((flips == 1).sum()),
            words_2bit=int((flips == 2).sum()),
            words_multi=int((flips >= 3).sum()),
            faulty_bits=int(flips.sum()),
        )


@dataclasses.dataclass
class DomainFaultStats:
    """Per-memory-domain fault statistics (multi-rail telemetry).

    Thin ordered mapping domain name -> FaultStats; iteration order is the
    store's domain order (== the kernel's counter row order).
    """

    by_domain: dict[str, FaultStats] = dataclasses.field(default_factory=dict)

    def __getitem__(self, domain: str) -> FaultStats:
        return self.by_domain[domain]

    def __contains__(self, domain: str) -> bool:
        return domain in self.by_domain

    @property
    def domains(self) -> tuple[str, ...]:
        return tuple(self.by_domain)

    def get(self, domain: str) -> FaultStats:
        return self.by_domain.get(domain, FaultStats())

    def total(self) -> FaultStats:
        """Aggregate over domains (a fresh FaultStats; nothing is aliased)."""
        return FaultStats.summed(self.by_domain.values())

    def accumulate(self, other: "DomainFaultStats") -> None:
        for d, st in other.by_domain.items():
            self.by_domain.setdefault(d, FaultStats()).accumulate(st)

    def coverage(self) -> dict:
        return {d: st.coverage() for d, st in self.by_domain.items()}
