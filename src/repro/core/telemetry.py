"""Fault telemetry: ECC outcomes vs. ground truth (paper Fig. 1/2 counters)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ecc

# Field order of the device-side counter vector produced by the fused
# inject_scrub kernel (kernels/inject_scrub.py). `words` is not reduced on
# device — the caller knows the store size.
COUNTER_FIELDS = (
    "clean", "corrected", "detected", "silent",
    "words_1bit", "words_2bit", "words_multi", "faulty_bits",
)


@dataclasses.dataclass
class FaultStats:
    """Aggregated per-read fault statistics for one memory domain."""

    words: int = 0
    clean: int = 0  # syndrome 0, no ground-truth flips
    corrected: int = 0  # ECC corrected a genuine single-bit fault
    detected: int = 0  # ECC raised the uncorrectable (DED) flag
    silent: int = 0  # >=2 flips that ECC mis-corrected or aliased to clean
    # ground-truth fault classes (paper's correctable/detectable/undetectable)
    words_1bit: int = 0
    words_2bit: int = 0
    words_multi: int = 0
    faulty_bits: int = 0

    def merge(self, other: "FaultStats") -> "FaultStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    @property
    def faulty_words(self) -> int:
        return self.words_1bit + self.words_2bit + self.words_multi

    def coverage(self) -> dict:
        """Fractions of faulty words by ECC outcome (paper's >90% / 7% split)."""
        n = max(self.faulty_words, 1)
        return {
            "correctable": self.corrected / n,
            "detectable": self.detected / n,
            "silent": self.silent / n,
        }

    @classmethod
    def from_counters(cls, counters, words: int) -> "FaultStats":
        """Build stats from the fused kernel's device-reduced counter vector."""
        c = np.asarray(counters).reshape(-1)
        assert c.size >= len(COUNTER_FIELDS), c.shape
        return cls(words=int(words), **{
            f: int(c[i]) for i, f in enumerate(COUNTER_FIELDS)
        })

    def counters(self) -> np.ndarray:
        """Inverse of from_counters (testing / serialization)."""
        return np.array([getattr(self, f) for f in COUNTER_FIELDS], np.int64)

    @classmethod
    def from_decode(cls, status: np.ndarray, flip_counts: np.ndarray) -> "FaultStats":
        """Build stats from per-word ECC status codes + ground-truth flip counts."""
        status = np.asarray(status).reshape(-1)
        flips = np.asarray(flip_counts).reshape(-1)
        corrected_true = (status == ecc.STATUS_CORRECTED) & (flips == 1)
        detected = status == ecc.STATUS_DETECTED
        silent = (flips >= 2) & ~detected
        # A 1-flip word always syndromes to its column => corrected; a 0-flip
        # word always syndromes to 0 => clean. Anything else is silent risk.
        return cls(
            words=int(status.size),
            clean=int(((status == ecc.STATUS_CLEAN) & (flips == 0)).sum()),
            corrected=int(corrected_true.sum()),
            detected=int(detected.sum()),
            silent=int(silent.sum()),
            words_1bit=int((flips == 1).sum()),
            words_2bit=int((flips == 2).sum()),
            words_multi=int((flips >= 3).sum()),
            faulty_bits=int(flips.sum()),
        )
