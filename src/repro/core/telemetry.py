"""Fault telemetry: ECC outcomes vs. ground truth (paper Fig. 1/2 counters)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ecc

# Field order of the device-side counter vector produced by the fused
# inject_scrub kernel (kernels/inject_scrub.py). `words` is not reduced on
# device — the caller knows the store size.
COUNTER_FIELDS = (
    "clean", "corrected", "detected", "silent",
    "words_1bit", "words_2bit", "words_multi", "faulty_bits",
)


@dataclasses.dataclass
class FaultStats:
    """Aggregated per-read fault statistics for one memory domain.

    ``shard`` records which mesh shard (chip / replica) produced the
    counters: -1 means "unsharded or aggregated across shards". It is
    bookkeeping, not a counter — ``accumulate`` never adds it, and merging
    stats from different shards resets it to -1 so a cross-shard total can
    never masquerade as one shard's telemetry.
    """

    words: int = 0
    clean: int = 0  # syndrome 0, no ground-truth flips
    corrected: int = 0  # ECC corrected a genuine single-bit fault
    detected: int = 0  # ECC raised the uncorrectable (DED) flag
    silent: int = 0  # >=2 flips that ECC mis-corrected or aliased to clean
    # ground-truth fault classes (paper's correctable/detectable/undetectable)
    words_1bit: int = 0
    words_2bit: int = 0
    words_multi: int = 0
    faulty_bits: int = 0
    shard: int = -1  # mesh shard id; -1 = unsharded / cross-shard aggregate

    def accumulate(self, other: "FaultStats") -> None:
        """Add ``other``'s counters into ``self``, in place.

        Deliberately returns None: the old ``merge`` name looked like a pure
        combinator but mutated the receiver, so call sites could silently
        alias the accumulator. Use ``FaultStats.summed`` for a pure merge.
        """
        for f in ("words",) + COUNTER_FIELDS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        if self.shard != other.shard:
            self.shard = -1

    @classmethod
    def summed(cls, stats) -> "FaultStats":
        """Pure merge: a fresh FaultStats totalling an iterable of stats.

        Entries may be plain FaultStats or any container exposing ``total()``
        (DomainFaultStats, ShardFaultStats) — the cross-shard / cross-domain
        reduction helper the mesh telemetry path leans on. The result's
        ``shard`` is that of the inputs when they agree, -1 otherwise (an
        aggregate over shards is not one shard's row).
        """
        out = cls()
        first = True
        for s in stats:
            if not isinstance(s, FaultStats):
                s = s.total()
            if first:
                out.shard = s.shard
                first = False
            out.accumulate(s)
        return out

    @property
    def faulty_words(self) -> int:
        return self.words_1bit + self.words_2bit + self.words_multi

    def coverage(self) -> dict:
        """Fractions of faulty words by ECC outcome (paper's >90% / 7% split)."""
        n = max(self.faulty_words, 1)
        return {
            "correctable": self.corrected / n,
            "detectable": self.detected / n,
            "silent": self.silent / n,
        }

    def to_dict(self) -> dict:
        """Flat JSON-ready counter dict: ``words``, every COUNTER_FIELDS
        entry, the derived ``faulty_words``, plus ``shard`` when tagged —
        the one serialization the benchmark/campaign/obs rows share instead
        of each hand-rolling its own field subset."""
        out = {"words": self.words}
        out.update({f: getattr(self, f) for f in COUNTER_FIELDS})
        out["faulty_words"] = self.faulty_words
        if self.shard >= 0:
            out["shard"] = self.shard
        return out

    def coverage_row(self) -> dict:
        """The sweep/benchmark row shape: raw counters + the flattened
        per-outcome coverage fractions (``coverage_<outcome>``)."""
        cov = self.coverage()
        return {
            "words": self.words,
            "faulty_words": self.faulty_words,
            "faulty_bits": self.faulty_bits,
            "corrected": self.corrected,
            "detected": self.detected,
            "silent": self.silent,
            **{f"coverage_{k}": v for k, v in cov.items()},
        }

    @classmethod
    def from_counters(cls, counters, words: int, shard: int = -1) -> "FaultStats":
        """Build stats from the fused kernel's device-reduced counter vector."""
        c = np.asarray(counters).reshape(-1)
        assert c.size >= len(COUNTER_FIELDS), c.shape
        return cls(words=int(words), shard=int(shard), **{
            f: int(c[i]) for i, f in enumerate(COUNTER_FIELDS)
        })

    def counters(self) -> np.ndarray:
        """Inverse of from_counters (testing / serialization)."""
        return np.array([getattr(self, f) for f in COUNTER_FIELDS], np.int64)

    @classmethod
    def from_counter_matrix(
        cls, counters, names, words_by_domain, shard: int = -1
    ) -> "DomainFaultStats":
        """Build per-domain stats from the kernel's (n_domains, 8+) counter
        block (row order == ``names`` == the store's domain order)."""
        c = np.asarray(counters)
        assert c.shape[0] == len(names) and c.shape[1] >= len(COUNTER_FIELDS), c.shape
        return DomainFaultStats(
            {
                d: cls.from_counters(c[i], words=words_by_domain[d], shard=shard)
                for i, d in enumerate(names)
            },
            shard=int(shard),
        )

    @classmethod
    def from_decode(cls, status: np.ndarray, flip_counts: np.ndarray) -> "FaultStats":
        """Build stats from per-word ECC status codes + ground-truth flip counts."""
        status = np.asarray(status).reshape(-1)
        flips = np.asarray(flip_counts).reshape(-1)
        corrected_true = (status == ecc.STATUS_CORRECTED) & (flips == 1)
        detected = status == ecc.STATUS_DETECTED
        silent = (flips >= 2) & ~detected
        # A 1-flip word always syndromes to its column => corrected; a 0-flip
        # word always syndromes to 0 => clean. Anything else is silent risk.
        return cls(
            words=int(status.size),
            clean=int(((status == ecc.STATUS_CLEAN) & (flips == 0)).sum()),
            corrected=int(corrected_true.sum()),
            detected=int(detected.sum()),
            silent=int(silent.sum()),
            words_1bit=int((flips == 1).sum()),
            words_2bit=int((flips == 2).sum()),
            words_multi=int((flips >= 3).sum()),
            faulty_bits=int(flips.sum()),
        )


@dataclasses.dataclass
class DomainFaultStats:
    """Per-memory-domain fault statistics (multi-rail telemetry).

    Thin ordered mapping domain name -> FaultStats; iteration order is the
    store's domain order (== the kernel's counter row order). ``shard``
    tags which mesh shard the rows came from (-1: unsharded / aggregated).
    """

    by_domain: dict[str, FaultStats] = dataclasses.field(default_factory=dict)
    shard: int = -1

    def __getitem__(self, domain: str) -> FaultStats:
        return self.by_domain[domain]

    def __contains__(self, domain: str) -> bool:
        return domain in self.by_domain

    @property
    def domains(self) -> tuple[str, ...]:
        return tuple(self.by_domain)

    def get(self, domain: str) -> FaultStats:
        return self.by_domain.get(domain, FaultStats())

    def total(self) -> FaultStats:
        """Aggregate over domains (a fresh FaultStats; nothing is aliased)."""
        return FaultStats.summed(self.by_domain.values())

    def accumulate(self, other: "DomainFaultStats") -> None:
        for d, st in other.by_domain.items():
            self.by_domain.setdefault(d, FaultStats(shard=st.shard)).accumulate(st)
        if self.shard != other.shard:
            self.shard = -1

    @classmethod
    def summed(cls, stats) -> "DomainFaultStats":
        """Pure cross-shard reduction: sum an iterable of DomainFaultStats
        into one fresh per-domain view (domain rows keep their identity,
        shard tags collapse to -1 unless every input is the same shard)."""
        out = cls()
        first = True
        for s in stats:
            if first:
                out.shard = s.shard
                first = False
            out.accumulate(s)
        return out

    def coverage(self) -> dict:
        return {d: st.coverage() for d, st in self.by_domain.items()}


@dataclasses.dataclass
class ShardFaultStats:
    """Per-shard, per-domain fault statistics (mesh-sharded telemetry).

    One DomainFaultStats per mesh shard, in shard order — the host view of
    the (n_shards, n_domains, 8) counter block the shard_map'd inject+scrub
    step returns. ``reduced()`` is the explicit cross-shard reduction; the
    per-shard rows are never silently collapsed (a `per_shard` rail walk
    needs every shard's own DED canary row).
    """

    by_shard: list = dataclasses.field(default_factory=list)

    @property
    def n_shards(self) -> int:
        return len(self.by_shard)

    @property
    def domains(self) -> tuple[str, ...]:
        return self.by_shard[0].domains if self.by_shard else ()

    def __getitem__(self, shard: int) -> DomainFaultStats:
        return self.by_shard[shard]

    @classmethod
    def from_counter_blocks(
        cls, counters, names, words_by_shard
    ) -> "ShardFaultStats":
        """Build from the sharded kernel's (n_shards, n_domains, 8+) counter
        block; ``words_by_shard`` is one {domain: words} dict per shard."""
        c = np.asarray(counters)
        assert c.ndim == 3 and c.shape[0] == len(words_by_shard), c.shape
        return cls(
            [
                FaultStats.from_counter_matrix(c[s], names, words_by_shard[s], shard=s)
                for s in range(c.shape[0])
            ]
        )

    def reduced(self) -> DomainFaultStats:
        """Cross-shard reduction to one per-domain view (the psum picture:
        what a single-counter log would have recorded)."""
        return DomainFaultStats.summed(self.by_shard)

    def total(self) -> FaultStats:
        return self.reduced().total()

    def accumulate(self, other: "ShardFaultStats") -> None:
        for s, st in enumerate(other.by_shard):
            if s < len(self.by_shard):
                self.by_shard[s].accumulate(st)
            else:
                # Growth path: adopt ``other``'s row outright (a fresh deep
                # copy via the pure reduction). Seeding an empty row with
                # shard=row-index and merging would collapse the tag to -1
                # whenever other's shard ids are not index-aligned (e.g. a
                # sub-fleet slice carrying shards 4..7).
                self.by_shard.append(DomainFaultStats.summed([st]))

    @classmethod
    def summed(cls, stats) -> "ShardFaultStats":
        """Pure cross-run reduction: sum an iterable of ShardFaultStats
        into a fresh one, row-aligned by shard index (the accumulate
        symmetry partner — no input is mutated or aliased)."""
        out = cls()
        for s in stats:
            out.accumulate(s)
        return out
