"""Application-aware runtime undervolting controller (paper §III.A / §IV).

The paper's key enabler: because of FIP, *correctable* faults always manifest
before *detectable* faults, which manifest before *undetectable* faults. The
DED (detected-but-uncorrectable) flag of the built-in ECC is therefore a safe
canary: keep lowering the rail while reads are clean-or-corrected; on the
first DED event, back off one step and lock. Silent-risk events (which the
hardware cannot see — we track them in simulation as ground truth) are also
treated as trip events when `paranoid=True`.

Escalation (DESIGN.md §12): with a codec subsystem the controller has a
second degree of freedom. Instead of always retreating the rail on a DED
trip, an ``EscalationPolicy`` lets a rail *step up its ECC scheme* — e.g.
SECDED -> DEC-TED — and keep descending at the same voltage: the DED events
that tripped the canary are exactly the double-bit class the stronger code
corrects. The ladder is finite; once exhausted, the next trip retreats and
locks as before. The redundancy cost of the stronger code is folded into the
power model (voltage.multi_rail_bram_power with per-domain check bits).

Accuracy canary (DESIGN.md §15): DED counters measure detectable corruption,
not output quality — DNNs tolerate many faults the counters overweight
(arXiv:2001.00053), and detect-only codes under re-encoding fault models can
corrupt state without raising DED at all. Controllers therefore accept an
optional per-interval ``divergence`` score (canary-prompt output divergence
vs the clean nominal rollout, [0, 1]); when it exceeds the configured
``divergence_slo`` the rail trips exactly like a DED canary — escalate if a
ladder step remains, else back off and lock — even with zero DED events.
"""

from __future__ import annotations

import dataclasses

from repro.codes import DEFAULT_CODEC
from repro.core.telemetry import FaultStats
from repro.core.voltage import PlatformProfile


@dataclasses.dataclass(frozen=True)
class EscalationPolicy:
    """Codec ladder for a DED-canary rail (weakest -> strongest).

    ``ded_rate``: minimum DED events per scrubbed word required to escalate;
    a trip at or below the threshold retreats the rail instead (the event is
    rare enough that paying the stronger code's check bits is not worth it).
    The default 0.0 escalates on any DED event while ladder steps remain.

    Under prefix sharing (DESIGN.md §16) the rate a rail is judged on is
    *reader-weighted* (see :func:`reader_weighted_stats`): a DED on a page
    with N readers counts N times against the physically scrubbed word
    count, so shared-heavy traffic crosses ``ded_rate`` earlier than the
    same physical fault population on private pages — escalation prices the
    correlated blast radius, not just the raw event rate.
    """

    ladder: tuple = (DEFAULT_CODEC, "dected79")
    ded_rate: float = 0.0

    def next_codec(self, current: str) -> str | None:
        """The ladder entry above ``current`` (None at or past the top)."""
        if current not in self.ladder:
            return None
        i = self.ladder.index(current)
        return self.ladder[i + 1] if i + 1 < len(self.ladder) else None


def reader_weighted_stats(weighted: FaultStats, physical: FaultStats) -> FaultStats:
    """Fold reader-weighted counters over the physical word population.

    ``weighted`` carries per-reader attributed counters (a shared page's
    events once per reader); ``physical`` carries the deduplicated scrub
    truth (each page once — what arena.stats and the power accounting see).
    The returned stats are what a sharing-aware rail should be judged on:
    weighted event counts over *physical* words, so ``detected/words`` (the
    ``EscalationPolicy.ded_rate`` numerator) amplifies with page fan-out.
    With no sharing the two views coincide and this is the identity.
    """
    return FaultStats.from_counters(
        weighted.counters(), words=physical.words, shard=physical.shard
    )


@dataclasses.dataclass
class ControllerRecord:
    voltage: float
    corrected: int
    detected: int
    silent: int
    action: str
    codec: str = DEFAULT_CODEC
    shard: int = -1  # mesh shard whose canary was judged (-1: unsharded)
    divergence: float = 0.0  # canary-prompt divergence this interval


class UndervoltController:
    """DED-canary voltage search: V_nom -> first-DED, then back off + lock."""

    def __init__(
        self,
        platform: PlatformProfile,
        step_v: float = 0.01,
        backoff_steps: int = 1,
        paranoid: bool = False,
        start_v: float | None = None,
        escalation: EscalationPolicy | None = None,
        codec: str | None = None,
        shard: int = -1,
        adaptive: bool = False,
        divergence_slo: float | None = None,
        domain: str | None = None,
    ):
        self.platform = platform
        self.step_v = step_v
        self.backoff_steps = backoff_steps
        self.paranoid = paranoid
        self.adaptive = adaptive
        self.divergence_slo = divergence_slo
        self.shard = int(shard)
        self.domain = domain  # rail name when owned by a MultiRailController
        self.recorder = None  # optional obs.TraceRecorder (flight recorder)
        # Warm start: the guardband is fault-free by definition (paper §III),
        # so a search may legally begin anywhere in [v_min, v_nom].
        self.voltage = (
            platform.v_nom if start_v is None
            else min(platform.v_nom, max(float(start_v), platform.v_min))
        )
        self.locked = False
        self.history: list[ControllerRecord] = []
        self.escalation = escalation
        self.codec = codec or (
            escalation.ladder[0] if escalation else DEFAULT_CODEC
        )
        self._pending_codec: str | None = None

    def pop_codec_change(self) -> str | None:
        """Codec escalated since the last poll (None otherwise). The caller
        applies it to the protected storage (PlaneStore.set_domain_codec /
        KVPageArena.change_codec) before the next telemetry interval."""
        change, self._pending_codec = self._pending_codec, None
        return change

    def bind_recorder(self, recorder) -> None:
        """Attach a flight recorder (obs.TraceRecorder); every ``update``
        mirrors its ControllerRecord as a ``rail_step`` event (plus
        ``codec_escalate`` / ``canary_trip`` on those decisions)."""
        self.recorder = recorder

    def update(
        self, stats: FaultStats, divergence: float | None = None
    ) -> float:
        """Feed one read-interval's telemetry; returns the next rail voltage.

        ``divergence``: optional canary-prompt output-divergence score for
        this interval ([0, 1], 0 = bit-identical to the clean nominal run).
        Scores above ``divergence_slo`` trip the rail even when the DED
        counters are clean (accuracy canary, DESIGN.md §15).
        """
        acc_trip = (
            divergence is not None
            and self.divergence_slo is not None
            and divergence > self.divergence_slo
        )
        ded_trip = stats.detected > 0 or (self.paranoid and stats.silent > 0)
        trip = ded_trip or acc_trip
        stronger = (
            self.escalation.next_codec(self.codec) if self.escalation else None
        )
        ded_rate = stats.detected / max(stats.words, 1)
        codec_before = self.codec
        if self.locked:
            if self.adaptive and trip:
                # A locked rail is only safe while the flux that locked it
                # holds. Under environment/aging drift (DESIGN.md §14) the
                # DED canary can re-trip at the locked point — retreat
                # another backoff step (stay locked; the walk never resumes
                # downward on its own).
                self.voltage = min(
                    self.platform.v_nom,
                    self.voltage + self.backoff_steps * self.step_v,
                )
                action = "drift+backoff"
            else:
                action = "hold"
        elif trip and stronger is not None and (
            acc_trip
            or (stats.detected > 0 and ded_rate > self.escalation.ded_rate)
        ):
            # Step the *code* up instead of retreating the rail: the DED
            # class that tripped is what the stronger code corrects. Voltage
            # holds; the walk resumes under the new scheme next interval.
            # An SLO-violating divergence score escalates unconditionally —
            # the policy trades check-bit overhead against output quality.
            self.codec = stronger
            self._pending_codec = stronger
            action = "escalate"
        elif trip:
            self.voltage = min(
                self.platform.v_nom,
                self.voltage + self.backoff_steps * self.step_v,
            )
            self.locked = True
            action = "acc+backoff" if acc_trip and not ded_trip else "trip+backoff"
        else:
            nxt = self.voltage - self.step_v
            if nxt < self.platform.v_crash:
                # Never cross the crash rail; lock at the last operable point.
                self.locked = True
                action = "floor"
            else:
                self.voltage = nxt
                action = "lower"
        self.history.append(
            ControllerRecord(
                self.voltage, stats.corrected, stats.detected, stats.silent,
                action, self.codec, self.shard,
                0.0 if divergence is None else float(divergence),
            )
        )
        rec = self.recorder
        if rec:
            # The joinability contract (DESIGN.md §17): every rail decision
            # event carries the very counters that caused it, so a retreat
            # or escalation in the trace needs no side lookup to explain.
            div = 0.0 if divergence is None else float(divergence)
            rec.emit(
                "rail_step", domain=self.domain, shard=self.shard,
                action=action, voltage=float(self.voltage), codec=self.codec,
                corrected=int(stats.corrected), detected=int(stats.detected),
                silent=int(stats.silent), words=int(stats.words),
                divergence=div,
            )
            rec.metrics.counter(
                "rail.actions", domain=self.domain or "", action=action,
                shard=self.shard,
            ).inc()
            if action == "escalate":
                rec.emit(
                    "codec_escalate", domain=self.domain, shard=self.shard,
                    codec_from=codec_before, codec_to=self.codec,
                    ded_rate=ded_rate, acc_trip=bool(acc_trip),
                )
            if acc_trip:
                rec.emit(
                    "canary_trip", domain=self.domain, shard=self.shard,
                    divergence=div, slo=float(self.divergence_slo),
                )
        return self.voltage


class MultiRailController:
    """Per-domain closed-loop undervolting: one DED canary per memory domain.

    Each named domain owns an UndervoltController against its own
    PlatformProfile (per-block fault variation, arXiv:2005.04737 /
    arXiv:2110.05855) and walks its rail down independently: a DED event in
    the attention arena backs off and locks only the attention rail while the
    MLP rail keeps descending. The search converges when every rail is
    locked; the resulting schedule dominates the single-rail lock (which must
    stop at the *first* DED anywhere) in total power.
    """

    def __init__(
        self,
        platform: PlatformProfile,
        domains,
        step_v: float = 0.01,
        backoff_steps: int = 1,
        paranoid: bool = False,
        start_v: float | None = None,
        profiles: dict | None = None,
        escalation: EscalationPolicy | None = None,
        codecs: dict | None = None,
        shard: int = -1,
        adaptive: bool = False,
        divergence_slo: float | None = None,
    ):
        profiles = profiles or {}
        codecs = codecs or {}
        self.domains = tuple(domains)
        assert self.domains, "MultiRailController needs at least one domain"
        self._platform = platform
        self.shard = int(shard)
        self._defaults = dict(
            step_v=step_v,
            backoff_steps=backoff_steps,
            paranoid=paranoid,
            start_v=start_v,
            escalation=escalation,
            shard=shard,
            adaptive=adaptive,
            divergence_slo=divergence_slo,
        )
        self.recorder = None
        self.rails = {
            d: UndervoltController(
                profiles.get(d, platform), codec=codecs.get(d), domain=d,
                **self._defaults,
            )
            for d in self.domains
        }

    def bind_recorder(self, recorder) -> None:
        """Attach a flight recorder to every rail (late-bound rails added
        via ``add_rail`` inherit it)."""
        self.recorder = recorder
        for c in self.rails.values():
            c.bind_recorder(recorder)

    def add_rail(
        self,
        domain: str,
        profile: PlatformProfile | None = None,
        codec: str | None = None,
    ):
        """Attach a late-bound rail (e.g. `kv` once the paged cache exists).

        Idempotent; the new rail inherits the controller's step/backoff/
        paranoia/escalation defaults and starts its own DED-canary walk.
        Returns the rail's UndervoltController.
        """
        if domain not in self.rails:
            self.domains = self.domains + (domain,)
            self.rails[domain] = UndervoltController(
                profile or self._platform, codec=codec, domain=domain,
                **self._defaults,
            )
            if self.recorder is not None:
                self.rails[domain].bind_recorder(self.recorder)
        return self.rails[domain]

    @property
    def locked(self) -> bool:
        return all(c.locked for c in self.rails.values())

    @property
    def voltages(self) -> dict:
        return {d: c.voltage for d, c in self.rails.items()}

    @property
    def history(self) -> dict:
        return {d: c.history for d, c in self.rails.items()}

    @property
    def codecs(self) -> dict:
        return {d: c.codec for d, c in self.rails.items()}

    def pop_codec_changes(self) -> dict:
        """{domain: codec} escalated since the last poll. The caller applies
        them to the protected stores before the next telemetry interval."""
        out = {}
        for d, c in self.rails.items():
            change = c.pop_codec_change()
            if change:
                out[d] = change
        return out

    def update(self, stats, divergence=None) -> dict:
        """Feed one scrub interval's per-domain telemetry.

        ``stats``: DomainFaultStats or {domain: FaultStats}; domains without
        telemetry this interval hold (no blind descent). ``divergence``: a
        scalar canary score broadcast to every rail (the canary rollout
        exercises the whole model, so attribution to a single domain is
        unknowable — protect-accuracy semantics retreat them all), or a
        {domain: score} dict when the caller can attribute. Returns the next
        {domain: voltage} schedule.
        """
        by_domain = getattr(stats, "by_domain", stats)
        div_of = (
            divergence.get if isinstance(divergence, dict)
            else (lambda d, _v=divergence: _v)
        )
        for d, ctrl in self.rails.items():
            if d in by_domain:
                ctrl.update(by_domain[d], divergence=div_of(d))
        return self.voltages


RAIL_POLICIES = ("uniform", "per_shard")


class MeshRailController:
    """Rail control across a mesh of chips (DESIGN.md §13).

    Every reliability shard (data-parallel replica / chip) has its own fault
    population, so its own safe V_min. Two policies:

      * ``uniform`` — one MultiRailController fed the *psum-aggregated*
        per-domain telemetry: any shard's DED event appears in the aggregate
        counters, so the shared schedule locks at the worst shard's V_min
        (the whole fleet runs one voltage per domain — simple supply
        design, conservative power);
      * ``per_shard`` — one MultiRailController per shard, each fed only its
        own shard's counter rows: every chip walks to its own first-DED
        point, modeling the per-board V_min spread the MLP undervolting
        follow-up measures (maximum power saving, per-chip supplies).

    On a 1-shard mesh both policies collapse to exactly the single
    MultiRailController walk (the refactor's bit-identity anchor).
    """

    def __init__(
        self,
        platform: PlatformProfile,
        domains,
        n_shards: int,
        policy: str = "uniform",
        **defaults,
    ):
        assert policy in RAIL_POLICIES, (policy, RAIL_POLICIES)
        assert n_shards >= 1, n_shards
        self.policy = policy
        self.n_shards = int(n_shards)
        self.domains = tuple(domains)
        if policy == "uniform":
            self.shards = [MultiRailController(platform, domains, **defaults)]
        else:
            self.shards = [
                MultiRailController(platform, domains, shard=s, **defaults)
                for s in range(self.n_shards)
            ]

    def shard(self, s: int) -> MultiRailController:
        """The MultiRailController judging shard ``s`` (the shared one under
        the uniform policy)."""
        return self.shards[0] if self.policy == "uniform" else self.shards[s]

    def bind_recorder(self, recorder) -> None:
        """Attach a flight recorder to every shard's controller."""
        for c in self.shards:
            c.bind_recorder(recorder)

    def add_rail(self, domain: str, profile=None, codec=None) -> list:
        """Attach a late-bound rail (the `kv` cache) on every shard's
        controller; returns the per-shard rail list (length n_shards —
        the uniform policy's single rail is shared across entries)."""
        if domain not in self.domains:
            self.domains = self.domains + (domain,)
        return [self.shard(s).add_rail(domain, profile, codec) for s in range(self.n_shards)]

    @property
    def locked(self) -> bool:
        return all(c.locked for c in self.shards)

    def locked_for(self, domains) -> bool:
        return all(
            c.rails[d].locked for c in self.shards for d in domains
        )

    @property
    def voltages(self) -> list:
        """Per-shard {domain: voltage} schedule (length n_shards)."""
        return [dict(self.shard(s).voltages) for s in range(self.n_shards)]

    @property
    def history(self) -> dict:
        """{(shard, domain): [ControllerRecord]} across every rail walked."""
        out = {}
        for s in range(self.n_shards):
            ctrl = self.shard(s)
            for d, recs in ctrl.history.items():
                out[(s if self.policy == "per_shard" else -1, d)] = recs
            if self.policy == "uniform":
                break
        return out

    @property
    def codecs(self) -> dict:
        """{domain: codec} of the shared walk (uniform) / shard 0 — mesh
        stores carry one codec per domain (per-shard ladders are not
        supported; see ServingEngine)."""
        return dict(self.shards[0].codecs)

    def pop_codec_changes(self) -> dict:
        """Escalations since the last poll (uniform policy only — the store
        applies them globally)."""
        assert self.policy == "uniform", (
            "per-shard codec escalation needs per-shard plane groups"
        )
        return self.shards[0].pop_codec_changes()

    def update(self, stats, divergence=None) -> list:
        """Feed one interval's mesh telemetry; returns the next per-shard
        schedule.

        ``stats``: a ShardFaultStats (per-shard rows), a list of
        DomainFaultStats (one per shard), or — uniform policy only — a
        single DomainFaultStats already reduced across shards.
        ``divergence``: scalar canary score broadcast to every shard's
        controller (replica shards serve the same weights, so a quality
        violation anywhere is a fleet-wide retreat signal), or a length-
        n_shards list of per-shard scores under the per_shard policy.
        """
        by_shard = getattr(stats, "by_shard", stats)
        if self.policy == "uniform":
            if isinstance(divergence, (list, tuple)):
                divergence = max(
                    (d for d in divergence if d is not None), default=None
                )
            if hasattr(by_shard, "by_domain"):  # already reduced
                self.shards[0].update(by_shard, divergence=divergence)
            else:
                from repro.core.telemetry import DomainFaultStats

                self.shards[0].update(
                    DomainFaultStats.summed(by_shard), divergence=divergence
                )
        else:
            assert not hasattr(by_shard, "by_domain"), (
                "per_shard policy needs per-shard telemetry rows"
            )
            assert len(by_shard) == self.n_shards, (
                len(by_shard), self.n_shards,
            )
            if not isinstance(divergence, (list, tuple)):
                divergence = [divergence] * self.n_shards
            for s, st in enumerate(by_shard):
                self.shards[s].update(st, divergence=divergence[s])
        return self.voltages
