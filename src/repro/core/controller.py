"""Application-aware runtime undervolting controller (paper §III.A / §IV).

The paper's key enabler: because of FIP, *correctable* faults always manifest
before *detectable* faults, which manifest before *undetectable* faults. The
DED (detected-but-uncorrectable) flag of the built-in ECC is therefore a safe
canary: keep lowering the rail while reads are clean-or-corrected; on the
first DED event, back off one step and lock. Silent-risk events (which the
hardware cannot see — we track them in simulation as ground truth) are also
treated as trip events when `paranoid=True`.
"""

from __future__ import annotations

import dataclasses

from repro.core.telemetry import FaultStats
from repro.core.voltage import PlatformProfile


@dataclasses.dataclass
class ControllerRecord:
    voltage: float
    corrected: int
    detected: int
    silent: int
    action: str


class UndervoltController:
    """DED-canary voltage search: V_nom -> first-DED, then back off + lock."""

    def __init__(
        self,
        platform: PlatformProfile,
        step_v: float = 0.01,
        backoff_steps: int = 1,
        paranoid: bool = False,
    ):
        self.platform = platform
        self.step_v = step_v
        self.backoff_steps = backoff_steps
        self.paranoid = paranoid
        self.voltage = platform.v_nom
        self.locked = False
        self.history: list[ControllerRecord] = []

    def update(self, stats: FaultStats) -> float:
        """Feed one read-interval's telemetry; returns the next rail voltage."""
        trip = stats.detected > 0 or (self.paranoid and stats.silent > 0)
        if self.locked:
            action = "hold"
        elif trip:
            self.voltage = min(
                self.platform.v_nom,
                self.voltage + self.backoff_steps * self.step_v,
            )
            self.locked = True
            action = "trip+backoff"
        else:
            nxt = self.voltage - self.step_v
            if nxt < self.platform.v_crash:
                # Never cross the crash rail; lock at the last operable point.
                self.locked = True
                action = "floor"
            else:
                self.voltage = nxt
                action = "lower"
        self.history.append(
            ControllerRecord(
                self.voltage, stats.corrected, stats.detected, stats.silent, action
            )
        )
        return self.voltage
