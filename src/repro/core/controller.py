"""Application-aware runtime undervolting controller (paper §III.A / §IV).

The paper's key enabler: because of FIP, *correctable* faults always manifest
before *detectable* faults, which manifest before *undetectable* faults. The
DED (detected-but-uncorrectable) flag of the built-in ECC is therefore a safe
canary: keep lowering the rail while reads are clean-or-corrected; on the
first DED event, back off one step and lock. Silent-risk events (which the
hardware cannot see — we track them in simulation as ground truth) are also
treated as trip events when `paranoid=True`.
"""

from __future__ import annotations

import dataclasses

from repro.core.telemetry import FaultStats
from repro.core.voltage import PlatformProfile


@dataclasses.dataclass
class ControllerRecord:
    voltage: float
    corrected: int
    detected: int
    silent: int
    action: str


class UndervoltController:
    """DED-canary voltage search: V_nom -> first-DED, then back off + lock."""

    def __init__(
        self,
        platform: PlatformProfile,
        step_v: float = 0.01,
        backoff_steps: int = 1,
        paranoid: bool = False,
        start_v: float | None = None,
    ):
        self.platform = platform
        self.step_v = step_v
        self.backoff_steps = backoff_steps
        self.paranoid = paranoid
        # Warm start: the guardband is fault-free by definition (paper §III),
        # so a search may legally begin anywhere in [v_min, v_nom].
        self.voltage = (
            platform.v_nom if start_v is None
            else min(platform.v_nom, max(float(start_v), platform.v_min))
        )
        self.locked = False
        self.history: list[ControllerRecord] = []

    def update(self, stats: FaultStats) -> float:
        """Feed one read-interval's telemetry; returns the next rail voltage."""
        trip = stats.detected > 0 or (self.paranoid and stats.silent > 0)
        if self.locked:
            action = "hold"
        elif trip:
            self.voltage = min(
                self.platform.v_nom,
                self.voltage + self.backoff_steps * self.step_v,
            )
            self.locked = True
            action = "trip+backoff"
        else:
            nxt = self.voltage - self.step_v
            if nxt < self.platform.v_crash:
                # Never cross the crash rail; lock at the last operable point.
                self.locked = True
                action = "floor"
            else:
                self.voltage = nxt
                action = "lower"
        self.history.append(
            ControllerRecord(
                self.voltage, stats.corrected, stats.detected, stats.silent, action
            )
        )
        return self.voltage


class MultiRailController:
    """Per-domain closed-loop undervolting: one DED canary per memory domain.

    Each named domain owns an UndervoltController against its own
    PlatformProfile (per-block fault variation, arXiv:2005.04737 /
    arXiv:2110.05855) and walks its rail down independently: a DED event in
    the attention arena backs off and locks only the attention rail while the
    MLP rail keeps descending. The search converges when every rail is
    locked; the resulting schedule dominates the single-rail lock (which must
    stop at the *first* DED anywhere) in total power.
    """

    def __init__(
        self,
        platform: PlatformProfile,
        domains,
        step_v: float = 0.01,
        backoff_steps: int = 1,
        paranoid: bool = False,
        start_v: float | None = None,
        profiles: dict | None = None,
    ):
        profiles = profiles or {}
        self.domains = tuple(domains)
        assert self.domains, "MultiRailController needs at least one domain"
        self._platform = platform
        self._defaults = dict(
            step_v=step_v,
            backoff_steps=backoff_steps,
            paranoid=paranoid,
            start_v=start_v,
        )
        self.rails = {
            d: UndervoltController(profiles.get(d, platform), **self._defaults)
            for d in self.domains
        }

    def add_rail(self, domain: str, profile: PlatformProfile | None = None):
        """Attach a late-bound rail (e.g. `kv` once the paged cache exists).

        Idempotent; the new rail inherits the controller's step/backoff/
        paranoia defaults and starts its own DED-canary walk. Returns the
        rail's UndervoltController.
        """
        if domain not in self.rails:
            self.domains = self.domains + (domain,)
            self.rails[domain] = UndervoltController(
                profile or self._platform, **self._defaults
            )
        return self.rails[domain]

    @property
    def locked(self) -> bool:
        return all(c.locked for c in self.rails.values())

    @property
    def voltages(self) -> dict:
        return {d: c.voltage for d, c in self.rails.items()}

    @property
    def history(self) -> dict:
        return {d: c.history for d, c in self.rails.items()}

    def update(self, stats) -> dict:
        """Feed one scrub interval's per-domain telemetry.

        ``stats``: DomainFaultStats or {domain: FaultStats}; domains without
        telemetry this interval hold (no blind descent). Returns the next
        {domain: voltage} schedule.
        """
        by_domain = getattr(stats, "by_domain", stats)
        for d, ctrl in self.rails.items():
            if d in by_domain:
                ctrl.update(by_domain[d])
        return self.voltages
