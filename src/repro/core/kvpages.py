"""Paged, SECDED-protected KV-cache arena (DESIGN.md §11).

The weight arena (core/planestore.py) made the *static* model state live in
undervolted ECC memory; this module does the same for the *dynamic* state —
the KV cache — so the paper's power saving applies to serving, where the
cache dominates on-chip memory traffic. The `kv` voltage domain introduced
with the multi-rail work (configs/shapes.MEMORY_DOMAINS) is backed here with
real storage for the first time.

Layout
  * The arena is a flat word store of ``n_pages`` fixed-size pages (plus one
    scratch page masked writes land on). A page holds ``page_tokens`` tokens;
    one token's payload is every attention layer's K and V row for that
    position, bitcast f32 -> uint32 and packed two words per SECDED(72,64)
    codeword: lo/hi uint32 planes + a uint8 parity plane, exactly the word
    geometry of the weight path.
  * `PageAllocator` hands out pages with single-owner bookkeeping; the
    continuous-batching scheduler (serving/scheduler.py) allocates one page
    per ``page_tokens`` positions per request and frees them on retire or
    preemption.
  * Writes encode (kernels/ops.encode); reads gather page rows and travel
    through the scrub-on-read kernel (kernels/paged_gather.py) which
    corrects single-bit faults, writes the corrected planes back, and emits
    per-page (clean, corrected, detected) counters.
  * `tick()` injects one interval's undervolting faults at the current `kv`
    rail voltage. Unlike the weight store — which keeps clean planes and
    re-derives the faulty view per voltage — the cache is mutable, so faults
    are XORed *into* the stored planes and persist until a scrub corrects
    them or a write overwrites the cell; each interval draws a fresh mask
    (key folded with the interval counter), modelling fault accumulation on
    a live memory rather than a voltage re-materialisation.

At nominal voltage no mask is ever non-zero and encode->decode is the
identity on the bitcast payload, so the paged read path is bit-identical to
a dense cache (tested in tests/test_kvpaged.py).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import codes
from repro.core import scenario
from repro.core.faultsim import _device_chunk_masks_jit
from repro.core.telemetry import FaultStats
from repro.core.voltage import PlatformProfile
from repro.kernels import ops as kops
from repro.kernels import paged_gather
from repro.obs import profile as obs_profile

PAGE_TOKENS = 8  # default page size (tokens); 2^k keeps slot math cheap


def dedup_page_table(table, scratch_page: int):
    """Deduplicate a page-id table for a single scrub pass (DESIGN.md §16).

    Under prefix sharing the same physical page appears in several readers'
    tables; scrubbing it once per reader would double-charge its counters
    and waste the scrub bandwidth the sharing exists to save (see
    kernels/paged_gather.py on the duplicate-row contract). Returns
    ``(upad, rows, n_unique)``:

      * ``upad``    — the unique non-scratch page ids ascending, padded with
        ``scratch_page`` to the next power of two (bounds the jit retrace
        set exactly like the scheduler's lane tables); when ``table``
        contains scratch entries at least one scratch slot is guaranteed so
        they never alias a real page's row.
      * ``rows``    — int32 of ``table``'s shape mapping every entry to its
        row in ``upad`` (scratch entries map to a scratch slot).
      * ``n_unique``— count of real (non-scratch) pages: ``upad[:n_unique]``
        rows of the scrub counters are the physical-telemetry rows.
    """
    table = np.asarray(table, np.int32)
    flat = table.reshape(-1)
    real = flat[flat != scratch_page]
    uniq = np.unique(real)
    n_u = len(uniq)
    has_scratch = len(real) != len(flat)
    target = 1 << max(n_u + int(has_scratch) - 1, 0).bit_length()
    upad = np.concatenate(
        [uniq, np.full(max(target, 1) - n_u, scratch_page, np.int32)]
    ).astype(np.int32)
    rows = np.where(
        flat == scratch_page, n_u, np.searchsorted(uniq, flat)
    ).astype(np.int32)
    return upad, rows.reshape(table.shape), n_u


@dataclasses.dataclass(frozen=True)
class KVGeometry:
    """Word-level geometry of one model's paged KV cache."""

    attn_positions: tuple[int, ...]  # period positions with an attn mixer
    n_groups: int
    n_kv_heads: int
    head_dim: int
    page_tokens: int = PAGE_TOKENS

    @classmethod
    def from_config(cls, cfg, page_tokens: int = PAGE_TOKENS) -> "KVGeometry":
        attn = tuple(
            j for j in range(cfg.period) if cfg.layer_kind(j)["mixer"] == "attn"
        )
        assert attn, f"{cfg.name}: no attention layers to page"
        return cls(attn, cfg.n_groups, cfg.n_kv_heads, cfg.hd, int(page_tokens))

    @property
    def token_f32(self) -> int:
        """f32 values per token: K and V rows of every attention layer."""
        return 2 * len(self.attn_positions) * self.n_groups * self.n_kv_heads * self.head_dim

    @property
    def token_words(self) -> int:
        """64-bit SECDED codewords per token (two f32 per codeword)."""
        return self.token_f32 // 2

    @property
    def words_per_page(self) -> int:
        return self.page_tokens * self.token_words

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)


class PageAllocator:
    """Free-list page allocator with refcounted-owner bookkeeping.

    Owners are opaque hashables (request ids, or the prefix trie's sentinel).
    A page starts single-owner via ``alloc``; additional readers attach with
    ``share`` (prefix sharing, DESIGN.md §16) and each reader drops only its
    own reference with ``free`` — the page goes dirty only when the *last*
    reference drops, so no page is ever recycled out from under a reader.
    The double-alloc / foreign-free asserts are the invariants the
    hypothesis tests drive.

    Freed pages land on a *dirty* list, not the free list: they still hold
    the previous owner's words and re-enter circulation via ``recycle()``.
    Note that sitting on the *free* list is no guarantee of cleanliness
    either — ``KVPageArena.tick`` injects faults into every arena word,
    allocated or not — so the serving loop zero-wipes *newly allocated*
    pages (in one batched scatter, and only once the arena has ever
    faulted) before any commit touches them: stale words and latent DED
    events from a page's past are never attributed to its next owner.
    """

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free = list(range(self.n_pages - 1, -1, -1))  # pop() -> page 0 first
        self._dirty: list[int] = []
        self._owners: dict[int, set] = {}

    @property
    def free_pages(self) -> int:
        """Pages allocatable without preemption (clean + recyclable)."""
        return len(self._free) + len(self._dirty)

    @property
    def dirty_pages(self) -> int:
        return len(self._dirty)

    @property
    def used_pages(self) -> int:
        return self.n_pages - self.free_pages

    def owner_of(self, page: int):
        """Sole owner of a single-reader page; a frozenset for shared pages;
        None for unallocated pages."""
        owners = self._owners.get(page)
        if not owners:
            return None
        if len(owners) == 1:
            return next(iter(owners))
        return frozenset(owners)

    def refcount(self, page: int) -> int:
        return len(self._owners.get(page, ()))

    def is_shared(self, page: int) -> bool:
        return self.refcount(page) > 1

    def shared_pages(self) -> list[int]:
        """Live pages with more than one reader, ascending."""
        return sorted(p for p, o in self._owners.items() if len(o) > 1)

    def alloc(self, owner) -> int | None:
        """One *clean* page for ``owner``; None if the clean list is empty
        (the caller recycles the dirty list, evicts trie leaves, or
        preempts)."""
        if not self._free:
            return None
        page = self._free.pop()
        assert page not in self._owners, f"page {page} double-allocated"
        self._owners[page] = {owner}
        return page

    def share(self, page: int, owner) -> None:
        """Attach ``owner`` as an additional reader of a live page."""
        owners = self._owners.get(page)
        assert owners, f"page {page} shared while unallocated"
        assert owner not in owners, f"page {page} already referenced by {owner!r}"
        owners.add(owner)

    def free(self, pages, owner) -> None:
        """Drop ``owner``'s reference on each page; a page goes dirty only
        when its last reference drops (never freed with refcount > 0)."""
        for page in pages:
            owners = self._owners.get(page)
            assert owners is not None and owner in owners, (
                f"page {page} freed by {owner!r} but owned by "
                f"{self.owner_of(page)!r}"
            )
            owners.discard(owner)
            if owners:
                continue  # surviving readers keep the page live
            del self._owners[page]
            self._dirty.append(page)

    def recycle(self) -> list:
        """Move the dirty list to the free list; returns the batch (the
        serving loop's allocation-time wipe handles the zeroing)."""
        batch, self._dirty = self._dirty, []
        self._free.extend(batch)
        return batch


class _TrieNode:
    __slots__ = ("key", "page", "parent", "children", "stamp")

    def __init__(self, key, page, parent):
        self.key = key        # tuple of page_tokens token ids (None at root)
        self.page = page      # physical page id (None at root)
        self.parent = parent
        self.children: dict[tuple, "_TrieNode"] = {}
        self.stamp = 0        # LRU clock of the last lookup/insert touch


class PrefixTrie:
    """Radix tree over *full-page* token prefixes (DESIGN.md §16).

    Each edge is one page's worth of token ids (``page_tokens`` of them), so
    a node at depth d names a d·page_tokens-token prefix and carries the one
    physical page storing that chunk's KV rows. The trie itself holds a
    reference on every registered page (sentinel owner), so a prefix stays
    cached after its last reader retires; capacity pressure evicts
    sole-referenced leaves in LRU order before the scheduler resorts to
    preemption. Only *complete* pages are ever registered — a request's
    partial tail page is private by construction, which is what makes
    divergence copy-on-write: the shared prefix is immutable, every writer
    appends into pages it exclusively owns.
    """

    OWNER = "<prefix-trie>"

    def __init__(
        self,
        alloc: PageAllocator,
        page_tokens: int,
        recorder=None,
        shard: int = -1,
    ):
        self.alloc = alloc
        self.page_tokens = int(page_tokens)
        self._root = _TrieNode(None, None, None)
        self._by_page: dict[int, _TrieNode] = {}
        self._clock = 0
        # Optional flight recorder (obs.TraceRecorder): registrations and
        # evictions land as trie_insert / trie_evict events (DESIGN.md §17).
        self.recorder = recorder
        self.shard = int(shard)

    def __len__(self) -> int:
        return len(self._by_page)

    def _chunks(self, tokens) -> list[tuple]:
        pt = self.page_tokens
        toks = [int(t) for t in tokens]
        return [
            tuple(toks[i : i + pt]) for i in range(0, len(toks) - pt + 1, pt)
        ]

    def lookup(self, tokens) -> list[int]:
        """Pages of the longest cached full-page prefix of ``tokens``,
        capped at len(tokens)-1 so at least one suffix token is always left
        to prefill (the decode step needs a current token)."""
        if len(tokens) < 2:
            return []
        max_pages = (len(tokens) - 1) // self.page_tokens
        node, pages = self._root, []
        self._clock += 1
        for key in self._chunks(tokens)[:max_pages]:
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = self._clock
            pages.append(child.page)
            node = child
        return pages

    def insert(self, tokens, pages) -> None:
        """Register ``pages`` as the full-page chunks of ``tokens``.

        ``pages`` must cover exactly the leading len(pages) full-page chunks
        (the caller passes a request's committed prompt pages). Chunks
        already present are stamped; new chunks take a trie reference via
        ``alloc.share`` so the page outlives its writer.
        """
        chunks = self._chunks(tokens)
        assert len(pages) <= len(chunks), "pages beyond full-page prefix"
        node = self._root
        self._clock += 1
        fresh = 0
        for key, page in zip(chunks, pages):
            child = node.children.get(key)
            if child is None:
                self.alloc.share(page, self.OWNER)
                child = _TrieNode(key, int(page), node)
                node.children[key] = child
                self._by_page[child.page] = child
                fresh += 1
            child.stamp = self._clock
            node = child
        if fresh and self.recorder:
            self.recorder.emit("trie_insert", shard=self.shard, pages=fresh)

    def _drop(self, node: _TrieNode) -> None:
        del node.parent.children[node.key]
        del self._by_page[node.page]
        self.alloc.free([node.page], self.OWNER)

    def evict_lru(self, n: int = 1) -> list[int]:
        """Drop up to ``n`` sole-referenced leaves, least recently touched
        first. Returns the pages released to the dirty list (the caller
        recycles). Leaves still shared with running readers are skipped —
        eviction never invalidates a reader."""
        freed = []
        while len(freed) < n:
            victims = [
                nd for nd in self._by_page.values()
                if not nd.children and self.alloc.refcount(nd.page) == 1
            ]
            if not victims:
                break
            victim = min(victims, key=lambda nd: nd.stamp)
            freed.append(victim.page)
            self._drop(victim)
        if freed and self.recorder:
            self.recorder.emit(
                "trie_evict", shard=self.shard, pages=len(freed), reason="lru"
            )
        return freed

    def pages(self) -> list[int]:
        """Every page the trie currently holds a reference on (sorted)."""
        return sorted(self._by_page)

    def evict_pages(self, pages) -> list[int]:
        """Forcibly drop the trie's reference on ``pages`` and every
        descendant chunk (a child's prefix is unreachable without its
        parent). Used when codec escalation refuses to re-protect shared
        pages: the trie reference goes away, surviving readers keep the
        page live until preemption recomputes them. Returns the pages whose
        trie reference was dropped."""
        dropped = []
        for page in pages:
            node = self._by_page.get(int(page))
            if node is None:
                continue
            stack = [node]
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                if nd.page in self._by_page:
                    dropped.append(nd.page)
                    self._drop(nd)
        if dropped and self.recorder:
            self.recorder.emit(
                "trie_evict", shard=self.shard, pages=len(dropped),
                reason="forced",
            )
        return dropped

    def drain(self) -> list[int]:
        """Release every trie reference (serve teardown): afterwards the
        allocator's pages_free_at_end bookkeeping sees no cached prefixes."""
        pages = list(self._by_page)
        for page in pages:
            node = self._by_page.get(page)
            if node is not None and node.page in self._by_page:
                del node.parent.children[node.key]
                del self._by_page[node.page]
                self.alloc.free([node.page], self.OWNER)
        self._root.children.clear()
        return pages


class SharedPageDEDError(RuntimeError):
    """Raised when ``KVPageArena.change_codec`` finds a latched
    detected-uncorrectable word on a page with multiple readers: re-encoding
    would seal the corruption as apparently-clean data for every reader at
    once (the correlated-failure regime of DESIGN.md §14). Carries the
    offending pages so the scheduler can evict/preempt and recompute."""

    def __init__(self, pages, codec: str):
        self.pages = tuple(int(p) for p in pages)
        self.codec = str(codec)
        super().__init__(
            f"codec change to {self.codec!r} refused: latched DED on shared "
            f"pages {list(self.pages)}"
        )


# ---------------------------------------------------------------------------
# jit'd arena primitives (module-level so tracing is shared across arenas)
# ---------------------------------------------------------------------------
def _payload_to_planes(payload, codec: str = "secded72"):
    """(N, token_f32) f32 -> lo/hi (N, token_words) uint32 + check plane.

    Check bits come from the codec's ``encode_jnp`` — the same fold the
    Pallas encode kernel runs — called as plain jnp inside the already-jit'd
    commit: the per-token write path is XLA-fused with the extract/scatter
    around it instead of paying a kernel launch per decode step.
    Bit-identical to `kernels/ops.encode` (it is the same function).
    """
    c = codes.get(codec)
    u = jax.lax.bitcast_convert_type(payload.astype(jnp.float32), jnp.uint32)
    lo, hi = u[:, 0::2], u[:, 1::2]
    return lo, hi, c.encode_jnp(lo, hi).astype(jnp.dtype(c.check_dtype))


def _planes_to_payload(lo, hi):
    """Inverse of `_payload_to_planes` (parity is not part of the payload)."""
    u = jnp.stack([lo, hi], axis=-1).reshape(lo.shape[0], -1)
    return jax.lax.bitcast_convert_type(u, jnp.float32)


@jax.jit
def _scatter_rows(plane, idx, rows):
    return plane.at[idx].set(rows)


@jax.jit
def _xor_into(plane, mask):
    return plane ^ mask


def _row_index(page_ids, words_per_page):
    """(P,) page ids -> (P, words_per_page) flat word indices."""
    return page_ids[:, None] * words_per_page + jnp.arange(
        words_per_page, dtype=jnp.int32
    )


@functools.partial(
    jax.jit, static_argnames=("token_words", "words_per_page", "codec")
)
def _commit_tokens(
    lo, hi, par, payload, page_ids, slots, *, token_words, words_per_page,
    codec: str = "secded72",
):
    """Encode token payload rows and scatter them into the arena planes."""
    rlo, rhi, rpar = _payload_to_planes(payload, codec)
    base = page_ids * words_per_page + slots * token_words
    idx = base[:, None] + jnp.arange(token_words, dtype=jnp.int32)[None, :]
    return lo.at[idx].set(rlo), hi.at[idx].set(rhi), par.at[idx].set(rpar)


@functools.partial(
    jax.jit, static_argnames=("words_per_page", "codec", "interpret")
)
def _scrub_rows(lo, hi, par, page_ids, *, words_per_page, codec, interpret):
    """Gather page rows, scrub-on-read, write corrected planes back."""
    idx = _row_index(page_ids, words_per_page)
    olo, ohi, opar, cnt = paged_gather.gather_scrub_pages(
        lo[idx], hi[idx], par[idx], codec=codec, interpret=interpret
    )
    return lo.at[idx].set(olo), hi.at[idx].set(ohi), par.at[idx].set(opar), olo, ohi, cnt


class KVPageArena:
    """The paged KV store: flat SECDED planes + rail state + fault model.

    ``n_pages`` real pages plus one scratch row (index ``n_pages``) that
    masked/inactive writes are steered to; the scratch row is never read.
    """

    def __init__(
        self,
        geom: KVGeometry,
        profile: PlatformProfile,
        n_pages: int,
        seed: int = 0,
        ecc: bool = True,
        codec: str = "secded72",
        shard: int = 0,
        env=None,
    ):
        self.geom = geom
        # Environment scenario (DESIGN.md §14): the burst shape and the
        # aging-drift clock live here; the flux multiplier is expected to
        # arrive *in the profile* (scenario.EnvironmentProfile.scale_profile
        # — the engine's store-derived kv profile is already scaled), so a
        # store-fed arena never double-scales.
        self.env = scenario.resolve(env)
        burst = self.env.burst if self.env else None
        self._burst = burst if (burst is not None and burst.enabled) else None
        self.profile = profile
        self.n_pages = int(n_pages)
        self.ecc = bool(ecc)
        self.seed = int(seed)
        self.codec_name = str(codec)
        self.codec = codes.get(self.codec_name)
        # Mesh shard identity (DESIGN.md §13): replica ``shard``'s arena is
        # its own silicon, so its interval draws come from a shard-folded
        # key — the same fold the shard_map'd weight path applies via
        # lax.axis_index. Shard 0 keeps the historical stream bit-for-bit.
        self.shard = int(shard)
        w = geom.words_per_page
        self.n_words = self.n_pages * w  # real (non-scratch) words
        total = (self.n_pages + 1) * w
        self._total_words = total
        self.lo = jnp.zeros((total,), jnp.uint32)
        self.hi = jnp.zeros((total,), jnp.uint32)
        # all-zero data has all-zero check bits in every registered linear
        # code: the empty arena is clean
        self.parity = jnp.zeros((total,), jnp.dtype(self.codec.check_dtype))
        self.voltage = float(profile.v_nom)
        self._key = jax.random.PRNGKey(self.seed ^ 0xCACE)
        if self.shard:
            self._key = jax.random.fold_in(self._key, self.shard)
        self._interval = 0
        self.faulted = False  # True once any tick() injected a mask
        self.stats = FaultStats()  # cumulative scrub-on-read telemetry

    @property
    def scratch_page(self) -> int:
        return self.n_pages

    # -- rail ---------------------------------------------------------------
    def set_voltage(self, v: float) -> None:
        self.voltage = float(v)

    def change_codec(self, codec: str, shared_pages=None) -> None:
        """Re-protect the live arena under another registered code (the `kv`
        rail's escalation path): the check plane is re-encoded from the
        current page contents through the new encoder — exactly what a
        hardware re-protection sweep would write, so faults the *old* code
        had not yet corrected are re-sealed as (apparent) clean data. Call
        right after a scrub interval so correctable faults were flushed
        first; the scheduler does.

        ``shared_pages`` (page ids with more than one reader) are scrubbed
        under the *old* code immediately before the switch — a single-owner
        page re-sealing a latent fault hurts one request, but a shared page
        would silently re-protect another reader's corrupted data, so if the
        flush scrub leaves a latched DED on any shared page the change is
        refused with :class:`SharedPageDEDError` (arena untouched) and the
        scheduler must evict/preempt those readers first.
        """
        if codec == self.codec_name:
            return
        ids = np.asarray(
            [] if shared_pages is None else list(shared_pages), np.int32
        )
        if ids.size:
            _, cnt = self.scrub_pages(ids)
            self.stats.accumulate(
                FaultStats.from_counters(
                    cnt.sum(axis=0),
                    words=int(ids.size) * self.geom.words_per_page,
                    shard=self.shard,
                )
            )
            detected = cnt[:, 2]  # COUNTER_FIELDS index of "detected"
            if detected.any():
                raise SharedPageDEDError(ids[detected > 0].tolist(), codec)
        self.codec_name = str(codec)
        self.codec = codes.get(self.codec_name)
        self.parity = kops.encode(self.lo, self.hi, codec=self.codec_name)

    def tick(self) -> None:
        """Inject one interval's faults at the current rail voltage.

        Fresh draw per interval (key folded with the interval counter): a
        live memory keeps accumulating faults while undervolted, it does not
        re-materialise them per voltage like the read-only weight arena.
        Inside the guardband the rate is exactly 0 and this is a no-op.
        With an environment set, the interval counter doubles as the aging
        clock — this chip's rate drifts by its deterministic per-shard
        multiplier as the soak progresses — and the masks carry the
        environment's correlated burst shape.
        """
        self._interval += 1
        rate = self.profile.fault_rate(self.voltage)
        if rate <= 0.0:
            return
        rate *= scenario.aging_multiplier(
            self.shard, self._interval, self.env, self.seed
        )
        key = jax.random.fold_in(self._key, self._interval)
        self.faulted = True
        mlo, mhi, mpar = obs_profile.call(
            "kv.inject_masks",
            _device_chunk_masks_jit(),
            key, self._total_words, jnp.float32(rate),
            jnp.float32(self.profile.row_sigma), n_check=self.codec.n_check,
            burst=self._burst,
        )
        self.lo = _xor_into(self.lo, mlo)
        self.hi = _xor_into(self.hi, mhi)
        self.parity = _xor_into(self.parity, mpar)
        if not self.ecc:
            # No-ECC baseline: check bits track the faulty data, the read-
            # path decoder becomes a pass-through and faults flow into
            # attention.
            self.parity = kops.encode(self.lo, self.hi, codec=self.codec_name)

    # -- data path ----------------------------------------------------------
    def zero_pages(self, page_ids) -> None:
        """Clear freshly allocated pages (all-zero data + parity is a valid
        clean codeword). Without this, a page re-allocated to a new request
        would expose the previous owner's stale — possibly faulty — words to
        the new owner's scrub, polluting its DED accounting and the canary."""
        ids = jnp.asarray(page_ids, jnp.int32).reshape(-1)
        if ids.size == 0:
            return
        idx = _row_index(ids, self.geom.words_per_page)
        z32 = jnp.zeros(idx.shape, jnp.uint32)
        self.lo = _scatter_rows(self.lo, idx, z32)
        self.hi = _scatter_rows(self.hi, idx, z32)
        self.parity = _scatter_rows(
            self.parity, idx, jnp.zeros(idx.shape, self.parity.dtype)
        )

    def commit_tokens(self, payload, page_ids, slots) -> None:
        """Write one token per row: payload (N, token_f32) f32, page_ids and
        slots (N,) int32 (slot = position within the page). Rows steered to
        the scratch page are don't-cares (inactive lanes)."""
        self.lo, self.hi, self.parity = obs_profile.call(
            "kv.commit_tokens",
            _commit_tokens,
            self.lo,
            self.hi,
            self.parity,
            payload,
            jnp.asarray(page_ids, jnp.int32),
            jnp.asarray(slots, jnp.int32),
            token_words=self.geom.token_words,
            words_per_page=self.geom.words_per_page,
            codec=self.codec_name,
        )

    def scrub_pages_async(self, page_ids):
        """Asynchronously dispatched scrub-on-read of ``page_ids`` (any
        shape, flattened): commits the corrected planes (scrub write-back)
        and returns (payload (P, page_tokens, token_f32) f32 device array,
        counters (P, 8) int32 DEVICE array) with no host sync — the caller
        defers the counter harvest (``np.asarray``) past whatever decode
        work it wants the scrub to overlap (DESIGN.md §18)."""
        ids = jnp.asarray(page_ids, jnp.int32).reshape(-1)
        self.lo, self.hi, self.parity, olo, ohi, cnt = obs_profile.call(
            "kv.paged_gather_scrub",
            _scrub_rows,
            self.lo,
            self.hi,
            self.parity,
            ids,
            words_per_page=self.geom.words_per_page,
            codec=self.codec_name,
            interpret=kops.use_interpret(),
        )
        payload = _planes_to_payload(
            olo.reshape(-1, self.geom.token_words),
            ohi.reshape(-1, self.geom.token_words),
        ).reshape(ids.shape[0], self.geom.page_tokens, self.geom.token_f32)
        return payload, cnt

    def scrub_pages(self, page_ids):
        """Scrub-on-read of ``page_ids`` (any shape, flattened): returns
        (payload (P, page_tokens, token_f32) f32, counters (P, 8) np.int32)
        and commits the corrected planes (scrub write-back)."""
        payload, cnt = self.scrub_pages_async(page_ids)
        return payload, np.asarray(cnt)
