"""Int8 symmetric quantization + 64-bit word packing.

The paper's NN accelerator keeps fixed-point weights in BRAM; we keep int8
weights in the ECC memory domain: 8 int8 values form one 64-bit codeword
(two uint32 lanes), matching the Xilinx ECC word geometry exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize(x: jnp.ndarray, axis: int | None = None):
    """Symmetric int8 quantization. Returns (q_int8, scale_float32).

    ``axis`` selects a per-slice scale (e.g. per output channel); None means
    one scale for the whole tensor.
    """
    absmax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=tuple(i for i in range(x.ndim) if i != axis), keepdims=True
    )
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def pack_int8_to_words(q: jnp.ndarray):
    """Pack int8 values into 64-bit words: returns (lo, hi) uint32 of shape
    (ceil(q.size/8),). Pads with zeros to a multiple of 8."""
    flat = q.reshape(-1)
    pad = (-flat.size) % 8
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.int8)])
    words = jax.lax.bitcast_convert_type(flat.reshape(-1, 2, 4), jnp.uint32)  # (n, 2)
    return words[:, 0], words[:, 1]


def unpack_words_to_int8(lo: jnp.ndarray, hi: jnp.ndarray, size: int) -> jnp.ndarray:
    """Inverse of pack_int8_to_words; returns int8 (size,)."""
    words = jnp.stack([lo, hi], axis=-1)  # (n, 2)
    q = jax.lax.bitcast_convert_type(words, jnp.int8).reshape(-1)  # (n*8,)
    return q[:size]


# ---------------------------------------------------------------------------
# Raw-bit packing for arbitrary dtypes (float32/bf16/int32/...): the memory
# domain stores exact bits, dtype-agnostic.
# ---------------------------------------------------------------------------
def array_to_words_np(arr: np.ndarray):
    """Host-side: arbitrary array -> (lo, hi) uint32 word planes + byte count."""
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    nbytes = raw.size
    pad = (-nbytes) % 8
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    words = raw.view(np.uint32).reshape(-1, 2)
    return np.ascontiguousarray(words[:, 0]), np.ascontiguousarray(words[:, 1]), nbytes


def words_to_array(lo: jnp.ndarray, hi: jnp.ndarray, nbytes: int, shape, dtype):
    """JAX-side: word planes -> array of the original shape/dtype (bit-exact)."""
    itemsize = np.dtype(dtype).itemsize
    if itemsize == 8:
        # 64-bit dtypes need x64 mode for a jax bitcast; reconstruct host-side
        # (bit-exactness is what matters for the memory domain).
        raw = np.stack([np.asarray(lo), np.asarray(hi)], axis=-1)
        raw = raw.astype(np.uint32).view(np.uint8).reshape(-1)[:nbytes]
        # returned as numpy: jnp.asarray would silently downcast f64 -> f32
        return raw.view(dtype).reshape(shape)
    words = jnp.stack([lo, hi], axis=-1)  # (n, 2)
    raw = jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(-1)[:nbytes]
    if itemsize == 1:
        out = jax.lax.bitcast_convert_type(raw, dtype)
    else:
        out = jax.lax.bitcast_convert_type(raw.reshape(-1, itemsize), dtype)
    return out.reshape(shape)
