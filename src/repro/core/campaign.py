"""Accuracy-under-undervolt campaign: divergence scoring + harness (§15).

The paper's headline result — ~40% BRAM power saving below the guardband with
negligible NN accuracy loss thanks to built-in ECC — is measured everywhere
else in this repo by proxy (DED counters). This module measures the quantity
users actually care about: *output divergence* of a served LM between the
clean nominal run and the fault-injected undervolted run, per codec, per
voltage, per environment scenario (the accuracy-vs-voltage curve).

Scorers (all exactly zero for clean-vs-clean, monotone in injected damage in
expectation):

  * greedy-match prefix length — per prompt, how many greedy-decoded tokens
    match the clean rollout before the first mismatch; ``token_divergence``
    collapses a batch to ``1 - mean(match_len)/n`` in [0, 1].
  * logit KL — mean KL(clean ‖ faulty) in nats over teacher-forced,
    position-aligned logits (``models.lm.sequence_logits`` on the *same*
    token sequence through both parameter sets; comparing logits along each
    model's own rollout is ill-defined after the first mismatch).
  * perplexity delta — each parameter set's perplexity of the *clean*
    continuation; the faulty model's excess is the quality loss.

The harness (``run_campaign``) drives a single-rail inline ``ServingEngine``
per (environment, codec): decode the reference at nominal (the guardband is
fault-free by construction, so nominal == clean), then walk the campaign
voltage grid, re-injecting faults and re-scoring at each step. The eval set
is synthetic fixed-seed prompts — the model is randomly initialised, so the
campaign measures *output stability under faults*, not task accuracy; that
is exactly the paper's experiment (their BRAM test patterns are synthetic
too) transplanted to LM serving.

Scores are computed against the engine's own quantized clean output (the
int8 ECC planes), not the raw float params: quantization noise cancels, so
a nonzero score is injected-fault damage and nothing else.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import scenario, sweep
from repro.core import voltage as vmod

# Bump when any scorer's definition changes: BENCH_accuracy rows and
# fig3's aligned rows carry this so trajectories across commits are only
# compared within a scorer generation.
SCORER_VERSION = 1

# Canary prompt length (ServingEngine.canary_divergence); short enough that
# a canary round costs one prefill + a dozen decode steps.
CANARY_PROMPT_LEN = 8


# ---------------------------------------------------------------------------
# Scorers
# ---------------------------------------------------------------------------
def greedy_match_len(ref: np.ndarray, test: np.ndarray) -> np.ndarray:
    """Per-row matched-prefix length of two (B, T) token grids.

    Row i scores t iff ``ref[i, :t] == test[i, :t]`` and either t == T or
    ``ref[i, t] != test[i, t]`` — the number of greedy tokens survived
    before the first divergence.
    """
    ref = np.asarray(ref)
    test = np.asarray(test)
    assert ref.shape == test.shape and ref.ndim == 2, (ref.shape, test.shape)
    neq = ref != test
    return np.where(
        neq.any(axis=1), neq.argmax(axis=1), ref.shape[1]
    ).astype(np.int64)


def token_divergence(ref: np.ndarray, test: np.ndarray) -> float:
    """``1 - mean(matched prefix fraction)`` in [0, 1]; exactly 0.0 iff
    every row of ``test`` is bit-identical to ``ref``."""
    ref = np.asarray(ref)
    n = ref.shape[1]
    if n == 0:
        return 0.0
    match = greedy_match_len(ref, test)
    return float(1.0 - match.mean() / n)


def label_divergence(ref: np.ndarray, test: np.ndarray) -> float:
    """Fraction of predictions differing from the clean run's (classifier
    form of ``token_divergence``; fig3's MLP rows use it so the LM campaign
    and the paper's accelerator figure share one divergence definition).
    Exactly 0.0 iff every prediction matches."""
    ref = np.asarray(ref)
    test = np.asarray(test)
    assert ref.shape == test.shape, (ref.shape, test.shape)
    if ref.size == 0:
        return 0.0
    return float((ref != test).mean())


def _log_softmax(logits: np.ndarray) -> np.ndarray:
    x = np.asarray(logits, np.float64)
    x = x - x.max(axis=-1, keepdims=True)
    return x - np.log(np.exp(x).sum(axis=-1, keepdims=True))


def logit_kl(ref_logits: np.ndarray, test_logits: np.ndarray) -> float:
    """Mean KL(ref ‖ test) in nats over all (batch, position) cells.

    Inputs are position-aligned (..., V) logits from the teacher-forced
    paired eval (``lm.sequence_logits`` on the same token sequence).
    Identical logits score exactly 0.0.
    """
    ref_logits = np.asarray(ref_logits)
    assert ref_logits.shape == np.asarray(test_logits).shape
    logp = _log_softmax(ref_logits)
    logq = _log_softmax(test_logits)
    kl = (np.exp(logp) * (logp - logq)).sum(axis=-1)
    return float(kl.mean())


def token_nll(logits: np.ndarray, tokens: np.ndarray) -> float:
    """Mean negative log-likelihood (nats/token) of ``tokens`` (B, T) under
    position-aligned ``logits`` (B, T, V)."""
    logits = np.asarray(logits)
    tokens = np.asarray(tokens)
    assert logits.shape[:2] == tokens.shape, (logits.shape, tokens.shape)
    logp = _log_softmax(logits)
    gold = np.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
    return float(-gold.mean())


def perplexity(logits: np.ndarray, tokens: np.ndarray) -> float:
    return float(np.exp(token_nll(logits, tokens)))


@dataclasses.dataclass(frozen=True)
class DivergenceReport:
    """One (voltage, codec) point's divergence vs the clean nominal run."""

    n_prompts: int
    n_tokens: int
    match_len: float  # mean greedy matched-prefix length (tokens)
    match_frac: float  # match_len / n_tokens
    divergence: float  # 1 - match_frac (the curve's y-axis and the SLO unit)
    kl: float  # mean KL(clean || faulty), nats (teacher-forced)
    ppl_clean: float  # clean params' perplexity of the clean continuation
    ppl_faulty: float  # faulty params' perplexity of the same continuation
    ppl_delta: float  # ppl_faulty - ppl_clean (>= ~0; 0 when bit-identical)
    scorer_version: int = SCORER_VERSION


def score(
    ref_tokens: np.ndarray,
    test_tokens: np.ndarray,
    ref_logits: np.ndarray | None = None,
    test_logits: np.ndarray | None = None,
    eval_tokens: np.ndarray | None = None,
) -> DivergenceReport:
    """Bundle every scorer over one clean/faulty rollout pair.

    ``ref_tokens``/``test_tokens``: (B, T) greedy continuations from the
    clean and faulty engines. ``ref_logits``/``test_logits``: optional
    (B, S, V) teacher-forced logits over ``eval_tokens`` (B, S) — the clean
    continuation both parameter sets are forced through; omit all three to
    skip the KL/perplexity axes (they report 0.0).
    """
    ref_tokens = np.asarray(ref_tokens)
    n = ref_tokens.shape[1]
    match = greedy_match_len(ref_tokens, test_tokens)
    kl = ppl_c = ppl_f = 0.0
    if ref_logits is not None:
        assert test_logits is not None and eval_tokens is not None
        kl = logit_kl(ref_logits, test_logits)
        ppl_c = perplexity(ref_logits, eval_tokens)
        ppl_f = perplexity(test_logits, eval_tokens)
    return DivergenceReport(
        n_prompts=int(ref_tokens.shape[0]),
        n_tokens=int(n),
        match_len=float(match.mean()),
        match_frac=float(match.mean() / max(n, 1)),
        divergence=token_divergence(ref_tokens, test_tokens),
        kl=kl,
        ppl_clean=ppl_c,
        ppl_faulty=ppl_f,
        ppl_delta=ppl_f - ppl_c,
    )


# ---------------------------------------------------------------------------
# Eval set + model configs
# ---------------------------------------------------------------------------
def eval_prompts(
    vocab: int, n_prompts: int, prompt_len: int, seed: int = 0
) -> np.ndarray:
    """Fixed synthetic eval set: (n_prompts, prompt_len) int32 in [0, vocab).

    Deterministic in ``seed`` so the canary reference, the campaign rows,
    and a reproducing run all decode the same prompts.
    """
    rng = np.random.default_rng(seed)
    return rng.integers(
        0, vocab, size=(n_prompts, prompt_len), dtype=np.int64
    ).astype(np.int32)


def campaign_model(name: str):
    """Resolve a campaign model name to a ModelConfig.

    ``tiny`` is the CI-sized config (qwen2-7b's layer recipe at smoke
    dimensions); ``<arch>-smoke`` shrinks any registered arch; a bare arch
    name is the production-shaped config (nightly/offline scale).
    """
    from repro import configs

    if name == "tiny":
        return dataclasses.replace(
            configs.get_smoke_config("qwen2-7b"), name="tiny"
        )
    if name.endswith("-smoke"):
        return configs.get_smoke_config(name[: -len("-smoke")])
    return configs.get_config(name)


# ---------------------------------------------------------------------------
# Campaign harness
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One accuracy campaign: model x codecs x voltages x environments."""

    model: str = "tiny"
    platform: str = "vc707"
    codecs: tuple = ("parity65", "secded72", "ileave88")
    voltages: tuple | None = None  # None -> sweep.campaign_voltage_grid
    environments: tuple = (None,)  # scenario names / profiles / None
    n_prompts: int = 4
    prompt_len: int = 8
    n_tokens: int = 24
    seed: int = 0
    max_len: int = 64
    # words for the sweep-proxy columns joined onto each row (0 disables);
    # the proxy shows what the DED counters would have said at the same
    # grid point, which is the gap this campaign exists to close
    proxy_words: int = 1 << 16

    def voltage_grid(self) -> tuple:
        profile = vmod.PLATFORMS[self.platform]
        if self.voltages is not None:
            return tuple(float(v) for v in self.voltages)
        return sweep.campaign_voltage_grid(profile)


def run_campaign(spec: CampaignSpec, recorder=None) -> list[dict]:
    """Run the campaign; one row dict per (environment, codec, voltage).

    Per (environment, codec) an inline single-rail ServingEngine is built at
    nominal, the clean reference rollout + teacher-forced logits are cached,
    and each grid voltage re-injects faults (``set_voltage``) and re-scores.
    Rows join the DivergenceReport with the engine's scrub telemetry
    (``FaultStats.to_dict``), the vmapped sweep's counter proxy at the same
    point, and the modeled BRAM power saving — everything the
    accuracy-vs-voltage figure needs. An optional ``recorder``
    (obs.TraceRecorder) gets one ``campaign_point`` event per row, with the
    step clock advancing once per grid point.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import lm
    from repro.serving import (
        FaultModelConfig,
        ProtectionConfig,
        ReliabilityConfig,
        ServingEngine,
    )

    cfg = campaign_model(spec.model)
    profile = vmod.PLATFORMS[spec.platform]
    voltages = spec.voltage_grid()
    prompts = eval_prompts(
        cfg.vocab, spec.n_prompts, spec.prompt_len, seed=spec.seed
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(spec.seed))
    logits_fn = jax.jit(lambda p, t: lm.sequence_logits(p, t, cfg))

    rows: list[dict] = []
    for env in spec.environments:
        envp = scenario.resolve(env)
        env_name = envp.name if envp is not None else None
        for codec in spec.codecs:
            proxy: dict[float, dict] = {}
            if spec.proxy_words:
                grid = [(profile, float(v)) for v in voltages]
                for r in sweep.sweep_codec_schemes(
                    [codec], grid, spec.proxy_words, seed=spec.seed, env=envp
                ):
                    proxy[round(r["voltage"], 4)] = r
            rel = ReliabilityConfig(
                platform=spec.platform,
                mode="inline",
                protection=ProtectionConfig(codecs=codec),
                fault_model=FaultModelConfig(environment=envp),
                seed=spec.seed,
            )
            eng = ServingEngine(cfg, params, rel=rel, max_len=spec.max_len)
            # nominal: guardband voltages inject zero faults, so this rollout
            # IS the clean (quantized) reference every score is against
            ref_tokens = eng.generate(prompts, spec.n_tokens)
            eval_tokens = np.concatenate([prompts, ref_tokens], axis=1)
            full = jnp.asarray(eval_tokens)
            # teacher-forced logits predicting positions prompt_len..end
            sl = slice(spec.prompt_len - 1, -1)
            ref_logits = np.asarray(logits_fn(eng.params, full))[:, sl]
            cont = eval_tokens[:, spec.prompt_len :]
            for v in voltages:
                t0 = time.perf_counter()
                eng.set_voltage(float(v))
                test_tokens = eng.generate(prompts, spec.n_tokens)
                test_logits = np.asarray(logits_fn(eng.params, full))[:, sl]
                us = (time.perf_counter() - t0) * 1e6
                rep = score(
                    ref_tokens, test_tokens, ref_logits, test_logits, cont
                )
                st = eng._last_scrub
                row = {
                    "model": spec.model,
                    "arch": cfg.name,
                    "platform": profile.name,
                    "codec": codec,
                    "environment": env_name,
                    "voltage": float(v),
                    "nominal": float(v) >= profile.v_min,
                    **dataclasses.asdict(rep),
                    **st.to_dict(),
                    "bram_saving_vs_nominal": vmod.power_saving(
                        profile.v_nom, float(v), ecc=True
                    ),
                    "seed": spec.seed,
                    "us": us,
                }
                if recorder:
                    recorder.advance(1)
                    recorder.emit(
                        "campaign_point", voltage=float(v), codec=codec,
                        divergence=float(rep.divergence),
                    )
                pr = proxy.get(round(float(v), 4))
                if pr is not None:
                    row.update(
                        proxy_words=pr["words"],
                        proxy_faulty_words=pr["faulty_words"],
                        proxy_corrected=pr["corrected"],
                        proxy_detected=pr["detected"],
                        proxy_silent=pr["silent"],
                    )
                rows.append(row)
    return rows
