"""Environment scenario matrix: correlated bursts, FIT multipliers, aging drift.

The i.i.d. per-bitplane flip model (core/faultsim.py) is the regime where
every SEC-class code looks alike: doubles are rare and randomly placed, so
``ileave88``/``dected79`` cannot differentiate from plain SECDED and the
escalation ladder never trips. Real reduced-voltage SRAM faults are not
i.i.d. — MoRS (arXiv:2110.05855) measures spatially correlated multi-bit
upsets with row/column clustering, and the error-pattern distribution over
fault *events* is roughly

    single 85% | double-adjacent 12% | triple-adjacent 2% | random-double 1%

This module is the model layer for that robustness axis, three orthogonal
knobs bundled per named *environment*:

  * **BurstProfile** — the correlated multi-bit-upset shape. Each base
    i.i.d. faulty bit is a burst *anchor*: with probability
    ``double_adjacent`` it extends one bitplane down the codeword, with
    ``triple_adjacent`` two bitplanes, with ``random_double`` it drags one
    extra uniformly-placed bit of the same word along, and with
    ``word_adjacent`` it repeats at the same bitplane of the next word (the
    column-cluster axis). The class draw per anchor position is
    voltage-independent, so FIP survives: the anchor set at V' < V is a
    superset, its promotions are position-fixed, hence the expanded set is a
    superset too. Expansion is a pure array function (``expand_bursts``)
    with a single implementation over an ``xp`` namespace — ``numpy`` for
    the host oracle, ``jax.numpy`` for the device path — so host/device
    bit-identity on shared draws is testable directly.
  * **rate_multiplier** — FIT-style flux scaling of the undervolting fault
    curve (consumer 1x / avionics 300x / space 50000x, the standard
    soft-error flux ratios). Applied by scaling (rate_crash, rate_floor)
    together, which multiplies ``fault_rate(v)`` uniformly below V_min while
    leaving the guardband and the curve's slope k untouched.
  * **aging drift** — a deterministic per-shard lognormal rate multiplier
    ``exp(drift_sigma * z_s * age / drift_tau)`` with ``z_s`` a hash-derived
    standard normal per chip (the derive_domain_profiles pattern): chips
    diverge over a long soak, the mean chip slowly worsens
    (E[m] = exp(sigma^2 t^2 / 2)), and drift_sigma=0 collapses every
    multiplier to exactly 1.0 — the no-drift baseline bit-for-bit.

Everything here is pure configuration + pure functions: no RNG state, no
device allocation. The default (env None / BurstProfile()) path is skipped
entirely by the fault field, reproducing the historical i.i.d. stream
bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
import zlib

import numpy as np

from repro.core.voltage import PlatformProfile, _erfinv

__all__ = [
    "ENVIRONMENTS",
    "MBU_DISTRIBUTION",
    "BurstProfile",
    "EnvironmentProfile",
    "aging_multiplier",
    "expand_bursts",
    "resolve",
    "scenario_voltage",
    "shard_aging_z",
]


@dataclasses.dataclass(frozen=True)
class BurstProfile:
    """Correlated multi-bit-upset shape: per-anchor promotion probabilities.

    All-zero (the default) means pure i.i.d. — the fault fields skip the
    expansion entirely, so the historical stream is reproduced bit-for-bit.
    The three class probabilities are disjoint fractions of one uniform draw
    per anchor position and must sum to <= 1.
    """

    double_adjacent: float = 0.0  # anchor extends 1 bitplane down
    triple_adjacent: float = 0.0  # anchor extends 2 bitplanes down
    random_double: float = 0.0  # anchor drags one random extra bit of its word
    word_adjacent: float = 0.0  # anchor repeats at the next word, same bitplane

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            assert 0.0 <= v <= 1.0, (f.name, v)
        assert (
            self.double_adjacent + self.triple_adjacent + self.random_double
        ) <= 1.0 + 1e-9, "anchor class fractions must sum to <= 1"

    @property
    def enabled(self) -> bool:
        return (
            self.double_adjacent > 0.0
            or self.triple_adjacent > 0.0
            or self.random_double > 0.0
            or self.word_adjacent > 0.0
        )

    @property
    def needs_class_draw(self) -> bool:
        return (
            self.double_adjacent + self.triple_adjacent + self.random_double
        ) > 0.0

    def class_thresholds(self) -> tuple[float, float, float]:
        """Cumulative thresholds (triple, triple+double, +random_double) for
        the single uniform class draw per anchor position."""
        p3 = self.triple_adjacent
        p2 = p3 + self.double_adjacent
        prd = p2 + self.random_double
        return p3, p2, prd


def _shift_planes(a, k: int, xp):
    """Shift a (n_bitplanes, m) bool matrix ``k`` bitplanes down (toward
    higher plane index), truncating at the codeword edge — a burst anchored
    in the top check bitplane has nowhere to extend."""
    z = xp.zeros((k,) + a.shape[1:], dtype=bool)
    return xp.concatenate([z, a[:-k]], axis=0)


def _shift_words(a, k: int, xp):
    """Shift along the word axis (column clustering), truncating at the
    chunk edge — chunk geometry is part of the deterministic stream layout,
    exactly like the per-chunk PRNG fold."""
    z = xp.zeros(a.shape[:1] + (k,), dtype=bool)
    return xp.concatenate([z, a[:, :-k]], axis=1)


def expand_bursts(
    faulty, burst: BurstProfile, class_u=None, word_u=None, extra_bit=None, xp=np
):
    """Expand i.i.d. anchors into correlated bursts. Pure and xp-generic.

    ``faulty``: (n_bitplanes, m) bool anchor matrix (the base i.i.d. draw).
    ``class_u``/``word_u``: (n_bitplanes, m) uniforms in [0, 1);
    ``extra_bit``: (m,) int bitplane index for the random-double companion.
    Draws gated off by a zero probability may be None. Returns the expanded
    bool matrix (a superset of ``faulty``: expansion ORs, never XORs, so a
    promotion landing on an already-faulty cell stays faulty — monotone in
    the anchor set, which is what preserves FIP).

    One implementation serves both paths: ``xp=numpy`` is the host oracle,
    ``xp=jax.numpy`` the device fault field; on identical inputs the two are
    bit-identical (property-tested).
    """
    if not burst.enabled:
        return faulty
    p3, p2, prd = burst.class_thresholds()
    out = faulty
    if p2 > 0.0:
        ext1 = faulty & (class_u < p2)  # extends >= 1 plane (double or triple)
        out = out | _shift_planes(ext1, 1, xp)
        if p3 > 0.0:
            ext2 = faulty & (class_u < p3)  # extends 2 planes (triple)
            out = out | _shift_planes(ext2, 2, xp)
    if burst.random_double > 0.0:
        rd = faulty & (class_u >= p2) & (class_u < prd)
        sel = xp.any(rd, axis=0)  # word has a random-double anchor
        nb = faulty.shape[0]
        onehot = (xp.arange(nb)[:, None] == extra_bit[None, :]) & sel[None, :]
        out = out | onehot
    if burst.word_adjacent > 0.0:
        col = faulty & (word_u < burst.word_adjacent)
        out = out | _shift_words(col, 1, xp)
    return out


# ---------------------------------------------------------------------------
# Environments
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EnvironmentProfile:
    """One row of the scenario matrix: flux, burst shape, aging drift."""

    name: str
    rate_multiplier: float = 1.0  # FIT-style flux multiplier on the curve
    burst: BurstProfile = BurstProfile()
    drift_sigma: float = 0.0  # per-chip aging spread (lognormal sigma at t=tau)
    drift_tau: float = 100.0  # soak intervals to reach one drift_sigma

    def scale_profile(self, profile: PlatformProfile) -> PlatformProfile:
        """Env-scaled fault curve: multiply (rate_crash, rate_floor) by the
        flux multiplier. Scaling both keeps the slope k — the whole curve
        below V_min shifts by exactly ``rate_multiplier``; the guardband
        (rate 0 above V_min) and V_crash are silicon properties and stay."""
        if self.rate_multiplier == 1.0:
            return profile
        return dataclasses.replace(
            profile,
            name=f"{profile.name}@{self.name}",
            rate_crash=profile.rate_crash * self.rate_multiplier,
            rate_floor=profile.rate_floor * self.rate_multiplier,
        )


# The MoRS-style measured error-pattern distribution (SNIPPETS): 12% of fault
# events extend to the adjacent bit, 2% to two adjacent bits, 1% drag a
# random second bit — on top of the 85% singles.
MBU_DISTRIBUTION = BurstProfile(
    double_adjacent=0.12,
    triple_adjacent=0.02,
    random_double=0.01,
    word_adjacent=0.04,
)

# FIT-style flux multipliers: terrestrial consumer baseline, avionics flight
# altitude (~300x neutron flux), space orbit (~5e4x, heavy-ion dominated with
# larger multi-bit clusters and faster aging).
ENVIRONMENTS = {
    "consumer": EnvironmentProfile(
        "consumer", 1.0, MBU_DISTRIBUTION, drift_sigma=0.05, drift_tau=200.0
    ),
    "avionics": EnvironmentProfile(
        "avionics",
        300.0,
        dataclasses.replace(MBU_DISTRIBUTION, word_adjacent=0.08),
        drift_sigma=0.10,
        drift_tau=150.0,
    ),
    "space": EnvironmentProfile(
        "space",
        50000.0,
        BurstProfile(
            double_adjacent=0.16,
            triple_adjacent=0.04,
            random_double=0.02,
            word_adjacent=0.12,
        ),
        drift_sigma=0.20,
        drift_tau=100.0,
    ),
}


def resolve(env, drift: float | None = None) -> EnvironmentProfile | None:
    """None / name / EnvironmentProfile -> EnvironmentProfile (or None).

    ``drift`` overrides the environment's ``drift_sigma`` when given; a bare
    ``drift`` with ``env=None`` yields a neutral environment (multiplier 1,
    i.i.d. bursts) carrying only the drift — the isolation knob the
    divergence tests use.
    """
    if env is None:
        if drift is None:
            return None
        return EnvironmentProfile("neutral", drift_sigma=float(drift))
    if isinstance(env, str):
        assert env in ENVIRONMENTS, (env, sorted(ENVIRONMENTS))
        env = ENVIRONMENTS[env]
    if drift is not None:
        env = dataclasses.replace(env, drift_sigma=float(drift))
    return env


# ---------------------------------------------------------------------------
# Per-shard aging drift
# ---------------------------------------------------------------------------
def shard_aging_z(shard: int, seed: int = 0) -> float:
    """Deterministic standard-normal aging slope for one chip — the
    derive_domain_profiles hash pattern, keyed by (seed, shard) so the slope
    is a property of the silicon sample, not of when it is asked."""
    h = zlib.crc32(f"aging:{seed}:{shard}".encode()) / 0xFFFFFFFF
    h = min(max(h, 1e-9), 1.0 - 1e-9)
    return math.sqrt(2.0) * _erfinv(2.0 * h - 1.0)


def aging_multiplier(
    shard: int, age: float, env: EnvironmentProfile | None, seed: int = 0
) -> float:
    """Fault-rate multiplier of chip ``shard`` after ``age`` soak intervals.

    ``exp(drift_sigma * z_shard * age / drift_tau)``: chips fan out
    lognormally as the soak progresses. Exactly 1.0 when env is None,
    drift_sigma == 0, or age <= 0 — the drift=0 collapse the divergence
    tests pin.
    """
    if env is None or env.drift_sigma <= 0.0 or age <= 0.0:
        return 1.0
    t = float(age) / float(env.drift_tau)
    return math.exp(env.drift_sigma * shard_aging_z(shard, seed) * t)


def scenario_voltage(
    profile: PlatformProfile,
    env: EnvironmentProfile | None,
    target_rate: float = 1e-4,
) -> float:
    """The voltage where the env-scaled fault rate reaches ``target_rate``.

    Environments shift the whole curve by their flux multiplier, so a fixed
    voltage compares codecs at wildly different fault densities (space is
    P_MAX-saturated at VC707's deepest step). This picks the operating point
    with comparable density per environment — bisection on the env-scaled
    ``fault_rate`` (monotone below V_min), clamped into (V_crash, V_min).
    """
    mult = env.rate_multiplier if env is not None else 1.0
    lo, hi = profile.v_crash, profile.v_min - 1e-4
    if mult * profile.fault_rate(lo) <= target_rate:
        return round(lo, 4)
    if mult * profile.fault_rate(hi) >= target_rate:
        return round(hi, 4)
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if mult * profile.fault_rate(mid) > target_rate:
            lo = mid  # too deep: rate too high -> move up
        else:
            hi = mid
    return round(0.5 * (lo + hi), 4)
