"""Pure-JAX Hsiao(72,64) SECDED encode / decode.

Codeword layout (TPU-friendly — no 72-bit scalar type exists):
  data  : (..., 2) uint32   -- [lo, hi] little-endian 64-bit word
  parity: (...,)   uint8    -- 8 check bits, stored in a parallel plane

These functions are the *oracle* implementations; `repro.kernels.secded_*`
provides the Pallas TPU kernels that must match them bit-exactly.

Status codes (see also `repro.core.telemetry`):
  0 = CLEAN      syndrome zero
  1 = CORRECTED  single-bit (data or parity) error corrected
  2 = DETECTED   uncorrectable error flagged (double-bit class)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hsiao

STATUS_CLEAN = 0
STATUS_CORRECTED = 1
STATUS_DETECTED = 2

_MASK_LO = jnp.asarray(hsiao.MASK_LO)  # (8,) uint32
_MASK_HI = jnp.asarray(hsiao.MASK_HI)  # (8,) uint32
_LUT = jnp.asarray(hsiao.SYNDROME_LUT)  # (256,) int32


def parity32(v: jnp.ndarray) -> jnp.ndarray:
    """Bitwise parity of each uint32 lane (XOR-fold), returns uint32 in {0,1}."""
    v = v ^ (v >> 16)
    v = v ^ (v >> 8)
    v = v ^ (v >> 4)
    v = v ^ (v >> 2)
    v = v ^ (v >> 1)
    return v & jnp.uint32(1)


def encode(lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Compute the 8 parity bits for 64-bit words given as two uint32 lanes.

    lo, hi: (...,) uint32.  Returns parity (...,) uint8.
    """
    lo = lo[..., None]  # (..., 1) broadcast against (8,) masks
    hi = hi[..., None]
    bits = parity32(lo & _MASK_LO) ^ parity32(hi & _MASK_HI)  # (..., 8)
    weights = jnp.asarray([1 << r for r in range(8)], dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def syndrome(lo: jnp.ndarray, hi: jnp.ndarray, parity: jnp.ndarray) -> jnp.ndarray:
    """Syndrome = recomputed parity XOR stored parity. (...,) uint8."""
    return encode(lo, hi) ^ parity


def decode(lo: jnp.ndarray, hi: jnp.ndarray, parity: jnp.ndarray):
    """SECDED decode.

    Returns (lo', hi', status) where status is int32 in {0,1,2} per word.
    Single-bit data errors are corrected in (lo', hi'); parity-bit errors are
    treated as corrected (data passes through untouched).
    """
    s = syndrome(lo, hi, parity).astype(jnp.int32)
    action = jnp.take(_LUT, s)  # -1 clean, -2 detect, 0..63 data bit, 64..71 parity bit

    is_clean = action == hsiao.LUT_CLEAN
    is_detect = action == hsiao.LUT_DETECT
    is_databit = (action >= 0) & (action < 64)

    bitidx = jnp.clip(action, 0, 63).astype(jnp.uint32)
    flip_lo = jnp.where(
        is_databit & (bitidx < 32), jnp.uint32(1) << (bitidx & 31), jnp.uint32(0)
    )
    flip_hi = jnp.where(
        is_databit & (bitidx >= 32), jnp.uint32(1) << (bitidx & 31), jnp.uint32(0)
    )
    status = jnp.where(
        is_clean,
        jnp.int32(STATUS_CLEAN),
        jnp.where(is_detect, jnp.int32(STATUS_DETECTED), jnp.int32(STATUS_CORRECTED)),
    )
    return lo ^ flip_lo, hi ^ flip_hi, status


# ---------------------------------------------------------------------------
# Host-side (numpy) reference used by tests for exhaustive bit-level checks.
# ---------------------------------------------------------------------------
def encode_np(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    def par32(v):
        v = v ^ (v >> 16)
        v = v ^ (v >> 8)
        v = v ^ (v >> 4)
        v = v ^ (v >> 2)
        v = v ^ (v >> 1)
        return v & np.uint32(1)

    lo = np.asarray(lo, np.uint32)[..., None]
    hi = np.asarray(hi, np.uint32)[..., None]
    bits = par32(lo & hsiao.MASK_LO) ^ par32(hi & hsiao.MASK_HI)
    return (bits << np.arange(8, dtype=np.uint32)).sum(-1).astype(np.uint8)
