"""Deterministic per-bitcell failure-threshold field (undervolting fault model).

Model (DESIGN.md §8): every bitcell *i* of a word-plane memory has a latent
uniform draw ``u_i`` (counter-based PRNG keyed by (seed, word-chunk, bitplane))
and every word (the paper's BRAM *row*) has a lognormal weakness factor
``f_w`` (E[f]=1). At rail voltage V the cell is faulty iff

    u_i < clip(rate(V) * f_w, 0, P_MAX)

Because ``rate(V)`` is monotone-decreasing in V and ``u_i`` is fixed, the
faulty set at V' < V is a superset of the faulty set at V — the paper's Fault
Inclusion Property (FIP) holds *by construction* and is property-tested.

The lognormal row weakness reproduces the paper's observed fault clustering:
uniform sparsity alone would make only ~2% of faulty words double-bit at
V_crash, whereas the paper measures ~7% detectable (double-bit) faults; with
row_sigma≈1.1 the model lands in the measured band (see tests/test_faultsim.py).

Fault semantics are read-time bit flips (XOR), so the observed-fault-rate
calibration against the paper's counters is exact.

Correlated bursts (DESIGN.md §14): an optional ``BurstProfile``
(core/scenario.py) promotes base i.i.d. faulty bits into multi-bit upsets —
adjacent-bitplane extension, random same-word companions, adjacent-word
column clusters — from *separate* voltage-independent draws, so FIP still
holds and the burst stream stays counter-based and replayable. The default
(no burst profile) skips the expansion entirely: the historical i.i.d.
stream is reproduced bit-for-bit at every level that consumes these masks.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.scenario import BurstProfile, expand_bursts
from repro.core.voltage import PlatformProfile

P_MAX = 0.5  # per-bit fault probability ceiling (clip for extreme weak rows)
N_DATA_BITS = 64
N_CHECK_DEFAULT = 8  # SECDED(72,64); other codecs pass their own n_check
N_BITPLANES = N_DATA_BITS + N_CHECK_DEFAULT  # historical 72-bitplane default


def _check_dtype(n_check: int):
    return np.uint8 if n_check <= 8 else np.uint32


@dataclasses.dataclass(frozen=True)
class FlipMasks:
    """Read-time XOR masks for a (n_words,) memory at one voltage."""

    lo: np.ndarray  # (n,) uint32 — flips in data bits 0..31
    hi: np.ndarray  # (n,) uint32 — flips in data bits 32..63
    parity: np.ndarray  # (n,) uint8/uint32 — flips in the codec's check bits

    @property
    def n_words(self) -> int:
        return self.lo.shape[0]

    def flip_counts(self) -> np.ndarray:
        """Ground-truth number of flipped bits per 72-bit codeword."""
        cnt = _popcount32(self.lo) + _popcount32(self.hi)
        return (cnt + _popcount32(self.parity.astype(np.uint32))).astype(np.int32)

    def total_flips(self) -> int:
        return int(self.flip_counts().sum())


def _popcount32(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.uint32).copy()
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return ((v * np.uint32(0x01010101)) >> 24).astype(np.int64)


class FaultField:
    """Failure-threshold field over ``n_words`` 72-bit codewords.

    Deterministic in (platform, seed): repeated calls, any voltage order, and
    any chunking produce identical masks. Generation is chunked so peak host
    memory stays ~``72 * chunk_words * 4`` bytes.
    """

    def __init__(
        self,
        platform: PlatformProfile,
        n_words: int,
        seed: int = 0,
        chunk_words: int = 1 << 18,
        n_check: int = N_CHECK_DEFAULT,
        burst: BurstProfile | None = None,
    ):
        self.platform = platform
        self.n_words = int(n_words)
        self.seed = int(seed)
        self.chunk_words = int(chunk_words)
        # Codeword geometry: 64 data bits + the codec's check bits. The
        # default (8, SECDED) reproduces the historical 72-bitplane stream
        # bit-for-bit; other widths draw their own (64 + n_check, m) field.
        self.n_check = int(n_check)
        # Correlated multi-bit-upset shape (DESIGN.md §14); None or a
        # disabled profile leaves the draw sequence untouched.
        self.burst = burst if (burst is not None and burst.enabled) else None

    # -- internals ----------------------------------------------------------
    def _chunk_rng(self, chunk_idx: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=(self.seed ^ (0xECC << 32), chunk_idx))
        )

    def _chunk_row_factor(self, rng: np.random.Generator, m: int) -> np.ndarray:
        sigma = self.platform.row_sigma
        z = rng.standard_normal(m, dtype=np.float32)
        return np.exp(sigma * z - 0.5 * sigma * sigma)

    def _chunk_masks(self, chunk_idx: int, m: int, rate: float):
        rng = self._chunk_rng(chunk_idx)
        f_row = self._chunk_row_factor(rng, m)
        # NOTE: u is drawn *after* f_row from the same counter stream; both are
        # voltage-independent, so FIP is preserved.
        u = rng.random((N_DATA_BITS + self.n_check, m), dtype=np.float32)
        p_word = np.clip(rate * f_row, 0.0, P_MAX)[None, :]  # (1, m)
        bits = u < p_word  # (64 + n_check, m) bool
        if self.burst is not None:
            # Burst expansion draws come *after* the base draw from the same
            # counter stream and are voltage-independent (anchor classes are
            # properties of positions, not of which anchors fired), so both
            # FIP and replayability survive; with no burst profile none of
            # these draws happen and the stream is the historical one.
            nb = N_DATA_BITS + self.n_check
            cu = (
                rng.random((nb, m), dtype=np.float32)
                if self.burst.needs_class_draw
                else None
            )
            wu = (
                rng.random((nb, m), dtype=np.float32)
                if self.burst.word_adjacent > 0.0
                else None
            )
            eb = rng.integers(0, nb, m) if self.burst.random_double > 0.0 else None
            bits = expand_bursts(bits, self.burst, cu, wu, eb, xp=np)
        pdt = _check_dtype(self.n_check)
        lo = np.zeros(m, np.uint32)
        hi = np.zeros(m, np.uint32)
        par = np.zeros(m, pdt)
        for b in range(32):
            lo |= bits[b].astype(np.uint32) << np.uint32(b)
        for b in range(32):
            hi |= bits[32 + b].astype(np.uint32) << np.uint32(b)
        for b in range(self.n_check):
            par |= bits[64 + b].astype(pdt) << pdt(b)
        return lo, hi, par

    # -- public -------------------------------------------------------------
    def masks(self, v: float) -> FlipMasks:
        """XOR flip masks for the whole memory at rail voltage ``v``."""
        rate = self.platform.fault_rate(v)
        los, his, pars = [], [], []
        for ci, start in enumerate(range(0, self.n_words, self.chunk_words)):
            m = min(self.chunk_words, self.n_words - start)
            lo, hi, par = self._chunk_masks(ci, m, rate)
            los.append(lo)
            his.append(hi)
            pars.append(par)
        if not los:  # zero-sized memory
            z32 = np.zeros(0, np.uint32)
            return FlipMasks(z32, z32, np.zeros(0, _check_dtype(self.n_check)))
        return FlipMasks(np.concatenate(los), np.concatenate(his), np.concatenate(pars))

    def device_field(self) -> "DeviceFaultField":
        """Device-resident counterpart over the same geometry (fresh stream)."""
        return DeviceFaultField(
            self.platform, self.n_words, seed=self.seed, n_check=self.n_check,
            burst=self.burst,
        )

    def sweep_histogram(self, voltages) -> list[dict]:
        """Per-voltage fault statistics (paper Fig. 1 / Fig. 2b machinery)."""
        out = []
        for v in voltages:
            mk = self.masks(v)
            counts = mk.flip_counts()
            out.append(
                {
                    "voltage": float(v),
                    "faulty_bits": int(counts.sum()),
                    "faults_per_mbit": counts.sum() / (self.n_words * 72 / (1024 * 1024)),
                    "words_1bit": int((counts == 1).sum()),
                    "words_2bit": int((counts == 2).sum()),
                    "words_multi": int((counts >= 3).sum()),
                }
            )
        return out


# ---------------------------------------------------------------------------
# Device-resident fault field (DESIGN.md §8/§9)
# ---------------------------------------------------------------------------
def _device_chunk_masks(
    key, m: int, rate, row_sigma, n_check: int = N_CHECK_DEFAULT,
    burst: BurstProfile | None = None,
):
    """jax implementation of the failure-threshold draw for one ``m``-word chunk.

    Same statistical model as FaultField._chunk_masks (lognormal row weakness
    x per-bit Bernoulli with clipped probability) but a different PRNG stream:
    counter-based threefry on device, so a voltage sweep never materialises a
    mask in host memory. Bernoulli draws compare raw uint32 random bits to
    ``floor(p * 2^32)`` — exact to within float32 threshold rounding. FIP
    holds by construction: the random bits depend only on (key, m), voltage
    enters through the threshold alone. ``n_check`` sets the codeword's
    check-bitplane count (default 8 keeps the historical SECDED stream);
    the per-word weakness draw is shared across widths, so scheme sweeps
    compare codecs on the same weak cells.

    ``burst`` (static) expands the i.i.d. anchors into correlated multi-bit
    upsets (core/scenario.expand_bursts). Its auxiliary draws come from
    constant-folded side keys — the base (krow, kbits) split is untouched —
    and depend only on (key, m), never on voltage, so FIP and the vmapped
    sweeps' batch hoisting both survive; ``burst=None`` (or a disabled
    profile) takes the historical code path exactly.
    """
    import jax
    import jax.numpy as jnp

    krow, kbits = jax.random.split(key)
    z = jax.random.normal(krow, (m,), jnp.float32)
    f_row = jnp.exp(row_sigma * z - 0.5 * row_sigma * row_sigma)
    p_word = jnp.clip(rate * f_row, 0.0, P_MAX)
    thresh = (p_word * 4294967296.0).astype(jnp.uint32)  # (m,)
    bits = jax.random.bits(kbits, (N_DATA_BITS + n_check, m), jnp.uint32)
    faulty = bits < thresh[None, :]  # (64 + n_check, m) bool
    if burst is not None and burst.enabled:
        from repro.core.scenario import expand_bursts as _expand

        nb = N_DATA_BITS + n_check
        cu = (
            jax.random.uniform(jax.random.fold_in(key, 0x6B51), (nb, m), jnp.float32)
            if burst.needs_class_draw
            else None
        )
        wu = (
            jax.random.uniform(jax.random.fold_in(key, 0x6B52), (nb, m), jnp.float32)
            if burst.word_adjacent > 0.0
            else None
        )
        eb = (
            jax.random.randint(jax.random.fold_in(key, 0x6B53), (m,), 0, nb)
            if burst.random_double > 0.0
            else None
        )
        faulty = _expand(faulty, burst, cu, wu, eb, xp=jnp)
    lo = jnp.zeros((m,), jnp.uint32)
    hi = jnp.zeros((m,), jnp.uint32)
    par = jnp.zeros((m,), jnp.uint32)
    for b in range(32):
        lo = lo | (faulty[b].astype(jnp.uint32) << b)
    for b in range(32):
        hi = hi | (faulty[32 + b].astype(jnp.uint32) << b)
    for b in range(n_check):
        par = par | (faulty[64 + b].astype(jnp.uint32) << b)
    return lo, hi, par.astype(jnp.dtype(_check_dtype(n_check)))


@functools.lru_cache(maxsize=None)
def _device_chunk_masks_jit():
    import jax

    return jax.jit(_device_chunk_masks, static_argnames=("m", "n_check", "burst"))


class DeviceFaultField:
    """Failure-threshold field generated on device with ``jax.random``.

    Drop-in for FaultField in the batched undervolting loop: ``masks(v)``
    returns device arrays and never touches host memory. The NumPy FaultField
    remains the reference oracle — the two are statistically equivalent
    (tested) but use different PRNG streams, so bit patterns differ.

    Generation is chunked like the host field (key folded per chunk index) so
    the transient (72, chunk) bits tensor stays ~72 MiB regardless of arena
    size, instead of 288 bytes x n_words in one allocation.
    """

    def __init__(
        self,
        platform: PlatformProfile,
        n_words: int,
        seed: int = 0,
        chunk_words: int = 1 << 18,
        n_check: int = N_CHECK_DEFAULT,
        burst: BurstProfile | None = None,
    ):
        import jax

        self.platform = platform
        self.n_words = int(n_words)
        self.seed = int(seed)
        self.chunk_words = int(chunk_words)
        self.n_check = int(n_check)
        self.burst = burst if (burst is not None and burst.enabled) else None
        self._key = jax.random.PRNGKey(self.seed ^ 0xECC)

    def masks(self, v: float):
        """(lo, hi, parity) device flip masks at rail voltage ``v``."""
        import jax.numpy as jnp

        return self.masks_for_rates(jnp.float32(self.platform.fault_rate(v)))

    def masks_for_rates(self, rates):
        """Masks for a scalar rate or an (n_words,) per-word rate vector.

        Per-word rates are how multi-rail domains share one arena stream:
        the random bits depend only on (seed, chunk), the rail voltage of a
        word's domain enters through its threshold alone, so FIP holds per
        word and a uniform rate vector is bit-identical to the scalar path.
        """
        import jax
        import jax.numpy as jnp

        rates = jnp.asarray(rates, jnp.float32)
        per_word = rates.ndim == 1
        if per_word:
            assert rates.shape == (self.n_words,), rates.shape
        sigma = jnp.float32(self.platform.row_sigma)
        fn = _device_chunk_masks_jit()
        los, his, pars = [], [], []
        for ci, start in enumerate(range(0, self.n_words, self.chunk_words)):
            m = min(self.chunk_words, self.n_words - start)
            rate = rates[start : start + m] if per_word else rates
            lo, hi, par = fn(
                jax.random.fold_in(self._key, ci), m, rate, sigma,
                n_check=self.n_check, burst=self.burst,
            )
            los.append(lo)
            his.append(hi)
            pars.append(par)
        if not los:  # zero-sized memory
            z32 = jnp.zeros((0,), jnp.uint32)
            return z32, z32, jnp.zeros((0,), jnp.dtype(_check_dtype(self.n_check)))
        if len(los) == 1:
            return los[0], his[0], pars[0]
        return jnp.concatenate(los), jnp.concatenate(his), jnp.concatenate(pars)
