"""EccMemoryDomain — software-defined "BRAM" voltage/reliability domain.

Arrays written into the domain are stored bit-exact as SECDED(72,64)-encoded
word planes (two uint32 data lanes + one uint8 parity plane). Reads happen at
the domain's current rail voltage: the fault field's XOR masks are applied to
*all three planes* (parity bits undervolt too, like the real BRAM), then the
ECC decoder corrects/flags per word and telemetry is collected.

The decode path itself is functional JAX (jit-able); mask generation is
host-side numpy at voltage-set time, mirroring the physical reality that the
fault pattern is a property of the silicon + rail, not of the computation.

`read()` is the reference path used by benchmarks/examples; the serving stack
uses the same planes with the fused Pallas read path (`kernels/ecc_matmul`).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecc, quantize
from repro.core.faultsim import FaultField, FlipMasks
from repro.core.telemetry import FaultStats
from repro.core.voltage import PLATFORMS, PlatformProfile


@dataclasses.dataclass
class EncodedArray:
    """One array stored in the domain (host-resident planes + metadata)."""

    lo: np.ndarray  # (n,) uint32
    hi: np.ndarray  # (n,) uint32
    parity: np.ndarray  # (n,) uint8
    nbytes: int
    shape: tuple
    dtype: Any
    field: FaultField

    @property
    def n_words(self) -> int:
        return self.lo.shape[0]


def _encode_planes(arr: np.ndarray):
    lo, hi, nbytes = quantize.array_to_words_np(arr)
    parity = np.asarray(ecc.encode_np(lo, hi))
    return lo, hi, parity, nbytes


class EccMemoryDomain:
    """A named collection of SECDED-protected arrays under one voltage rail."""

    def __init__(
        self,
        platform: str | PlatformProfile = "vc707",
        seed: int = 0,
        ecc_enabled: bool = True,
        voltage: float | None = None,
    ):
        self.platform = (
            PLATFORMS[platform] if isinstance(platform, str) else platform
        )
        self.seed = seed
        self.ecc_enabled = ecc_enabled
        self.voltage = self.platform.v_nom if voltage is None else voltage
        self._store: dict[str, EncodedArray] = {}
        self.stats = FaultStats()

    # -- rail control --------------------------------------------------------
    def set_voltage(self, v: float) -> None:
        if v < self.platform.v_crash:
            raise RuntimeError(
                f"rail collapsed: {v:.3f} V < V_crash={self.platform.v_crash} V"
            )
        self.voltage = float(v)

    # -- storage --------------------------------------------------------------
    def write(self, name: str, arr) -> None:
        arr = np.asarray(arr)
        lo, hi, parity, nbytes = _encode_planes(arr)
        # Per-array fault field, deterministic in (domain seed, array name).
        fseed = (self.seed * 0x9E3779B1 + zlib.crc32(name.encode())) & 0x7FFFFFFF
        field = FaultField(self.platform, lo.shape[0], seed=fseed)
        self._store[name] = EncodedArray(
            lo, hi, parity, nbytes, tuple(arr.shape), arr.dtype, field
        )

    def write_pytree(self, prefix: str, tree) -> None:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        for path, leaf in flat:
            self.write(prefix + jax.tree_util.keystr(path), leaf)

    def names(self):
        return list(self._store)

    def entry(self, name: str) -> EncodedArray:
        return self._store[name]

    # -- read path -------------------------------------------------------------
    def read(self, name: str, voltage: float | None = None, collect_stats: bool = True):
        """Read one array at the rail voltage. Returns (array, FaultStats)."""
        e = self._store[name]
        v = self.voltage if voltage is None else voltage
        masks = e.field.masks(v)
        arr, stats = decode_read(
            e, masks, ecc_enabled=self.ecc_enabled, collect_stats=collect_stats
        )
        if collect_stats:
            self.stats.accumulate(stats)
        return arr, stats

    def read_pytree(self, prefix: str, tree_like, voltage: float | None = None):
        """Read a whole pytree (written with write_pytree). Returns (tree, stats)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        out, agg = [], FaultStats()
        for path, _ in flat:
            arr, stats = self.read(prefix + jax.tree_util.keystr(path), voltage)
            out.append(arr)
            agg.accumulate(stats)
        return jax.tree_util.tree_unflatten(treedef, out), agg


def decode_read(
    e: EncodedArray,
    masks: FlipMasks,
    ecc_enabled: bool = True,
    collect_stats: bool = True,
):
    """Functional fault-inject + SECDED-decode read of one EncodedArray."""
    lo = jnp.asarray(e.lo) ^ jnp.asarray(masks.lo)
    hi = jnp.asarray(e.hi) ^ jnp.asarray(masks.hi)
    parity = jnp.asarray(e.parity) ^ jnp.asarray(masks.parity)
    if ecc_enabled:
        lo, hi, status = ecc.decode(lo, hi, parity)
    else:
        status = jnp.zeros(lo.shape, jnp.int32)
    arr = quantize.words_to_array(lo, hi, e.nbytes, e.shape, e.dtype)
    stats = (
        FaultStats.from_decode(np.asarray(status), masks.flip_counts())
        if collect_stats and ecc_enabled
        else FaultStats(
            words=e.n_words,
            words_1bit=int((masks.flip_counts() == 1).sum()),
            words_2bit=int((masks.flip_counts() == 2).sum()),
            words_multi=int((masks.flip_counts() >= 3).sum()),
            faulty_bits=masks.total_flips(),
        )
    )
    return arr, stats
