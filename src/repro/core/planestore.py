"""Batched plane store: every EccWeight plane of a model in one flat arena.

The per-leaf undervolting loop launched 2-3 kernels *per weight matrix* per
voltage step and synced a per-leaf status array back to the host each time.
The store concatenates all (lo, hi, check) planes into flat (n_words,)
arenas at protect time, keeps a leaf -> [offset, offset+size) slice index,
and makes a voltage step one fused ``inject_scrub`` launch per *codec
group* with a single counter block crossing to host (DESIGN.md §9/§12).

Mask sources:
  * "host"   — the NumPy FaultField oracle, one field per leaf keyed exactly
    like the historical per-leaf path (``leaf_seed``), so the batched step is
    bit-identical to the per-leaf reference (tested);
  * "device" — one DeviceFaultField per codec group: counter-based
    jax.random, masks never exist in host memory (statistically equivalent,
    FIP holds).

Codecs (DESIGN.md §12): every memory domain selects a registered ECC scheme
(``codecs`` maps domain -> codec name; default everything on the built-in
``secded72``). Slots sharing a codec form one *group* with its own
concatenated planes and one fused kernel launch per voltage step — the
uniform-SECDED default is exactly one group whose planes alias the master
arrays, so the historical single-launch behaviour (and its bit patterns) is
unchanged. ``set_domain_codec`` re-encodes a domain under a stronger code at
runtime — the controller escalation path.

Async dispatch + double buffering (DESIGN.md §18): every voltage step also
has a ``*_async`` form that dispatches the fused launches and returns
immediately with a ``PendingFaultStats`` — the ``np.asarray(counters)``
host sync (the only serialization point) is deferred to ``harvest()``, so
decode work dispatched after the step overlaps the scrub. On compiled
backends each codec group's planes rotate through a depth-2 buffer ring and
the launch donates the two-steps-stale faulty planes back to XLA
(``donate_argnums``), making the steady-state soak allocation-free; the
interpret/CPU lane skips donation (unsupported there) but keeps the same
dispatch order, so both lanes are bit-identical to the serial path.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import codes
from repro.core import scenario
from repro.core.faultsim import DeviceFaultField, FaultField
from repro.core.telemetry import DomainFaultStats, FaultStats
from repro.core.voltage import PlatformProfile
from repro.codes import DEFAULT_CODEC
from repro.kernels import ops as kops


def leaf_seed(base_seed: int, key: str) -> int:
    """Per-leaf fault-field seed; must stay stable across refactors — the
    fault pattern is a property of (silicon sample, rail), i.e. (seed, leaf)."""
    return (base_seed * 0x9E3779B1 + zlib.crc32(key.encode())) & 0x7FFFFFFF


@dataclasses.dataclass
class PendingFaultStats:
    """Deferred telemetry from an asynchronously dispatched voltage step.

    Holds the per-group device counter blocks of a ``set_voltage_async`` /
    ``set_rails_async`` / ``set_rails_sharded_async`` dispatch. The planes
    are already usable (JAX async dispatch); ``harvest()`` performs the one
    host sync the synchronous method would have done inline and returns
    exactly the stats object it would have returned — same counters,
    same reduction, same denominators (tested bit-identical).
    """

    counters: list
    finish: Any  # callable(list[np.ndarray]) -> FaultStats-family object

    def harvest(self):
        return self.finish([np.asarray(c) for c in self.counters])


# Double-buffer donation (DESIGN.md §18): the stale faulty planes handed
# back to XLA are matched to the step's outputs by shape/dtype
# (input-output aliasing), so on these platforms the steady-state soak
# rotates two plane buffers instead of allocating a third every step. CPU
# and other interpret-lane platforms don't honor donation — they take the
# plain launch with identical math.
_DONATE_PLATFORMS = ("gpu", "cuda", "rocm", "tpu")


def _donation_supported() -> bool:
    return jax.default_backend() in _DONATE_PLATFORMS


@functools.partial(
    jax.jit,
    static_argnames=("codec", "reencode"),
    donate_argnums=(6, 7, 8),
    keep_unused=True,
)
def _fused_step_donated(
    lo, hi, check, mlo, mhi, mpar, stale_lo, stale_hi, stale_check,
    *, codec, reencode,
):
    # The stale planes contribute storage, not values: the kernel math is
    # exactly kops.inject_scrub.
    del stale_lo, stale_hi, stale_check
    return kops.inject_scrub(
        lo, hi, check, mlo, mhi, mpar, codec=codec, reencode=reencode
    )


@functools.partial(
    jax.jit,
    static_argnames=("codec", "reencode", "n_domains"),
    donate_argnums=(7, 8, 9),
    keep_unused=True,
)
def _fused_domains_step_donated(
    lo, hi, check, mlo, mhi, mpar, dom_ids, stale_lo, stale_hi, stale_check,
    *, n_domains, codec, reencode,
):
    del stale_lo, stale_hi, stale_check
    return kops.inject_scrub_domains(
        lo, hi, check, mlo, mhi, mpar, dom_ids, n_domains,
        codec=codec, reencode=reencode,
    )


@dataclasses.dataclass(frozen=True)
class Slot:
    """Arena placement of one EccWeight leaf's planes."""

    key: str
    offset: int
    size: int
    shape: tuple
    domain: str = "all"


@dataclasses.dataclass
class _CodecGroup:
    """Slots sharing one ECC scheme: one fused launch per voltage step."""

    name: str
    codec: Any  # codes.Codec
    slot_ids: tuple  # indices into store.slots, arena order
    offsets: tuple  # per-slot word offset inside the group arena
    n_words: int
    lo: Any  # (n_words,) uint32 clean data
    hi: Any
    check: Any  # (n_words,) codec check dtype
    dom_ids: Any  # (n_words,) jnp int32 (store-global domain indices)
    dom_ids_np: np.ndarray
    device_field: DeviceFaultField
    sharded: Any = None  # _ShardedGroup when the store is mesh-sharded


@dataclasses.dataclass
class _ShardedGroup:
    """Mesh-partitioned view of one codec group (DESIGN.md §13).

    The group planes padded to a shard multiple and placed with the arena
    NamedSharding: each reliability shard (chip) owns ``local_words``
    contiguous words and draws their faults from its own per-shard stream
    inside the shard_map'd rail step.
    """

    seed: int  # the group's device-stream seed (shard 0 reproduces it)
    local_words: int
    pad: int
    lo: Any  # (n_shards * local_words,) uint32, sharded
    hi: Any
    check: Any
    dom: Any  # (n_shards * local_words,) int32, spill index on pad words


class PlaneStore:
    """Flat arena over a sequence of EccWeight leaves (clean planes, device).

    With a ``domain_key`` classifier the arena is partitioned into named
    memory domains (DESIGN.md §10): every slot belongs to one domain, and
    ``set_rails`` drives a separate rail voltage per domain through one fused
    inject+scrub launch (per codec group) with per-domain counter rows.
    ``profiles`` optionally gives each domain its own PlatformProfile
    (MoRS-style per-instance fault behaviour); rails without a dedicated
    profile use ``platform``. ``codecs`` maps domains to registered ECC
    schemes (str for all domains, dict for per-domain choices).
    """

    def __init__(
        self,
        leaves,
        keys,
        platform: PlatformProfile,
        seed: int = 0,
        mask_source: str = "host",
        domain_key=None,
        profiles=None,
        codecs=None,
        mesh=None,
        env=None,
    ):
        assert mask_source in ("host", "device"), mask_source
        assert len(leaves) == len(set(keys)), "leaf keys must be unique"
        self.platform = platform
        self.seed = int(seed)
        self.mask_source = mask_source
        self.mesh = mesh
        # Environment scenario (DESIGN.md §14): name or EnvironmentProfile.
        # Flux multiplier enters through domain_profile (so every rate
        # consumer sees the scaled curve), the burst shape through the fault
        # fields, aging drift through set_rails_sharded's per-shard rate
        # multipliers. env=None is the historical store bit-for-bit.
        self.env = scenario.resolve(env)
        self._soak = 0  # sharded scrub intervals stepped (the drift clock)
        # A disabled burst shape normalizes to None so the fault fields (and
        # the make_rail_step cache) take the historical path exactly.
        burst = self.env.burst if self.env else None
        self._burst = burst if (burst is not None and burst.enabled) else None
        if mesh is not None:
            # Mesh-sharded arena (DESIGN.md §13): masks must be generated
            # inside shard_map from per-shard streams — the host oracle has
            # no shard identity.
            assert mask_source == "device", "sharded arenas need device masks"
        self._profiles = dict(profiles or {})
        self._external_words: dict[str, int] = {}
        self._external_shard_words: dict[int, dict[str, int]] = {}
        self._external_codecs: dict[str, str] = {}
        classify = domain_key if domain_key is not None else (lambda _k: "all")
        slots, off = [], 0
        los, his, pars = [], [], []
        for key, leaf in zip(keys, leaves):
            size = int(leaf.lo.size)
            slots.append(
                Slot(key, off, size, tuple(leaf.lo.shape), str(classify(key)))
            )
            los.append(leaf.lo.reshape(-1))
            his.append(leaf.hi.reshape(-1))
            pars.append(leaf.parity.reshape(-1))
            off += size
        # The arena owns the clean plane data; keep only plane-free leaf
        # metadata (scale/k/n/fuse) so the store doesn't hold a second full
        # copy of every plane.
        self._leaves = [
            dataclasses.replace(leaf, lo=None, hi=None, parity=None)
            for leaf in leaves
        ]
        self.slots = tuple(slots)
        self.n_words = off
        if los:
            self.lo = jnp.concatenate(los)
            self.hi = jnp.concatenate(his)
            self.parity = jnp.concatenate(pars)  # SECDED check bits, as packed
        else:
            self.lo = jnp.zeros((0,), jnp.uint32)
            self.hi = jnp.zeros((0,), jnp.uint32)
            self.parity = jnp.zeros((0,), jnp.uint8)
        # Domain order: first appearance in arena order (stable across runs
        # for a fixed leaf ordering); this is the counter row order.
        self.domains = tuple(dict.fromkeys(s.domain for s in self.slots))
        self._dom_index = {d: i for i, d in enumerate(self.domains)}
        dom_ids = np.zeros(self.n_words, np.int32)
        for s in self.slots:
            dom_ids[s.offset : s.offset + s.size] = self._dom_index[s.domain]
        self._dom_ids_np = dom_ids
        self._dom_ids = jnp.asarray(dom_ids) if self.n_words else jnp.zeros((0,), jnp.int32)
        # Per-domain codec choices (default: the built-in SECDED everywhere).
        if codecs is None:
            codecs = {}
        elif isinstance(codecs, str):
            codecs = {d: codecs for d in self.domains}
        self._codecs = {d: str(codecs.get(d, DEFAULT_CODEC)) for d in self.domains}
        for name in self._codecs.values():
            codes.get(name)  # fail fast on unknown codecs
        self._build_groups()

    # -- codec groups --------------------------------------------------------
    def codec_of(self, domain: str) -> str:
        return self._codecs.get(domain, DEFAULT_CODEC)

    def _build_groups(self) -> None:
        """(Re)build the per-codec sub-arenas from the master clean planes.

        The uniform-default case — every domain on one codec — produces a
        single group whose planes alias the master arrays (no copy, no
        re-encode for SECDED), keeping the historical memory footprint,
        launch count, and bit patterns.
        """
        by_codec: dict[str, list[int]] = {}
        for si, s in enumerate(self.slots):
            by_codec.setdefault(self.codec_of(s.domain), []).append(si)
        single = len(by_codec) == 1
        groups = []
        for cname, slot_ids in by_codec.items():
            codec = codes.get(cname)
            offsets, off = [], 0
            for si in slot_ids:
                offsets.append(off)
                off += self.slots[si].size
            if single:
                lo, hi = self.lo, self.hi
                dom_np = self._dom_ids_np
                dom = self._dom_ids
                dseed = self.seed
            else:
                sel = np.concatenate(
                    [
                        np.arange(
                            self.slots[si].offset,
                            self.slots[si].offset + self.slots[si].size,
                        )
                        for si in slot_ids
                    ]
                )
                idx = jnp.asarray(sel)
                lo, hi = self.lo[idx], self.hi[idx]
                dom_np = self._dom_ids_np[sel]
                dom = jnp.asarray(dom_np)
                # A stable, codec-keyed stream: regrouping must not change
                # the masks of groups whose membership did not change.
                dseed = (self.seed ^ zlib.crc32(cname.encode())) & 0x7FFFFFFF
            if cname == DEFAULT_CODEC and single:
                check = self.parity  # the leaves arrived SECDED-encoded
            else:
                check = kops.encode(lo, hi, codec=cname) if off else jnp.zeros(
                    (0,), jnp.dtype(codec.check_dtype)
                )
            groups.append(
                _CodecGroup(
                    name=cname,
                    codec=codec,
                    slot_ids=tuple(slot_ids),
                    offsets=tuple(offsets),
                    n_words=off,
                    lo=lo,
                    hi=hi,
                    check=check,
                    dom_ids=dom,
                    dom_ids_np=dom_np,
                    device_field=DeviceFaultField(
                        self.env.scale_profile(self.platform)
                        if self.env
                        else self.platform,
                        off,
                        seed=dseed,
                        n_check=codec.n_check,
                        burst=self._burst,
                    ),
                )
            )
        self._groups = groups
        # Depth-2 plane buffer ring per codec group (§18): regrouping (codec
        # escalation) changes plane geometry, so stale buffers are dropped.
        self._plane_hist: dict[str, list] = {}
        if self.mesh is not None:
            self._build_sharded_groups()
        # Per-leaf host oracle fields, keyed like the historical per-leaf
        # path; the check-bitplane count follows the slot's codec.
        self._host_fields = {}
        for g in self._groups:
            for si in g.slot_ids:
                s = self.slots[si]
                self._host_fields[s.key] = FaultField(
                    self.domain_profile(s.domain),
                    s.size,
                    seed=leaf_seed(self.seed, s.key),
                    n_check=g.codec.n_check,
                    burst=self._burst,
                )

    # -- mesh sharding (DESIGN.md §13) ---------------------------------------
    @property
    def n_shards(self) -> int:
        """Reliability shard (chip) count; 0 when the store is unsharded."""
        if self.mesh is None:
            return 0
        from repro.distributed.sharding import reliability_shards

        return reliability_shards(self.mesh)

    def _build_sharded_groups(self) -> None:
        """Partition every codec group's planes across the mesh.

        Word ``w`` of a group lands on shard ``w // local_words``; pad words
        (zero data, spill domain index) fill the tail so every shard owns the
        same word count. A 1-shard mesh adds no padding and shard 0 keeps the
        group's device-stream seed, so the sharded step is bit-identical to
        the unsharded device path (tested in tests/test_meshrel.py).
        """
        from repro.distributed import meshrel

        n_shards = self.n_shards
        sigmas = {self.domain_profile(d).row_sigma for d in self.domains}
        assert len(sigmas) <= 1, (
            "sharded arenas share one row-weakness field per chip; "
            f"got sigmas {sorted(sigmas)}"
        )
        sharding = meshrel.arena_sharding(self.mesh)
        spill = len(self.domains)
        self._shard_words = [dict.fromkeys(self.domains, 0) for _ in range(n_shards)]
        for g in self._groups:
            padded = meshrel.pad_to_shards(g.n_words, n_shards)
            pad = padded - g.n_words
            dom_np = np.concatenate(
                [g.dom_ids_np, np.full(pad, spill, np.int32)]
            ) if pad else g.dom_ids_np
            local = padded // n_shards if n_shards else 0
            for s in range(n_shards):
                counts = np.bincount(
                    dom_np[s * local : (s + 1) * local], minlength=spill + 1
                )
                for i, d in enumerate(self.domains):
                    self._shard_words[s][d] += int(counts[i])

            def padded_plane(x, dtype=None):
                x = jnp.asarray(x)
                if pad:
                    x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
                return jax.device_put(x, sharding)

            g.sharded = _ShardedGroup(
                seed=g.device_field.seed,
                local_words=local,
                pad=pad,
                lo=padded_plane(g.lo),
                hi=padded_plane(g.hi),
                check=padded_plane(g.check),
                dom=jax.device_put(jnp.asarray(dom_np), sharding),
            )

    def shard_words_by_domain(self) -> list:
        """Per-shard {domain: words} (power weighting + per-shard telemetry
        denominators), arena slices plus shard-registered external domains."""
        assert self.mesh is not None
        out = []
        for s in range(self.n_shards):
            d = dict(self._shard_words[s])
            for dom, w in self._external_shard_words.get(s, {}).items():
                d[dom] = d.get(dom, 0) + w
            out.append(d)
        return out

    def _normalize_schedule(self, schedule) -> list:
        """One {domain: voltage} dict per shard from any accepted form:
        a single dict (uniform), a sequence of per-shard dicts, or a dict
        whose values are per-shard sequences."""
        n = self.n_shards
        if isinstance(schedule, dict):
            if any(np.ndim(v) for v in schedule.values()):
                for d, v in schedule.items():
                    assert np.ndim(v) == 0 or np.size(v) == n, (
                        f"domain {d!r}: {np.size(v)} voltages for {n} shards"
                    )
                per = []
                for s in range(n):
                    per.append(
                        {
                            d: float(np.asarray(v).reshape(-1)[s])
                            if np.ndim(v)
                            else float(v)
                            for d, v in schedule.items()
                        }
                    )
                return per
            # independent dicts: a caller adjusting one shard's entry must
            # not silently retune every chip
            return [dict(schedule) for _ in range(n)]
        schedule = [dict(s) for s in schedule]
        assert len(schedule) == n, (len(schedule), n)
        return schedule

    def set_rails_sharded_async(self, schedule, ecc: bool = True):
        """Asynchronously dispatched ``set_rails_sharded``: the collective-
        free shard_map'd launches (meshrel.make_rail_step) go out per codec
        group and the (n_shards, n_domains, 8) per-shard counter blocks stay
        on device until ``pending.harvest()`` — a soak of N intervals pays
        one counter sync instead of N (DESIGN.md §18)."""
        from repro.core.telemetry import ShardFaultStats
        from repro.distributed import meshrel

        assert self.mesh is not None, "set_rails_sharded needs a mesh"
        schedule = self._normalize_schedule(schedule)
        n_shards = self.n_shards
        if self.n_words == 0:
            empty = ShardFaultStats(
                [DomainFaultStats(shard=s) for s in range(n_shards)]
            )
            return list(self._leaves), PendingFaultStats([], lambda _c: empty)
        profiles = {d: self.domain_profile(d) for d in self.domains}
        sigma = next(iter({p.row_sigma for p in profiles.values()}))
        # One scrub interval per rail step: the aging clock. At env=None or
        # drift_sigma=0 every multiplier is exactly 1.0 and the table is the
        # historical one bit-for-bit.
        self._soak += 1
        mult = (
            np.array(
                [
                    scenario.aging_multiplier(s, self._soak, self.env, self.seed)
                    for s in range(n_shards)
                ],
                np.float32,
            )
            if self.env is not None
            else None
        )
        rates = meshrel.schedule_rates(
            schedule, self.domains, profiles, n_shards, shard_multipliers=mult
        )
        counters, planes = [], {}
        host = jax.devices()[0]
        for g in self._groups:
            sg = g.sharded
            step = meshrel.make_rail_step(
                self.mesh, sg.local_words, len(self.domains), g.name,
                sg.seed, float(sigma), reencode=not ecc,
                burst=self._burst,
            )
            flo, fhi, fpar, per_shard = step(
                sg.lo, sg.hi, sg.check, sg.dom, jnp.asarray(rates)
            )
            counters.append(per_shard)
            # The CPU engine's decode path is single-device, so the faulty
            # planes are gathered once per rail step; a TP mesh would keep
            # them sharded in place (the weights are consumed sharded).
            planes[g.name] = tuple(
                jax.device_put(x, host) for x in (flo, fhi, fpar)
            )

        def finish(host_counters):
            total = np.zeros((n_shards, len(self.domains), 8), np.int64)
            for c in host_counters:
                total += c
            return ShardFaultStats.from_counter_blocks(
                total, self.domains, self.shard_words_by_domain()
            )

        return self._slice_leaves(planes), PendingFaultStats(counters, finish)

    def set_rails_sharded(self, schedule, ecc: bool = True):
        """Per-(shard, domain) voltage step across the whole mesh.

        One collective-free shard_map'd fused inject+scrub launch per codec
        group: every shard injects its own fault population at its own rails
        and tallies its own counter rows; only the (n_shards, n_domains, 8)
        counter block crosses to host (any fleet aggregate is the caller's
        one-per-soak ``meshrel.fold_counters``). Returns
        (faulty_leaves, ShardFaultStats). A uniform schedule on a 1-shard
        mesh is bit-identical to ``set_rails`` with device masks.
        """
        leaves, pending = self.set_rails_sharded_async(schedule, ecc=ecc)
        return leaves, pending.harvest()

    def set_domain_codec(self, domain: str, codec_name: str) -> None:
        """Re-protect ``domain`` under another registered code (the
        controller escalation path). Check planes are re-encoded from the
        clean master data; fault fields follow the new bitplane geometry.
        Other domains' groups are rebuilt with identical membership, seeds
        and geometry, so their mask streams are unchanged."""
        codes.get(codec_name)  # validate early
        assert domain in self.domains, (domain, self.domains)
        if self.codec_of(domain) == codec_name:
            return
        self._codecs[domain] = str(codec_name)
        self._build_groups()

    def codecs_by_domain(self) -> dict:
        out = {d: self.codec_of(d) for d in self.domains}
        out.update(self._external_codecs)
        return out

    def check_bits_by_domain(self) -> dict:
        """Check bits per 64-bit word for every domain (power weighting)."""
        return {d: codes.get(c).n_check for d, c in self.codecs_by_domain().items()}

    # -- domains -------------------------------------------------------------
    def domain_profile(self, domain: str) -> PlatformProfile:
        """The domain's fault curve, env-flux-scaled when an environment is
        set — every rate consumer (host fields, device rate vectors, the
        sharded rate tables, the engine's controllers) sees one curve."""
        prof = self._profiles.get(domain, self.platform)
        return self.env.scale_profile(prof) if self.env else prof

    def register_domain_words(
        self, domain: str, words: int, codec: str = DEFAULT_CODEC,
        shard: int | None = None,
    ) -> None:
        """Account storage that lives *outside* the weight arena — e.g. the
        paged KV cache (core/kvpages.py) — under a named domain.

        External domains join ``words_by_domain`` (power weighting, telemetry
        denominators) but not the arena's counter rows: their planes are not
        part of this store's fused inject+scrub launch, they carry their own
        fault machinery and report telemetry separately. ``codec`` records
        the external store's scheme for the redundancy-cost power weighting.
        ``shard`` attributes the words to one reliability shard's chip (mesh
        stores: each replica's KV arena is its own silicon); None registers
        them store-wide (the unsharded path).
        """
        if shard is None:
            self._external_words[str(domain)] = int(words)
        else:
            self._external_shard_words.setdefault(int(shard), {})[str(domain)] = (
                int(words)
            )
        self._external_codecs[str(domain)] = str(codec)

    def words_by_domain(self) -> dict:
        """Word count per domain (power weighting + telemetry denominators),
        arena slots plus any registered external domains (shard-registered
        externals contribute their cross-shard sum)."""
        counts = dict.fromkeys(self.domains, 0)
        for s in self.slots:
            counts[s.domain] += s.size
        for d, w in self._external_words.items():
            counts[d] = counts.get(d, 0) + w
        for per in self._external_shard_words.values():
            for d, w in per.items():
                counts[d] = counts.get(d, 0) + w
        return counts

    # -- masks ---------------------------------------------------------------
    def _group_host_masks(self, g: _CodecGroup, volts: dict):
        """Concatenated per-leaf oracle masks for one group (bit-identical to
        the per-leaf path: same fields, same seeds, same order)."""
        mlos, mhis, mpars = [], [], []
        for si in g.slot_ids:
            s = self.slots[si]
            mk = self._host_fields[s.key].masks(volts[s.domain])
            mlos.append(mk.lo)
            mhis.append(mk.hi)
            mpars.append(mk.parity)
        cat = lambda xs, dt: (
            jnp.asarray(np.concatenate(xs)) if xs else jnp.zeros((0,), dt)
        )
        return (
            cat(mlos, jnp.uint32),
            cat(mhis, jnp.uint32),
            cat(mpars, jnp.dtype(g.codec.check_dtype)),
        )

    def _group_rates(self, g: _CodecGroup, volts: dict) -> np.ndarray:
        """Per-word fault rate vector for a {domain: voltage} schedule."""
        rates = np.zeros(g.n_words, np.float32)
        for d, i in self._dom_index.items():
            rates[g.dom_ids_np == i] = self.domain_profile(d).fault_rate(
                float(volts[d])
            )
        return rates

    def _group_masks(self, g: _CodecGroup, v):
        volts = v if isinstance(v, dict) else {d: v for d in self.domains}
        if self.mask_source == "device":
            # Per-domain profiles make the rate a function of the word's
            # domain even under a scalar rail, so route through the rate
            # vector (the host path gets this for free from its per-leaf
            # fields); profile-less stores keep the scalar fast path.
            if isinstance(v, dict) or self._profiles:
                return g.device_field.masks_for_rates(self._group_rates(g, volts))
            return g.device_field.masks(v)
        return self._group_host_masks(g, volts)

    # Legacy single-group helpers (kept for the uniform-codec arena).
    def host_masks(self, v):
        assert len(self._groups) == 1, "host_masks is a single-group helper"
        volts = v if isinstance(v, dict) else {d: v for d in self.domains}
        return self._group_host_masks(self._groups[0], volts)

    def masks(self, v):
        assert len(self._groups) == 1, "masks is a single-group helper"
        return self._group_masks(self._groups[0], v)

    # -- the batched voltage step --------------------------------------------
    def _stale_planes(self, name: str):
        """Pop the two-steps-old faulty planes for donation (None until the
        ring has depth 2, or off compiled backends)."""
        if not _donation_supported():
            return None
        hist = self._plane_hist.setdefault(name, [])
        return hist.pop(0) if len(hist) >= 2 else None

    def _retire_planes(self, name: str, planes) -> None:
        hist = self._plane_hist.setdefault(name, [])
        hist.append(planes)
        del hist[:-2]

    def _fused_group_step(self, g: _CodecGroup, mlo, mhi, mpar, *,
                          reencode: bool, domains: bool):
        """One fused inject+scrub launch for a codec group, donating the
        stale buffer ring slot on compiled backends (§18)."""
        stale = self._stale_planes(g.name)
        if domains:
            if stale is not None:
                out = _fused_domains_step_donated(
                    g.lo, g.hi, g.check, mlo, mhi, mpar, g.dom_ids, *stale,
                    n_domains=len(self.domains), codec=g.name,
                    reencode=reencode,
                )
            else:
                out = kops.inject_scrub_domains(
                    g.lo, g.hi, g.check, mlo, mhi, mpar,
                    g.dom_ids, len(self.domains), codec=g.name,
                    reencode=reencode,
                )
        elif stale is not None:
            out = _fused_step_donated(
                g.lo, g.hi, g.check, mlo, mhi, mpar, *stale,
                codec=g.name, reencode=reencode,
            )
        else:
            out = kops.inject_scrub(
                g.lo, g.hi, g.check, mlo, mhi, mpar,
                codec=g.name, reencode=reencode,
            )
        self._retire_planes(g.name, out[:3])
        return out

    def set_voltage_async(self, v: float, ecc: bool = True):
        """Asynchronously dispatched ``set_voltage``: the fused launches go
        out, nothing syncs to host. Returns (faulty_leaves,
        PendingFaultStats) immediately — the leaves are usable right away
        (async dispatch) and ``pending.harvest()`` is the one deferred
        counter sync, so decode work dispatched in between overlaps the
        scrub instead of serializing behind it (DESIGN.md §18).

        Donation contract: on compiled backends the launch donates the
        group's two-steps-stale faulty planes; callers must not hold plane
        references across two or more voltage steps.
        """
        assert self.mesh is None, "mesh-sharded stores step via set_rails_sharded"
        if self.n_words == 0:
            return list(self._leaves), PendingFaultStats(
                [], lambda _c: FaultStats()
            )
        counters, planes = [], {}
        for g in self._groups:
            mlo, mhi, mpar = self._group_masks(g, v)
            flo, fhi, fpar, cnt = self._fused_group_step(
                g, mlo, mhi, mpar, reencode=not ecc, domains=False
            )
            counters.append(cnt)
            planes[g.name] = (flo, fhi, fpar)

        def finish(host_counters, n_words=self.n_words):
            total = np.zeros(8, np.int64)
            for c in host_counters:
                total += c
            return FaultStats.from_counters(total, words=n_words)

        return self._slice_leaves(planes), PendingFaultStats(counters, finish)

    def set_voltage(self, v: float, ecc: bool = True):
        """One fused inject+scrub launch per codec group for the whole store.

        Returns (faulty_leaves, FaultStats). faulty_leaves are the input
        EccWeight leaves with lo/hi/parity replaced by arena slices at rail
        voltage ``v`` (scale/k/n/fuse untouched).
        """
        leaves, pending = self.set_voltage_async(v, ecc=ecc)
        return leaves, pending.harvest()

    def set_rails_async(self, volts: dict, ecc: bool = True):
        """Asynchronously dispatched ``set_rails`` (same deferred-harvest
        and donation contract as ``set_voltage_async``)."""
        assert self.mesh is None, "mesh-sharded stores step via set_rails_sharded"
        missing = set(self.domains) - set(volts)
        assert not missing, f"rails missing for domains: {sorted(missing)}"
        if self.n_words == 0:
            return list(self._leaves), PendingFaultStats(
                [], lambda _c: DomainFaultStats()
            )
        counters, planes = [], {}
        for g in self._groups:
            mlo, mhi, mpar = self._group_masks(g, dict(volts))
            flo, fhi, fpar, cnt = self._fused_group_step(
                g, mlo, mhi, mpar, reencode=not ecc, domains=True
            )
            counters.append(cnt)
            planes[g.name] = (flo, fhi, fpar)

        def finish(host_counters):
            total = np.zeros((len(self.domains), 8), np.int64)
            for c in host_counters:
                total += c
            return FaultStats.from_counter_matrix(
                total, self.domains, self.words_by_domain()
            )

        return self._slice_leaves(planes), PendingFaultStats(counters, finish)

    def set_rails(self, volts: dict, ecc: bool = True):
        """One fused inject+scrub launch per codec group with a separate rail
        per domain.

        ``volts`` maps every domain name to its rail voltage. Returns
        (faulty_leaves, DomainFaultStats) — one counter row per domain
        crosses to host. A uniform schedule is bit-identical to
        ``set_voltage`` (same fields/streams, same kernel math; tested).
        """
        leaves, pending = self.set_rails_async(volts, ecc=ecc)
        return leaves, pending.harvest()

    def _slice_leaves(self, planes: dict):
        """Reassemble per-leaf EccWeight views from per-group faulty planes."""
        out: list = [None] * len(self.slots)
        for g in self._groups:
            flo, fhi, fpar = planes[g.name]
            for si, off in zip(g.slot_ids, g.offsets):
                s = self.slots[si]
                out[si] = dataclasses.replace(
                    self._leaves[si],
                    lo=flo[off : off + s.size].reshape(s.shape),
                    hi=fhi[off : off + s.size].reshape(s.shape),
                    parity=fpar[off : off + s.size].reshape(s.shape),
                )
        return out
