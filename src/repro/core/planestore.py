"""Batched plane store: every EccWeight plane of a model in one flat arena.

The per-leaf undervolting loop launched 2-3 kernels *per weight matrix* per
voltage step and synced a per-leaf status array back to the host each time.
The store concatenates all (lo, hi, parity) planes into flat (n_words,)
arenas at protect time, keeps a leaf -> [offset, offset+size) slice index,
and makes a voltage step exactly one fused ``inject_scrub`` launch over the
whole model with a single (8,) counter vector crossing to host
(DESIGN.md §9).

Mask sources:
  * "host"   — the NumPy FaultField oracle, one field per leaf keyed exactly
    like the historical per-leaf path (``leaf_seed``), so the batched step is
    bit-identical to the per-leaf reference (tested);
  * "device" — one DeviceFaultField over the arena: counter-based jax.random,
    masks never exist in host memory (statistically equivalent, FIP holds).
"""

from __future__ import annotations

import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.faultsim import DeviceFaultField, FaultField
from repro.core.telemetry import DomainFaultStats, FaultStats
from repro.core.voltage import PlatformProfile
from repro.kernels import ops as kops


def leaf_seed(base_seed: int, key: str) -> int:
    """Per-leaf fault-field seed; must stay stable across refactors — the
    fault pattern is a property of (silicon sample, rail), i.e. (seed, leaf)."""
    return (base_seed * 0x9E3779B1 + zlib.crc32(key.encode())) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class Slot:
    """Arena placement of one EccWeight leaf's planes."""

    key: str
    offset: int
    size: int
    shape: tuple
    domain: str = "all"


class PlaneStore:
    """Flat arena over a sequence of EccWeight leaves (clean planes, device).

    With a ``domain_key`` classifier the arena is partitioned into named
    memory domains (DESIGN.md §10): every slot belongs to one domain, and
    ``set_rails`` drives a separate rail voltage per domain through one fused
    inject+scrub launch with per-domain counter rows. ``profiles`` optionally
    gives each domain its own PlatformProfile (MoRS-style per-instance fault
    behaviour); rails without a dedicated profile use ``platform``.
    """

    def __init__(
        self,
        leaves,
        keys,
        platform: PlatformProfile,
        seed: int = 0,
        mask_source: str = "host",
        domain_key=None,
        profiles=None,
    ):
        assert mask_source in ("host", "device"), mask_source
        assert len(leaves) == len(set(keys)), "leaf keys must be unique"
        self.platform = platform
        self.seed = int(seed)
        self.mask_source = mask_source
        self._profiles = dict(profiles or {})
        self._external_words: dict[str, int] = {}
        classify = domain_key if domain_key is not None else (lambda _k: "all")
        slots, off = [], 0
        los, his, pars = [], [], []
        for key, leaf in zip(keys, leaves):
            size = int(leaf.lo.size)
            slots.append(
                Slot(key, off, size, tuple(leaf.lo.shape), str(classify(key)))
            )
            los.append(leaf.lo.reshape(-1))
            his.append(leaf.hi.reshape(-1))
            pars.append(leaf.parity.reshape(-1))
            off += size
        # The arena owns the clean plane data; keep only plane-free leaf
        # metadata (scale/k/n/fuse) so the store doesn't hold a second full
        # copy of every plane.
        self._leaves = [
            dataclasses.replace(leaf, lo=None, hi=None, parity=None)
            for leaf in leaves
        ]
        self.slots = tuple(slots)
        self.n_words = off
        if los:
            self.lo = jnp.concatenate(los)
            self.hi = jnp.concatenate(his)
            self.parity = jnp.concatenate(pars)
        else:
            self.lo = jnp.zeros((0,), jnp.uint32)
            self.hi = jnp.zeros((0,), jnp.uint32)
            self.parity = jnp.zeros((0,), jnp.uint8)
        # Domain order: first appearance in arena order (stable across runs
        # for a fixed leaf ordering); this is the counter row order.
        self.domains = tuple(dict.fromkeys(s.domain for s in self.slots))
        self._dom_index = {d: i for i, d in enumerate(self.domains)}
        dom_ids = np.zeros(self.n_words, np.int32)
        for s in self.slots:
            dom_ids[s.offset : s.offset + s.size] = self._dom_index[s.domain]
        self._dom_ids_np = dom_ids
        self._dom_ids = jnp.asarray(dom_ids) if self.n_words else jnp.zeros((0,), jnp.int32)
        self._host_fields = {
            s.key: FaultField(
                self.domain_profile(s.domain), s.size,
                seed=leaf_seed(self.seed, s.key),
            )
            for s in self.slots
        }
        self._device_field = DeviceFaultField(platform, self.n_words, seed=self.seed)

    # -- domains -------------------------------------------------------------
    def domain_profile(self, domain: str) -> PlatformProfile:
        return self._profiles.get(domain, self.platform)

    def register_domain_words(self, domain: str, words: int) -> None:
        """Account storage that lives *outside* the weight arena — e.g. the
        paged KV cache (core/kvpages.py) — under a named domain.

        External domains join ``words_by_domain`` (power weighting, telemetry
        denominators) but not the arena's counter rows: their planes are not
        part of this store's fused inject+scrub launch, they carry their own
        fault machinery and report telemetry separately.
        """
        self._external_words[str(domain)] = int(words)

    def words_by_domain(self) -> dict:
        """Word count per domain (power weighting + telemetry denominators),
        arena slots plus any registered external domains."""
        counts = dict.fromkeys(self.domains, 0)
        for s in self.slots:
            counts[s.domain] += s.size
        for d, w in self._external_words.items():
            counts[d] = counts.get(d, 0) + w
        return counts

    # -- masks ---------------------------------------------------------------
    def host_masks(self, v):
        """Concatenated per-leaf oracle masks (bit-identical to the per-leaf
        path: same fields, same seeds, same order). ``v`` is a scalar rail
        voltage or a {domain: voltage} mapping."""
        volts = v if isinstance(v, dict) else {d: v for d in self.domains}
        mlos, mhis, mpars = [], [], []
        for s in self.slots:
            mk = self._host_fields[s.key].masks(volts[s.domain])
            mlos.append(mk.lo)
            mhis.append(mk.hi)
            mpars.append(mk.parity)
        cat = lambda xs, dt: (
            jnp.asarray(np.concatenate(xs)) if xs else jnp.zeros((0,), dt)
        )
        return cat(mlos, jnp.uint32), cat(mhis, jnp.uint32), cat(mpars, jnp.uint8)

    def _rail_rates(self, volts: dict) -> np.ndarray:
        """Per-word fault rate vector for a {domain: voltage} schedule."""
        rates = np.zeros(self.n_words, np.float32)
        for d, i in self._dom_index.items():
            rates[self._dom_ids_np == i] = self.domain_profile(d).fault_rate(
                float(volts[d])
            )
        return rates

    def masks(self, v):
        if self.mask_source == "device":
            # Per-domain profiles make the rate a function of the word's
            # domain even under a scalar rail, so route through the rate
            # vector (the host path gets this for free from its per-leaf
            # fields); profile-less stores keep the scalar fast path.
            if isinstance(v, dict) or self._profiles:
                volts = v if isinstance(v, dict) else {d: v for d in self.domains}
                return self._device_field.masks_for_rates(self._rail_rates(volts))
            return self._device_field.masks(v)
        return self.host_masks(v)

    # -- the batched voltage step --------------------------------------------
    def set_voltage(self, v: float, ecc: bool = True):
        """One fused inject+scrub launch for the whole store.

        Returns (faulty_leaves, FaultStats). faulty_leaves are the input
        EccWeight leaves with lo/hi/parity replaced by arena slices at rail
        voltage ``v`` (scale/k/n/fuse untouched).
        """
        if self.n_words == 0:
            return list(self._leaves), FaultStats()
        mlo, mhi, mpar = self.masks(v)
        flo, fhi, fpar, counters = kops.inject_scrub(
            self.lo, self.hi, self.parity, mlo, mhi, mpar, reencode=not ecc
        )
        stats = FaultStats.from_counters(np.asarray(counters), words=self.n_words)
        return self._slice_leaves(flo, fhi, fpar), stats

    def set_rails(self, volts: dict, ecc: bool = True):
        """One fused inject+scrub launch with a separate rail per domain.

        ``volts`` maps every domain name to its rail voltage. Returns
        (faulty_leaves, DomainFaultStats) — one counter row per domain
        crosses to host. A uniform schedule is bit-identical to
        ``set_voltage`` (same fields/streams, same kernel math; tested).
        """
        missing = set(self.domains) - set(volts)
        assert not missing, f"rails missing for domains: {sorted(missing)}"
        if self.n_words == 0:
            return list(self._leaves), DomainFaultStats()
        mlo, mhi, mpar = self.masks(dict(volts))
        flo, fhi, fpar, counters = kops.inject_scrub_domains(
            self.lo, self.hi, self.parity, mlo, mhi, mpar,
            self._dom_ids, len(self.domains), reencode=not ecc,
        )
        stats = FaultStats.from_counter_matrix(
            np.asarray(counters), self.domains, self.words_by_domain()
        )
        return self._slice_leaves(flo, fhi, fpar), stats

    def _slice_leaves(self, flo, fhi, fpar):
        return [
            dataclasses.replace(
                leaf,
                lo=flo[s.offset : s.offset + s.size].reshape(s.shape),
                hi=fhi[s.offset : s.offset + s.size].reshape(s.shape),
                parity=fpar[s.offset : s.offset + s.size].reshape(s.shape),
            )
            for s, leaf in zip(self.slots, self._leaves)
        ]
