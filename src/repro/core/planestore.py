"""Batched plane store: every EccWeight plane of a model in one flat arena.

The per-leaf undervolting loop launched 2-3 kernels *per weight matrix* per
voltage step and synced a per-leaf status array back to the host each time.
The store concatenates all (lo, hi, check) planes into flat (n_words,)
arenas at protect time, keeps a leaf -> [offset, offset+size) slice index,
and makes a voltage step one fused ``inject_scrub`` launch per *codec
group* with a single counter block crossing to host (DESIGN.md §9/§12).

Mask sources:
  * "host"   — the NumPy FaultField oracle, one field per leaf keyed exactly
    like the historical per-leaf path (``leaf_seed``), so the batched step is
    bit-identical to the per-leaf reference (tested);
  * "device" — one DeviceFaultField per codec group: counter-based
    jax.random, masks never exist in host memory (statistically equivalent,
    FIP holds).

Codecs (DESIGN.md §12): every memory domain selects a registered ECC scheme
(``codecs`` maps domain -> codec name; default everything on the built-in
``secded72``). Slots sharing a codec form one *group* with its own
concatenated planes and one fused kernel launch per voltage step — the
uniform-SECDED default is exactly one group whose planes alias the master
arrays, so the historical single-launch behaviour (and its bit patterns) is
unchanged. ``set_domain_codec`` re-encodes a domain under a stronger code at
runtime — the controller escalation path.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro import codes
from repro.core.faultsim import DeviceFaultField, FaultField
from repro.core.telemetry import DomainFaultStats, FaultStats
from repro.core.voltage import PlatformProfile
from repro.codes import DEFAULT_CODEC
from repro.kernels import ops as kops


def leaf_seed(base_seed: int, key: str) -> int:
    """Per-leaf fault-field seed; must stay stable across refactors — the
    fault pattern is a property of (silicon sample, rail), i.e. (seed, leaf)."""
    return (base_seed * 0x9E3779B1 + zlib.crc32(key.encode())) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class Slot:
    """Arena placement of one EccWeight leaf's planes."""

    key: str
    offset: int
    size: int
    shape: tuple
    domain: str = "all"


@dataclasses.dataclass
class _CodecGroup:
    """Slots sharing one ECC scheme: one fused launch per voltage step."""

    name: str
    codec: Any  # codes.Codec
    slot_ids: tuple  # indices into store.slots, arena order
    offsets: tuple  # per-slot word offset inside the group arena
    n_words: int
    lo: Any  # (n_words,) uint32 clean data
    hi: Any
    check: Any  # (n_words,) codec check dtype
    dom_ids: Any  # (n_words,) jnp int32 (store-global domain indices)
    dom_ids_np: np.ndarray
    device_field: DeviceFaultField


class PlaneStore:
    """Flat arena over a sequence of EccWeight leaves (clean planes, device).

    With a ``domain_key`` classifier the arena is partitioned into named
    memory domains (DESIGN.md §10): every slot belongs to one domain, and
    ``set_rails`` drives a separate rail voltage per domain through one fused
    inject+scrub launch (per codec group) with per-domain counter rows.
    ``profiles`` optionally gives each domain its own PlatformProfile
    (MoRS-style per-instance fault behaviour); rails without a dedicated
    profile use ``platform``. ``codecs`` maps domains to registered ECC
    schemes (str for all domains, dict for per-domain choices).
    """

    def __init__(
        self,
        leaves,
        keys,
        platform: PlatformProfile,
        seed: int = 0,
        mask_source: str = "host",
        domain_key=None,
        profiles=None,
        codecs=None,
    ):
        assert mask_source in ("host", "device"), mask_source
        assert len(leaves) == len(set(keys)), "leaf keys must be unique"
        self.platform = platform
        self.seed = int(seed)
        self.mask_source = mask_source
        self._profiles = dict(profiles or {})
        self._external_words: dict[str, int] = {}
        self._external_codecs: dict[str, str] = {}
        classify = domain_key if domain_key is not None else (lambda _k: "all")
        slots, off = [], 0
        los, his, pars = [], [], []
        for key, leaf in zip(keys, leaves):
            size = int(leaf.lo.size)
            slots.append(
                Slot(key, off, size, tuple(leaf.lo.shape), str(classify(key)))
            )
            los.append(leaf.lo.reshape(-1))
            his.append(leaf.hi.reshape(-1))
            pars.append(leaf.parity.reshape(-1))
            off += size
        # The arena owns the clean plane data; keep only plane-free leaf
        # metadata (scale/k/n/fuse) so the store doesn't hold a second full
        # copy of every plane.
        self._leaves = [
            dataclasses.replace(leaf, lo=None, hi=None, parity=None)
            for leaf in leaves
        ]
        self.slots = tuple(slots)
        self.n_words = off
        if los:
            self.lo = jnp.concatenate(los)
            self.hi = jnp.concatenate(his)
            self.parity = jnp.concatenate(pars)  # SECDED check bits, as packed
        else:
            self.lo = jnp.zeros((0,), jnp.uint32)
            self.hi = jnp.zeros((0,), jnp.uint32)
            self.parity = jnp.zeros((0,), jnp.uint8)
        # Domain order: first appearance in arena order (stable across runs
        # for a fixed leaf ordering); this is the counter row order.
        self.domains = tuple(dict.fromkeys(s.domain for s in self.slots))
        self._dom_index = {d: i for i, d in enumerate(self.domains)}
        dom_ids = np.zeros(self.n_words, np.int32)
        for s in self.slots:
            dom_ids[s.offset : s.offset + s.size] = self._dom_index[s.domain]
        self._dom_ids_np = dom_ids
        self._dom_ids = jnp.asarray(dom_ids) if self.n_words else jnp.zeros((0,), jnp.int32)
        # Per-domain codec choices (default: the built-in SECDED everywhere).
        if codecs is None:
            codecs = {}
        elif isinstance(codecs, str):
            codecs = {d: codecs for d in self.domains}
        self._codecs = {d: str(codecs.get(d, DEFAULT_CODEC)) for d in self.domains}
        for name in self._codecs.values():
            codes.get(name)  # fail fast on unknown codecs
        self._build_groups()

    # -- codec groups --------------------------------------------------------
    def codec_of(self, domain: str) -> str:
        return self._codecs.get(domain, DEFAULT_CODEC)

    def _build_groups(self) -> None:
        """(Re)build the per-codec sub-arenas from the master clean planes.

        The uniform-default case — every domain on one codec — produces a
        single group whose planes alias the master arrays (no copy, no
        re-encode for SECDED), keeping the historical memory footprint,
        launch count, and bit patterns.
        """
        by_codec: dict[str, list[int]] = {}
        for si, s in enumerate(self.slots):
            by_codec.setdefault(self.codec_of(s.domain), []).append(si)
        single = len(by_codec) == 1
        groups = []
        for cname, slot_ids in by_codec.items():
            codec = codes.get(cname)
            offsets, off = [], 0
            for si in slot_ids:
                offsets.append(off)
                off += self.slots[si].size
            if single:
                lo, hi = self.lo, self.hi
                dom_np = self._dom_ids_np
                dom = self._dom_ids
                dseed = self.seed
            else:
                sel = np.concatenate(
                    [
                        np.arange(
                            self.slots[si].offset,
                            self.slots[si].offset + self.slots[si].size,
                        )
                        for si in slot_ids
                    ]
                )
                idx = jnp.asarray(sel)
                lo, hi = self.lo[idx], self.hi[idx]
                dom_np = self._dom_ids_np[sel]
                dom = jnp.asarray(dom_np)
                # A stable, codec-keyed stream: regrouping must not change
                # the masks of groups whose membership did not change.
                dseed = (self.seed ^ zlib.crc32(cname.encode())) & 0x7FFFFFFF
            if cname == DEFAULT_CODEC and single:
                check = self.parity  # the leaves arrived SECDED-encoded
            else:
                check = kops.encode(lo, hi, codec=cname) if off else jnp.zeros(
                    (0,), jnp.dtype(codec.check_dtype)
                )
            groups.append(
                _CodecGroup(
                    name=cname,
                    codec=codec,
                    slot_ids=tuple(slot_ids),
                    offsets=tuple(offsets),
                    n_words=off,
                    lo=lo,
                    hi=hi,
                    check=check,
                    dom_ids=dom,
                    dom_ids_np=dom_np,
                    device_field=DeviceFaultField(
                        self.platform, off, seed=dseed, n_check=codec.n_check
                    ),
                )
            )
        self._groups = groups
        # Per-leaf host oracle fields, keyed like the historical per-leaf
        # path; the check-bitplane count follows the slot's codec.
        self._host_fields = {}
        for g in self._groups:
            for si in g.slot_ids:
                s = self.slots[si]
                self._host_fields[s.key] = FaultField(
                    self.domain_profile(s.domain),
                    s.size,
                    seed=leaf_seed(self.seed, s.key),
                    n_check=g.codec.n_check,
                )

    def set_domain_codec(self, domain: str, codec_name: str) -> None:
        """Re-protect ``domain`` under another registered code (the
        controller escalation path). Check planes are re-encoded from the
        clean master data; fault fields follow the new bitplane geometry.
        Other domains' groups are rebuilt with identical membership, seeds
        and geometry, so their mask streams are unchanged."""
        codes.get(codec_name)  # validate early
        assert domain in self.domains, (domain, self.domains)
        if self.codec_of(domain) == codec_name:
            return
        self._codecs[domain] = str(codec_name)
        self._build_groups()

    def codecs_by_domain(self) -> dict:
        out = {d: self.codec_of(d) for d in self.domains}
        out.update(self._external_codecs)
        return out

    def check_bits_by_domain(self) -> dict:
        """Check bits per 64-bit word for every domain (power weighting)."""
        return {d: codes.get(c).n_check for d, c in self.codecs_by_domain().items()}

    # -- domains -------------------------------------------------------------
    def domain_profile(self, domain: str) -> PlatformProfile:
        return self._profiles.get(domain, self.platform)

    def register_domain_words(
        self, domain: str, words: int, codec: str = DEFAULT_CODEC
    ) -> None:
        """Account storage that lives *outside* the weight arena — e.g. the
        paged KV cache (core/kvpages.py) — under a named domain.

        External domains join ``words_by_domain`` (power weighting, telemetry
        denominators) but not the arena's counter rows: their planes are not
        part of this store's fused inject+scrub launch, they carry their own
        fault machinery and report telemetry separately. ``codec`` records
        the external store's scheme for the redundancy-cost power weighting.
        """
        self._external_words[str(domain)] = int(words)
        self._external_codecs[str(domain)] = str(codec)

    def words_by_domain(self) -> dict:
        """Word count per domain (power weighting + telemetry denominators),
        arena slots plus any registered external domains."""
        counts = dict.fromkeys(self.domains, 0)
        for s in self.slots:
            counts[s.domain] += s.size
        for d, w in self._external_words.items():
            counts[d] = counts.get(d, 0) + w
        return counts

    # -- masks ---------------------------------------------------------------
    def _group_host_masks(self, g: _CodecGroup, volts: dict):
        """Concatenated per-leaf oracle masks for one group (bit-identical to
        the per-leaf path: same fields, same seeds, same order)."""
        mlos, mhis, mpars = [], [], []
        for si in g.slot_ids:
            s = self.slots[si]
            mk = self._host_fields[s.key].masks(volts[s.domain])
            mlos.append(mk.lo)
            mhis.append(mk.hi)
            mpars.append(mk.parity)
        cat = lambda xs, dt: (
            jnp.asarray(np.concatenate(xs)) if xs else jnp.zeros((0,), dt)
        )
        return (
            cat(mlos, jnp.uint32),
            cat(mhis, jnp.uint32),
            cat(mpars, jnp.dtype(g.codec.check_dtype)),
        )

    def _group_rates(self, g: _CodecGroup, volts: dict) -> np.ndarray:
        """Per-word fault rate vector for a {domain: voltage} schedule."""
        rates = np.zeros(g.n_words, np.float32)
        for d, i in self._dom_index.items():
            rates[g.dom_ids_np == i] = self.domain_profile(d).fault_rate(
                float(volts[d])
            )
        return rates

    def _group_masks(self, g: _CodecGroup, v):
        volts = v if isinstance(v, dict) else {d: v for d in self.domains}
        if self.mask_source == "device":
            # Per-domain profiles make the rate a function of the word's
            # domain even under a scalar rail, so route through the rate
            # vector (the host path gets this for free from its per-leaf
            # fields); profile-less stores keep the scalar fast path.
            if isinstance(v, dict) or self._profiles:
                return g.device_field.masks_for_rates(self._group_rates(g, volts))
            return g.device_field.masks(v)
        return self._group_host_masks(g, volts)

    # Legacy single-group helpers (kept for the uniform-codec arena).
    def host_masks(self, v):
        assert len(self._groups) == 1, "host_masks is a single-group helper"
        volts = v if isinstance(v, dict) else {d: v for d in self.domains}
        return self._group_host_masks(self._groups[0], volts)

    def masks(self, v):
        assert len(self._groups) == 1, "masks is a single-group helper"
        return self._group_masks(self._groups[0], v)

    # -- the batched voltage step --------------------------------------------
    def set_voltage(self, v: float, ecc: bool = True):
        """One fused inject+scrub launch per codec group for the whole store.

        Returns (faulty_leaves, FaultStats). faulty_leaves are the input
        EccWeight leaves with lo/hi/parity replaced by arena slices at rail
        voltage ``v`` (scale/k/n/fuse untouched).
        """
        if self.n_words == 0:
            return list(self._leaves), FaultStats()
        total = np.zeros(8, np.int64)
        planes = {}
        for g in self._groups:
            mlo, mhi, mpar = self._group_masks(g, v)
            flo, fhi, fpar, counters = kops.inject_scrub(
                g.lo, g.hi, g.check, mlo, mhi, mpar,
                codec=g.name, reencode=not ecc,
            )
            total += np.asarray(counters)
            planes[g.name] = (flo, fhi, fpar)
        stats = FaultStats.from_counters(total, words=self.n_words)
        return self._slice_leaves(planes), stats

    def set_rails(self, volts: dict, ecc: bool = True):
        """One fused inject+scrub launch per codec group with a separate rail
        per domain.

        ``volts`` maps every domain name to its rail voltage. Returns
        (faulty_leaves, DomainFaultStats) — one counter row per domain
        crosses to host. A uniform schedule is bit-identical to
        ``set_voltage`` (same fields/streams, same kernel math; tested).
        """
        missing = set(self.domains) - set(volts)
        assert not missing, f"rails missing for domains: {sorted(missing)}"
        if self.n_words == 0:
            return list(self._leaves), DomainFaultStats()
        total = np.zeros((len(self.domains), 8), np.int64)
        planes = {}
        for g in self._groups:
            mlo, mhi, mpar = self._group_masks(g, dict(volts))
            flo, fhi, fpar, counters = kops.inject_scrub_domains(
                g.lo, g.hi, g.check, mlo, mhi, mpar,
                g.dom_ids, len(self.domains), codec=g.name, reencode=not ecc,
            )
            total += np.asarray(counters)
            planes[g.name] = (flo, fhi, fpar)
        stats = FaultStats.from_counter_matrix(
            total, self.domains, self.words_by_domain()
        )
        return self._slice_leaves(planes), stats

    def _slice_leaves(self, planes: dict):
        """Reassemble per-leaf EccWeight views from per-group faulty planes."""
        out: list = [None] * len(self.slots)
        for g in self._groups:
            flo, fhi, fpar = planes[g.name]
            for si, off in zip(g.slot_ids, g.offsets):
                s = self.slots[si]
                out[si] = dataclasses.replace(
                    self._leaves[si],
                    lo=flo[off : off + s.size].reshape(s.shape),
                    hi=fhi[off : off + s.size].reshape(s.shape),
                    parity=fpar[off : off + s.size].reshape(s.shape),
                )
        return out
