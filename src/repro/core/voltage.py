"""Voltage rail model: fault-rate curves, power model, platform profiles.

Calibrated to the paper's measured anchors (DESIGN.md §1/§8):

  * V_nom = 1.0 V; guardband averages 39% across platforms (no faults >= V_min).
  * Fault rate grows exponentially from ~0 at V_min to R_crash at V_crash.
  * VC707 R_crash = 652 faults/Mbit; KC705-A = 4.1x KC705-B; VC707 >> KC705.
  * BRAM power (no ECC): 2.4 W @ 1.0 V, 0.31 W @ 0.61 V, 0.198 W @ 0.54 V.
    We fit P(V) = a*exp(b*V) + c exactly through the three anchors.
  * ECC adds 13 mW at 0.54 V (4.2%), scaled ~V^2 for dynamic power.
  * Accelerator: P_total = P_bram + P_rest with P_rest chosen so the
    nominal->crash saving is the paper's 25.2%.

TPUs expose no software voltage rail; this module is the *model* half of the
hardware adaptation (DESIGN.md §2) and every number is validated against the
paper in tests/test_voltage.py and benchmarks/.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import zlib

MBIT = 1024 * 1024.0


@dataclasses.dataclass(frozen=True)
class PlatformProfile:
    """Undervolting behaviour of one physical FPGA sample (paper Fig. 1)."""

    name: str
    v_nom: float
    v_min: float  # minimum safe voltage (guardband floor)
    v_crash: float  # lowest operational voltage
    rate_crash: float  # observed faults per bit at v_crash
    rate_floor: float  # rate at v_min (just-detectable; ~1 fault / tested mem)
    row_sigma: float  # lognormal per-row weakness (fault clustering)

    @property
    def guardband(self) -> float:
        return 1.0 - self.v_min / self.v_nom

    @property
    def k(self) -> float:
        """Exponential slope of the fault-rate curve (per volt)."""
        return math.log(self.rate_crash / self.rate_floor) / (self.v_min - self.v_crash)

    def fault_rate(self, v: float) -> float:
        """Observed per-bit fault probability at rail voltage ``v``.

        Zero inside the guardband (>= v_min), exponential below it. Below
        v_crash the device does not operate; we clamp to the crash rate so the
        model stays defined for sweeps that touch the boundary.
        """
        if v >= self.v_min:
            return 0.0
        v = max(v, self.v_crash)
        return self.rate_crash * math.exp(-self.k * (v - self.v_crash))

    def faults_per_mbit(self, v: float) -> float:
        return self.fault_rate(v) * MBIT


# Tested memory in the paper: 512 x (1024 x 64-bit) words (+8 parity) = 37.7 Mbit.
_TESTED_BITS = 512 * 1024 * 72.0

PLATFORMS = {
    # VC707: the paper's headline numbers. 652 faults/Mbit = 0.06% at 0.54 V.
    "vc707": PlatformProfile(
        name="vc707", v_nom=1.0, v_min=0.61, v_crash=0.54,
        rate_crash=652.0 / MBIT, rate_floor=1.0 / _TESTED_BITS, row_sigma=1.40,
    ),
    # KC705 samples: lower absolute rate than VC707 (power-optimised part),
    # 4.1x apart from each other (die-to-die variation, paper Fig. 1).
    "kc705a": PlatformProfile(
        name="kc705a", v_nom=1.0, v_min=0.605, v_crash=0.53,
        rate_crash=150.0 / MBIT, rate_floor=1.0 / _TESTED_BITS, row_sigma=1.40,
    ),
    "kc705b": PlatformProfile(
        name="kc705b", v_nom=1.0, v_min=0.615, v_crash=0.53,
        rate_crash=150.0 / 4.1 / MBIT, rate_floor=1.0 / _TESTED_BITS, row_sigma=1.40,
    ),
}


# ---------------------------------------------------------------------------
# Power model
# ---------------------------------------------------------------------------
_P_ANCHORS = ((0.54, 0.198), (0.61, 0.31), (1.0, 2.4))  # paper Table I(b), no ECC
ECC_POWER_AT_CRASH_W = 0.013  # +13 mW at 0.54 V (Table I(b))


@functools.lru_cache(maxsize=None)
def _fit_power() -> tuple[float, float, float]:
    """Fit P(V) = a*exp(b*V) + c exactly through the three paper anchors."""
    (v1, p1), (v2, p2), (v3, p3) = _P_ANCHORS

    def resid(b: float) -> float:
        # Given b, a is determined by two anchor differences; residual on ratio.
        return (p3 - p2) / (p2 - p1) - (
            (math.exp(b * v3) - math.exp(b * v2)) / (math.exp(b * v2) - math.exp(b * v1))
        )

    lo_b, hi_b = 0.1, 30.0
    for _ in range(200):
        mid = 0.5 * (lo_b + hi_b)
        if resid(lo_b) * resid(mid) <= 0:
            hi_b = mid
        else:
            lo_b = mid
    b = 0.5 * (lo_b + hi_b)
    a = (p2 - p1) / (math.exp(b * v2) - math.exp(b * v1))
    c = p1 - a * math.exp(b * v1)
    return a, b, c


def bram_power(v: float, ecc: bool = False) -> float:
    """BRAM rail power (W) at voltage ``v`` (dynamic + static, paper Table I)."""
    a, b, c = _fit_power()
    p = a * math.exp(b * v) + c
    if ecc:
        p += ECC_POWER_AT_CRASH_W * (v / 0.54) ** 2
    return p


# Accelerator: undervolting BRAMs 1.0 -> 0.54 V (with ECC) saves 25.2% of total.
_P_TOTAL_NOM = (bram_power(1.0) - 0.211) / 0.252  # ~8.69 W
P_REST_W = _P_TOTAL_NOM - bram_power(1.0)


def accelerator_power(v: float, ecc: bool = True) -> float:
    """Total NN-accelerator power with the BRAM rail at ``v`` (paper §IV)."""
    return P_REST_W + bram_power(v, ecc=ecc)


def power_saving(v_from: float, v_to: float, ecc: bool = False) -> float:
    """Fractional BRAM power saving when undervolting v_from -> v_to."""
    p0, p1 = bram_power(v_from, ecc=False), bram_power(v_to, ecc=ecc)
    return 1.0 - p1 / p0


# ---------------------------------------------------------------------------
# Multi-rail extension (DESIGN.md §10)
# ---------------------------------------------------------------------------
def derive_domain_profiles(
    base: PlatformProfile, domains, spread: float = 0.5, seed: int = 0
) -> dict:
    """Per-domain PlatformProfiles modelling block-to-block fault variation.

    The MLP follow-up (arXiv:2005.04737) and MoRS (arXiv:2110.05855) show
    different memory blocks / SRAM instances fault at measurably different
    rates under the same rail — the paper itself measures 4.1x between two
    KC705 samples. We scale each domain's fault-rate curve by a lognormal
    instance factor (E[f] = 1, deterministic in (seed, domain name)) while
    keeping the guardband and crash rail of the base silicon: the variation
    is in *where* faults appear below V_min, not in the operating envelope.
    """
    out = {}
    for d in domains:
        h = zlib.crc32(f"{seed}:{d}".encode()) / 0xFFFFFFFF  # [0, 1)
        # inverse-normal via erfinv on the centered uniform draw
        z = math.sqrt(2.0) * _erfinv(2.0 * h - 1.0)
        f = math.exp(spread * z - 0.5 * spread * spread)
        out[d] = dataclasses.replace(
            base,
            name=f"{base.name}/{d}",
            rate_crash=base.rate_crash * f,
        )
    return out


def _erfinv(x: float) -> float:
    """Scalar inverse error function (Winitzki approximation, |err|<2e-3)."""
    a = 0.147
    ln1mx2 = math.log(max(1.0 - x * x, 1e-30))
    t = 2.0 / (math.pi * a) + ln1mx2 / 2.0
    return math.copysign(math.sqrt(math.sqrt(t * t - ln1mx2 / a) - t), x)


def redundancy_factor(n_check: int) -> float:
    """Array-size scale of an ECC scheme vs the paper's measured geometry.

    The Table-I power anchors were measured on 72-bit BRAM words (64 data +
    8 built-in check bits); a codec with ``n_check`` check bits stores
    ``64 + n_check`` bits per word, so its array draws that bit-ratio of the
    measured curve (dynamic and leakage both scale ~linearly with bitcells).
    """
    return (64 + int(n_check)) / 72.0


def multi_rail_bram_power(
    volts: dict, words_by_domain: dict, ecc: bool = True,
    check_bits: dict | None = None,
) -> float:
    """Total BRAM power (W) with each domain's rail at its own voltage.

    The paper's P(V) curve is for the whole tested memory; a domain holding a
    fraction of the arena's words draws that fraction of the curve at *its*
    rail. Domains absent from ``words_by_domain`` draw nothing.
    ``check_bits`` (domain -> check bits per 64-bit word) folds each
    domain's ECC redundancy into its draw — the cost side of the codec
    escalation trade-off (DESIGN.md §12); omitted domains assume the
    measured 8-bit SECDED geometry (factor 1).
    """
    total = max(sum(words_by_domain.values()), 1)
    check_bits = check_bits or {}
    return sum(
        (words_by_domain[d] / total)
        * bram_power(float(v), ecc=ecc)
        * redundancy_factor(check_bits.get(d, 8))
        for d, v in volts.items()
        if d in words_by_domain
    )


def multi_rail_power_saving(
    volts: dict, words_by_domain: dict, ecc: bool = True, v_nom: float = 1.0,
    check_bits: dict | None = None,
) -> float:
    """Fractional BRAM saving of a per-domain schedule vs the nominal rail."""
    p0 = bram_power(v_nom, ecc=False)
    return 1.0 - multi_rail_bram_power(
        volts, words_by_domain, ecc=ecc, check_bits=check_bits
    ) / p0


# ---------------------------------------------------------------------------
# Mesh extension (DESIGN.md §13): every reliability shard is its own chip
# ---------------------------------------------------------------------------
def mesh_bram_power(
    schedules, words_by_shard, ecc: bool = True, check_bits: dict | None = None,
) -> float:
    """Total BRAM power (W) across a mesh of chips.

    ``schedules``: one {domain: voltage} rail schedule per shard;
    ``words_by_shard``: the matching {domain: words} dicts. Each shard's
    memory is a full chip-local BRAM array drawing the calibrated P(V)
    curve at its own rails — the mesh total is the plain sum (the rails are
    per-chip supplies; nothing is shared).
    """
    assert len(schedules) == len(words_by_shard), (
        len(schedules), len(words_by_shard),
    )
    return sum(
        multi_rail_bram_power(v, w, ecc=ecc, check_bits=check_bits)
        for v, w in zip(schedules, words_by_shard)
    )


def mesh_power_saving(
    schedules, words_by_shard, ecc: bool = True, v_nom: float = 1.0,
    check_bits: dict | None = None,
) -> float:
    """Fleet-level fractional BRAM saving vs every chip at the nominal rail.

    The denominator is n_shards x the nominal single-chip draw, so a
    `per_shard` schedule's extra headroom on strong chips shows up directly
    against the uniform worst-chip lock.
    """
    p0 = len(schedules) * bram_power(v_nom, ecc=False)
    return 1.0 - mesh_bram_power(
        schedules, words_by_shard, ecc=ecc, check_bits=check_bits
    ) / max(p0, 1e-30)
