"""Vmapped undervolting sweeps: (platform x voltage) and (schedule x domain)
fault-rate curves in one compiled call (DESIGN.md §10).

The historical benchmark loop (benchmarks/fig1_fault_rate.py) walked every
(platform, voltage) pair in Python: one mask generation + one decode dispatch
per point, ~25 points per platform. Because the device fault field is a pure
function of (key, rate, sigma), the whole grid is a `jax.vmap` over the
(rate, sigma) vectors instead: the random bits and the per-row weakness are
voltage-independent (FIP), so XLA hoists them out of the batched dimension
and the sweep reads the per-cell threshold comparison V times from registers
rather than regenerating the field V times from HBM.

Bit-compatibility: points are evaluated on exactly the `DeviceFaultField`
stream — same key schedule (seed ^ 0xECC, fold_in per chunk), same threshold
arithmetic — so a vmapped sweep point equals the per-voltage loop's masks
bit-for-bit (tested in tests/test_multirail.py).

Classification runs on a zero memory, like the paper's hardware test design:
the flip masks *are* the faulty codeword, the stored parity flips are the
parity-plane mask, and `ecc.decode` yields the per-word SECDED outcome.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import ecc
from repro.core.faultsim import _device_chunk_masks
from repro.core.telemetry import DomainFaultStats, FaultStats
from repro.core.voltage import PlatformProfile

# Dispatch accounting (compiled-call count, the sweep's analogue of
# kernels.ops.launch_count): one per chunk per public call, independent of
# how many (platform, voltage) points ride in the batch.
_dispatches = {"n": 0}


def reset_dispatch_count() -> None:
    _dispatches["n"] = 0


def dispatch_count() -> int:
    return _dispatches["n"]


def _classify_tallies(mlo, mhi, mpar):
    """Per-word boolean tally planes (telemetry.COUNTER_FIELDS lanes 0..6)
    plus flip counts, for one chunk's masks applied to a zero memory. Shares
    the outcome predicates with the fused kernel (inject_scrub)."""
    import jax.numpy as jnp

    from repro.kernels.inject_scrub import _popcount32, outcome_tallies

    _, _, status = ecc.decode(mlo, mhi, mpar)
    flips = _popcount32(mlo) + _popcount32(mhi) + _popcount32(mpar.astype(jnp.uint32))
    return outcome_tallies(False, status, flips), flips


def _point_counters(key, rate, sigma, m, burst=None):
    import jax.numpy as jnp

    tallies, flips = _classify_tallies(
        *_device_chunk_masks(key, m, rate, sigma, burst=burst)
    )
    cnt = [jnp.sum(t.astype(jnp.int32)) for t in tallies]
    cnt.append(jnp.sum(flips))
    return jnp.stack(cnt)


@functools.lru_cache(maxsize=None)
def _grid_chunk_fn(burst=None):
    """jit(vmap) over the (rate, sigma) point vectors; key and chunk size are
    shared across the batch (one fault field, many rails). ``burst`` is a
    hashable scenario.BurstProfile closed over as a compile-time constant —
    its auxiliary draws depend only on the key, so XLA hoists them out of
    the batch exactly like the base field."""
    import jax

    return jax.jit(
        jax.vmap(
            functools.partial(_point_counters, burst=burst),
            in_axes=(None, 0, 0, None),
        ),
        static_argnums=(3,),
    )


def _env_burst(env):
    """The environment's burst shape, normalized so a disabled profile hits
    the historical (burst-free) compile cache entry."""
    if env is None or not env.burst.enabled:
        return None
    return env.burst


def _domain_point_counters(key, rates_w, sigma, m, dom_ids, n_domains):
    """(n_domains, 8) counters for one chunk under a per-word rate vector."""
    import jax.numpy as jnp

    tallies, flips = _classify_tallies(*_device_chunk_masks(key, m, rates_w, sigma))
    rows = []
    for d in range(n_domains):
        sel = dom_ids == d
        cnt = [jnp.sum((t & sel).astype(jnp.int32)) for t in tallies]
        cnt.append(jnp.sum(jnp.where(sel, flips, 0)))
        rows.append(jnp.stack(cnt))
    return jnp.stack(rows)


@functools.lru_cache(maxsize=None)
def _schedule_chunk_fn():
    import jax

    return jax.jit(
        jax.vmap(_domain_point_counters, in_axes=(None, 0, None, None, None, None)),
        static_argnums=(3, 5),
    )


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (platform, voltage) grid point's aggregated fault statistics."""

    platform: str
    voltage: float
    stats: FaultStats


def sweep_platform_grid(
    grid, n_words: int, seed: int = 0, chunk_words: int = 1 << 18, env=None
) -> list[SweepPoint]:
    """Evaluate a flat (PlatformProfile, voltage) grid in one vmapped call.

    ``grid``: iterable of (profile, voltage) pairs — e.g. all three paper
    platforms x their critical-region voltage steps. Returns one SweepPoint
    per pair, in order. All points share the fault-field stream keyed by
    ``seed`` (the DeviceFaultField stream for the same geometry). ``env``
    (scenario.EnvironmentProfile) scales every rate by its flux multiplier
    and applies its burst shape; None is the historical sweep bit-for-bit.
    """
    import jax

    grid = list(grid)
    if not grid:
        return []
    rates = np.array(
        [p.fault_rate(float(v)) for p, v in grid], np.float32
    )
    if env is not None:
        rates *= np.float32(env.rate_multiplier)
    sigmas = np.array([p.row_sigma for p, _ in grid], np.float32)
    fn = _grid_chunk_fn(_env_burst(env))
    key = jax.random.PRNGKey(seed ^ 0xECC)
    total = np.zeros((len(grid), 8), np.int64)
    for ci, start in enumerate(range(0, n_words, chunk_words)):
        m = min(chunk_words, n_words - start)
        _dispatches["n"] += 1
        total += np.asarray(fn(jax.random.fold_in(key, ci), rates, sigmas, m))
    return [
        SweepPoint(p.name, float(v), FaultStats.from_counters(total[i], n_words))
        for i, (p, v) in enumerate(grid)
    ]


def sweep_platform_grid_sharded(
    grid,
    n_words: int,
    n_shards: int,
    seed: int = 0,
    chunk_words: int = 1 << 18,
    env=None,
    age: float = 0.0,
) -> list[list[SweepPoint]]:
    """Per-shard (platform, voltage) grids: one sweep per mesh chip.

    Every shard evaluates the same grid on its *own* fault population —
    shard 0 on the unsharded stream (``sweep_platform_grid`` row-for-row),
    shard s > 0 on ``fold_in(key, s)`` — the same key schedule the
    shard_map'd rail step derives from ``lax.axis_index``. Returns
    ``n_shards`` lists of SweepPoints; the per-shard first-DED voltages give
    the chip-to-chip V_min spread (arXiv:2005.04737) without touching a
    controller. ``env``/``age`` add the scenario axis: every shard's rates
    are scaled by the environment flux multiplier *and* its own aging-drift
    multiplier at soak age ``age`` (scenario.aging_multiplier), so the
    per-chip spread grows with the soak; at env=None or drift 0 every
    multiplier is 1.0 and the sweep is the historical one bit-for-bit.
    """
    import jax

    from repro.core import scenario

    grid = list(grid)
    if not grid or n_shards <= 0:
        return [[] for _ in range(max(n_shards, 0))]
    rates = np.array([p.fault_rate(float(v)) for p, v in grid], np.float32)
    if env is not None:
        rates *= np.float32(env.rate_multiplier)
    sigmas = np.array([p.row_sigma for p, _ in grid], np.float32)
    fn = _grid_chunk_fn(_env_burst(env))
    base = jax.random.PRNGKey(seed ^ 0xECC)
    out = []
    for s in range(n_shards):
        key = base if s == 0 else jax.random.fold_in(base, s)
        mult = np.float32(scenario.aging_multiplier(s, age, env, seed))
        total = np.zeros((len(grid), 8), np.int64)
        for ci, start in enumerate(range(0, n_words, chunk_words)):
            m = min(chunk_words, n_words - start)
            _dispatches["n"] += 1
            total += np.asarray(
                fn(jax.random.fold_in(key, ci), rates * mult, sigmas, m)
            )
        out.append(
            [
                SweepPoint(p.name, float(v), FaultStats.from_counters(total[i], n_words, shard=s))
                for i, (p, v) in enumerate(grid)
            ]
        )
    return out


def shard_vmin_spread(
    profile, voltages, n_words: int, n_shards: int, seed: int = 0,
    env=None, age: float = 0.0,
):
    """First-DED voltage per shard on a descending voltage walk.

    The mesh analogue of the paper's V_min measurement: walk ``voltages``
    (descending) per shard and report the last voltage *before* its first
    detected-uncorrectable event — the per-chip lock point a `per_shard`
    rail policy converges to. Returns a list of n_shards voltages; ``None``
    for a shard that DEDs already at the grid's top voltage (the grid holds
    no safe point for that chip — callers must widen it, not lock there).
    ``env``/``age`` thread the scenario axis through (see
    ``sweep_platform_grid_sharded``): under aging drift the per-chip V_mins
    fan out as the soak progresses.
    """
    grid = [(profile, float(v)) for v in voltages]
    per_shard = sweep_platform_grid_sharded(
        grid, n_words, n_shards, seed=seed, env=env, age=age
    )
    out = []
    for points in per_shard:
        vmin = None
        for pt in points:
            if pt.stats.detected > 0:
                break
            vmin = pt.voltage
        out.append(vmin)
    return out


def sweep_rail_schedules(
    schedules,
    domains,
    dom_ids: np.ndarray,
    profiles,
    seed: int = 0,
    chunk_words: int = 1 << 18,
) -> list[DomainFaultStats]:
    """Evaluate N per-domain rail schedules in one vmapped call.

    ``schedules``: iterable of {domain: voltage} mappings; ``domains`` the
    counter row order; ``dom_ids`` the (n_words,) arena domain index (e.g.
    ``PlaneStore._dom_ids_np``); ``profiles`` maps domain -> PlatformProfile
    (a single profile is broadcast). Returns one DomainFaultStats per
    schedule. Row sigma must be shared (one weakness field per arena).
    """
    import jax

    schedules = [dict(s) for s in schedules]
    domains = tuple(domains)
    if not schedules:
        return []
    if isinstance(profiles, PlatformProfile):
        profiles = {d: profiles for d in domains}
    sigmas = {profiles[d].row_sigma for d in domains}
    assert len(sigmas) == 1, "arena shares one row-weakness field"
    sigma = np.float32(sigmas.pop())
    dom_ids = np.asarray(dom_ids, np.int32)
    n_words = dom_ids.shape[0]
    words_by_domain = {
        d: int((dom_ids == i).sum()) for i, d in enumerate(domains)
    }
    # (S, n_words) per-word rates: schedule s gives word w its domain's rate
    dom_rates = np.array(
        [
            [profiles[d].fault_rate(float(s[d])) for d in domains]
            for s in schedules
        ],
        np.float32,
    )  # (S, D)
    rates_w = dom_rates[:, dom_ids]  # (S, n_words)
    fn = _schedule_chunk_fn()
    key = jax.random.PRNGKey(seed ^ 0xECC)
    total = np.zeros((len(schedules), len(domains), 8), np.int64)
    for ci, start in enumerate(range(0, n_words, chunk_words)):
        m = min(chunk_words, n_words - start)
        _dispatches["n"] += 1
        total += np.asarray(
            fn(
                jax.random.fold_in(key, ci),
                rates_w[:, start : start + m],
                sigma,
                m,
                dom_ids[start : start + m],
                len(domains),
            )
        )
    return [
        FaultStats.from_counter_matrix(total[s], domains, words_by_domain)
        for s in range(len(schedules))
    ]


# ---------------------------------------------------------------------------
# Codec scheme comparison (DESIGN.md §12)
# ---------------------------------------------------------------------------
def _codec_point_counters(key, rate, sigma, m, codec_name, burst=None):
    """(8,) counters for one chunk under one codec, on a zero memory.

    The flip masks *are* the faulty codeword; the per-word weakness draw is
    shared across codecs (faultsim._device_chunk_masks), so every scheme is
    judged on the same weak cells — only the check-bitplane count (and thus
    the exposed bit budget) differs. "corrected" counts *genuine*
    corrections exactly: the decoder's flip must restore the all-zero data
    word, which for SECDED coincides with the historical
    status==CORRECTED & flips==1 predicate.
    """
    import jax.numpy as jnp

    from repro import codes
    from repro.kernels.inject_scrub import _popcount32, outcome_tallies

    c = codes.get(codec_name)
    mlo, mhi, mpar = _device_chunk_masks(
        key, m, rate, sigma, n_check=c.n_check, burst=burst
    )
    synd = c.encode_jnp(mlo, mhi) ^ mpar.astype(jnp.uint32)
    flip_lo, flip_hi, _, status = c.classify_jnp(synd)
    flips = _popcount32(mlo) + _popcount32(mhi) + _popcount32(mpar.astype(jnp.uint32))
    # On the zero memory the masks are the codeword, so the genuine-
    # corrected plane (exact accounting, all codecs) is correction == mask.
    genuine = (status == 1) & (flip_lo == mlo) & (flip_hi == mhi)
    tallies = outcome_tallies(True, status, flips, genuine)
    cnt = [jnp.sum(t.astype(jnp.int32)) for t in tallies]
    cnt.append(jnp.sum(flips))
    return jnp.stack(cnt)


@functools.lru_cache(maxsize=None)
def _codec_chunk_fn(codec_name: str, burst=None):
    import jax

    return jax.jit(
        jax.vmap(
            functools.partial(
                _codec_point_counters, codec_name=codec_name, burst=burst
            ),
            in_axes=(None, 0, 0, None),
        ),
        static_argnums=(3,),
    )


def sweep_codec_schemes(
    codec_names, grid, n_words: int, seed: int = 0, chunk_words: int = 1 << 18,
    env=None,
) -> list[dict]:
    """Coverage vs check-bit overhead for every (codec, platform, voltage).

    ``grid``: iterable of (PlatformProfile, voltage) pairs, vmapped per codec
    exactly like ``sweep_platform_grid``. Returns one row dict per
    (codec, grid point) with the codec's geometry, the aggregated
    FaultStats counters, and the coverage fractions — the scheme-comparison
    table benchmarks/codec_compare.py emits (DESIGN.md §12). ``env``
    (scenario.EnvironmentProfile) adds the scenario axis — flux-scaled rates
    and the environment's burst shape — and tags each row with the
    environment name; None is the historical sweep bit-for-bit.
    """
    import jax

    grid = list(grid)
    rows: list[dict] = []
    if not grid:
        return rows
    rates = np.array([p.fault_rate(float(v)) for p, v in grid], np.float32)
    if env is not None:
        rates *= np.float32(env.rate_multiplier)
    sigmas = np.array([p.row_sigma for p, _ in grid], np.float32)
    for cname in codec_names:
        from repro import codes

        codec = codes.get(cname)
        fn = _codec_chunk_fn(cname, _env_burst(env))
        key = jax.random.PRNGKey(seed ^ 0xECC)
        total = np.zeros((len(grid), 8), np.int64)
        for ci, start in enumerate(range(0, n_words, chunk_words)):
            m = min(chunk_words, n_words - start)
            _dispatches["n"] += 1
            total += np.asarray(fn(jax.random.fold_in(key, ci), rates, sigmas, m))
        for i, (p, v) in enumerate(grid):
            st = FaultStats.from_counters(total[i], n_words)
            row_env = {} if env is None else {"environment": env.name}
            rows.append(
                {
                    **row_env,
                    "codec": cname,
                    "check_bits": codec.n_check,
                    "overhead": codec.overhead,
                    "platform": p.name,
                    "voltage": float(v),
                    **st.coverage_row(),
                }
            )
    return rows


# ---------------------------------------------------------------------------
# CLI (nightly CI lane): the paper's platform x voltage grid as JSON
# ---------------------------------------------------------------------------
def campaign_voltage_grid(
    profile: PlatformProfile, step: float = 0.02
) -> tuple:
    """The accuracy campaign's voltage axis for one platform (DESIGN.md §15).

    Nominal (the clean anchor every divergence score is measured against),
    the guardband edge ``v_min`` (last fault-free point by construction),
    then every ``step`` volts through the critical region down to the crash
    rail — the region where the paper's accuracy-vs-voltage curve earns its
    shape. Descending order, so campaign rows read like the rail walk.
    """
    grid = [profile.v_nom, profile.v_min]
    v = profile.v_min - step
    while v > profile.v_crash + 1e-9:
        grid.append(round(v, 3))
        v -= step
    grid.append(profile.v_crash)
    return tuple(grid)


def paper_grid():
    """All three paper platforms x their critical-region voltage steps."""
    from repro.core import voltage

    pairs = []
    for prof in voltage.PLATFORMS.values():
        vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
        pairs.extend((prof, float(v)) for v in vs)
    return pairs


def main(argv=None) -> None:
    """``python -m repro.core.sweep [--out FILE] [--words N] [--seed S]``

    Runs the full vmapped platform x voltage sweep on the paper's tested-
    memory geometry and writes one JSON row per grid point — the trajectory
    artifact the nightly CI lane uploads so fault-curve drift is visible
    across commits.
    """
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--out", default=None, help="JSON output path (default stdout)")
    ap.add_argument("--words", type=int, default=512 * 1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    points = sweep_platform_grid(paper_grid(), args.words, seed=args.seed)
    rows = [
        {
            "platform": p.platform,
            "voltage": p.voltage,
            **p.stats.coverage_row(),
            "coverage": p.stats.coverage(),
            "dispatches": dispatch_count(),
        }
        for p in points
    ]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} sweep points -> {args.out}")
    else:
        json.dump(rows, sys.stdout, indent=1)


if __name__ == "__main__":
    main()
