"""Hsiao (72,64) SECDED code construction — thin re-export.

The construction moved behind the pluggable codec interface in
``repro.codes.secded`` (DESIGN.md §12); this module keeps the historical
import surface (``hsiao.DATA_COLS``, ``hsiao.SYNDROME_LUT``, ...) alive for
the oracle decoder (`core/ecc.py`) and the SECDED Pallas kernels. The
tables are bit-identical to the pre-codec construction (tested).
"""

from repro.codes.secded import (  # noqa: F401
    CODE,
    DATA_COLS,
    LUT_CLEAN,
    LUT_DETECT,
    MASK_HI,
    MASK_LO,
    N_BITS,
    N_DATA,
    N_PARITY,
    SYNDROME_LUT,
    build_code,
)

__all__ = [
    "CODE",
    "DATA_COLS",
    "LUT_CLEAN",
    "LUT_DETECT",
    "MASK_HI",
    "MASK_LO",
    "N_BITS",
    "N_DATA",
    "N_PARITY",
    "SYNDROME_LUT",
    "build_code",
]
