"""Hsiao (72,64) SECDED code construction.

Xilinx 7-series BRAMs protect 64-bit words with 8 parity bits (UG473). The code
class is an odd-weight-column (Hsiao) SECDED code: every column of the 8x72
parity-check matrix H is distinct and has odd weight, the 8 parity positions use
the weight-1 identity columns, and the 64 data positions use all 56 weight-3
columns plus 8 weight-5 columns chosen to balance row weights (minimises the
XOR-tree depth in hardware; irrelevant for us but we keep the construction
faithful and deterministic).

Decode classification (syndrome s = stored_parity XOR recomputed_parity):
  s == 0                 -> NONE       (no error, or an aliasing >=4-bit error)
  s == a data column     -> CORRECTED  (flip that data bit)
  s == a parity column   -> CORRECTED  (parity-bit error; data untouched)
  otherwise              -> DETECTED   (uncorrectable; includes all 2-bit errors
                                        because XOR of two odd columns is even)

All constants are exported as numpy arrays so both the pure-jnp reference and
the Pallas kernels share one source of truth.
"""

from __future__ import annotations

import functools

import numpy as np

N_DATA = 64
N_PARITY = 8
N_BITS = N_DATA + N_PARITY  # 72-bit codeword

# Sentinel values in the syndrome lookup table.
LUT_CLEAN = -1  # syndrome 0
LUT_DETECT = -2  # uncorrectable (even-weight or unused odd syndrome)
# 0..63   -> flip that data bit
# 64..71  -> parity bit (64 + r) had the error; data is fine.


def _popcount8(x: int) -> int:
    return bin(x & 0xFF).count("1")


@functools.lru_cache(maxsize=None)
def build_code() -> dict:
    """Deterministically construct the Hsiao(72,64) code tables."""
    w3 = [c for c in range(256) if _popcount8(c) == 3]  # 56 columns
    w5 = [c for c in range(256) if _popcount8(c) == 5]  # 56 candidates

    # Row weights from the 56 weight-3 columns are already balanced (21 each).
    row_weight = np.zeros(N_PARITY, dtype=np.int64)
    for c in w3:
        for r in range(N_PARITY):
            row_weight[r] += (c >> r) & 1

    # Greedily pick 8 weight-5 columns to keep row weights balanced
    # (each row ends up covered exactly 5 extra times -> 26 total).
    chosen: list[int] = []
    for _ in range(8):
        best, best_key = None, None
        for c in w5:
            if c in chosen:
                continue
            trial = row_weight.copy()
            for r in range(N_PARITY):
                trial[r] += (c >> r) & 1
            key = (int(trial.max()), int(trial.var() * 1e6), c)
            if best_key is None or key < best_key:
                best, best_key = c, key
        chosen.append(best)
        for r in range(N_PARITY):
            row_weight[r] += (best >> r) & 1

    data_cols = np.array(w3 + chosen, dtype=np.uint8)  # 64 columns
    parity_cols = np.array([1 << r for r in range(N_PARITY)], dtype=np.uint8)
    assert len(set(data_cols.tolist()) | set(parity_cols.tolist())) == N_BITS

    # Encode masks: parity bit r covers data bit d iff bit r of data_cols[d].
    mask_lo = np.zeros(N_PARITY, dtype=np.uint32)
    mask_hi = np.zeros(N_PARITY, dtype=np.uint32)
    for d in range(N_DATA):
        col = int(data_cols[d])
        for r in range(N_PARITY):
            if (col >> r) & 1:
                if d < 32:
                    mask_lo[r] |= np.uint32(1 << d)
                else:
                    mask_hi[r] |= np.uint32(1 << (d - 32))

    # Syndrome lookup table (256 entries).
    lut = np.full(256, LUT_DETECT, dtype=np.int32)
    lut[0] = LUT_CLEAN
    for d in range(N_DATA):
        lut[int(data_cols[d])] = d
    for r in range(N_PARITY):
        lut[1 << r] = N_DATA + r

    return {
        "data_cols": data_cols,
        "parity_cols": parity_cols,
        "mask_lo": mask_lo,
        "mask_hi": mask_hi,
        "syndrome_lut": lut,
        "row_weight": row_weight,
    }


CODE = build_code()
DATA_COLS: np.ndarray = CODE["data_cols"]
MASK_LO: np.ndarray = CODE["mask_lo"]
MASK_HI: np.ndarray = CODE["mask_hi"]
SYNDROME_LUT: np.ndarray = CODE["syndrome_lut"]
