"""The reliability flight recorder (DESIGN.md §17).

One ``TraceRecorder`` rides through a whole run — engine construction,
rail autotuning, one or many serve streams, campaigns — collecting typed
events (obs/events.py) on a deterministic monotonic step-clock and feeding
a ``MetricsRegistry``. The clock advances on *logical* progress only
(decode dispatch steps, scrub intervals, autotune rounds — never
wall-clock), so two identical runs produce byte-identical traces and a
trace diff is a behaviour diff.

Instrumented call sites hold an ``Optional[TraceRecorder]`` and guard with
plain truthiness (``if rec: rec.emit(...)``) — the disabled path is one
``is not None``-equivalent check, no object construction, no allocation,
and bit-identical numerics (the recorder only ever *reads* host values the
stack already computed).
"""

from __future__ import annotations

from repro.obs.events import EVENT_KINDS, validate_event
from repro.obs.metrics import MetricsRegistry


class TraceRecorder:
    """Append-only typed event log + metrics on a deterministic step-clock.

    ``strict=True`` (default) validates every event against the schema at
    emit time — emission sites are few and host-side, so the cost is noise
    and a malformed event fails at the source instead of at export.
    """

    def __init__(self, strict: bool = True, profiler=None):
        self.events: list[dict] = []
        self.step = 0
        self.metrics = MetricsRegistry()
        self.strict = strict
        # Optional obs.profile.KernelProfiler. Wall-clock rows live on the
        # profiler, NOT in the event log — the log must stay deterministic.
        self.profiler = profiler

    def __bool__(self) -> bool:  # `if rec:` guards at instrumented sites
        return True

    def __len__(self) -> int:
        return len(self.events)

    # -- the step clock -----------------------------------------------------
    def advance(self, n: int = 1) -> int:
        """Advance the logical clock by ``n`` steps (n >= 0); returns it."""
        assert n >= 0, n
        self.step += int(n)
        return self.step

    # -- emission -----------------------------------------------------------
    def emit(
        self,
        kind: str,
        *,
        shard: int = -1,
        domain: str | None = None,
        request_id: int | None = None,
        **payload,
    ) -> dict:
        """Append one event at the current step; returns the event dict."""
        ev = {
            "seq": len(self.events),
            "step": self.step,
            "kind": kind,
            "shard": int(shard),
            "domain": domain,
            "request_id": None if request_id is None else int(request_id),
            **payload,
        }
        if self.strict:
            validate_event(ev)
        self.events.append(ev)
        return ev

    # -- queries (report/test helpers) --------------------------------------
    def of_kind(self, *kinds: str) -> list[dict]:
        for k in kinds:
            assert k in EVENT_KINDS, k
        want = set(kinds)
        return [e for e in self.events if e["kind"] in want]

    def shards(self) -> list[int]:
        return sorted({e["shard"] for e in self.events})

    # -- exports (thin delegates; see obs/export.py) ------------------------
    def to_jsonl(self, path=None) -> str:
        from repro.obs import export

        return export.to_jsonl(self, path)

    def to_chrome_trace(self, path=None) -> dict:
        from repro.obs import export

        return export.to_chrome_trace(self, path)

    def summary_markdown(self) -> str:
        from repro.obs import export

        return export.summary_markdown(self)
