"""Metrics registry: counters, gauges, histograms (DESIGN.md §17).

Aggregate (end-of-run) views of the quantities the event log records over
time. Everything is plain host Python fed from values the stack already
computes — registering and updating metrics never touches device state, so
a run with metrics is bit-identical to one without.

Metric identity is ``(name, labels)`` where labels is a sorted tuple of
``(key, value)`` pairs — the usual dimensional-metrics model (per-domain /
per-shard rail gauges share a name and differ in labels). ``to_dict()`` is
deterministic (sorted) so two identical runs serialize identically.
"""

from __future__ import annotations

import dataclasses

from repro.core.telemetry import COUNTER_FIELDS, FaultStats

#: Default histogram bucket upper bounds (values are engine steps / counts;
#: the last implicit bucket is +inf).
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


@dataclasses.dataclass
class Counter:
    """Monotone event count."""

    value: int = 0

    def inc(self, v: int = 1) -> None:
        assert v >= 0, f"counters are monotone (inc {v})"
        self.value += int(v)

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


@dataclasses.dataclass
class Gauge:
    """Last-set value plus its observed range (min/max/n)."""

    value: float | None = None
    min: float | None = None
    max: float | None = None
    n: int = 0

    def set(self, v) -> None:
        v = float(v)
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.n += 1

    def snapshot(self) -> dict:
        return {
            "type": "gauge", "value": self.value,
            "min": self.min, "max": self.max, "n": self.n,
        }


class Histogram:
    """Fixed-bucket histogram with sum/count/min/max."""

    def __init__(self, buckets=DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # last = +inf overflow
        self.sum = 0.0
        self.count = 0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, v) -> None:
        v = float(v)
        i = len(self.buckets)
        for j, ub in enumerate(self.buckets):
            if v <= ub:
                i = j
                break
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "buckets": list(self.buckets),
            "counts": list(self.counts),
            "sum": self.sum, "count": self.count,
            "min": self.min, "max": self.max, "mean": self.mean,
        }


class MetricsRegistry:
    """Name+labels -> metric instance; create-on-first-touch."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, labels: dict, factory):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = factory()
        return m

    def counter(self, name: str, **labels) -> Counter:
        m = self._get(name, labels, Counter)
        assert isinstance(m, Counter), f"{name}: registered as {type(m).__name__}"
        return m

    def gauge(self, name: str, **labels) -> Gauge:
        m = self._get(name, labels, Gauge)
        assert isinstance(m, Gauge), f"{name}: registered as {type(m).__name__}"
        return m

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, **labels) -> Histogram:
        m = self._get(name, labels, lambda: Histogram(buckets))
        assert isinstance(m, Histogram), f"{name}: registered as {type(m).__name__}"
        return m

    def observe_fault_stats(self, prefix: str, st: FaultStats, **labels) -> None:
        """Fold one FaultStats into ``<prefix>.<counter>`` counters — the
        bridge from the existing telemetry containers. Accepts FaultStats,
        DomainFaultStats (one label set per domain) or ShardFaultStats
        (per shard per domain)."""
        by_shard = getattr(st, "by_shard", None)
        if by_shard is not None:
            for row in by_shard:
                self.observe_fault_stats(prefix, row, **labels)
            return
        by_domain = getattr(st, "by_domain", None)
        if by_domain is not None:
            for d, row in by_domain.items():
                self.observe_fault_stats(prefix, row, domain=d, **labels)
            return
        if st.shard >= 0 and "shard" not in labels:
            labels["shard"] = st.shard
        self.counter(f"{prefix}.words", **labels).inc(st.words)
        for f in COUNTER_FIELDS:
            self.counter(f"{prefix}.{f}", **labels).inc(getattr(st, f))

    def get(self, name: str, **labels):
        """The metric instance, or None if never touched."""
        return self._metrics.get((name, _label_key(labels)))

    def __len__(self) -> int:
        return len(self._metrics)

    def to_dict(self) -> dict:
        """Deterministic {"name{k=v,...}": snapshot} mapping (sorted)."""
        out = {}
        for (name, labels) in sorted(
            self._metrics, key=lambda k: (k[0], str(k[1]))
        ):
            tag = ",".join(f"{k}={v}" for k, v in labels)
            key = f"{name}{{{tag}}}" if tag else name
            out[key] = self._metrics[(name, labels)].snapshot()
        return out
