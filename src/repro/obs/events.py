"""Typed trace events for the reliability flight recorder (DESIGN.md §17).

Every event is a flat JSON-serializable dict with a fixed envelope:

    seq         monotone event index (total causal order of the whole run)
    step        deterministic step-clock value (engine decode steps / scrub
                intervals / autotune rounds — never wall-clock)
    kind        one of EVENT_KINDS
    shard       mesh shard id (-1: unsharded / fleet-wide)
    domain      memory domain name or None (events not tied to a rail)
    request_id  serving request id or None

plus the kind's payload fields. The registry below is the schema the CI
smoke validates emitted JSONL against: a kind must be registered, the
envelope must be complete and well-typed, and every required payload field
must be present (extra payload fields are allowed — the schema is a floor,
not a ceiling, so exporters stay forward-compatible).
"""

from __future__ import annotations

ENVELOPE_FIELDS = ("seq", "step", "kind", "shard", "domain", "request_id")

#: kind -> required payload field names (beyond the envelope).
EVENT_KINDS: dict[str, tuple[str, ...]] = {
    # serve lifecycle -------------------------------------------------------
    "serve_begin": ("n_requests", "n_lanes", "scrub_interval"),
    "serve_end": ("steps", "preemptions", "finished"),
    # scheduler -------------------------------------------------------------
    "admit": ("lane", "prompt_len", "shared_tokens"),
    "preempt": ("lane", "pages_freed", "preemptions"),
    "page_grow": ("pages_added", "pages_total"),
    "retire": ("tokens", "latency_steps", "first_token_step", "preemptions"),
    "gauge": ("name", "value"),
    # prefix-sharing trie ---------------------------------------------------
    "prefix_hit": ("tokens", "pages"),
    "trie_insert": ("pages",),
    "trie_evict": ("pages",),
    # speculative decode ----------------------------------------------------
    "spec_block": ("k", "lanes", "emitted", "slots"),
    # rails / ECC -----------------------------------------------------------
    "rail_step": (
        "action", "voltage", "codec",
        "corrected", "detected", "silent", "words", "divergence",
    ),
    "codec_escalate": ("codec_from", "codec_to", "ded_rate", "acc_trip"),
    "canary_trip": ("divergence", "slo"),
    "canary_probe": ("divergence",),
    "kv_scrub": (
        "interval", "voltage", "codec",
        "corrected", "detected", "silent", "words",
    ),
    "kv_codec_change": ("codec",),
    "shared_ded_recovery": ("pages", "preempted"),
    # campaigns -------------------------------------------------------------
    "campaign_point": ("voltage", "codec", "divergence"),
}


class EventSchemaError(ValueError):
    """An emitted event does not satisfy the registered schema."""


def validate_event(ev: dict) -> dict:
    """Validate one event dict against the schema; returns it unchanged.

    Raises EventSchemaError on an unknown kind, a missing/ill-typed
    envelope field, or a missing required payload field.
    """
    for f in ENVELOPE_FIELDS:
        if f not in ev:
            raise EventSchemaError(f"missing envelope field {f!r}: {ev}")
    kind = ev["kind"]
    if kind not in EVENT_KINDS:
        raise EventSchemaError(f"unknown event kind {kind!r}")
    if not isinstance(ev["seq"], int) or not isinstance(ev["step"], int):
        raise EventSchemaError(f"seq/step must be ints: {ev}")
    if not isinstance(ev["shard"], int):
        raise EventSchemaError(f"shard must be an int: {ev}")
    if ev["domain"] is not None and not isinstance(ev["domain"], str):
        raise EventSchemaError(f"domain must be a str or None: {ev}")
    if ev["request_id"] is not None and not isinstance(ev["request_id"], int):
        raise EventSchemaError(f"request_id must be an int or None: {ev}")
    missing = [f for f in EVENT_KINDS[kind] if f not in ev]
    if missing:
        raise EventSchemaError(f"{kind}: missing payload fields {missing}")
    return ev


def validate_events(events) -> int:
    """Validate an iterable of events + the seq total order; returns the
    count (the CI smoke's one-call check)."""
    n = 0
    prev = -1
    for ev in events:
        validate_event(ev)
        if ev["seq"] <= prev:
            raise EventSchemaError(
                f"seq not strictly increasing: {ev['seq']} after {prev}"
            )
        prev = ev["seq"]
        n += 1
    return n
