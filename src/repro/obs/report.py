"""Markdown run-summary renderer for flight-recorder JSONL traces.

    python -m repro.obs.report trace.jsonl [--out SUMMARY.md] [--validate]

Reads an event log written by ``TraceRecorder.to_jsonl`` (or any JSONL of
schema-conforming events), optionally validates every line against the
event schema, and renders the same markdown summary the in-process
``recorder.summary_markdown()`` produces.
"""

from __future__ import annotations

import argparse

from repro.obs import events as events_mod
from repro.obs import export


def render(path, validate: bool = False) -> str:
    events = export.read_jsonl(path)
    if validate:
        events_mod.validate_events(events)
    return export.summary_markdown(events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="JSONL event log path")
    ap.add_argument("--out", default=None, help="write markdown here (default stdout)")
    ap.add_argument(
        "--validate", action="store_true",
        help="validate every event against the schema first",
    )
    args = ap.parse_args(argv)
    md = render(args.trace, validate=args.validate)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
    else:
        print(md, end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
