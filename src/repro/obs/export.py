"""Trace exporters: JSONL, Chrome trace-event JSON, markdown summary.

JSONL is the canonical archival format — one event per line, sorted keys,
no whitespace variance — so byte-equality of two logs is semantic equality
of two runs (the determinism contract tests/test_obs.py pins).

The Chrome trace export loads in Perfetto / chrome://tracing: one process
("track") per mesh shard, threads for the scheduler, per-request lifetime
spans, the kv scrub cadence and each voltage rail; gauges become counter
tracks. The trace ``ts`` axis is the deterministic step-clock (1 step ==
1 "microsecond" — logical time, not wall time).
"""

from __future__ import annotations

import json

#: Fixed thread-track ids inside each shard's process track.
TID_SERVE = 0
TID_REQUESTS = 1
TID_KV = 2
TID_RAIL_BASE = 10  # + sorted-domain index


def event_lines(recorder_or_events) -> list[str]:
    events = getattr(recorder_or_events, "events", recorder_or_events)
    return [
        json.dumps(ev, sort_keys=True, separators=(",", ":"))
        for ev in events
    ]


def to_jsonl(recorder_or_events, path=None) -> str:
    """Serialize to JSONL (one event per line); write to ``path`` if given."""
    text = "\n".join(event_lines(recorder_or_events))
    if text:
        text += "\n"
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


def read_jsonl(path) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _pid(shard: int) -> int:
    return shard + 1  # shard -1 (unsharded/global) -> pid 0


def to_chrome_trace(recorder_or_events, path=None) -> dict:
    """Chrome trace-event JSON with per-shard tracks (Perfetto-loadable)."""
    events = getattr(recorder_or_events, "events", recorder_or_events)
    shards = sorted({e["shard"] for e in events})
    domains = sorted({e["domain"] for e in events if e["domain"] is not None})
    tid_of_domain = {d: TID_RAIL_BASE + i for i, d in enumerate(domains)}
    out: list[dict] = []
    for s in shards:
        pid = _pid(s)
        name = "global" if s < 0 else f"shard {s}"
        out.append({
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": name},
        })
        for tid, tname in (
            (TID_SERVE, "serve"), (TID_REQUESTS, "requests"), (TID_KV, "kv"),
        ):
            out.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": tname},
            })
        for d in domains:
            out.append({
                "ph": "M", "name": "thread_name", "pid": pid,
                "tid": tid_of_domain[d], "args": {"name": f"rail:{d}"},
            })
    admit_step: dict = {}  # (shard, rid) -> first admission step
    for ev in events:
        pid = _pid(ev["shard"])
        kind = ev["kind"]
        args = {
            k: v for k, v in ev.items()
            if k not in ("seq", "step", "kind", "shard")
        }
        if kind == "gauge":
            out.append({
                "ph": "C", "name": ev["name"], "ts": ev["step"], "pid": pid,
                "args": {"value": ev["value"]},
            })
            continue
        if kind == "admit":
            admit_step.setdefault((ev["shard"], ev["request_id"]), ev["step"])
        if kind == "retire":
            t0 = admit_step.get(
                (ev["shard"], ev["request_id"]),
                ev["step"] - ev["latency_steps"],
            )
            out.append({
                "ph": "X", "name": f"req {ev['request_id']}", "ts": t0,
                "dur": max(ev["step"] - t0, 1), "pid": pid,
                "tid": TID_REQUESTS, "args": args,
            })
        if ev["domain"] is not None and kind in (
            "rail_step", "codec_escalate", "canary_trip"
        ):
            tid = tid_of_domain[ev["domain"]]
        elif kind in ("kv_scrub", "kv_codec_change", "shared_ded_recovery"):
            tid = TID_KV
        else:
            tid = TID_SERVE
        out.append({
            "ph": "i", "name": kind, "ts": ev["step"], "pid": pid,
            "tid": tid, "s": "t", "args": args,
        })
        if kind == "rail_step":
            out.append({
                "ph": "C", "name": f"V[{ev['domain']}]", "ts": ev["step"],
                "pid": pid, "args": {"value": ev["voltage"]},
            })
    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f, sort_keys=True)
    return trace


# -- markdown run summary ----------------------------------------------------

def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def summary_markdown(recorder_or_events) -> str:
    """Human-readable run summary (the `python -m repro.obs.report` body)."""
    events = getattr(recorder_or_events, "events", recorder_or_events)
    metrics = getattr(recorder_or_events, "metrics", None)
    lines = ["# Reliability flight-recorder summary", ""]
    if not events:
        lines.append("_empty trace_")
        return "\n".join(lines) + "\n"
    shards = sorted({e["shard"] for e in events})
    lines += [
        f"- events: **{len(events)}**, final step-clock: "
        f"**{events[-1]['step']}**",
        f"- shards: {', '.join(str(s) for s in shards)}",
        "",
        "## Event counts",
        "",
        "| kind | count |",
        "|---|---|",
    ]
    counts: dict = {}
    for e in events:
        counts[e["kind"]] = counts.get(e["kind"], 0) + 1
    for k in sorted(counts):
        lines.append(f"| {k} | {counts[k]} |")

    rails = [e for e in events if e["kind"] == "rail_step"]
    if rails:
        lines += [
            "", "## Rail trajectories", "",
            "| shard | domain | steps | V first | V last | codec last "
            "| trips | escalations |",
            "|---|---|---|---|---|---|---|---|",
        ]
        by_rail: dict = {}
        for e in rails:
            by_rail.setdefault((e["shard"], e["domain"]), []).append(e)
        for (s, d), evs in sorted(by_rail.items(), key=str):
            trips = sum(
                1 for e in evs
                if "backoff" in e["action"] or e["action"] == "floor"
            )
            esc = sum(1 for e in evs if e["action"] == "escalate")
            lines.append(
                f"| {s} | {d} | {len(evs)} | {_fmt(evs[0]['voltage'])} "
                f"| {_fmt(evs[-1]['voltage'])} | {evs[-1]['codec']} "
                f"| {trips} | {esc} |"
            )

    scrubs = [e for e in events if e["kind"] == "kv_scrub"]
    if scrubs:
        det = sum(e["detected"] for e in scrubs)
        cor = sum(e["corrected"] for e in scrubs)
        sil = sum(e["silent"] for e in scrubs)
        lines += [
            "", "## KV scrub",
            "",
            f"- intervals: {len(scrubs)}, corrected: {cor}, detected: {det}, "
            f"silent: {sil}",
            f"- final kv voltage: "
            f"{_fmt(scrubs[-1]['voltage'])} V ({scrubs[-1]['codec']})",
        ]

    retires = [e for e in events if e["kind"] == "retire"]
    if retires:
        lat = [e["latency_steps"] for e in retires]
        lines += [
            "", "## Requests", "",
            f"- finished: {len(retires)}, mean latency: "
            f"{_fmt(sum(lat) / len(lat))} steps, max: {max(lat)}",
        ]
        pre = sum(e["preemptions"] for e in retires)
        if pre:
            lines.append(f"- preemptions experienced: {pre}")
    specs = [e for e in events if e["kind"] == "spec_block"]
    if specs:
        slots = sum(e["slots"] for e in specs)
        emitted = sum(e["emitted"] for e in specs)
        lines += [
            "", "## Speculative decode", "",
            f"- dispatches: {len(specs)}, emitted {emitted}/{slots} "
            f"slots (acceptance {_fmt(emitted / max(slots, 1))})",
        ]

    if metrics is not None and len(metrics):
        lines += [
            "", "## Metrics", "",
            "| metric | value |",
            "|---|---|",
        ]
        for name, snap in metrics.to_dict().items():
            if snap["type"] == "counter":
                val = _fmt(snap["value"])
            elif snap["type"] == "gauge":
                val = (
                    f"{_fmt(snap['value'])} "
                    f"(min {_fmt(snap['min'])}, max {_fmt(snap['max'])})"
                )
            else:
                val = (
                    f"mean {_fmt(snap['mean'])}, n {snap['count']}, "
                    f"max {_fmt(snap['max'])}"
                )
            lines.append(f"| `{name}` | {val} |")

    profiler = getattr(recorder_or_events, "profiler", None)
    if profiler is not None and profiler.rows:
        lines += ["", profiler.summary_markdown()]
    return "\n".join(lines) + "\n"
