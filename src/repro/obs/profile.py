"""Opt-in wall-clock kernel profiling hooks (DESIGN.md §17).

Wall-clock is the one thing the deterministic trace must never contain, so
profiling rows live here, beside the recorder rather than inside it. A
``KernelProfiler`` is installed globally (``enable()``); instrumented
dispatch sites route through :func:`call`, which is a single module-global
``None`` check when profiling is off — the hot path pays nothing and the
dispatch result is returned untouched either way.

When profiling is on, each call is bracketed with ``jax.block_until_ready``
on the dispatch *result* (async dispatch would otherwise attribute device
time to whoever synchronizes next) and the row is tagged ``interpret`` or
``compiled`` from the kernel backend actually in force
(kernels/backend.resolve) — the BENCH trajectory story's key column.

Besides the timing rows, the profiler carries *gauges*: wall-clock-derived
scalars that are observations about overlap/efficiency rather than per-call
latencies — e.g. ``serve.scrub_overlap_frac``, the fraction of each async
scrub's dispatch-to-counters-ready window that decode blocks covered
(DESIGN.md §18). Gauges live here and NOT in the recorder's metrics for the
same reason the timing rows do: wall-clock must never enter the
deterministic trace.
"""

from __future__ import annotations

import time


class KernelProfiler:
    """Aggregating wall-clock rows for named dispatch sites."""

    def __init__(self):
        self.rows: dict[str, dict] = {}
        self.gauges: dict[str, dict] = {}

    def record_gauge(self, name: str, value: float) -> None:
        """Observe one wall-clock-derived scalar (running mean + last +
        min/max), e.g. the §18 scrub overlap fraction."""
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = {
                "name": name, "n": 0, "sum": 0.0,
                "last": 0.0, "min": None, "max": None,
            }
        v = float(value)
        g["n"] += 1
        g["sum"] += v
        g["last"] = v
        g["min"] = v if g["min"] is None else min(g["min"], v)
        g["max"] = v if g["max"] is None else max(g["max"], v)

    def gauge_rows(self) -> list[dict]:
        return [
            {**g, "mean": g["sum"] / max(g["n"], 1)}
            for _, g in sorted(self.gauges.items())
        ]

    def record(self, name: str, ms: float) -> None:
        row = self.rows.get(name)
        if row is None:
            row = self.rows[name] = {
                "name": name, "calls": 0, "total_ms": 0.0,
                "min_ms": None, "max_ms": 0.0, "backend": backend_tag(),
            }
        row["calls"] += 1
        row["total_ms"] += ms
        row["min_ms"] = ms if row["min_ms"] is None else min(row["min_ms"], ms)
        row["max_ms"] = max(row["max_ms"], ms)

    def to_rows(self) -> list[dict]:
        """BENCH-shaped rows (sorted by name, mean included)."""
        return [
            {**r, "mean_ms": r["total_ms"] / max(r["calls"], 1)}
            for _, r in sorted(self.rows.items())
        ]

    def summary_markdown(self) -> str:
        lines = [
            "## Kernel profile (wall-clock)", "",
            "| dispatch | backend | calls | mean ms | min ms | max ms |",
            "|---|---|---|---|---|---|",
        ]
        for r in self.to_rows():
            lines.append(
                f"| {r['name']} | {r['backend']} | {r['calls']} "
                f"| {r['mean_ms']:.3f} | {r['min_ms']:.3f} "
                f"| {r['max_ms']:.3f} |"
            )
        if self.gauges:
            lines += [
                "", "| gauge | n | mean | last | min | max |",
                "|---|---|---|---|---|---|",
            ]
            for g in self.gauge_rows():
                lines.append(
                    f"| {g['name']} | {g['n']} | {g['mean']:.3f} "
                    f"| {g['last']:.3f} | {g['min']:.3f} | {g['max']:.3f} |"
                )
        return "\n".join(lines) + "\n"


_ACTIVE: KernelProfiler | None = None


def backend_tag() -> str:
    """``interpret`` / ``compiled``: which Pallas lowering is in force."""
    from repro.kernels import backend as _backend

    return _backend.tag()


def gauge(name: str, value: float) -> None:
    """Record a wall-clock-derived gauge on the active profiler (no-op —
    one global ``None`` check — when profiling is off)."""
    if _ACTIVE is not None:
        _ACTIVE.record_gauge(name, value)


def enable(profiler: KernelProfiler | None = None) -> KernelProfiler:
    """Install (and return) the active profiler."""
    global _ACTIVE
    _ACTIVE = profiler or KernelProfiler()
    return _ACTIVE


def disable() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> KernelProfiler | None:
    return _ACTIVE


def call(name: str, fn, *args, **kwargs):
    """Dispatch ``fn(*args, **kwargs)``, profiled when a profiler is active.

    The off path is one global ``None`` check; the on path blocks on the
    result so the row measures the dispatch it brackets, not the next sync
    point downstream.
    """
    if _ACTIVE is None:
        return fn(*args, **kwargs)
    import jax

    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    _ACTIVE.record(name, (time.perf_counter() - t0) * 1e3)
    return out
