"""Opt-in wall-clock kernel profiling hooks (DESIGN.md §17).

Wall-clock is the one thing the deterministic trace must never contain, so
profiling rows live here, beside the recorder rather than inside it. A
``KernelProfiler`` is installed globally (``enable()``); instrumented
dispatch sites route through :func:`call`, which is a single module-global
``None`` check when profiling is off — the hot path pays nothing and the
dispatch result is returned untouched either way.

When profiling is on, each call is bracketed with ``jax.block_until_ready``
on the dispatch *result* (async dispatch would otherwise attribute device
time to whoever synchronizes next) and the row is tagged ``interpret`` or
``compiled`` from the kernel backend actually in force
(kernels/ops.use_interpret) — the BENCH trajectory story's key column.
"""

from __future__ import annotations

import time


class KernelProfiler:
    """Aggregating wall-clock rows for named dispatch sites."""

    def __init__(self):
        self.rows: dict[str, dict] = {}

    def record(self, name: str, ms: float) -> None:
        row = self.rows.get(name)
        if row is None:
            row = self.rows[name] = {
                "name": name, "calls": 0, "total_ms": 0.0,
                "min_ms": None, "max_ms": 0.0, "backend": backend_tag(),
            }
        row["calls"] += 1
        row["total_ms"] += ms
        row["min_ms"] = ms if row["min_ms"] is None else min(row["min_ms"], ms)
        row["max_ms"] = max(row["max_ms"], ms)

    def to_rows(self) -> list[dict]:
        """BENCH-shaped rows (sorted by name, mean included)."""
        return [
            {**r, "mean_ms": r["total_ms"] / max(r["calls"], 1)}
            for _, r in sorted(self.rows.items())
        ]

    def summary_markdown(self) -> str:
        lines = [
            "## Kernel profile (wall-clock)", "",
            "| dispatch | backend | calls | mean ms | min ms | max ms |",
            "|---|---|---|---|---|---|",
        ]
        for r in self.to_rows():
            lines.append(
                f"| {r['name']} | {r['backend']} | {r['calls']} "
                f"| {r['mean_ms']:.3f} | {r['min_ms']:.3f} "
                f"| {r['max_ms']:.3f} |"
            )
        return "\n".join(lines) + "\n"


_ACTIVE: KernelProfiler | None = None


def backend_tag() -> str:
    """``interpret`` / ``compiled``: which Pallas lowering is in force."""
    from repro.kernels import ops as kops

    return "interpret" if kops.use_interpret() else "compiled"


def enable(profiler: KernelProfiler | None = None) -> KernelProfiler:
    """Install (and return) the active profiler."""
    global _ACTIVE
    _ACTIVE = profiler or KernelProfiler()
    return _ACTIVE


def disable() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> KernelProfiler | None:
    return _ACTIVE


def call(name: str, fn, *args, **kwargs):
    """Dispatch ``fn(*args, **kwargs)``, profiled when a profiler is active.

    The off path is one global ``None`` check; the on path blocks on the
    result so the row measures the dispatch it brackets, not the next sync
    point downstream.
    """
    if _ACTIVE is None:
        return fn(*args, **kwargs)
    import jax

    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    _ACTIVE.record(name, (time.perf_counter() - t0) * 1e3)
    return out
