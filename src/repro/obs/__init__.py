"""repro.obs — the reliability flight recorder (DESIGN.md §17).

One deterministic, causally-ordered record of what the reliability stack
did and why: typed trace events on a step-clock (never wall-clock), a
metrics registry fed from the existing FaultStats containers, JSONL /
Chrome-trace / markdown exporters, and opt-in wall-clock kernel profiling
hooks kept strictly outside the deterministic event log.

Quick use::

    from repro.obs import TraceRecorder
    rec = TraceRecorder()
    eng = ServingEngine(cfg, params, rel, recorder=rec)
    eng.serve(requests, ...)
    rec.to_jsonl("trace.jsonl")
    rec.to_chrome_trace("trace.json")    # load in Perfetto
    print(rec.summary_markdown())        # or: python -m repro.obs.report
"""

from repro.obs.events import (
    ENVELOPE_FIELDS,
    EVENT_KINDS,
    EventSchemaError,
    validate_event,
    validate_events,
)
from repro.obs.export import (
    read_jsonl,
    summary_markdown,
    to_chrome_trace,
    to_jsonl,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.profile import KernelProfiler
from repro.obs.recorder import TraceRecorder

__all__ = [
    "ENVELOPE_FIELDS",
    "EVENT_KINDS",
    "Counter",
    "EventSchemaError",
    "Gauge",
    "Histogram",
    "KernelProfiler",
    "MetricsRegistry",
    "TraceRecorder",
    "read_jsonl",
    "summary_markdown",
    "to_chrome_trace",
    "to_jsonl",
    "validate_event",
    "validate_events",
]
