"""The consolidated serving API (DESIGN.md §16).

One import surface for everything a serving caller needs: the engine and its
grouped reliability configuration, the request/report protocol types, and the
decode-block helper contract external factories implement. Submodules stay
importable directly (``repro.serving.engine`` etc.) — this package re-exports
the stable names so callers stop reaching into module internals:

    from repro.serving import ServingEngine, ReliabilityConfig, ServeRequest

Import order matters here: ``engine`` imports ``scheduler``/``steps``, so the
protocol layers load first (keeps the package safe to import from any entry
point, including ``repro.serving.scheduler`` itself).
"""

from repro.obs import MetricsRegistry, TraceRecorder
from repro.serving.scheduler import (
    ContinuousBatchingScheduler,
    MeshServeReport,
    Request,
    RequestState,
    ServeReport,
    ServeRequest,
    normalize_requests,
    partition_requests,
    serve_stream,
)
from repro.serving.steps import (
    DecodeBlockHelpers,
    HelpersFactory,
    PagedHelpers,
    make_paged_helpers,
    make_prefill_step,
    make_serve_step,
)
from repro.serving.engine import (
    CanaryConfig,
    FaultModelConfig,
    ProtectionConfig,
    RailsConfig,
    ReliabilityConfig,
    ReliabilityConfigError,
    ServingEngine,
)

__all__ = [
    "CanaryConfig",
    "ContinuousBatchingScheduler",
    "DecodeBlockHelpers",
    "FaultModelConfig",
    "HelpersFactory",
    "MeshServeReport",
    "MetricsRegistry",
    "PagedHelpers",
    "ProtectionConfig",
    "RailsConfig",
    "ReliabilityConfig",
    "ReliabilityConfigError",
    "Request",
    "RequestState",
    "ServeReport",
    "ServeRequest",
    "ServingEngine",
    "TraceRecorder",
    "make_paged_helpers",
    "make_prefill_step",
    "make_serve_step",
    "normalize_requests",
    "partition_requests",
    "serve_stream",
]
