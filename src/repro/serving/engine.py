"""Batched serving engine with ECC-protected weights under an undervolted rail.

The paper's §IV evaluation as a service: model weights live in an
`EccMemoryDomain` ("BRAM") at a configurable rail voltage; every voltage
change re-materialises the faulty-but-corrected view of the weights through
the SECDED read path; the DED-canary `UndervoltController` consumes scrub
telemetry between generation rounds and walks the rail down until the first
detected-uncorrectable event. Power comes from the calibrated Table-I model.

Two protection layouts:
  * mode="domain"  — any arch: raw weight bits stored in the domain, decoded
    view refreshed per voltage (matches the paper's BRAM-resident weights);
  * mode="inline"  — dense archs: big matrices replaced by int8 EccWeight
    planes; every forward pass runs the (Pallas) decode-matmul read path,
    faults injected into the planes XOR-style. This is the TPU-native fused
    path (DESIGN.md §2) and the paper-representative dry-run/hillclimb cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import shapes
from repro.core import (
    EscalationPolicy,
    MeshRailController,
    MultiRailController,
    UndervoltController,
    scenario,
    voltage as vmod,
)
from repro.core.faultsim import FaultField
from repro.core.kvpages import PAGE_TOKENS, KVGeometry, KVPageArena
from repro.core.memory import EccMemoryDomain
from repro.core.planestore import PlaneStore, leaf_seed
from repro.core.telemetry import DomainFaultStats, FaultStats, ShardFaultStats
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.base import ModelConfig
from repro.serving import scheduler as sched
from repro.serving import steps as serve_steps


class ReliabilityConfigError(ValueError, AssertionError):
    """An invalid reliability-config combination.

    Subclasses ``ValueError`` (the typed contract ``validate()`` documents)
    *and* ``AssertionError`` (what the historical inline ``assert`` guards
    raised, and what existing callers catch)."""


@dataclasses.dataclass(frozen=True)
class FaultModelConfig:
    """How faults are generated and applied (DESIGN.md §7/§14)."""

    # "host": NumPy FaultField oracle (bit-identical to the per-leaf path);
    # "device": counter-based jax.random masks, never materialised on host
    mask_source: str = "host"
    # inline mode: one fused inject+scrub launch over the whole-model plane
    # arena (True) vs the historical per-leaf loop (False, reference path)
    batched: bool = True
    # Environment scenario: None (historical i.i.d. stream, bit-for-bit), a
    # name from scenario.ENVIRONMENTS, or an EnvironmentProfile.
    environment: Any = None
    # Override the environment's aging-drift sigma (scenario.resolve).
    drift: float | None = None


@dataclasses.dataclass(frozen=True)
class RailsConfig:
    """Voltage-rail topology and controller tuning (DESIGN.md §10/§13)."""

    # partition the plane arena into memory domains, each with its own
    # closed-loop rail (implies the batched inline path)
    multi_rail: bool = False
    # mesh engines: "uniform" locks one schedule at the worst shard's first
    # DED; "per_shard" walks every chip to its own V_min
    policy: str = "uniform"
    # >0: per-domain fault-curve variation (lognormal sigma)
    spread: float = 0.0
    step_v: float = 0.01
    # warm-start voltage for the canary search (None -> v_nom)
    start_v: float | None = None
    # locked rails re-trip under drift: retreat another backoff step
    adaptive: bool = False


@dataclasses.dataclass(frozen=True)
class ProtectionConfig:
    """What is protected and under which ECC schemes (DESIGN.md §12)."""

    # a registered codec name for every domain, or a {domain: name} mapping
    codecs: Any = None
    # EscalationPolicy or tuple of codec names weakest -> strongest
    escalation: Any = None
    protect: tuple = ("weights",)
    # include the embedding table in the protected arena (None -> multi_rail)
    embed: bool | None = None


@dataclasses.dataclass(frozen=True)
class CanaryConfig:
    """DED/accuracy canary behavior (DESIGN.md §15)."""

    # >0 reserves this many fixed canary prompts per autotune round
    prompts: int = 0
    # decoded continuation length per canary prompt
    tokens: int = 12
    # canary divergence scores above this trip the rail even when the DED
    # counters are clean; None records but never trips
    divergence_slo: float | None = None
    # also treat SILENT (ground-truth-only) events as canary trips
    paranoid: bool = False


# flat legacy field -> (sub-config attribute) per group; the flat names stay
# constructible (deprecation shim) and always mirror the resolved sub-configs
_REL_GROUPS: dict = {
    "fault_model": (
        FaultModelConfig,
        {
            "mask_source": "mask_source",
            "batched": "batched",
            "environment": "environment",
            "drift": "drift",
        },
    ),
    "rails": (
        RailsConfig,
        {
            "multi_rail": "multi_rail",
            "rail_policy": "policy",
            "rail_spread": "spread",
            "controller_step_v": "step_v",
            "controller_start_v": "start_v",
            "adaptive_rails": "adaptive",
        },
    ),
    "protection": (
        ProtectionConfig,
        {
            "codecs": "codecs",
            "escalation": "escalation",
            "protect": "protect",
            "protect_embed": "embed",
        },
    ),
    "canary": (
        CanaryConfig,
        {
            "canary_prompts": "prompts",
            "canary_tokens": "tokens",
            "divergence_slo": "divergence_slo",
            "paranoid": "paranoid",
        },
    ),
}

_FLAT_KWARG_WARNED = False


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """Reliability knobs for a ServingEngine.

    The canonical surface is the four grouped sub-configs —
    ``fault_model`` (:class:`FaultModelConfig`), ``rails``
    (:class:`RailsConfig`), ``protection`` (:class:`ProtectionConfig`) and
    ``canary`` (:class:`CanaryConfig`) — plus the ungrouped scalars below.
    The historical flat keywords (``mask_source``, ``multi_rail``,
    ``canary_prompts``, ...) remain constructible as a deprecation shim with
    identical semantics; after ``__post_init__`` the flat attributes and the
    sub-configs always agree (a non-default flat value wins over its group,
    which is what makes ``dataclasses.replace(rel, batched=False)``
    round-trip), so readers may use either view. The one shim blind spot: a
    flat keyword handed its *default* value is indistinguishable from
    "unspecified" and loses to an explicit sub-config — round-trip through
    the grouped fields when a sub-config is in play. ``validate()`` — called by
    ``ServingEngine.__init__`` — raises :class:`ReliabilityConfigError`
    (a ``ValueError``) on contradictory combinations instead of the old
    scattered inline asserts.
    """

    platform: str = "vc707"
    ecc: bool = True
    voltage: float | None = None  # None -> nominal
    protect: tuple = ("weights",)
    mode: str = "domain"  # domain | inline
    fuse: bool = True  # inline mode: fused Pallas read path vs naive
    seed: int = 0
    controller_step_v: float = 0.01
    # inline mode: one fused inject+scrub launch over the whole-model plane
    # arena (True) vs the historical per-leaf loop (False, reference path)
    batched: bool = True
    # "host": NumPy FaultField oracle (bit-identical to per-leaf path);
    # "device": counter-based jax.random masks, never materialised on host
    mask_source: str = "host"
    # Multi-rail (DESIGN.md §10): partition the plane arena into memory
    # domains (configs/shapes.domain_of) and give each its own closed-loop
    # rail. Implies the batched inline path.
    multi_rail: bool = False
    # also treat SILENT (ground-truth-only) events as canary trips
    paranoid: bool = False
    # include the embedding table in the protected arena (None -> multi_rail:
    # single-rail engines keep the historical attn/mlp-only protected set)
    protect_embed: bool | None = None
    # >0: per-domain fault-curve variation (lognormal sigma) modelling
    # block-to-block differences (arXiv:2005.04737 / MoRS); 0: shared curve
    rail_spread: float = 0.0
    # warm-start voltage for the canary search (None -> v_nom); the
    # guardband [v_min, v_nom] is fault-free by definition, so starting at
    # its edge saves ~40 no-op rounds without changing the lock point
    controller_start_v: float | None = None
    # Per-domain ECC scheme selection (DESIGN.md §12): a registered codec
    # name for every domain, or a {domain: name} mapping (unnamed domains
    # keep the built-in secded72). Dict form implies multi_rail.
    codecs: Any = None
    # Optional DED-canary escalation ladder (multi-rail only): an
    # EscalationPolicy, or a tuple of codec names weakest -> strongest. On a
    # DED trip a rail steps up its code instead of retreating (see
    # core/controller.py); the redundancy cost lands in power_report.
    escalation: Any = None
    # Mesh rail policy (DESIGN.md §13; engines built with a mesh):
    # "uniform" locks one schedule at the worst shard's first DED;
    # "per_shard" walks every chip to its own V_min.
    rail_policy: str = "uniform"
    # Environment scenario (DESIGN.md §14): None (historical i.i.d. stream,
    # bit-for-bit), a name from scenario.ENVIRONMENTS ("consumer" /
    # "avionics" / "space"), or an EnvironmentProfile. Scales every domain's
    # fault flux, shapes the masks into correlated multi-bit bursts, and
    # drifts each mesh shard's rate over the soak.
    environment: Any = None
    # Override the environment's aging-drift sigma (scenario.resolve); a bare
    # drift with environment=None gets the neutral 1x-flux burst-free env.
    drift: float | None = None
    # Locked rails re-trip under drift: retreat another backoff step instead
    # of holding (core/controller.py `adaptive`).
    adaptive_rails: bool = False
    # Accuracy canary (DESIGN.md §15): >0 reserves this many fixed canary
    # prompts; each autotune round greedy-decodes them against a cached
    # clean-nominal reference rollout and feeds the divergence score
    # (1 - mean matched-prefix fraction, [0, 1]) to the controller alongside
    # the DED counters. Inline mode only.
    canary_prompts: int = 0
    # decoded continuation length per canary prompt (prompt length is
    # core/campaign.CANARY_PROMPT_LEN)
    canary_tokens: int = 12
    # Divergence SLO for the rails: canary scores above this trip the rail
    # (escalate if a ladder step remains, else back off + lock) even when
    # the DED counters are clean. None: canary scores are recorded in the
    # controller history but never trip.
    divergence_slo: float | None = None
    # -- grouped sub-configs (the canonical surface; see class docstring) --
    fault_model: FaultModelConfig | None = None
    rails: RailsConfig | None = None
    protection: ProtectionConfig | None = None
    canary: CanaryConfig | None = None

    def __post_init__(self):
        global _FLAT_KWARG_WARNED
        defaults = {f.name: f.default for f in dataclasses.fields(self)}
        flat_used = []
        for group, (cls_, fmap) in _REL_GROUPS.items():
            sub = getattr(self, group)
            vals = {}
            for flat, name in fmap.items():
                v = getattr(self, flat)
                try:
                    is_default = v == defaults[flat]
                except Exception:
                    is_default = v is defaults[flat]
                if not is_default:
                    # a non-default flat kwarg wins over its sub-config —
                    # dataclasses.replace() re-passes every flat field, so
                    # this rule is what makes replace(rel, x=y) round-trip
                    vals[name] = v
                    if sub is None or getattr(sub, name) != v:
                        flat_used.append(flat)
                elif sub is not None:
                    vals[name] = getattr(sub, name)
                else:
                    vals[name] = v
            # re-synthesize so flat attributes and sub-config always agree
            for flat, name in fmap.items():
                object.__setattr__(self, flat, vals[name])
            object.__setattr__(self, group, cls_(**vals))
        if flat_used and not _FLAT_KWARG_WARNED:
            _FLAT_KWARG_WARNED = True
            import warnings

            warnings.warn(
                "flat ReliabilityConfig keywords "
                f"({', '.join(sorted(set(flat_used)))}) are deprecated; use "
                "the grouped sub-configs (fault_model=FaultModelConfig(...), "
                "rails=RailsConfig(...), protection=ProtectionConfig(...), "
                "canary=CanaryConfig(...))",
                DeprecationWarning,
                stacklevel=3,
            )

    def validate(self, *, mesh=None) -> "ReliabilityConfig":
        """Reject contradictory combinations with a typed error.

        Raises :class:`ReliabilityConfigError` (a ``ValueError``) and returns
        ``self`` so ``rel.validate()`` chains. ``mesh`` enables the extra
        mesh-engine constraints (DESIGN.md §13)."""

        def _require(cond: bool, msg: str):
            if not cond:
                raise ReliabilityConfigError(msg)

        _require(
            self.mode in ("domain", "inline"),
            f"mode must be 'domain' or 'inline', got {self.mode!r}",
        )
        _require(
            self.platform in vmod.PLATFORMS,
            f"unknown platform {self.platform!r}",
        )
        _require(
            self.rail_policy in ("uniform", "per_shard"),
            f"rail_policy must be 'uniform' or 'per_shard', got "
            f"{self.rail_policy!r}",
        )
        if self.mode == "domain":
            _require(
                self.codecs in (None, "secded72"),
                "domain mode stores raw bits behind the built-in SECDED; "
                "codec selection needs mode='inline'",
            )
        else:
            _require(
                not self.multi_rail or self.batched,
                "multi_rail drives the batched plane arena",
            )
            _require(
                self.batched or self.codecs in (None, "secded72"),
                "the per-leaf reference path is SECDED-only; codec "
                "selection needs the batched arena",
            )
            _require(
                self.multi_rail
                or self.codecs is None
                or isinstance(self.codecs, str),
                "per-domain codec dicts need multi_rail=True",
            )
        if mesh is not None:
            _require(
                self.multi_rail and self.mode == "inline",
                "mesh engines drive the multi-rail batched plane arena",
            )
            _require(
                self.mask_source == "device",
                "mesh engines need device masks (per-shard streams live "
                "inside shard_map)",
            )
            _require(
                self.rail_policy == "uniform" or self.escalation is None,
                "per-shard codec escalation needs per-shard plane groups; "
                "use rail_policy='uniform' with an escalation ladder",
            )
        return self

    @property
    def embed_protected(self) -> bool:
        return self.multi_rail if self.protect_embed is None else self.protect_embed

    @property
    def environment_profile(self):
        return scenario.resolve(self.environment, drift=self.drift)

    @property
    def escalation_policy(self) -> EscalationPolicy | None:
        if self.escalation is None:
            return None
        if isinstance(self.escalation, EscalationPolicy):
            return self.escalation
        return EscalationPolicy(ladder=tuple(self.escalation))


def _decode_gather_table(ew: kops.EccWeight, codec: str = "secded72") -> jnp.ndarray:
    """ECC-read an EccWeight back to a dequantized float (K, N) table.

    Gather-read tables (the embedding) cannot go through the fused
    decode-matmul kernel; their ECC read happens when the rail moves, exactly
    like domain mode's refresh — at nominal voltage this is the identity on
    the quantized values. Weight leaves protected by a non-SECDED codec take
    the same path: the fused matmul kernel reads Hsiao planes only, so
    stronger codes pay a decode-at-refresh materialisation instead
    (DESIGN.md §12).
    """
    from repro.kernels import ref as kref

    lo, hi, _ = kops.decode(ew.lo, ew.hi, ew.parity, codec=codec)
    if lo.ndim == 3:  # layer-stacked (G, K/8, N): unpack per group
        w_i8 = jnp.stack(
            [kref.unpack_ecc_weights(lo[g], hi[g]) for g in range(lo.shape[0])]
        )
        return w_i8.astype(jnp.float32) * ew.scale[:, None, :]
    w_i8 = kref.unpack_ecc_weights(lo, hi)
    return w_i8.astype(jnp.float32) * ew.scale


def _pack_stacked(leaf) -> kops.EccWeight:
    """Pack a layer-stacked (G, K, N) float weight into stacked ECC planes.

    The scan over layer groups slices the leading G off every plane leaf, so
    the in-scan view is exactly the 2D EccWeight the kernels expect."""
    g = leaf.shape[0]
    packed = [kops.pack_ecc_weights(jnp.asarray(leaf[i], jnp.float32)) for i in range(g)]
    return kops.EccWeight(
        lo=jnp.stack([p.lo for p in packed]),
        hi=jnp.stack([p.hi for p in packed]),
        parity=jnp.stack([p.parity for p in packed]),
        scale=jnp.stack([p.scale for p in packed]),
        k=packed[0].k,
        n=packed[0].n,
        fuse=packed[0].fuse,
    )


def protect_params_inline(
    params, cfg: ModelConfig, seed: int = 0, include_embed: bool = False
):
    """Replace weight matrices (K%8==0) with SECDED int8 EccWeight planes.

    Handles both plain (K, N) and layer-stacked (G, K, N) leaves. Returns
    (new_params, plane_sizes) where plane_sizes maps path -> word count
    (for voltage-dependent fault injection). ``include_embed`` extends the
    protected set to the embedding table (multi-rail engines protect it as
    its own voltage domain).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, fields = [], {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        wanted = "attn" in key or "mlp" in key or (include_embed and "embed" in key)
        if not hasattr(leaf, "ndim") or not wanted:
            out.append(leaf)
            continue
        if leaf.ndim == 2 and leaf.shape[0] % 8 == 0 and min(leaf.shape) >= 64:
            ew = kops.pack_ecc_weights(jnp.asarray(leaf, jnp.float32))
        elif leaf.ndim == 3 and leaf.shape[1] % 8 == 0 and min(leaf.shape[1:]) >= 64:
            ew = _pack_stacked(leaf)
        else:
            out.append(leaf)
            continue
        out.append(ew)
        fields[key] = ew.lo.size
    return jax.tree_util.tree_unflatten(treedef, out), fields


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        rel: ReliabilityConfig | None = None,
        max_len: int = 512,
        mesh=None,
        recorder=None,
    ):
        self.cfg = cfg
        self.rel = rel
        self.max_len = max_len
        self.mesh = mesh
        # Optional reliability flight recorder (obs.TraceRecorder): every
        # rail decision, serve-loop event and canary probe lands in one
        # causally-ordered deterministic trace. None (the default) is the
        # bit-identical zero-overhead path (DESIGN.md §17).
        self.recorder = recorder
        # One typed gate replaces the historical scattered inline asserts:
        # every contradictory combination (mesh-sharded reliability included,
        # DESIGN.md §13) raises ReliabilityConfigError before any state is
        # built.
        if rel is not None:
            rel.validate(mesh=mesh)
        elif mesh is not None:
            raise ReliabilityConfigError(
                "mesh engines drive the multi-rail batched plane arena "
                "(a ReliabilityConfig is required)"
            )
        self.platform = vmod.PLATFORMS[rel.platform] if rel else None
        self.controller = (
            UndervoltController(
                self.platform,
                step_v=rel.controller_step_v,
                paranoid=rel.paranoid,
                start_v=rel.controller_start_v,
                divergence_slo=rel.divergence_slo,
            )
            if rel and not rel.multi_rail
            else None  # multi-rail controller is built once the arena exists
        )
        self._canary_ref = None  # clean-nominal canary rollout, built lazily
        self.rails = None  # {domain: voltage} when multi_rail; [dict] per shard on a mesh
        self.rail_stats = DomainFaultStats()  # cumulative per-domain telemetry
        self.shard_stats = ShardFaultStats()  # cumulative per-shard rows (mesh)
        self.stats = FaultStats()
        self._clean_params = params
        if rel is None:
            self.params = params
            self.domain = None
        elif rel.mode == "domain":
            self.domain = EccMemoryDomain(
                rel.platform, seed=rel.seed, ecc_enabled=rel.ecc,
                voltage=rel.voltage or 1.0,
            )
            self.domain.write_pytree("w", params)
            self.params = params  # refreshed by set_voltage
            self.set_voltage(self.domain.voltage)
        else:  # inline (validate() already rejected the contradictory combos)
            self.domain = None
            self.params, self._plane_sizes = protect_params_inline(
                params, cfg, seed=rel.seed, include_embed=rel.embed_protected
            )
            self._clean_inline = self.params
            self._fields: dict[str, FaultField] = {}
            # Batched plane arena: flatten once, record which flat slots hold
            # EccWeight planes, and key each by its tree path (the per-leaf
            # fault-field seeds depend on it).
            flat, self._inline_treedef = jax.tree_util.tree_flatten_with_path(
                self._clean_inline,
                is_leaf=lambda x: isinstance(x, kops.EccWeight),
            )
            self._inline_template = [leaf for _, leaf in flat]
            self._ecc_slots = [
                (i, jax.tree_util.keystr(path))
                for i, (path, leaf) in enumerate(flat)
                if isinstance(leaf, kops.EccWeight)
            ]
            rail_profiles = (
                vmod.derive_domain_profiles(
                    self.platform, shapes.MEMORY_DOMAINS,
                    spread=rel.rail_spread, seed=rel.seed,
                )
                if rel.multi_rail and rel.rail_spread > 0
                else None
            )
            if rel.multi_rail:
                store_codecs = shapes.domain_codecs(rel.codecs)
            else:
                store_codecs = rel.codecs
            self._store = PlaneStore(
                [self._inline_template[i] for i, _ in self._ecc_slots],
                [key for _, key in self._ecc_slots],
                self.platform,
                seed=rel.seed,
                mask_source=rel.mask_source,
                domain_key=shapes.domain_of if rel.multi_rail else None,
                profiles=rail_profiles,
                codecs=store_codecs,
                mesh=mesh,
                env=rel.environment_profile,
            )
            self.voltage = rel.voltage or self.platform.v_nom
            if rel.multi_rail:
                rail_kw = dict(
                    step_v=rel.controller_step_v,
                    paranoid=rel.paranoid,
                    start_v=rel.controller_start_v,
                    profiles={
                        d: self._store.domain_profile(d)
                        for d in self._store.domains
                    },
                    escalation=rel.escalation_policy,
                    codecs={
                        d: self._store.codec_of(d) for d in self._store.domains
                    },
                    adaptive=rel.adaptive_rails,
                    divergence_slo=rel.divergence_slo,
                )
                if mesh is not None:
                    self.controller = MeshRailController(
                        self.platform,
                        self._store.domains,
                        self._store.n_shards,
                        policy=rel.rail_policy,
                        **rail_kw,
                    )
                else:
                    self.controller = MultiRailController(
                        self.platform, self._store.domains, **rail_kw
                    )
                self.set_rails({d: self.voltage for d in self._store.domains})
            else:
                self.set_voltage(self.voltage)
        if recorder is not None and self.controller is not None:
            self.controller.bind_recorder(recorder)

        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, cfg, c, pos)
        )
        self._prefill = jax.jit(
            lambda p, t, c: lm.prefill(p, t, cfg, c)
        )
        self._decode_loop = jax.jit(
            lambda p, t, c, s0, n: lm.greedy_decode_loop(p, t, cfg, c, s0, n),
            static_argnums=(4,),
        )

    # -- voltage control ------------------------------------------------------
    def set_voltage(self, v: float):
        self.voltage = float(v)
        if self.rel is None:
            return
        if self.rel.multi_rail:
            self.set_rails({d: float(v) for d in self._store.domains})
        elif self.rel.mode == "domain":
            self.domain.set_voltage(v)
            self.params, stats = self.domain.read_pytree("w", self._clean_params)
            self.stats.accumulate(stats)
        elif self.rel.batched:
            self._apply_inline_faults_batched(v)
        else:
            self._apply_inline_faults(v)

    def set_rails(self, volts: dict):
        """Per-domain voltage step: one fused launch, one counter row per
        domain crossing to host (multi-rail engines only). Rails not named
        in ``volts`` (the late-bound `kv` cache rail, whose storage lives
        outside the weight arena) keep their current voltage — dropping
        them would silently skew the power accounting, which weights every
        domain in ``words_by_domain`` including the registered cache words."""
        assert self.rel is not None and self.rel.multi_rail
        if self.mesh is not None:
            return self._set_rails_mesh(volts)
        new = {d: float(v) for d, v in volts.items()}
        if self.rails:
            new = {**self.rails, **new}
        self.rails = new
        self.voltage = max(self.rails.values())  # most conservative rail
        leaves, dstats = self._store.set_rails(self.rails, ecc=self.rel.ecc)
        self.params = self._reassemble_params(leaves)
        self.rail_stats.accumulate(dstats)
        self.stats.accumulate(dstats.total())
        self._last_scrub = dstats

    def _set_rails_mesh(self, volts):
        """Mesh rail step: one shard_map'd fused launch per codec group,
        every chip at its own schedule (DESIGN.md §13). ``volts`` is any
        form ``PlaneStore._normalize_schedule`` accepts — one dict, a
        per-shard list, or per-shard value arrays."""
        schedule = self._store._normalize_schedule(volts)
        if self.rails:
            schedule = [
                {**old, **{d: float(v) for d, v in new.items()}}
                for old, new in zip(self.rails, schedule)
            ]
        else:
            schedule = [
                {d: float(v) for d, v in s.items()} for s in schedule
            ]
        self.rails = schedule
        self.voltage = max(v for s in schedule for v in s.values())
        leaves, sstats = self._store.set_rails_sharded(
            schedule, ecc=self.rel.ecc
        )
        self.params = self._reassemble_params(leaves)
        self.shard_stats.accumulate(sstats)
        reduced = sstats.reduced()
        self.rail_stats.accumulate(reduced)
        self.stats.accumulate(reduced.total())
        self._last_scrub = sstats

    def _leaf_codec(self, key: str) -> str:
        if self.rel.multi_rail:
            return self._store.codec_of(shapes.domain_of(key))
        slots = self._store.slots
        return self._store.codec_of(slots[0].domain) if slots else "secded72"

    def _reassemble_params(self, leaves):
        """Put faulty arena slices back into the param tree; embedding-like
        tables (read by gather, not matmul) are materialised through the ECC
        decode at refresh time — the fused read path only covers matmuls.
        Leaves protected by a non-SECDED codec take the same decode-at-
        refresh path: the fused decode-matmul kernel reads Hsiao planes
        only (DESIGN.md §12)."""
        flat = list(self._inline_template)
        for (i, key), leaf in zip(self._ecc_slots, leaves):
            codec = self._leaf_codec(key)
            if "embed" in key or codec != "secded72":
                flat[i] = _decode_gather_table(leaf, codec=codec)
            else:
                flat[i] = leaf
        return jax.tree_util.tree_unflatten(self._inline_treedef, flat)

    def _apply_inline_faults_batched(self, v: float):
        """Whole-model voltage step: one fused inject+scrub kernel launch over
        the plane arena; only the (8,) counter vector crosses to host."""
        leaves, stats = self._store.set_voltage(v, ecc=self.rel.ecc)
        self.params = self._reassemble_params(leaves)
        self.stats.accumulate(stats)
        self._last_scrub = stats

    def _apply_inline_faults(self, v: float):
        """Per-leaf reference path (one inject + one scrub launch per leaf,
        masks generated on host). Kept for parity tests and benchmarks."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self._clean_inline, is_leaf=lambda x: isinstance(x, kops.EccWeight)
        )
        out = []
        agg = FaultStats()
        for path, leaf in flat:
            if not isinstance(leaf, kops.EccWeight):
                out.append(leaf)
                continue
            key = jax.tree_util.keystr(path)
            field = self._fields.get(key)
            if field is None:
                field = FaultField(
                    self.platform, leaf.lo.size, seed=leaf_seed(self.rel.seed, key)
                )
                self._fields[key] = field
            masks = field.masks(v)
            mlo = jnp.asarray(masks.lo.reshape(leaf.lo.shape))
            mhi = jnp.asarray(masks.hi.reshape(leaf.hi.shape))
            mpar = jnp.asarray(masks.parity.reshape(leaf.parity.shape))
            flo, fhi, fpar = kops.inject(leaf.lo, leaf.hi, leaf.parity, mlo, mhi, mpar)
            faulty = dataclasses.replace(leaf, lo=flo, hi=fhi, parity=fpar)
            if not self.rel.ecc:
                # No-ECC baseline: zero the parity contribution by decoding off
                # — we emulate by treating planes as raw (decode would mis-fire),
                # so instead keep faulty planes and a pass-through decode: the
                # raw faulty bits flow straight into the matmul.
                faulty = dataclasses.replace(faulty, parity=kops.encode(faulty.lo, faulty.hi))
            status = np.asarray(kops.scrub(faulty))
            agg.accumulate(FaultStats.from_decode(status, masks.flip_counts()))
            out.append(_decode_gather_table(faulty) if "embed" in key else faulty)
        self.params = jax.tree_util.tree_unflatten(treedef, out)
        self.stats.accumulate(agg)
        self._last_scrub = agg

    # -- serving --------------------------------------------------------------
    def generate(
        self,
        prompts: np.ndarray,
        n_tokens: int,
        *,
        use_scan: bool = True,
        params=None,
    ):
        """Greedy-decode a batch. prompts: (B, S0) int32. Returns (B, n).

        use_scan=True rolls the decode loop into one lax.scan program (one
        dispatch for the whole rollout; compiled once per n_tokens value);
        use_scan=False is the historical per-token Python loop, kept as the
        reference the scan path is tested against. ``params`` overrides the
        engine's (possibly fault-injected) weights for this rollout — the
        accuracy canary uses it to decode the clean reference through the
        same jitted programs.
        """
        p = self.params if params is None else params
        b, s0 = prompts.shape
        cache = lm.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(p, jnp.asarray(prompts), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        if not use_scan:
            outs = [tok]
            for i in range(n_tokens - 1):
                logits, cache = self._decode(p, tok, cache, s0 + i)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                outs.append(tok)
            return np.concatenate([np.asarray(o) for o in outs], axis=1)
        toks, _ = self._decode_loop(
            p, tok, cache, jnp.int32(s0), n_tokens - 1
        )
        return np.concatenate([np.asarray(tok), np.asarray(toks)], axis=1)

    # -- accuracy canary (DESIGN.md §15) ---------------------------------------
    def canary_divergence(self) -> float | None:
        """Greedy-decode the canary prompts at the current rails and score
        them against the cached clean-nominal rollout.

        Returns ``1 - mean(matched prefix fraction)`` in [0, 1] (exactly 0.0
        when every canary continuation is bit-identical to the clean run), or
        None when the canary is disabled (``rel.canary_prompts == 0``). The
        reference is decoded once, lazily, from the *clean* plane templates
        through the same quantized ECC read path — so quantization noise
        cancels and only injected faults can score.
        """
        if self.rel is None or not self.rel.canary_prompts:
            return None
        assert self.rel.mode == "inline", (
            "the accuracy canary decodes against the clean inline plane "
            "templates; mode='domain' has no arena to diff"
        )
        from repro.core import campaign

        prompts = campaign.eval_prompts(
            self.cfg.vocab,
            self.rel.canary_prompts,
            campaign.CANARY_PROMPT_LEN,
            seed=self.rel.seed ^ 0xACC,
        )
        if self._canary_ref is None:
            clean = self._reassemble_params(
                [self._inline_template[i] for i, _ in self._ecc_slots]
            )
            self._canary_ref = self.generate(
                prompts, self.rel.canary_tokens, params=clean
            )
        cur = self.generate(prompts, self.rel.canary_tokens)
        div = campaign.token_divergence(self._canary_ref, cur)
        if self.recorder:
            self.recorder.emit("canary_probe", divergence=float(div))
        return div

    # -- continuous batching over the paged SECDED KV cache --------------------
    def serve(
        self,
        requests,
        *,
        n_lanes: int = 4,
        page_tokens: int = PAGE_TOKENS,
        n_pages: int | None = None,
        scrub_interval: int = 1,
        max_block: int = 16,
        kv_voltage: float | None = None,
        walk_kv: bool = False,
        share_prefix: bool = False,
        speculative: int = 0,
        draft_params=None,
        draft_cfg: ModelConfig | None = None,
        scrub_overlap: bool | None = None,
    ) -> sched.ServeReport:
        """Serve a stream of variable-length requests (DESIGN.md §11/§16).

        ``requests``: iterable of (prompt (s0,) int32, max_new_tokens) pairs
        or scheduler.Request/``ServeRequest`` objects. The KV cache lives in
        SECDED pages on the `kv` voltage domain; every read scrubs. At
        nominal voltage the output tokens are bit-identical to `generate` on
        the same batch composition (tested).

        ``share_prefix=True`` enables the copy-on-write prefix-sharing trie:
        requests with identical full-page prompt prefixes share physical
        pages (scrubbed once, chunk-prefilled only on the private suffix)
        with reader-weighted DED telemetry (DESIGN.md §16). Bit-identical
        outputs at nominal voltage, gated by the shared_over_private
        throughput ratio in BENCH_serve.

        ``speculative=K`` (K >= 2, with ``draft_params``/``draft_cfg``)
        drafts K-1 tokens per dispatch with the draft model and verifies all
        K positions in one chunked target forward; the emitted stream is
        exactly the greedy rollout (accepted-prefix property, tested).

        ``walk_kv`` (multi-rail engines): attach a `kv` rail to the
        MultiRailController and let the per-interval scrub DED counters walk
        the cache voltage independently of the weight rails.

        ``scrub_overlap`` (None = auto, DESIGN.md §18): overlap the interval
        scrub with the decode blocks by deferring its counter harvest to the
        next interval boundary — bit-identical outputs/stats/rail walks to
        the serialized path; auto demotes to serialized when codec
        escalation is live. ``False`` forces the serialized path.

        Mesh engines (DESIGN.md §13) serve the stream data-parallel: the
        requests are partitioned round-robin across the reliability shards,
        every replica runs its own continuous-batching loop over its own
        KV arena (its own chip: per-shard fault stream, per-shard `kv` rail
        under the `per_shard` policy) and the merged MeshServeReport carries
        both the per-shard rows and the cross-shard aggregate.
        """
        assert shapes.supports_paged_kv(self.cfg), (
            f"{self.cfg.name}: paged KV unsupported (see shapes.supports_paged_kv)"
        )
        if int(speculative) >= 2:
            assert draft_params is not None and draft_cfg is not None, (
                "speculative decode needs draft_params + draft_cfg"
            )
        else:
            draft_params = draft_cfg = None
        if self.mesh is not None:
            return self._serve_mesh(
                requests,
                n_lanes=n_lanes,
                page_tokens=page_tokens,
                n_pages=n_pages,
                scrub_interval=scrub_interval,
                max_block=max_block,
                kv_voltage=kv_voltage,
                walk_kv=walk_kv,
                share_prefix=share_prefix,
                speculative=speculative,
                draft_params=draft_params,
                draft_cfg=draft_cfg,
                scrub_overlap=scrub_overlap,
            )
        profile = self.platform or vmod.PLATFORMS["vc707"]
        envp = self.rel.environment_profile if self.rel is not None else None
        if self.rel is not None and self.rel.multi_rail:
            profile = self._store.domain_profile("kv")  # env-scaled flux
        elif envp is not None:
            profile = envp.scale_profile(profile)
        geom = KVGeometry.from_config(self.cfg, page_tokens)
        if n_pages is None:
            n_pages = n_lanes * geom.pages_for(self.max_len)
        kv_codec = (
            shapes.domain_codecs(self.rel.codecs)["kv"]
            if self.rel is not None
            else shapes.DEFAULT_CODEC
        )
        if walk_kv and self.controller is not None:
            rail = getattr(self.controller, "rails", {}).get("kv")
            if rail is not None:
                # A previous serve's escalation persists: the rail learned
                # this domain needs the stronger code, so the fresh arena is
                # protected under it — controller state and applied
                # protection must never diverge (DESIGN.md §12).
                kv_codec = rail.codec
        arena = KVPageArena(
            geom,
            profile,
            n_pages,
            seed=self.rel.seed if self.rel else 0,
            ecc=self.rel.ecc if self.rel else True,
            codec=kv_codec,
            env=envp,
        )
        if kv_voltage is None:
            if self.rails is not None and "kv" in self.rails:
                kv_voltage = self.rails["kv"]
            elif self.rel is not None:
                kv_voltage = self.voltage
            else:
                kv_voltage = profile.v_nom
        arena.set_voltage(float(kv_voltage))

        kv_controller = None
        if walk_kv:
            assert self.rel is not None and self.rel.multi_rail, (
                "walk_kv needs a multi-rail engine"
            )
            kv_controller = self.controller.add_rail("kv", profile, codec=kv_codec)
            # The controller is the source of truth for the walked rail: the
            # arena must inject interval-1 faults at the voltage the canary
            # believes it is judging, or the first-DED decision is made on
            # telemetry from a different operating point. (An explicit
            # kv_voltage only pins the rail when it is not being walked.)
            arena.set_voltage(kv_controller.voltage)
        helpers = self._paged_helpers(geom, kv_codec, draft_cfg=draft_cfg)
        report = sched.serve_stream(
            self.params,
            self.cfg,
            helpers,
            arena,
            requests,
            n_lanes=n_lanes,
            max_len=self.max_len,
            scrub_interval=scrub_interval,
            max_block=max_block,
            kv_controller=kv_controller,
            # escalation rebuilds the spec helpers too: the draft cfg rides
            # along so a mid-serve codec change keeps speculating
            helpers_factory=lambda cname: self._paged_helpers(
                geom, cname, draft_cfg=draft_cfg
            ),
            share_prefix=share_prefix,
            speculative=speculative,
            draft_params=draft_params,
            draft_cfg=draft_cfg,
            recorder=self.recorder,
            scrub_overlap=scrub_overlap,
        )
        # Fold the cache telemetry + storage into the engine's books: the kv
        # domain now has real words (power weighting) and real counters.
        self.stats.accumulate(report.kv_stats)
        self.rail_stats.accumulate(DomainFaultStats({"kv": report.kv_stats}))
        if self.rel is not None and self.rel.mode == "inline":
            self._store.register_domain_words(
                "kv", arena.n_words, codec=arena.codec_name
            )
        if self.rails is not None:
            self.rails["kv"] = arena.voltage
        self.kv_arena = arena
        return report

    def _serve_mesh(
        self,
        requests,
        *,
        n_lanes: int,
        page_tokens: int,
        n_pages: int | None,
        scrub_interval: int,
        max_block: int,
        kv_voltage: float | None,
        walk_kv: bool,
        share_prefix: bool = False,
        speculative: int = 0,
        draft_params=None,
        draft_cfg: ModelConfig | None = None,
        scrub_overlap: bool | None = None,
    ) -> "sched.MeshServeReport":
        """Data-parallel continuous batching across the reliability shards.

        Each replica is one chip: its KV arena draws the shard's own fault
        stream (KVPageArena(shard=s) — the host-side mirror of the
        shard_map path's axis_index key fold) and, under `per_shard` rails,
        walks its own `kv` voltage. The `uniform` policy threads ONE shared
        kv rail through every replica's stream in turn, so its canary sees
        every chip's DED events — the worst-shard lock.
        """
        import dataclasses as _dc

        geom = KVGeometry.from_config(self.cfg, page_tokens)
        if n_pages is None:
            n_pages = n_lanes * geom.pages_for(self.max_len)
        profile = self._store.domain_profile("kv")
        n_shards = self._store.n_shards
        parts = sched.partition_requests(
            sched.normalize_requests(requests), n_shards
        )
        base_codec = shapes.domain_codecs(self.rel.codecs)["kv"]
        kv_rails = (
            self.controller.add_rail("kv", profile, codec=base_codec)
            if walk_kv
            else [None] * n_shards
        )
        reports = []
        for s in range(n_shards):
            rail = kv_rails[s]
            # A previous serve's escalation persists per rail (DESIGN.md §12).
            kv_codec = rail.codec if rail is not None else base_codec
            arena = KVPageArena(
                geom,
                profile,
                n_pages,
                seed=self.rel.seed,
                ecc=self.rel.ecc,
                codec=kv_codec,
                shard=s,
                env=self.rel.environment_profile,
            )
            if kv_voltage is not None:
                arena.set_voltage(float(kv_voltage))
            else:
                arena.set_voltage(float(self.rails[s].get("kv", self.voltage)))
            if rail is not None:
                # The controller is the source of truth for a walked rail
                # (see serve()); under `uniform` the shared rail resumes
                # from wherever the previous shard's stream left it — the
                # worst-shard canary by construction.
                arena.set_voltage(rail.voltage)
            report = sched.serve_stream(
                self.params,
                self.cfg,
                self._paged_helpers(geom, kv_codec, draft_cfg=draft_cfg),
                arena,
                parts[s],
                n_lanes=n_lanes,
                max_len=self.max_len,
                scrub_interval=scrub_interval,
                max_block=max_block,
                kv_controller=rail,
                helpers_factory=lambda cname: self._paged_helpers(
                    geom, cname, draft_cfg=draft_cfg
                ),
                share_prefix=share_prefix,
                speculative=speculative,
                draft_params=draft_params,
                draft_cfg=draft_cfg,
                recorder=self.recorder,
                scrub_overlap=scrub_overlap,
            )
            reports.append(report)
            self._store.register_domain_words(
                "kv", arena.n_words, codec=arena.codec_name, shard=s
            )
            self.rails[s]["kv"] = arena.voltage
        mesh_report = sched.MeshServeReport.merge(reports)
        self.stats.accumulate(mesh_report.kv_stats)
        self.rail_stats.accumulate(
            DomainFaultStats({"kv": mesh_report.kv_stats})
        )
        self.shard_stats.accumulate(
            ShardFaultStats(
                [
                    DomainFaultStats(
                        {"kv": _dc.replace(r.kv_stats, shard=s)}, shard=s
                    )
                    for s, r in enumerate(reports)
                ]
            )
        )
        self.kv_arenas = [r.arena for r in reports]
        self.kv_arena = self.kv_arenas[0]
        return mesh_report

    def _paged_helpers(
        self,
        geom: KVGeometry,
        codec: str = "secded72",
        draft_cfg: ModelConfig | None = None,
    ) -> serve_steps.PagedHelpers:
        cache = getattr(self, "_paged_helper_cache", None)
        if cache is None:
            cache = self._paged_helper_cache = {}
        key = (geom, codec, draft_cfg)
        if key not in cache:
            cache[key] = serve_steps.make_paged_helpers(
                self.cfg, geom, codec, draft_cfg=draft_cfg
            )
        return cache[key]

    # -- runtime undervolting loop ---------------------------------------------
    def autotune_voltage(self, max_rounds: int = 60):
        """Paper §III/IV: lower the rail(s) until the ECC's DED flag trips.

        Single-rail: returns (locked voltage, history). Multi-rail: every
        domain walks its own rail to its own first-DED point independently;
        returns ({domain: voltage}, {domain: history}).
        """
        assert self.rel is not None and self.controller is not None
        if self.mesh is not None:
            return self._autotune_rails_mesh(max_rounds)
        if self.rel.multi_rail:
            return self._autotune_rails(max_rounds)
        for _ in range(max_rounds):
            if self.recorder:
                self.recorder.advance(1)  # one autotune round == one clock step
            round_stats = (
                self._last_scrub if self.rel.mode == "inline" else self._domain_scrub()
            )
            v = self.controller.update(
                round_stats, divergence=self.canary_divergence()
            )
            if self.controller.locked:
                # re-apply the backed-off (safe) voltage before serving
                self.set_voltage(self.controller.voltage)
                break
            self.set_voltage(v)
        return self.controller.voltage, self.controller.history

    def _autotune_rails(self, max_rounds: int):
        # Align the arena with the controller's starting schedule so the
        # first scrub interval reflects the voltages being judged.
        self.set_rails(self.controller.voltages)
        # Only the weight-arena rails are judged here: a late-attached `kv`
        # rail gets its telemetry from the serving stream (serve(walk_kv=True)),
        # not from the weight scrub, and must not stall this loop.
        arena_rails = self._store.domains
        for _ in range(max_rounds):
            if self.recorder:
                self.recorder.advance(1)
            # Scalar canary score broadcast to every rail: the canary rollout
            # exercises the whole model, so a violation retreats all rails
            # (protect-accuracy semantics; see MultiRailController.update).
            volts = self.controller.update(
                self._last_scrub, divergence=self.canary_divergence()
            )
            # A rail that escalated its codec re-protects its domain before
            # the schedule is applied: the next interval's telemetry must be
            # judged under the stronger code (DESIGN.md §12). Only arena
            # rails are polled here — a late-bound rail's changes stay
            # pending for the component that owns its storage (the serving
            # loop applies `kv` escalations via the scheduler).
            for d in arena_rails:
                cname = self.controller.rails[d].pop_codec_change()
                if cname:
                    self._store.set_domain_codec(d, cname)
            # apply the new schedule (the backed-off one on the final round)
            self.set_rails(volts)
            if all(self.controller.rails[d].locked for d in arena_rails):
                break
        return self.controller.voltages, self.controller.history

    def _autotune_rails_mesh(self, max_rounds: int):
        """Mesh rail search: every chip's canary is judged on its own
        counter rows. `per_shard` walks each chip to its own V_min;
        `uniform` locks one schedule at the worst chip's first DED (the
        psum-aggregated counters trip on any shard's event)."""
        self.set_rails(self.controller.voltages)
        arena_rails = self._store.domains
        for _ in range(max_rounds):
            if self.recorder:
                self.recorder.advance(1)
            schedule = self.controller.update(
                self._last_scrub, divergence=self.canary_divergence()
            )
            if self.controller.policy == "uniform":
                # Escalations apply store-wide (one codec per domain across
                # the mesh); per_shard policy forbids ladders at init.
                for d in arena_rails:
                    cname = self.controller.shards[0].rails[d].pop_codec_change()
                    if cname:
                        self._store.set_domain_codec(d, cname)
            self.set_rails(schedule)
            if self.controller.locked_for(arena_rails):
                break
        return self.controller.voltages, self.controller.history

    def _domain_scrub(self) -> FaultStats:
        agg = FaultStats()
        for name in self.domain.names():
            _, st = self.domain.read(name)
            agg.accumulate(st)
        return agg

    def _check_bits(self) -> dict:
        """Per-domain ECC check bits (the redundancy-cost power weighting)."""
        store = getattr(self, "_store", None)
        return store.check_bits_by_domain() if store is not None else {}

    def power_w(self) -> float:
        """Modeled accelerator power at the current rail voltage(s); on a
        mesh, the fleet total (every reliability shard is its own chip)."""
        ecc = bool(self.rel and self.rel.ecc)
        if self.mesh is not None:
            return self._store.n_shards * vmod.P_REST_W + vmod.mesh_bram_power(
                self.rails, self._store.shard_words_by_domain(), ecc=ecc,
                check_bits=self._check_bits(),
            )
        if self.rails is not None:
            return vmod.P_REST_W + vmod.multi_rail_bram_power(
                self.rails, self._store.words_by_domain(), ecc=ecc,
                check_bits=self._check_bits(),
            )
        # Single rail: the whole arena shares one codec; its redundancy
        # scales the BRAM draw (factor 1 for the measured SECDED geometry).
        bits = self._check_bits()
        factor = vmod.redundancy_factor(next(iter(bits.values()), 8))
        return vmod.P_REST_W + vmod.bram_power(self.voltage, ecc=ecc) * factor

    def power_report(self) -> dict:
        """Per-rail power breakdown + fractional BRAM saving vs nominal,
        including each domain's codec and its redundancy cost. Mesh engines
        report per-shard chips plus the fleet aggregate (DESIGN.md §13)."""
        ecc = bool(self.rel and self.rel.ecc)
        if self.mesh is not None:
            words = self._store.shard_words_by_domain()
            bits = self._check_bits()
            per_shard = [
                {
                    "shard": s,
                    "rails": dict(self.rails[s]),
                    "bram_w": vmod.multi_rail_bram_power(
                        self.rails[s], words[s], ecc=ecc, check_bits=bits
                    ),
                    "saving_vs_nominal": vmod.multi_rail_power_saving(
                        self.rails[s], words[s], ecc=ecc, check_bits=bits
                    ),
                }
                for s in range(self._store.n_shards)
            ]
            bram = vmod.mesh_bram_power(
                self.rails, words, ecc=ecc, check_bits=bits
            )
            return {
                "n_shards": self._store.n_shards,
                "policy": self.rel.rail_policy,
                "codecs": self._store.codecs_by_domain(),
                "check_bits": bits,
                "shards": per_shard,
                "bram_w": bram,
                "total_w": self.power_w(),
                "saving_vs_nominal": vmod.mesh_power_saving(
                    self.rails, words, ecc=ecc, check_bits=bits
                ),
            }
        if self.rails is not None:
            words = self._store.words_by_domain()
            total = max(sum(words.values()), 1)
            bits = self._check_bits()
            codecs = self._store.codecs_by_domain()
            return {
                "rails": dict(self.rails),
                "codecs": codecs,
                "check_bits": bits,
                "bram_w": vmod.multi_rail_bram_power(
                    self.rails, words, ecc=ecc, check_bits=bits
                ),
                "bram_w_by_domain": {
                    d: (words[d] / total)
                    * vmod.bram_power(v, ecc=ecc)
                    * vmod.redundancy_factor(bits.get(d, 8))
                    for d, v in self.rails.items()
                },
                "total_w": self.power_w(),
                "saving_vs_nominal": vmod.multi_rail_power_saving(
                    self.rails, words, ecc=ecc, check_bits=bits
                ),
            }
        bits = self._check_bits()
        factor = vmod.redundancy_factor(next(iter(bits.values()), 8))
        return {
            "rails": {"all": self.voltage},
            "codecs": dict(getattr(self, "_store", None).codecs_by_domain())
            if getattr(self, "_store", None) is not None
            else {},
            "bram_w": vmod.bram_power(self.voltage, ecc=ecc) * factor,
            "total_w": self.power_w(),
            "saving_vs_nominal": 1.0
            - vmod.bram_power(self.voltage, ecc=ecc) * factor
            / vmod.bram_power(1.0, ecc=False),
        }
