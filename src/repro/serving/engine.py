"""Batched serving engine with ECC-protected weights under an undervolted rail.

The paper's §IV evaluation as a service: model weights live in an
`EccMemoryDomain` ("BRAM") at a configurable rail voltage; every voltage
change re-materialises the faulty-but-corrected view of the weights through
the SECDED read path; the DED-canary `UndervoltController` consumes scrub
telemetry between generation rounds and walks the rail down until the first
detected-uncorrectable event. Power comes from the calibrated Table-I model.

Two protection layouts:
  * mode="domain"  — any arch: raw weight bits stored in the domain, decoded
    view refreshed per voltage (matches the paper's BRAM-resident weights);
  * mode="inline"  — dense archs: big matrices replaced by int8 EccWeight
    planes; every forward pass runs the (Pallas) decode-matmul read path,
    faults injected into the planes XOR-style. This is the TPU-native fused
    path (DESIGN.md §2) and the paper-representative dry-run/hillclimb cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UndervoltController, voltage as vmod
from repro.core.faultsim import FaultField
from repro.core.memory import EccMemoryDomain
from repro.core.planestore import PlaneStore, leaf_seed
from repro.core.telemetry import FaultStats
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    platform: str = "vc707"
    ecc: bool = True
    voltage: float | None = None  # None -> nominal
    protect: tuple = ("weights",)
    mode: str = "domain"  # domain | inline
    fuse: bool = True  # inline mode: fused Pallas read path vs naive
    seed: int = 0
    controller_step_v: float = 0.01
    # inline mode: one fused inject+scrub launch over the whole-model plane
    # arena (True) vs the historical per-leaf loop (False, reference path)
    batched: bool = True
    # "host": NumPy FaultField oracle (bit-identical to per-leaf path);
    # "device": counter-based jax.random masks, never materialised on host
    mask_source: str = "host"


def _pack_stacked(leaf) -> kops.EccWeight:
    """Pack a layer-stacked (G, K, N) float weight into stacked ECC planes.

    The scan over layer groups slices the leading G off every plane leaf, so
    the in-scan view is exactly the 2D EccWeight the kernels expect."""
    g = leaf.shape[0]
    packed = [kops.pack_ecc_weights(jnp.asarray(leaf[i], jnp.float32)) for i in range(g)]
    return kops.EccWeight(
        lo=jnp.stack([p.lo for p in packed]),
        hi=jnp.stack([p.hi for p in packed]),
        parity=jnp.stack([p.parity for p in packed]),
        scale=jnp.stack([p.scale for p in packed]),
        k=packed[0].k,
        n=packed[0].n,
        fuse=packed[0].fuse,
    )


def protect_params_inline(params, cfg: ModelConfig, seed: int = 0):
    """Replace weight matrices (K%8==0) with SECDED int8 EccWeight planes.

    Handles both plain (K, N) and layer-stacked (G, K, N) leaves. Returns
    (new_params, plane_sizes) where plane_sizes maps path -> word count
    (for voltage-dependent fault injection).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, fields = [], {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if not hasattr(leaf, "ndim") or not ("attn" in key or "mlp" in key):
            out.append(leaf)
            continue
        if leaf.ndim == 2 and leaf.shape[0] % 8 == 0 and min(leaf.shape) >= 64:
            ew = kops.pack_ecc_weights(jnp.asarray(leaf, jnp.float32))
        elif leaf.ndim == 3 and leaf.shape[1] % 8 == 0 and min(leaf.shape[1:]) >= 64:
            ew = _pack_stacked(leaf)
        else:
            out.append(leaf)
            continue
        out.append(ew)
        fields[key] = ew.lo.size
    return jax.tree_util.tree_unflatten(treedef, out), fields


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        rel: ReliabilityConfig | None = None,
        max_len: int = 512,
    ):
        self.cfg = cfg
        self.rel = rel
        self.max_len = max_len
        self.platform = vmod.PLATFORMS[rel.platform] if rel else None
        self.controller = (
            UndervoltController(self.platform, step_v=rel.controller_step_v)
            if rel
            else None
        )
        self.stats = FaultStats()
        self._clean_params = params
        if rel is None:
            self.params = params
            self.domain = None
        elif rel.mode == "domain":
            self.domain = EccMemoryDomain(
                rel.platform, seed=rel.seed, ecc_enabled=rel.ecc,
                voltage=rel.voltage or 1.0,
            )
            self.domain.write_pytree("w", params)
            self.params = params  # refreshed by set_voltage
            self.set_voltage(self.domain.voltage)
        else:  # inline
            self.domain = None
            self.params, self._plane_sizes = protect_params_inline(
                params, cfg, seed=rel.seed
            )
            self._clean_inline = self.params
            self._fields: dict[str, FaultField] = {}
            # Batched plane arena: flatten once, record which flat slots hold
            # EccWeight planes, and key each by its tree path (the per-leaf
            # fault-field seeds depend on it).
            flat, self._inline_treedef = jax.tree_util.tree_flatten_with_path(
                self._clean_inline,
                is_leaf=lambda x: isinstance(x, kops.EccWeight),
            )
            self._inline_template = [leaf for _, leaf in flat]
            self._ecc_slots = [
                (i, jax.tree_util.keystr(path))
                for i, (path, leaf) in enumerate(flat)
                if isinstance(leaf, kops.EccWeight)
            ]
            self._store = PlaneStore(
                [self._inline_template[i] for i, _ in self._ecc_slots],
                [key for _, key in self._ecc_slots],
                self.platform,
                seed=rel.seed,
                mask_source=rel.mask_source,
            )
            self.voltage = rel.voltage or self.platform.v_nom
            self.set_voltage(self.voltage)

        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, cfg, c, pos)
        )
        self._prefill = jax.jit(
            lambda p, t, c: lm.prefill(p, t, cfg, c)
        )
        self._decode_loop = jax.jit(
            lambda p, t, c, s0, n: lm.greedy_decode_loop(p, t, cfg, c, s0, n),
            static_argnums=(4,),
        )

    # -- voltage control ------------------------------------------------------
    def set_voltage(self, v: float):
        self.voltage = float(v)
        if self.rel is None:
            return
        if self.rel.mode == "domain":
            self.domain.set_voltage(v)
            self.params, stats = self.domain.read_pytree("w", self._clean_params)
            self.stats.merge(stats)
        elif self.rel.batched:
            self._apply_inline_faults_batched(v)
        else:
            self._apply_inline_faults(v)

    def _apply_inline_faults_batched(self, v: float):
        """Whole-model voltage step: one fused inject+scrub kernel launch over
        the plane arena; only the (8,) counter vector crosses to host."""
        leaves, stats = self._store.set_voltage(v, ecc=self.rel.ecc)
        flat = list(self._inline_template)
        for (i, _), leaf in zip(self._ecc_slots, leaves):
            flat[i] = leaf
        self.params = jax.tree_util.tree_unflatten(self._inline_treedef, flat)
        self.stats.merge(stats)
        self._last_scrub = stats

    def _apply_inline_faults(self, v: float):
        """Per-leaf reference path (one inject + one scrub launch per leaf,
        masks generated on host). Kept for parity tests and benchmarks."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self._clean_inline, is_leaf=lambda x: isinstance(x, kops.EccWeight)
        )
        out = []
        agg = FaultStats()
        for path, leaf in flat:
            if not isinstance(leaf, kops.EccWeight):
                out.append(leaf)
                continue
            key = jax.tree_util.keystr(path)
            field = self._fields.get(key)
            if field is None:
                field = FaultField(
                    self.platform, leaf.lo.size, seed=leaf_seed(self.rel.seed, key)
                )
                self._fields[key] = field
            masks = field.masks(v)
            mlo = jnp.asarray(masks.lo.reshape(leaf.lo.shape))
            mhi = jnp.asarray(masks.hi.reshape(leaf.hi.shape))
            mpar = jnp.asarray(masks.parity.reshape(leaf.parity.shape))
            flo, fhi, fpar = kops.inject(leaf.lo, leaf.hi, leaf.parity, mlo, mhi, mpar)
            faulty = dataclasses.replace(leaf, lo=flo, hi=fhi, parity=fpar)
            if not self.rel.ecc:
                # No-ECC baseline: zero the parity contribution by decoding off
                # — we emulate by treating planes as raw (decode would mis-fire),
                # so instead keep faulty planes and a pass-through decode: the
                # raw faulty bits flow straight into the matmul.
                faulty = dataclasses.replace(faulty, parity=kops.encode(faulty.lo, faulty.hi))
            status = np.asarray(kops.scrub(faulty))
            agg.merge(FaultStats.from_decode(status, masks.flip_counts()))
            out.append(faulty)
        self.params = jax.tree_util.tree_unflatten(treedef, out)
        self.stats.merge(agg)
        self._last_scrub = agg

    # -- serving --------------------------------------------------------------
    def generate(self, prompts: np.ndarray, n_tokens: int, *, use_scan: bool = True):
        """Greedy-decode a batch. prompts: (B, S0) int32. Returns (B, n).

        use_scan=True rolls the decode loop into one lax.scan program (one
        dispatch for the whole rollout; compiled once per n_tokens value);
        use_scan=False is the historical per-token Python loop, kept as the
        reference the scan path is tested against.
        """
        b, s0 = prompts.shape
        cache = lm.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        if not use_scan:
            outs = [tok]
            for i in range(n_tokens - 1):
                logits, cache = self._decode(self.params, tok, cache, s0 + i)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                outs.append(tok)
            return np.concatenate([np.asarray(o) for o in outs], axis=1)
        toks, _ = self._decode_loop(
            self.params, tok, cache, jnp.int32(s0), n_tokens - 1
        )
        return np.concatenate([np.asarray(tok), np.asarray(toks)], axis=1)

    # -- runtime undervolting loop ---------------------------------------------
    def autotune_voltage(self, max_rounds: int = 60):
        """Paper §III/IV: lower the rail until the ECC's DED flag trips."""
        assert self.rel is not None and self.controller is not None
        for _ in range(max_rounds):
            round_stats = (
                self._last_scrub if self.rel.mode == "inline" else self._domain_scrub()
            )
            v = self.controller.update(round_stats)
            if self.controller.locked:
                # re-apply the backed-off (safe) voltage before serving
                self.set_voltage(self.controller.voltage)
                break
            self.set_voltage(v)
        return self.controller.voltage, self.controller.history

    def _domain_scrub(self) -> FaultStats:
        agg = FaultStats()
        for name in self.domain.names():
            _, st = self.domain.read(name)
            agg.merge(st)
        return agg

    def power_w(self) -> float:
        """Modeled accelerator power at the current rail voltage."""
        return vmod.accelerator_power(self.voltage, ecc=bool(self.rel and self.rel.ecc))
