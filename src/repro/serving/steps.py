"""Serving step functions: prefill, single-token decode (greedy), and the
paged-cache lane helpers for continuous batching.

`serve_step` is what decode_32k / long_500k dry-run cells lower: one new token
against a seq_len-deep KV cache (or SSM state), returning the sampled token
and the updated cache. Cache buffers are donated so the compiled step updates
in place.

`make_paged_helpers` builds the jit'd glue between the dense per-lane decode
cache and the SECDED page arena (core/kvpages.py): extract one token's K/V
payload per lane, load a prefilled batch-of-1 cache into a lane, and refresh
lane caches from scrubbed page payloads. The payload layout (per token: for
each attention period position, K then V, each (groups, kv_heads, head_dim)
C-order) is defined *only* here — extract and refresh are exact inverses.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.kvpages import KVGeometry
from repro.models import lm
from repro.models.base import ModelConfig
from repro.obs import profile as obs_profile


def _profiled(name: str, fn):
    """Route a jit'd dispatch through the opt-in wall-clock profiler.

    When no profiler is enabled this is a single ``is None`` check on top
    of the call (obs/profile.call) — the deterministic event log never
    sees these timings, so traces stay bit-reproducible either way.
    """
    if fn is None:
        return None

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        return obs_profile.call(name, fn, *args, **kwargs)

    return wrapped


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, cache, img=None):
        logits, cache = lm.prefill(params, tokens, cfg, cache, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def _extract_tokens(cache, idx, *, geom: KVGeometry):
    """Per-lane token payload: cache tree + (L,) positions -> (L, token_f32)."""
    parts = []
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cache[f"p{j}"][name]  # (g, L, S, H, D)
            sel = jnp.take_along_axis(
                c, idx.reshape(1, -1, 1, 1, 1).astype(jnp.int32), axis=2
            )  # (g, L, 1, H, D)
            parts.append(jnp.moveaxis(sel[:, :, 0], 0, 1).reshape(idx.shape[0], -1))
    return jnp.concatenate(parts, axis=1).astype(jnp.float32)


def _extract_span(cachem, *, start: int, stop: int, geom: KVGeometry):
    """Window payload: batch-of-m cache -> (m, stop-start, token_f32) for
    cache positions start..stop-1 (prefix sharing commits only the private
    suffix — the shared pages already hold positions 0..start-1)."""
    parts = []
    span = stop - start
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cachem[f"p{j}"][name]  # (g, m, S, H, D)
            m = c.shape[1]
            sel = jnp.moveaxis(c[:, :, start:stop], 0, 2)  # (m, span, g, H, D)
            parts.append(sel.reshape(m, span, -1))
    return jnp.concatenate(parts, axis=2).astype(jnp.float32)


def _extract_range(cachem, *, s0: int, geom: KVGeometry):
    """Prompt payload: batch-of-m cache -> (m, s0, token_f32), tokens 0..s0-1."""
    return _extract_span(cachem, start=0, stop=s0, geom=geom)


def _refresh_cache(cache, payload, n_tok, *, geom: KVGeometry):
    """Scatter scrubbed page payloads back into the lane caches.

    payload: (L, T, token_f32) decoded tokens in position order (T >= the
    cache depth S is sliced; T < S leaves the tail untouched); n_tok: (L,)
    valid-token counts — positions >= n_tok keep their cache bits.
    """
    length, t_total, _ = payload.shape
    out = {k: dict(v) for k, v in cache.items()}
    off = 0
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cache[f"p{j}"][name]  # (g, L, S, H, D)
            g, _, s, h, d = c.shape
            t = min(t_total, s)
            sz = g * h * d
            part = payload[:, :t, off : off + sz].reshape(length, t, g, h, d)
            part = jnp.moveaxis(part, 2, 0).astype(c.dtype)  # (g, L, t, H, D)
            valid = (jnp.arange(t)[None, :] < n_tok[:, None])[None, :, :, None, None]
            out[f"p{j}"][name] = c.at[:, :, :t].set(
                jnp.where(valid, part, c[:, :, :t])
            )
            off += sz
    return out


def _load_lane(cache, cachem, src_row, lane):
    """Copy row ``src_row`` of a prefilled batch-of-m cache into ``lane``."""
    return jax.tree_util.tree_map(
        lambda c, cm: jax.lax.dynamic_update_slice_in_dim(
            c,
            jax.lax.dynamic_slice_in_dim(cm.astype(c.dtype), src_row, 1, 1),
            lane,
            1,
        ),
        cache,
        cachem,
    )


def _multistep(
    params, tok, cache, lo, hi, par, pos0, page_ids, slots, *, cfg, geom,
    codec="secded72",
):
    """Decode ``k`` tokens per lane in one dispatch (multi-step scheduling).

    The continuous-batching loop pays Python dispatch per token where the
    fixed-batch loop pays one `lax.scan`; this rolls a *block* of k decode
    steps — decode, extract the written token's KV, commit it to the page
    arena — into one scanned program. page_ids/slots: (k, L) per-step page
    targets (precomputed on host; inactive lanes point at the scratch page).

    Returns (tokens (k, L), cache, lo, hi, par).
    """
    from repro.core.kvpages import _commit_tokens

    def body(carry, xs):
        tok, cache, lo, hi, par, pos = carry
        pids, slts = xs
        logits, cache = lm.decode_step(params, tok, cfg, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        payload = _extract_tokens(cache, pos, geom=geom)
        lo, hi, par = _commit_tokens(
            lo, hi, par, payload, pids, slts,
            token_words=geom.token_words,
            words_per_page=geom.words_per_page,
            codec=codec,
        )
        return (nxt, cache, lo, hi, par, pos + 1), nxt[:, 0]

    (tok, cache, lo, hi, par, _), toks = jax.lax.scan(
        body, (tok, cache, lo, hi, par, pos0), (page_ids, slots)
    )
    return toks, cache, lo, hi, par


def _chunk_prefill(params, tokens, cache, pos0, *, cfg):
    """Chunked prefill of ``tokens`` (m, s) at per-lane cache position
    ``pos0`` (m,): the prefix-sharing admission path — the shared prefix is
    already in the cache (refreshed from its pages), only the private
    suffix runs through the model. Returns (next_tok (m,), cache)."""
    logits, cache = lm.chunk_step(params, tokens, cfg, cache, pos0)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


def _spec_multistep(
    params, dparams, tok, cache, dcache, lo, hi, par, pos0, page_ids, slots,
    *, cfg, dcfg, geom, codec="secded72", k, scratch_page,
):
    """Draft k-1 tokens with the draft model, verify all k positions with
    the target model in ONE chunk dispatch, commit pages only for accepted
    tokens (DESIGN.md §16).

    tok: (L, 1) current token; cache/dcache: target/draft lane caches;
    pos0: (L,) position of ``tok``; page_ids/slots: (k, L) host page
    targets for positions pos0..pos0+k-1 (inactive lanes already point at
    the scratch page).

    Greedy acceptance: the target's chunk logits give greedy[:, i] =
    argmax P(. | t0, d1..d_i); draft d_{i+1} is accepted iff it equals
    greedy[:, i], and ``n_emit = 1 + #accepted-prefix`` in [1, k] — so the
    emitted tokens greedy[:, :n_emit] are exactly the tokens step-by-step
    greedy decode would have produced, regardless of draft quality (the
    accepted-prefix property, tested). Rejected drafts' K/V rows stay in
    the dense lane cache beyond the valid length (masked by every later
    attention and overwritten before they are ever attended) and their
    page commits are steered to the scratch row.

    Returns (greedy (L, k), n_emit (L,), cache, dcache, lo, hi, par).
    """
    from repro.core.kvpages import _commit_tokens

    length = tok.shape[0]

    def draft_body(carry, _):
        t, dc, p = carry
        logits, dc = lm.decode_step(dparams, t, dcfg, dc, p)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (nxt, dc, p + 1), nxt[:, 0]

    if k > 1:
        # length=k, not k-1: the k-th step's sampled token is discarded but
        # its decode writes tokens_v[:, k-1]'s K/V into the draft cache —
        # otherwise full acceptance leaves a hole at pos0+k-1 that the next
        # block's draft would attend as garbage (hurting acceptance, never
        # correctness: the target verifies regardless).
        (_, dcache, _), drafts = jax.lax.scan(
            draft_body, (tok, dcache, pos0), None, length=k
        )
        tokens_v = jnp.concatenate([tok, drafts[:-1].T], axis=1)  # (L, k)
    else:
        tokens_v = tok  # degenerate k=1: plain single-step decode via chunk
    full, cache = lm.chunk_logits(params, tokens_v, cfg, cache, pos0)
    greedy = jnp.argmax(full, axis=-1).astype(jnp.int32)  # (L, k)
    if k > 1:
        match = (tokens_v[:, 1:] == greedy[:, :-1]).astype(jnp.int32)
        n_emit = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)  # (L,)
    else:
        n_emit = jnp.ones((length,), jnp.int32)

    # Commit positions pos0+i only where i < n_emit: the committed rows are
    # exactly the block's accepted sequence [t0, accepted drafts] — the same
    # resume_seq prefix the non-speculative path commits.
    payloads = jax.vmap(
        lambda i: _extract_tokens(cache, pos0 + i, geom=geom)
    )(jnp.arange(k))  # (k, L, F)
    accept = jnp.arange(k)[:, None] < n_emit[None, :]
    commit_ids = jnp.where(accept, page_ids, scratch_page)
    lo, hi, par = _commit_tokens(
        lo, hi, par,
        payloads.reshape(k * length, -1),
        commit_ids.reshape(-1),
        slots.reshape(-1),
        token_words=geom.token_words,
        words_per_page=geom.words_per_page,
        codec=codec,
    )
    return greedy, n_emit, cache, dcache, lo, hi, par


@runtime_checkable
class DecodeBlockHelpers(Protocol):
    """The decode-block helper contract the continuous-batching scheduler
    consumes (DESIGN.md §11/§16). ``make_paged_helpers`` is the canonical
    producer; anything item-accessible with these keys satisfies it."""

    def __getitem__(self, name: str) -> Callable: ...


@dataclasses.dataclass(frozen=True)
class PagedHelpers:
    """jit'd continuous-batching helpers sharing one payload layout.

    Attribute and ``helpers["name"]`` access are both supported — the
    scheduler historically indexed a plain dict and external factories may
    still return one (see :class:`DecodeBlockHelpers`).

      prefill(params, tokens (m,s), cachem)       -> (next_tok (m,), cachem)
      multistep(params, tok, cache, lo, hi, par,
                pos (L,), page_ids (k,L), slots)  -> (toks (k,L), cache, planes)
      extract_range(cachem, s0=s)                 -> (m, s, token_f32) payload
      extract_span(cachem, start=a, stop=b)       -> (m, b-a, token_f32)
      load_lane(cache, cachem, src_row, lane)     -> cache
      refresh(cache, payload (L,T,F), n_tok (L,)) -> cache
      chunk(params, tokens (m,s), cachem, pos0)   -> (next_tok (m,), cachem)
      spec_multistep(params, dparams, tok, cache, dcache, lo, hi, par,
                pos (L,), page_ids (k,L), slots, k=, scratch_page=)
                -> (greedy (L,k), n_emit (L,), cache, dcache, planes)

    Single-step decode is multistep with k=1 (one (1, L) page row); the
    per-token extract lives inside the multistep scan body. ``codec`` is
    the SECDED-family codec the commit path encodes with — rebuild the
    helpers (via the engine's helpers factory) when the kv rail escalates.
    """

    codec: str
    prefill: Callable
    multistep: Callable
    extract_range: Callable
    extract_span: Callable
    load_lane: Callable
    refresh: Callable
    chunk: Callable
    spec_multistep: Optional[Callable] = None

    def __getitem__(self, name: str) -> Callable:
        fn = getattr(self, name)
        if fn is None:
            raise KeyError(name)
        return fn

    def get(self, name: str, default: Any = None) -> Any:
        return getattr(self, name, default) or default


@runtime_checkable
class HelpersFactory(Protocol):
    """codec name -> decode-block helpers, called by the scheduler when the
    kv rail's escalation ladder changes the arena's codec mid-serve."""

    def __call__(self, codec: str) -> DecodeBlockHelpers: ...


def make_paged_helpers(
    cfg: ModelConfig,
    geom: KVGeometry,
    codec: str = "secded72",
    draft_cfg: ModelConfig | None = None,
) -> PagedHelpers:
    """Build the jit'd :class:`PagedHelpers` bundle for one (config,
    geometry, codec) triple. ``draft_cfg`` enables ``spec_multistep`` (the
    draft model's decode runs inside the same scanned dispatch)."""
    spec = None
    if draft_cfg is not None:
        spec = _profiled(
            "decode.spec_multistep",
            jax.jit(
                functools.partial(
                    _spec_multistep,
                    cfg=cfg, dcfg=draft_cfg, geom=geom, codec=codec,
                ),
                static_argnames=("k", "scratch_page"),
            ),
        )
    return PagedHelpers(
        codec=codec,
        prefill=_profiled("decode.prefill", jax.jit(make_prefill_step(cfg))),
        multistep=_profiled(
            "decode.multistep",
            jax.jit(
                functools.partial(_multistep, cfg=cfg, geom=geom, codec=codec)
            ),
        ),
        extract_range=jax.jit(
            functools.partial(_extract_range, geom=geom), static_argnames=("s0",)
        ),
        extract_span=jax.jit(
            functools.partial(_extract_span, geom=geom),
            static_argnames=("start", "stop"),
        ),
        load_lane=jax.jit(_load_lane),
        refresh=jax.jit(functools.partial(_refresh_cache, geom=geom)),
        chunk=_profiled(
            "decode.chunk_prefill",
            jax.jit(functools.partial(_chunk_prefill, cfg=cfg)),
        ),
        spec_multistep=spec,
    )


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos, img=None):
        logits, cache = lm.decode_step(params, tokens, cfg, cache, pos, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            next_tok = next_tok[:, :, None]  # (B, K, 1)
        else:
            next_tok = next_tok[:, None]  # (B, 1)
        return next_tok, cache

    return serve_step
