"""Serving step functions: prefill and single-token decode (greedy).

`serve_step` is what decode_32k / long_500k dry-run cells lower: one new token
against a seq_len-deep KV cache (or SSM state), returning the sampled token
and the updated cache. Cache buffers are donated so the compiled step updates
in place.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.base import ModelConfig


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, cache, img=None):
        logits, cache = lm.prefill(params, tokens, cfg, cache, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos, img=None):
        logits, cache = lm.decode_step(params, tokens, cfg, cache, pos, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            next_tok = next_tok[:, :, None]  # (B, K, 1)
        else:
            next_tok = next_tok[:, None]  # (B, 1)
        return next_tok, cache

    return serve_step
