"""Serving step functions: prefill, single-token decode (greedy), and the
paged-cache lane helpers for continuous batching.

`serve_step` is what decode_32k / long_500k dry-run cells lower: one new token
against a seq_len-deep KV cache (or SSM state), returning the sampled token
and the updated cache. Cache buffers are donated so the compiled step updates
in place.

`make_paged_helpers` builds the jit'd glue between the dense per-lane decode
cache and the SECDED page arena (core/kvpages.py): extract one token's K/V
payload per lane, load a prefilled batch-of-1 cache into a lane, and refresh
lane caches from scrubbed page payloads. The payload layout (per token: for
each attention period position, K then V, each (groups, kv_heads, head_dim)
C-order) is defined *only* here — extract and refresh are exact inverses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kvpages import KVGeometry
from repro.models import lm
from repro.models.base import ModelConfig


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, cache, img=None):
        logits, cache = lm.prefill(params, tokens, cfg, cache, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def _extract_tokens(cache, idx, *, geom: KVGeometry):
    """Per-lane token payload: cache tree + (L,) positions -> (L, token_f32)."""
    parts = []
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cache[f"p{j}"][name]  # (g, L, S, H, D)
            sel = jnp.take_along_axis(
                c, idx.reshape(1, -1, 1, 1, 1).astype(jnp.int32), axis=2
            )  # (g, L, 1, H, D)
            parts.append(jnp.moveaxis(sel[:, :, 0], 0, 1).reshape(idx.shape[0], -1))
    return jnp.concatenate(parts, axis=1).astype(jnp.float32)


def _extract_range(cachem, *, s0: int, geom: KVGeometry):
    """Prompt payload: batch-of-m cache -> (m, s0, token_f32), tokens 0..s0-1."""
    parts = []
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cachem[f"p{j}"][name]  # (g, m, S, H, D)
            m = c.shape[1]
            sel = jnp.moveaxis(c[:, :, :s0], 0, 2)  # (m, s0, g, H, D)
            parts.append(sel.reshape(m, s0, -1))
    return jnp.concatenate(parts, axis=2).astype(jnp.float32)


def _refresh_cache(cache, payload, n_tok, *, geom: KVGeometry):
    """Scatter scrubbed page payloads back into the lane caches.

    payload: (L, T, token_f32) decoded tokens in position order (T >= the
    cache depth S is sliced; T < S leaves the tail untouched); n_tok: (L,)
    valid-token counts — positions >= n_tok keep their cache bits.
    """
    length, t_total, _ = payload.shape
    out = {k: dict(v) for k, v in cache.items()}
    off = 0
    for j in geom.attn_positions:
        for name in ("k", "v"):
            c = cache[f"p{j}"][name]  # (g, L, S, H, D)
            g, _, s, h, d = c.shape
            t = min(t_total, s)
            sz = g * h * d
            part = payload[:, :t, off : off + sz].reshape(length, t, g, h, d)
            part = jnp.moveaxis(part, 2, 0).astype(c.dtype)  # (g, L, t, H, D)
            valid = (jnp.arange(t)[None, :] < n_tok[:, None])[None, :, :, None, None]
            out[f"p{j}"][name] = c.at[:, :, :t].set(
                jnp.where(valid, part, c[:, :, :t])
            )
            off += sz
    return out


def _load_lane(cache, cachem, src_row, lane):
    """Copy row ``src_row`` of a prefilled batch-of-m cache into ``lane``."""
    return jax.tree_util.tree_map(
        lambda c, cm: jax.lax.dynamic_update_slice_in_dim(
            c,
            jax.lax.dynamic_slice_in_dim(cm.astype(c.dtype), src_row, 1, 1),
            lane,
            1,
        ),
        cache,
        cachem,
    )


def _multistep(
    params, tok, cache, lo, hi, par, pos0, page_ids, slots, *, cfg, geom,
    codec="secded72",
):
    """Decode ``k`` tokens per lane in one dispatch (multi-step scheduling).

    The continuous-batching loop pays Python dispatch per token where the
    fixed-batch loop pays one `lax.scan`; this rolls a *block* of k decode
    steps — decode, extract the written token's KV, commit it to the page
    arena — into one scanned program. page_ids/slots: (k, L) per-step page
    targets (precomputed on host; inactive lanes point at the scratch page).

    Returns (tokens (k, L), cache, lo, hi, par).
    """
    from repro.core.kvpages import _commit_tokens

    def body(carry, xs):
        tok, cache, lo, hi, par, pos = carry
        pids, slts = xs
        logits, cache = lm.decode_step(params, tok, cfg, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        payload = _extract_tokens(cache, pos, geom=geom)
        lo, hi, par = _commit_tokens(
            lo, hi, par, payload, pids, slts,
            token_words=geom.token_words,
            words_per_page=geom.words_per_page,
            codec=codec,
        )
        return (nxt, cache, lo, hi, par, pos + 1), nxt[:, 0]

    (tok, cache, lo, hi, par, _), toks = jax.lax.scan(
        body, (tok, cache, lo, hi, par, pos0), (page_ids, slots)
    )
    return toks, cache, lo, hi, par


def make_paged_helpers(cfg: ModelConfig, geom: KVGeometry, codec: str = "secded72"):
    """jit'd continuous-batching helpers sharing one payload layout.

    Returns a dict of:
      prefill(params, tokens (m,s), cachem)       -> (next_tok (m,), cachem)
      multistep(params, tok, cache, lo, hi, par,
                pos (L,), page_ids (k,L), slots)  -> (toks (k,L), cache, planes)
      extract_range(cachem, s)                    -> (m, s, token_f32) payload
      load_lane(cache, cachem, src_row, lane)     -> cache
      refresh(cache, payload (L,T,F), n_tok (L,)) -> cache

    Single-step decode is multistep with k=1 (one (1, L) page row); the
    per-token extract lives inside the multistep scan body.
    """
    return {
        "prefill": jax.jit(make_prefill_step(cfg)),
        "multistep": jax.jit(
            functools.partial(_multistep, cfg=cfg, geom=geom, codec=codec)
        ),
        "extract_range": jax.jit(
            functools.partial(_extract_range, geom=geom), static_argnames=("s0",)
        ),
        "load_lane": jax.jit(_load_lane),
        "refresh": jax.jit(functools.partial(_refresh_cache, geom=geom)),
    }


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos, img=None):
        logits, cache = lm.decode_step(params, tokens, cfg, cache, pos, img=img)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            next_tok = next_tok[:, :, None]  # (B, K, 1)
        else:
            next_tok = next_tok[:, None]  # (B, 1)
        return next_tok, cache

    return serve_step
