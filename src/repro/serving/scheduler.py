"""Continuous-batching scheduler over the paged SECDED KV cache.

The fixed-batch engine (`ServingEngine.generate`) serves one rectangular
batch: every request the same prompt length, every request decoded for the
same number of tokens, lanes idle once their request is done. This module
serves a *stream* of variable-length requests instead (DESIGN.md §11):

  * a fixed number of batch *lanes* decode in lock-step, each lane at its own
    sequence position (models/lm.py per-lane `pos` vectors);
  * requests are admitted FCFS into free lanes when the page arena has room
    for their prompt (plus one decode page);
  * each lane's KV is committed token-by-token into SECDED pages
    (core/kvpages.py); pages are allocated on demand as a request crosses a
    page boundary;
  * under page pressure the *youngest* running request is preempted
    (recompute-style: pages freed, request re-queued at the front; on
    re-admission its prompt plus already-generated tokens are re-prefilled),
    so the oldest requests always make progress;
  * every ``scrub_interval`` steps the arena injects the current `kv`-rail
    interval faults, all live pages are scrubbed-on-read (corrected planes
    written back, per-page counters attributed to the owning request), and
    lane caches are refreshed from the corrected payload. The interval's
    aggregate counters optionally drive the `kv` rail of a
    MultiRailController — the cache voltage walks independently of the
    weight rails.

Scheduling is pure host logic; all device work goes through the jit'd
helpers from serving/steps.py and the arena methods, with fixed shapes so
nothing retraces across steps (prefill/commit trace once per distinct
prompt length).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.obs import profile as obs_profile
from repro.core.kvpages import (
    KVGeometry,
    KVPageArena,
    PageAllocator,
    PrefixTrie,
    SharedPageDEDError,
    dedup_page_table,
)
from repro.core.controller import reader_weighted_stats
from repro.core.telemetry import FaultStats


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: a prompt and a greedy-decode budget."""

    rid: int
    prompt: np.ndarray  # (s0,) int32
    max_new_tokens: int


#: Public name of the request protocol type (`repro.serving.ServeRequest`):
#: the consolidated serving API exports the dataclass under the name the
#: engine/scheduler docs use; `Request` remains for existing call sites.
ServeRequest = Request


@dataclasses.dataclass
class RequestState:
    req: Request
    status: str = "waiting"  # waiting | running | finished
    lane: int = -1
    admit_seq: int = -1  # admission order; preemption evicts the youngest
    pages: list = dataclasses.field(default_factory=list)
    tokens: list = dataclasses.field(default_factory=list)  # generated so far
    stats: FaultStats = dataclasses.field(default_factory=FaultStats)
    preemptions: int = 0
    shared_tokens: int = 0  # leading tokens served from trie-shared pages
    # flight-recorder bookkeeping (step-clock values; -1 = never/not traced)
    admit_step: int = -1  # clock at FIRST admission (re-admissions keep it)
    first_token_step: int = -1
    finish_step: int = -1

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def stored(self) -> int:
        """Tokens whose KV lives in pages: prompt + fed decode tokens.

        The freshest generated token is produced *before* its KV is written
        (it is stored when fed to the next decode step), hence the -1.
        """
        return len(self.req.prompt) + max(len(self.tokens) - 1, 0)

    @property
    def resume_seq(self) -> np.ndarray:
        """Token sequence a (re-)admission prefills: prompt + all generated
        tokens except the last (whose KV the next decode step will write)."""
        gen = np.asarray(self.tokens[:-1], np.int32)
        return np.concatenate([self.req.prompt.astype(np.int32), gen])

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.req.max_new_tokens


@dataclasses.dataclass
class ServeReport:
    """Outcome of one `serve_stream` run."""

    outputs: dict  # rid -> (max_new_tokens,) np.int32 generated tokens
    request_stats: dict  # rid -> FaultStats (scrub-on-read telemetry)
    kv_stats: FaultStats  # aggregate cache telemetry
    steps: int  # batched decode steps executed
    preemptions: int
    kv_voltages: list  # kv rail trajectory (one entry per scrub interval)
    arena: KVPageArena
    pages_free_at_end: int  # == arena.n_pages unless the allocator leaked
    prefix_hit_tokens: int = 0  # prompt tokens served from shared pages
    spec_dispatches: int = 0  # speculative verify blocks executed
    spec_emitted: int = 0  # tokens emitted by speculative blocks


def normalize_requests(requests) -> list:
    """(prompt, max_new_tokens) pairs -> Request objects with stream-order
    rids (pre-built Requests pass through untouched)."""
    return [
        r
        if isinstance(r, Request)
        else Request(i, np.asarray(r[0], np.int32), int(r[1]))
        for i, r in enumerate(requests)
    ]


def partition_requests(requests, n_shards: int) -> list:
    """Round-robin the stream across ``n_shards`` data-parallel replicas.

    Round-robin by arrival index keeps each replica's queue in global FCFS
    order (admission inside a replica stays FCFS), and a 1-shard mesh gets
    the whole stream in order — the serve path's bit-identity anchor.
    """
    assert n_shards >= 1, n_shards
    parts: list = [[] for _ in range(n_shards)]
    for i, r in enumerate(requests):
        parts[i % n_shards].append(r)
    return parts


@dataclasses.dataclass
class MeshServeReport:
    """Merged outcome of one data-parallel mesh serve (DESIGN.md §13).

    Per-shard ServeReports stay intact in ``by_shard`` — the per-chip DED
    counters and kv-rail trajectories are the whole point of the mesh
    telemetry — while the merged views answer the single-stream questions
    (which tokens came back, what did the cache see in aggregate).
    """

    by_shard: list  # ServeReport per reliability shard
    outputs: dict  # rid -> generated tokens, merged across shards
    request_stats: dict  # rid -> FaultStats, merged across shards
    kv_stats: FaultStats  # cross-shard aggregate cache telemetry
    shard_of: dict  # rid -> shard that served it
    steps: int  # total decode dispatch steps across shards
    preemptions: int

    @property
    def kv_stats_by_shard(self) -> list:
        """Per-chip cache telemetry, shard-tagged (never collapsed)."""
        return [
            dataclasses.replace(r.kv_stats, shard=s)
            for s, r in enumerate(self.by_shard)
        ]

    @property
    def kv_voltages_by_shard(self) -> list:
        return [list(r.kv_voltages) for r in self.by_shard]

    @classmethod
    def merge(cls, reports) -> "MeshServeReport":
        reports = list(reports)
        outputs, request_stats, shard_of = {}, {}, {}
        for s, r in enumerate(reports):
            for rid, toks in r.outputs.items():
                assert rid not in outputs, f"request {rid} served twice"
                outputs[rid] = toks
                shard_of[rid] = s
            request_stats.update(r.request_stats)
        return cls(
            by_shard=reports,
            outputs=outputs,
            request_stats=request_stats,
            kv_stats=FaultStats.summed(r.kv_stats for r in reports),
            shard_of=shard_of,
            steps=sum(r.steps for r in reports),
            preemptions=sum(r.preemptions for r in reports),
        )


class ContinuousBatchingScheduler:
    """Host-side lane + page bookkeeping (admit / grow / preempt / retire)."""

    def __init__(
        self,
        requests,
        n_lanes: int,
        alloc: PageAllocator,
        geom: KVGeometry,
        arena: KVPageArena | None = None,
        trie: PrefixTrie | None = None,
        recorder=None,
    ):
        self.waiting = deque(RequestState(r) for r in requests)
        self.lanes: list = [None] * n_lanes
        self.alloc = alloc
        self.geom = geom
        self.arena = arena  # needed to wipe recycled pages before reuse
        self.trie = trie  # prefix-sharing radix tree (None = private pages)
        self.recorder = recorder  # optional obs.TraceRecorder
        self.shard = arena.shard if arena is not None else -1
        self.finished: dict = {}
        self.preemptions = 0
        self._admit_counter = 0
        self.fresh_pages: list = []  # allocated since last wipe drain

    def _alloc(self, owner):
        """Page for ``owner``; recycles the dirty list when the clean free
        list runs dry, then evicts sole-referenced trie leaves (LRU) before
        giving up — cached prefixes yield to live requests, preemption is
        the last resort. Every allocation is recorded in ``fresh_pages`` —
        the serve loop zero-wipes the batch before anything commits to it
        (once the arena has faulted, even 'clean'-list pages hold stale
        words: tick() injects into the whole arena, allocated or not)."""
        page = self.alloc.alloc(owner)
        if page is None and self.trie is not None and not self.alloc.dirty_pages:
            self.trie.evict_lru(1)
        if page is None and self.alloc.dirty_pages:
            self.alloc.recycle()
            page = self.alloc.alloc(owner)
        if page is not None:
            self.fresh_pages.append(page)
        return page

    def drain_fresh_pages(self) -> None:
        """Wipe pages allocated since the last drain (no-op pre-fault: an
        arena that never ticked below the guardband is zero/valid-data only,
        and scrub of a previous owner's *valid* words is clean by identity)."""
        if self.fresh_pages and self.arena is not None and self.arena.faulted:
            self.arena.zero_pages(np.asarray(self.fresh_pages, np.int32))
        self.fresh_pages.clear()

    @property
    def running(self) -> list:
        return [st for st in self.lanes if st is not None]

    @property
    def unfinished(self) -> bool:
        return bool(self.waiting) or any(self.lanes)

    def _free_lane(self):
        for i, st in enumerate(self.lanes):
            if st is None:
                return i
        return None

    def admit(self):
        """Admit waiting requests FCFS while lanes + pages allow; yields the
        admitted (lane, state, resume_seq) triples (pages pre-allocated to
        cover the prefilled sequence plus the first decode token).

        With a prefix trie, the longest cached full-page prefix of the
        sequence is *shared* (refcounted) instead of allocated: the state's
        ``shared_tokens`` records how deep, ``pages`` starts with the shared
        pages, and only the private suffix needs fresh allocations (trie
        leaves are LRU-evicted under pressure before admission stalls).
        """
        while self.waiting:
            lane = self._free_lane()
            if lane is None:
                break
            st = self.waiting[0]
            seq = st.resume_seq
            shared: list = []
            if self.trie is not None:
                shared = self.trie.lookup(seq)
                for p in shared:
                    self.alloc.share(p, st.rid)
            need = self.geom.pages_for(len(seq) + 1) - len(shared)
            if need > self.alloc.free_pages and self.trie is not None:
                # cached-but-unreferenced prefixes yield to the admission
                # (the just-shared pages are pinned by st.rid's reference)
                self.trie.evict_lru(need - self.alloc.free_pages)
            if need > self.alloc.free_pages:
                if shared:
                    self.alloc.free(shared, st.rid)  # undo; retry next round
                break
            self.waiting.popleft()
            st.pages = shared + [self._alloc(st.rid) for _ in range(need)]
            st.shared_tokens = len(shared) * self.geom.page_tokens
            st.status, st.lane = "running", lane
            st.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.lanes[lane] = st
            rec = self.recorder
            if rec:
                if st.admit_step < 0:
                    st.admit_step = rec.step
                rec.emit(
                    "admit", request_id=st.rid, shard=self.shard, lane=lane,
                    prompt_len=len(seq), shared_tokens=st.shared_tokens,
                )
                rec.metrics.counter("serve.admissions").inc()
                if shared:
                    rec.emit(
                        "prefix_hit", request_id=st.rid, shard=self.shard,
                        tokens=st.shared_tokens, pages=len(shared),
                    )
            yield lane, st, seq

    def ensure_pages(self, st: RequestState, until: int | None = None) -> bool:
        """Guarantee pages exist for positions up to ``until`` (default: the
        position the next decode step writes); preempts younger requests
        under pressure. False if ``st`` itself had to be preempted (i.e. it
        is the youngest and the arena is full)."""
        until = st.stored if until is None else until
        added = 0
        while until // self.geom.page_tokens >= len(st.pages):
            page = self._alloc(st.rid)
            if page is not None:
                st.pages.append(page)
                added += 1
                continue
            victim = max(self.running, key=lambda s: s.admit_seq)
            self.preempt(victim)
            if victim is st:
                return False
        if added and self.recorder:
            self.recorder.emit(
                "page_grow", request_id=st.rid, shard=self.shard,
                pages_added=added, pages_total=len(st.pages),
            )
        return True

    def preempt(self, st: RequestState) -> None:
        """Recompute-style preemption: drop pages, re-queue at the front."""
        if self.recorder:
            self.recorder.emit(
                "preempt", request_id=st.rid, shard=self.shard, lane=st.lane,
                pages_freed=len(st.pages), preemptions=st.preemptions + 1,
            )
        self.alloc.free(st.pages, st.rid)
        self.lanes[st.lane] = None
        st.pages, st.lane, st.admit_seq = [], -1, -1
        st.shared_tokens = 0
        st.status = "waiting"
        st.preemptions += 1
        self.preemptions += 1
        self.waiting.appendleft(st)

    def retire(self, st: RequestState) -> None:
        rec = self.recorder
        if rec:
            st.finish_step = rec.step
            lat = rec.step - st.admit_step if st.admit_step >= 0 else 0
            rec.emit(
                "retire", request_id=st.rid, shard=self.shard,
                tokens=len(st.tokens), latency_steps=lat,
                first_token_step=st.first_token_step,
                preemptions=st.preemptions,
            )
            rec.metrics.histogram("request.latency_steps").observe(lat)
            if st.first_token_step >= 0 and st.admit_step >= 0:
                rec.metrics.histogram("request.first_token_steps").observe(
                    st.first_token_step - st.admit_step
                )
        self.alloc.free(st.pages, st.rid)
        self.lanes[st.lane] = None
        st.pages, st.lane = [], -1
        st.shared_tokens = 0
        st.status = "finished"
        self.finished[st.rid] = st


def serve_stream(
    params,
    cfg,
    helpers,
    arena: KVPageArena,
    requests,
    *,
    n_lanes: int,
    max_len: int,
    scrub_interval: int = 1,
    max_block: int = 16,
    kv_controller=None,
    init_cache_fn=None,
    helpers_factory=None,
    share_prefix: bool = False,
    speculative: int = 0,
    draft_params=None,
    draft_cfg=None,
    recorder=None,
    scrub_overlap: bool | None = None,
) -> ServeReport:
    """Drive a request stream to completion over the paged cache.

    ``helpers`` comes from serving/steps.make_paged_helpers (any
    ``DecodeBlockHelpers``-shaped mapping works); ``kv_controller``
    is an optional UndervoltController fed the per-interval scrub telemetry —
    its output voltage is applied to the arena (the `kv` rail walk). When the
    controller escalates its ECC scheme (core/controller.py EscalationPolicy),
    the arena is re-encoded under the stronger code and ``helpers_factory``
    (codec name -> helpers, see serving/steps.HelpersFactory) supplies a
    commit path matching the new check-plane geometry. Without a factory
    there is no way to apply a stronger code to the live arena, so
    escalation is *suppressed* around each controller update (and the
    caller's policy restored afterwards) — the controller must never advance
    its codec state past the protection actually in force (it would
    mis-report and double-escalate).

    Decode runs in *blocks* of up to ``max_block`` steps lowered to one
    scanned dispatch (multi-step scheduling): the block size is the largest
    power of two that no active lane's remaining budget — and no pending
    scrub deadline — cuts short, so blocks never decode wasted tokens and
    the scrub cadence stays exact. ``max_block=1`` recovers the one-dispatch-
    per-token loop (what the preemption tests pin down).

    ``share_prefix`` turns on the prefix-sharing trie (DESIGN.md §16):
    identical full-page prompt prefixes map to the same physical pages
    (refcounted; divergence is copy-on-write by construction since only
    complete, immutable prompt pages are shared), admission scrubs the
    shared pages *once* and chunk-prefills only the private suffix, and the
    interval scrub deduplicates shared pages — physically each is scrubbed
    once (that is the power/throughput win) while the DED telemetry fed to
    the kv controller stays *reader-weighted*: a detected-uncorrectable on
    a page with N readers is N correlated request failures, so it counts N
    times against the physical word count and the escalation ladder trips
    earlier (scrub-aware sharing).

    ``speculative=K`` (with ``draft_params``/``draft_cfg``) drafts K-1
    tokens per dispatch with the draft model (dense, reliable-memory lane
    caches — the *target* cache is what lives in undervolted pages) and
    verifies all K positions with one chunked target forward; only accepted
    tokens' page commits land (rejected rows steer to the scratch page), so
    the emitted stream is exactly the greedy rollout.

    ``scrub_overlap`` (DESIGN.md §18) moves the interval scrub off the
    decode critical path: tick + scrub-on-read + cache refresh are
    dispatched as usual (device-side dependencies keep the refresh ordered
    before the next decode block), but the counter harvest — the
    ``np.asarray`` host sync plus all stats/controller/recorder work — is
    deferred until just before the *next* interval's tick (and stream end),
    so the decode blocks in between overlap the scrub instead of waiting
    for it. Bit-identity is structural: the controller's rail move from
    interval N's counters lands before interval N+1's injection exactly as
    in the serialized path, per-lane attribution is captured at dispatch
    time (preemption between intervals can't skew it), and the device
    work is the same launches in the same order — planes, counters, tokens
    and rail walks are byte-identical (tested). ``None`` (auto) overlaps
    except when codec escalation is live (``kv_controller.escalation`` with
    a ``helpers_factory``): escalation rebinds the commit path mid-stream,
    which must stay synchronous with the scrub that flushed the arena, so
    those streams auto-demote to the serialized path.
    """
    import jax.numpy as jnp

    from repro.models import lm
    from repro.serving import steps as steps_mod

    geom = arena.geom
    requests = normalize_requests(requests)
    for r in requests:
        total = len(r.prompt) + r.max_new_tokens
        assert total <= max_len, (r.rid, total, max_len)
        assert geom.pages_for(total) <= arena.n_pages, (
            f"request {r.rid} needs {geom.pages_for(total)} pages, "
            f"arena has {arena.n_pages}"
        )
        assert r.max_new_tokens >= 1 and len(r.prompt) >= 1

    init_cache_fn = init_cache_fn or (lambda b: lm.init_cache(cfg, b, max_len))
    alloc = PageAllocator(arena.n_pages)
    rec = recorder
    trie = (
        PrefixTrie(
            alloc, geom.page_tokens, recorder=rec, shard=arena.shard
        )
        if share_prefix
        else None
    )
    sched = ContinuousBatchingScheduler(
        requests, n_lanes, alloc, geom, arena=arena, trie=trie, recorder=rec
    )
    if rec:
        rec.emit(
            "serve_begin", shard=arena.shard, n_requests=len(requests),
            n_lanes=n_lanes, scrub_interval=scrub_interval,
            share_prefix=bool(share_prefix), speculative=int(speculative),
            voltage=float(arena.voltage), codec=arena.codec_name,
        )
    spec_k = int(speculative)
    if spec_k >= 2:
        assert draft_params is not None and draft_cfg is not None, (
            "speculative decode needs draft_params + draft_cfg"
        )
        assert helpers.get("spec_multistep") is not None, (
            "helpers were built without a draft config (spec_multistep)"
        )
        import jax

        draft_prefill = jax.jit(steps_mod.make_prefill_step(draft_cfg))
        dcache = lm.init_cache(draft_cfg, n_lanes, max_len)
    else:
        draft_prefill, dcache = None, None
    cache = init_cache_fn(n_lanes)
    cur_tok = np.zeros(n_lanes, np.int32)
    pos_v = np.zeros(n_lanes, np.int32)
    steps = 0
    since_scrub = 0
    kv_voltages: list = []
    prefix_hit_tokens = 0
    spec_dispatches = 0
    spec_emitted = 0

    overlap = scrub_overlap
    if overlap is None:
        # Auto-demotion (see docstring): live codec escalation must rebind
        # the commit path synchronously with the scrub that flushed it.
        overlap = not (
            kv_controller is not None
            and helpers_factory is not None
            and getattr(kv_controller, "escalation", None) is not None
        )
    pending_scrub = None  # deferred interval harvest (overlap mode)

    def _dispatch_scrub():
        """Interval scrub device work: tick, scrub-on-read, cache refresh —
        all async dispatch, no host sync. Returns the capture the deferred
        harvest needs: the device counters plus dispatch-time attribution
        (the (state, n_pages) pairs and dedup rows as of THIS interval —
        preemption or retirement before the harvest must not skew them)."""
        nonlocal cache
        arena.tick()
        # Table width tracks the *live* page maximum (power-of-two
        # bucketed so the jit shape set stays logarithmic), not worst-
        # case stream capacity: the scrub pass scales with pages that
        # actually hold tokens, and scratch filler rows are pure waste.
        live_max = max(len(st.pages) for st in sched.running)
        p_cols = 1 << max(live_max - 1, 0).bit_length()
        table = np.full((n_lanes, p_cols), arena.scratch_page, np.int32)
        n_tok = np.zeros(n_lanes, np.int32)
        lanes_cap: list = []
        for i, st in enumerate(sched.lanes):
            if st is None:
                lanes_cap.append(None)
                continue
            table[i, : len(st.pages)] = st.pages
            n_tok[i] = st.stored  # already counts the token committed above
            lanes_cap.append((st, len(st.pages)))
        if trie is None:
            payload, cnt = arena.scrub_pages_async(table.reshape(-1))
            cache = helpers["refresh"](
                cache,
                payload.reshape(n_lanes, -1, geom.token_f32),
                jnp.asarray(n_tok),
            )
            cap = {"mode": "private", "cnt": cnt, "p_cols": p_cols}
        else:
            # Prefix sharing: scrub each unique live page ONCE (that is
            # the physical work and the arena.stats truth), then fan the
            # corrected payload out to every reader's lane cache.
            upad, rows, n_u = dedup_page_table(table, arena.scratch_page)
            payload_u, cnt = arena.scrub_pages_async(upad)
            cache = helpers["refresh"](
                cache,
                payload_u[jnp.asarray(rows.reshape(-1))].reshape(
                    n_lanes, -1, geom.token_f32
                ),
                jnp.asarray(n_tok),
            )
            cap = {"mode": "shared", "cnt": cnt, "rows": rows, "n_u": n_u}
        cap["lanes"] = lanes_cap
        # Gauge values describe the interval being scrubbed, so snapshot
        # them now — at harvest time the scheduler has moved on.
        cap["gauges"] = (
            sched.alloc.free_pages, len(sched.waiting), len(sched.running)
        )
        cap["t_dispatch"] = time.perf_counter()
        return cap

    def _harvest_scrub(cap):
        """The deferred half of the interval scrub: the one host sync plus
        all stats / controller / recorder work, bit-identical to running
        inline (same counters, same reduction order, same rail move)."""
        nonlocal helpers
        t0 = time.perf_counter()
        cnt = np.asarray(cap["cnt"])
        t1 = time.perf_counter()
        if overlap and obs_profile.active():
            # Overlap efficiency: fraction of the dispatch->counters-ready
            # window the decode blocks covered; the residue (t1 - t0) is
            # what serving still waited on the scrub.
            span = max(t1 - cap["t_dispatch"], 1e-9)
            obs_profile.gauge(
                "serve.scrub_overlap_frac",
                (t0 - cap["t_dispatch"]) / span,
            )
        interval = FaultStats()  # reader-weighted attribution
        if cap["mode"] == "private":
            cnt = cnt.reshape(n_lanes, cap["p_cols"], 8)
            for i, lc in enumerate(cap["lanes"]):
                if lc is None:
                    continue
                st, n_p = lc
                rows_c = cnt[i, :n_p]
                rs = FaultStats.from_counters(
                    rows_c.sum(axis=0), words=n_p * geom.words_per_page
                )
                st.stats.accumulate(rs)
                interval.accumulate(rs)
            # without sharing every live page has one reader: the
            # reader-weighted view IS the physical view
            physical = interval
            arena.stats.accumulate(interval)
        else:
            rows, n_u = cap["rows"], cap["n_u"]
            for i, lc in enumerate(cap["lanes"]):
                if lc is None:
                    continue
                st, n_p = lc
                rs = FaultStats.from_counters(
                    cnt[rows[i, :n_p]].sum(axis=0),
                    words=n_p * geom.words_per_page,
                )
                st.stats.accumulate(rs)
                interval.accumulate(rs)
            physical = FaultStats.from_counters(
                cnt[:n_u].sum(axis=0),
                words=n_u * geom.words_per_page,
                shard=arena.shard,
            )
            arena.stats.accumulate(physical)
        if kv_controller is not None and not kv_controller.locked:
            # See docstring: without a factory a stronger code cannot be
            # applied to the live arena, so escalation is suppressed for
            # this update only (the caller's policy is left intact).
            saved_policy = kv_controller.escalation
            if helpers_factory is None:
                kv_controller.escalation = None
            try:
                # Scrub-aware sharing: reader-weighted counters over the
                # *physical* word population — a DED on an N-reader page
                # counts N times, so ded_rate amplifies with fan-out and
                # the escalation ladder trips earlier than it would for
                # private pages (core/controller.reader_weighted_stats).
                arena.set_voltage(
                    kv_controller.update(
                        reader_weighted_stats(interval, physical)
                    )
                )
            finally:
                kv_controller.escalation = saved_policy
            change = kv_controller.pop_codec_change()
            if change and rec:
                rec.emit(
                    "kv_codec_change", shard=arena.shard, domain="kv",
                    codec=change,
                )
            if change:
                # Escalate right after the scrub above flushed every
                # correctable fault: the arena re-encodes under the
                # stronger code and the commit path switches with it.
                # (A change can only arrive when a factory exists —
                # escalation was suppressed above otherwise. Escalation-
                # capable streams run serialized — see scrub_overlap — so
                # this runs at the same point the inline path would.)
                shared_now = None
                if trie is not None:
                    shared_now = sorted(
                        set(sched.alloc.shared_pages()) | set(trie.pages())
                    )
                try:
                    arena.change_codec(change, shared_pages=shared_now)
                except SharedPageDEDError as err:
                    # Refuse-and-copy: a latched DED on a shared page
                    # must not be re-sealed for N readers. Drop the
                    # trie's claim on the poisoned prefixes, preempt
                    # every running reader (recompute *is* the copy —
                    # fresh pages, re-prefilled KV), then re-protect.
                    trie.evict_pages(err.pages)
                    bad = set(err.pages)
                    preempted = 0
                    for st in list(sched.running):
                        if bad & set(st.pages):
                            sched.preempt(st)
                            preempted += 1
                    arena.change_codec(change)
                    if rec:
                        rec.emit(
                            "shared_ded_recovery", shard=arena.shard,
                            domain="kv", pages=len(err.pages),
                            preempted=preempted,
                        )
                helpers = helpers_factory(change)
        if rec:
            rec.emit(
                "kv_scrub", shard=arena.shard, domain="kv",
                interval=len(kv_voltages), voltage=float(arena.voltage),
                codec=arena.codec_name, corrected=physical.corrected,
                detected=physical.detected, silent=physical.silent,
                words=physical.words,
            )
            m = rec.metrics
            lbl = {"shard": arena.shard} if arena.shard >= 0 else {}
            m.observe_fault_stats("kv.scrub", physical, **lbl)
            free_pages, queue_depth, lanes_active = cap["gauges"]
            for gname, val in (
                ("kv.pages_free", free_pages),
                ("sched.queue_depth", queue_depth),
                ("sched.lanes_active", lanes_active),
            ):
                m.gauge(gname, **lbl).set(val)
                rec.emit(
                    "gauge", shard=arena.shard, name=gname, value=val
                )
        kv_voltages.append(arena.voltage)

    while sched.unfinished:
        # -- admission: batch same-shape prefills, commit the prompts' KV --
        groups: dict = {}
        for lane, st, seq in sched.admit():
            groups.setdefault((len(seq), st.shared_tokens), []).append(
                (lane, st, seq)
            )
        sched.drain_fresh_pages()  # wipe before the prompt commits below
        for (s0, sh), grp in groups.items():
            m = len(grp)
            cachem = init_cache_fn(m)
            seqs = np.stack([seq for _, _, seq in grp])
            if sh:
                # Prefix hit: refresh the shared pages' payload into the
                # batch cache (scrub-on-read — each *unique* page once, its
                # counters attributed to every reader), then chunk-prefill
                # only the private suffix at pos0 = sh.
                n_sp = sh // geom.page_tokens
                ptab = np.stack([st.pages[:n_sp] for _, st, _ in grp])
                upad, rows, n_u = dedup_page_table(ptab, arena.scratch_page)
                payload_u, cnt_u = arena.scrub_pages(upad)
                payload = jnp.asarray(payload_u)[
                    jnp.asarray(rows.reshape(-1))
                ].reshape(m, sh, geom.token_f32)
                cachem = helpers["refresh"](
                    cachem, payload, jnp.full((m,), sh, jnp.int32)
                )
                tokm, cachem = helpers["chunk"](
                    params,
                    jnp.asarray(seqs[:, sh:]),
                    cachem,
                    jnp.full((m,), sh, jnp.int32),
                )
                payload_sfx = helpers["extract_span"](cachem, start=sh, stop=s0)
                tok_idx = np.arange(sh, s0)
                # physical telemetry once; per-reader attribution below
                arena.stats.accumulate(
                    FaultStats.from_counters(
                        cnt_u[:n_u].sum(axis=0),
                        words=n_u * geom.words_per_page,
                        shard=arena.shard,
                    )
                )
                for r, (_, st, _) in zip(rows, grp):
                    st.stats.accumulate(
                        FaultStats.from_counters(
                            cnt_u[r].sum(axis=0),
                            words=n_sp * geom.words_per_page,
                        )
                    )
                prefix_hit_tokens += sh * m
            else:
                tokm, cachem = helpers["prefill"](params, jnp.asarray(seqs), cachem)
                payload_sfx = helpers["extract_range"](cachem, s0=s0)
                tok_idx = np.arange(s0)
            page_ids = np.stack(
                [
                    [st.pages[t // geom.page_tokens] for t in tok_idx]
                    for _, st, _ in grp
                ]
            )
            arena.commit_tokens(
                payload_sfx.reshape(m * len(tok_idx), -1),
                page_ids.reshape(-1),
                np.tile(tok_idx % geom.page_tokens, m),
            )
            if trie is not None:
                # register the prompts' complete pages (partial tail pages
                # stay private — that is what makes divergence CoW-free)
                for _, st, seq in grp:
                    trie.insert(seq, st.pages[: len(seq) // geom.page_tokens])
            if draft_prefill is not None:
                dcachem = lm.init_cache(draft_cfg, m, max_len)
                _, dcachem = draft_prefill(draft_params, jnp.asarray(seqs), dcachem)
            tok_host = np.asarray(tokm).reshape(-1)
            for row, (lane, st, _) in enumerate(grp):
                cache = helpers["load_lane"](cache, cachem, row, lane)
                if draft_prefill is not None:
                    dcache = helpers["load_lane"](dcache, dcachem, row, lane)
                if not st.tokens:  # fresh admission: keep the prefill's token
                    st.tokens = [int(tok_host[row])]
                    if rec and st.first_token_step < 0:
                        st.first_token_step = rec.step
                if st.done:  # budget met by the prefill token alone
                    sched.retire(st)
                    continue
                cur_tok[lane] = st.tokens[-1]
                pos_v[lane] = s0

        # -- block size: no lane's budget, and no scrub deadline, overrun ---
        running = sched.running
        if not running:
            if not sched.unfinished:
                break
            assert sched.waiting, "deadlock: no lanes active and queue empty"
            continue  # freed pages let admission proceed next iteration
        k = min(st.req.max_new_tokens - len(st.tokens) for st in running)
        k = max(1, min(k, max_block))
        if scrub_interval:
            k = max(1, min(k, scrub_interval - since_scrub))
        k = 1 << (k.bit_length() - 1)  # power-of-two bucket: few scan shapes

        # -- page growth for the whole block; preempt on pressure -----------
        for st in list(running):
            if st.status == "running":  # an earlier growth may have evicted it
                sched.ensure_pages(st, until=st.stored + k - 1)
        active = [i for i, st in enumerate(sched.lanes) if st is not None]
        if not active:
            continue
        sched.drain_fresh_pages()  # wipe growth pages before the block commits

        # -- k decode steps + per-token page commits in one dispatch --------
        page_ids = np.full((k, n_lanes), arena.scratch_page, np.int32)
        slots = np.zeros((k, n_lanes), np.int32)
        for i in active:
            st = sched.lanes[i]
            for j in range(k):
                t = pos_v[i] + j
                page_ids[j, i] = st.pages[t // geom.page_tokens]
                slots[j, i] = t % geom.page_tokens
        if spec_k >= 2 and k >= 2:
            # Draft k-1 tokens, verify all k in one chunked target forward;
            # page commits land only for the accepted prefix (rejected rows
            # steer to the scratch page inside the dispatch).
            kk = min(k, spec_k)
            greedy, n_emit, cache, dcache, arena.lo, arena.hi, arena.parity = (
                helpers["spec_multistep"](
                    params,
                    draft_params,
                    jnp.asarray(cur_tok[:, None]),
                    cache,
                    dcache,
                    arena.lo,
                    arena.hi,
                    arena.parity,
                    jnp.asarray(pos_v),
                    jnp.asarray(page_ids[:kk]),
                    jnp.asarray(slots[:kk]),
                    k=kk,
                    scratch_page=arena.scratch_page,
                )
            )
            greedy_host = np.asarray(greedy)
            n_host = np.asarray(n_emit)
            steps += 1
            spec_dispatches += 1
            adv = max((int(n_host[i]) for i in active), default=0)
            if rec:
                # clock first so same-dispatch retires see the post-block step
                rec.advance(max(adv, 1))
                rec.emit(
                    "spec_block", shard=arena.shard, k=kk,
                    lanes=len(active),
                    emitted=int(sum(n_host[i] for i in active)),
                    slots=kk * len(active),
                )
                rec.metrics.counter("spec.slots").inc(kk * len(active))
                rec.metrics.counter("spec.emitted").inc(
                    int(sum(n_host[i] for i in active))
                )
            for i in active:
                st = sched.lanes[i]
                n = int(n_host[i])
                st.tokens.extend(int(t) for t in greedy_host[i, :n])
                spec_emitted += n
                cur_tok[i] = st.tokens[-1]
                pos_v[i] += n
                if st.done:
                    sched.retire(st)
            since_scrub += adv
        else:
            toks, cache, arena.lo, arena.hi, arena.parity = helpers["multistep"](
                params,
                jnp.asarray(cur_tok[:, None]),
                cache,
                arena.lo,
                arena.hi,
                arena.parity,
                jnp.asarray(pos_v),
                jnp.asarray(page_ids),
                jnp.asarray(slots),
            )
            toks_host = np.asarray(toks)
            steps += k
            since_scrub += k
            if rec:
                rec.advance(k)  # the deterministic clock IS decode progress
            for i in active:
                st = sched.lanes[i]
                st.tokens.extend(int(t) for t in toks_host[:, i])
                cur_tok[i] = st.tokens[-1]
                pos_v[i] += k
                if st.done:
                    sched.retire(st)

        # -- scrub interval: inject at the kv rail, scrub-on-read, refresh --
        if scrub_interval and since_scrub >= scrub_interval:
            since_scrub = 0
        else:
            continue
        # Off-critical-path scrub (§18): interval N's counters are
        # harvested immediately before interval N+1's tick, so the
        # controller's rail move still lands before the next injection —
        # exactly where the serialized path puts it — while the decode
        # blocks in between overlapped interval N's scrub device work.
        if pending_scrub is not None:
            _harvest_scrub(pending_scrub)
            pending_scrub = None
        if sched.running:
            cap = _dispatch_scrub()
            if overlap:
                pending_scrub = cap
            else:
                _harvest_scrub(cap)

    if pending_scrub is not None:
        # Stream drained with a scrub in flight: harvest before teardown so
        # the report's stats/voltages match the serialized path exactly.
        _harvest_scrub(pending_scrub)
        pending_scrub = None

    if trie is not None:
        # Serve teardown: the prefix cache has no meaning past this stream,
        # so release every trie reference before the free-page accounting
        # (pages_free_at_end must see the arena fully reclaimed).
        trie.drain()
        sched.alloc.recycle()
    outputs = {
        rid: np.asarray(st.tokens, np.int32) for rid, st in sched.finished.items()
    }
    if rec:
        rec.emit(
            "serve_end", shard=arena.shard, steps=steps,
            preemptions=sched.preemptions, finished=len(outputs),
        )
        lbl = {"shard": arena.shard} if arena.shard >= 0 else {}
        rec.metrics.counter("serve.steps", **lbl).inc(steps)
        rec.metrics.counter("serve.preemptions", **lbl).inc(sched.preemptions)
        rec.metrics.counter("serve.prefix_hit_tokens", **lbl).inc(
            prefix_hit_tokens
        )
    return ServeReport(
        outputs=outputs,
        request_stats={rid: st.stats for rid, st in sched.finished.items()},
        kv_stats=arena.stats,
        steps=steps,
        preemptions=sched.preemptions,
        kv_voltages=kv_voltages,
        arena=arena,
        pages_free_at_end=sched.alloc.free_pages,
        prefix_hit_tokens=prefix_hit_tokens,
        spec_dispatches=spec_dispatches,
        spec_emitted=spec_emitted,
    )
