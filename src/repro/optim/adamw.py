"""AdamW with configurable state dtype (bf16 moments for 100B+ models).

Pure-JAX (no optax dependency in this offline container). Moments inherit the
parameter sharding automatically under pjit because they are elementwise maps
of the parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = None  # None -> float32 moments; jnp.bfloat16 for 100B+
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * frac)
    return cfg.lr * warm * cos


def init(params, cfg: AdamWConfig):
    dt = cfg.state_dtype

    def zeros(p):
        return jnp.zeros(p.shape, dt or jnp.float32)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        step_p = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step_p
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
