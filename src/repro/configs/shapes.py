"""Assigned input-shape sets and ShapeDtypeStruct builders for the dry-run.

Every LM-family arch is paired with four shapes:
  train_4k    seq 4096,   global_batch 256  -> train_step
  prefill_32k seq 32768,  global_batch 32   -> prefill_step
  decode_32k  seq 32768 (KV), global_batch 128 -> serve_step (1 new token)
  long_500k   seq 524288 (KV), global_batch 1  -> serve_step; sub-quadratic
              archs only (rwkv6 SSM, mixtral SWA, jamba hybrid) — skips are
              recorded in DESIGN.md §Arch-applicability.

`input_specs` returns ShapeDtypeStructs only: the dry-run never allocates.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Sub-quadratic bar for long_500k: SSM / SWA / hybrid only.
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "mixtral-8x22b", "jamba-1.5-large-398b"}


# ---------------------------------------------------------------------------
# Memory domains (multi-rail undervolting, DESIGN.md §10)
# ---------------------------------------------------------------------------
# The BRAM arena is partitioned into named voltage domains; each domain gets
# its own rail, fault-field slice, and ECC counter row. Order is the counter
# row order everywhere (kernel, telemetry, controller). `MEMORY_DOMAINS` is
# the registry; `domain_of` classifies a flattened-pytree leaf key into one.
# Substrings are matched in order, so e.g. "['blocks']['p0']['attn']['wq']"
# lands in "attention" before the "mlp" patterns are consulted.
MEMORY_DOMAINS: tuple[str, ...] = ("embedding", "attention", "mlp", "kv")

_DOMAIN_PATTERNS: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("kv", ("kv", "cache")),
    ("embedding", ("embed", "unembed", "vocab")),
    ("attention", ("attn", "attention", "w_r", "w_k", "w_v", "w_g", "w_o")),
    ("mlp", ("mlp", "ffn", "moe", "expert", "in_proj", "out_proj")),
)


def domain_of(key: str, default: str = "mlp") -> str:
    """Map a pytree leaf key (jax.tree_util.keystr) to its memory domain."""
    low = key.lower()
    for name, pats in _DOMAIN_PATTERNS:
        if any(p in low for p in pats):
            return name
    return default


# Default ECC scheme per memory domain (DESIGN.md §12). The built-in BRAM
# SECDED everywhere, matching the paper; engines override per domain via
# ReliabilityConfig.codecs, and the controller escalation ladder may move a
# domain up at runtime.
from repro.codes import DEFAULT_CODEC  # noqa: E402 (single source of truth)


def domain_codecs(overrides=None) -> dict[str, str]:
    """Resolve a codec choice into a full {domain: codec name} mapping.

    ``overrides`` may be None (all defaults), a codec name (every domain),
    or a {domain: name} mapping (unnamed domains keep the default). Codec
    names are validated against the registry, domain names against
    MEMORY_DOMAINS — a typo'd domain silently keeping its default codec is
    exactly the misconfiguration this helper exists to prevent.
    """
    from repro import codes

    out = {d: DEFAULT_CODEC for d in MEMORY_DOMAINS}
    if overrides is None:
        pass
    elif isinstance(overrides, str):
        out = {d: overrides for d in out}
    else:
        for d, name in dict(overrides).items():
            assert d in out, f"unknown memory domain {d!r}; known: {sorted(out)}"
            out[d] = str(name)
    for name in out.values():
        codes.get(name)  # fail fast on unknown codecs
    return out


def rail_policy(name: str) -> str:
    """Validate a mesh rail policy name (DESIGN.md §13).

    ``uniform``: one voltage per domain across every chip, locked at the
    worst shard's first DED. ``per_shard``: every chip walks its own V_min.
    Validated here, next to the memory-domain registry, for the same reason
    as ``domain_codecs``: a typo'd policy silently falling back to a default
    is the misconfiguration to prevent.
    """
    from repro.core.controller import RAIL_POLICIES

    name = str(name)
    assert name in RAIL_POLICIES, (
        f"unknown rail policy {name!r}; known: {RAIL_POLICIES}"
    )
    return name


def supports_paged_kv(cfg: ModelConfig) -> bool:
    """Whether the paged SECDED KV cache (core/kvpages.py) covers this arch.

    Paging fixed-size token pages assumes every mixer is full-context
    attention with a position-indexed cache: SSM/RWKV state is not paged
    (it is O(1) per lane, not per token), SWA ring buffers and quantized
    caches keep their own layouts, and codebook decoders interleave tokens.
    """
    all_attn = all(
        cfg.layer_kind(j)["mixer"] == "attn" for j in range(cfg.period)
    )
    return (
        all_attn
        and not cfg.sliding_window
        and not cfg.kv_quant
        and not cfg.n_codebooks
    )


def supported_shapes(arch: str) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        names.append("long_500k")
    return names


def _tok_struct(cfg: ModelConfig, b: int, s: int):
    if cfg.n_codebooks:
        return jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str, *, batch_override: int = 0):
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    sh = SHAPES[shape_name]
    b = batch_override or sh.global_batch
    s = sh.seq_len

    if sh.kind == "train":
        specs = {
            "tokens": _tok_struct(cfg, b, s),
            "labels": _tok_struct(cfg, b, s),
        }
        if cfg.family == "vlm":
            specs["img"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype
            )
        return specs

    if sh.kind == "prefill":
        specs = {
            "tokens": _tok_struct(cfg, b, s),
            "cache": cache_struct(cfg, b, s),
        }
        if cfg.family == "vlm":
            specs["img"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype
            )
        return specs

    # decode: one new token against a seq_len-deep cache/state
    specs = {
        "tokens": _tok_struct(cfg, b, 1),
        "cache": cache_struct(cfg, b, s),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["img"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype
        )
    return specs


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree of the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: lm.init_cache(
            cfg, batch, max_len, img_tokens=cfg.n_img_tokens
        )
    )
