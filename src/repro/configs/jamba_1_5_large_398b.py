"""Architecture config: jamba-1.5-large-398b [hybrid] — mamba:attn 1:7 interleave, MoE 16e top-2

[arXiv:2403.19887; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, attn_every=8, d_state=16, ssm_expand=2,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_experts=4, d_state=8,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
