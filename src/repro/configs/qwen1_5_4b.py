"""Architecture config: qwen1.5-4b [dense] — QKV bias

[hf:Qwen/Qwen1.5 family; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab=151936, qkv_bias=True, rope_theta=5e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
