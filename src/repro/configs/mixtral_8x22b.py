"""Architecture config: mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention

[arXiv:2401.04088; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768, rope_theta=1e6, sliding_window=4096,
    n_experts=8, top_k=2,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_experts=4, sliding_window=32,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
