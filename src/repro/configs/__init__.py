"""Architecture registry: ``--arch <id>`` resolves through `get_config`."""

from __future__ import annotations

import importlib

from repro.configs import shapes
from repro.configs.shapes import SHAPES, input_specs, supported_shapes

ARCHS = {
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "minitron-8b": "minitron_8b",
    "qwen2-7b": "qwen2_7b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "rwkv6-3b": "rwkv6_3b",
    "musicgen-medium": "musicgen_medium",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mixtral-8x22b": "mixtral_8x22b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    # the paper's own accelerator workload (MLP on MNIST-class tasks)
    "paper-nn": "paper_nn",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()


__all__ = [
    "ARCHS", "SHAPES", "get_config", "get_smoke_config", "input_specs",
    "supported_shapes", "shapes",
]
