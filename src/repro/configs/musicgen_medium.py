"""Architecture config: musicgen-medium [audio] — decoder-only over EnCodec tokens (frontend stub)

[arXiv:2306.05284; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, n_codebooks=4,
    norm_type="layernorm", gated_mlp=False, mlp_act="gelu",
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=64, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
