"""Architecture config: qwen2-7b [dense] — GQA kv4, QKV bias

[arXiv:2407.10671; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, qkv_bias=True, rope_theta=1e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
