"""Architecture config: llama-3.2-vision-11b [vlm] — cross-attn image layers (frontend stub)

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, rope_theta=5e5,
    cross_attn_every=5, n_img_tokens=6404,  # 4 tiles x 1601 patch embeddings
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_img_tokens=8,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
