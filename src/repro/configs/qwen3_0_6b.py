"""Architecture config: qwen3-0.6b [dense] — qk_norm, GQA

[hf:Qwen/Qwen3-8B family; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
