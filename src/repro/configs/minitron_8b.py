"""Architecture config: minitron-8b [dense] — pruned nemotron, relu^2 MLP

[arXiv:2407.14679; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=256000, gated_mlp=False, mlp_act="relu2",
    rope_theta=1e4,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
