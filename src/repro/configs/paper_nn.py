"""The paper's own workload: the FPGA NN-accelerator case study (§IV).

An MLP classifier (MNIST-class tasks, per [16]'s methodology) whose weights
live in the ECC-protected BRAM voltage domain as int8 fixed-point — the
configuration undervolted in paper Fig. 3.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperNNConfig:
    name: str = "paper-nn"
    family: str = "mlp"
    layer_sizes: tuple = (784, 256, 128, 10)  # 28x28 MNIST -> 10 classes
    dataset: str = "mnist"
    platform: str = "vc707"
    train_steps: int = 600
    batch_size: int = 128
    lr: float = 3e-3


def config() -> PaperNNConfig:
    return PaperNNConfig()


def smoke_config() -> PaperNNConfig:
    return dataclasses.replace(config(), layer_sizes=(64, 32, 10), train_steps=40)
