"""Architecture config: rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free

[arXiv:2404.05892; hf]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, rwkv_head_dim=64,
    d_ff=8960, vocab=65536, norm_type="layernorm",
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, rwkv_head_dim=16,
    d_ff=224, vocab=256, param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
