"""Architecture config: llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    """Exact published configuration (dry-run / full-scale)."""
    return ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    n_experts=16, top_k=1, shared_expert=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
    config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_experts=4,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
)
