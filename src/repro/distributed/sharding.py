"""Logical-axis -> mesh sharding rules (DP / FSDP / TP / EP / SP).

Parameters carry logical axes from the model spec tree; this module maps them
onto the production mesh with automatic legality fallbacks:

  * "vocab" / "heads" / "ffn" / "experts" -> "model"  (TP / EP)
  * "embed"        -> batch super-axis ("pod","data") when FSDP is enabled
  * "layers"/None  -> replicated

One mesh axis is never used twice in a spec; non-divisible dims fall back to
replication (e.g. mixtral's 8 experts on a 16-way model axis fall back to
TP-on-ffn, which is the right call anyway). Decode KV caches are sharded over
the *sequence* axis on "model" (flash-decoding: softmax reductions over the
sharded axis lower to tiny all-reduces), and over every axis for the B=1
long-context cells.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models.base import ModelConfig, Spec

TP_AXES = ("vocab", "heads", "ffn", "experts")


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def reliability_axes(mesh: Mesh) -> tuple:
    """Mesh axes the reliability layer shards over (DESIGN.md §13).

    One reliability shard = one chip with its own voltage rails and fault
    population. The repo's mesh convention places TP inside a replica whose
    memories share a board/rail, so the shard unit is the data-parallel
    replica: the batch super-axis ("pod", "data"). A mesh without batch
    axes (kernel micro-harnesses) treats every axis as a shard axis — each
    device is then its own chip.
    """
    ba = batch_axes(mesh)
    return ba if ba else tuple(mesh.axis_names)


def reliability_shards(mesh: Mesh) -> int:
    """Chip count of the reliability layer on ``mesh`` (rail-set count)."""
    return _axes_size(mesh, reliability_axes(mesh))


def _axes_size(mesh: Mesh, axes: tuple) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def spec_for(logical: tuple, shape: tuple, mesh: Mesh, fsdp: bool) -> P:
    used: set = set()
    parts = []
    for ax, dim in zip(logical, shape):
        target: tuple = ()
        granularity = 0  # extra unit-count constraint (head-granular TP)
        if ax is not None and ax.startswith("heads:"):
            target = ("model",)
            granularity = int(ax.split(":")[1])
        elif ax in TP_AXES:
            target = ("model",)
        elif ax == "embed" and fsdp:
            target = batch_axes(mesh)
        size = _axes_size(mesh, target) if target else 1
        ok = (
            target
            and not (set(target) & used)
            and dim % size == 0
            and (granularity == 0 or granularity % size == 0)
        )
        if ok:
            used.update(target)
            parts.append(target[0] if len(target) == 1 else tuple(target))
        else:
            parts.append(None)
    return P(*parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh, fsdp: bool):
    """NamedSharding tree matching lm.param_struct(cfg)."""
    specs = lm.init_specs(cfg)

    def one(s: Spec):
        return NamedSharding(mesh, spec_for(s.axes, s.shape, mesh, fsdp))

    return jax.tree_util.tree_map(one, specs, is_leaf=lambda x: isinstance(x, Spec))


def spec_fsdp_only(logical: tuple, shape: tuple, mesh: Mesh) -> P:
    """Pure ZeRO-3: no tensor parallelism — shard the largest weight dim over
    ALL mesh axes combined (weights gathered per layer, zero activation
    all-reduces). The §Perf alternative for small-activation-heavy models."""
    all_axes = tuple(mesh.axis_names)
    size = _axes_size(mesh, all_axes)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    parts: list = [None] * len(shape)
    for i in order:
        if logical[i] != "layers" and shape[i] % size == 0:
            parts[i] = all_axes if len(all_axes) > 1 else all_axes[0]
            break
    return P(*parts)


def param_shardings_fsdp_only(cfg: ModelConfig, mesh: Mesh):
    specs = lm.init_specs(cfg)

    def one(s: Spec):
        return NamedSharding(mesh, spec_fsdp_only(s.axes, s.shape, mesh))

    return jax.tree_util.tree_map(one, specs, is_leaf=lambda x: isinstance(x, Spec))


def data_sharding_all_axes(mesh: Mesh, global_batch: int):
    """Batch sharded over every mesh axis (pure-DP/FSDP regime)."""
    axes = tuple(mesh.axis_names)
    if global_batch % _axes_size(mesh, axes) == 0:
        return NamedSharding(mesh, P(axes))
    return data_sharding(mesh, global_batch)


def data_sharding(mesh: Mesh, global_batch: int):
    """Sharding for (B, ...) batch arrays; replicate if B doesn't divide."""
    ba = batch_axes(mesh)
    if ba and global_batch % _axes_size(mesh, ba) == 0:
        return NamedSharding(mesh, P(ba if len(ba) > 1 else ba[0]))
    return NamedSharding(mesh, P())


def batch_shardings(mesh: Mesh, batch_struct):
    """Apply data_sharding to every leaf of a {tokens, labels, img} batch."""

    def one(leaf):
        return data_sharding(mesh, leaf.shape[0])

    return jax.tree_util.tree_map(one, batch_struct)


def _seq_axes(mesh: Mesh, b: int, s: int):
    """Axes for the KV sequence dim: 'model' plus (if batch is unshardable)
    the batch axes too — used by B=1 long-context decode."""
    ba = batch_axes(mesh)
    batch_ok = ba and b % _axes_size(mesh, ba) == 0
    axes = ("model",) if batch_ok else tuple(ba) + ("model",)
    if s % _axes_size(mesh, axes) == 0:
        return axes, batch_ok
    return (), batch_ok


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_struct):
    """Sharding tree for the decode cache (see module docstring)."""
    ba = batch_axes(mesh)
    b_axis = ba if len(ba) > 1 else (ba[0] if ba else None)

    def one(path, leaf):
        key = jax.tree_util.keystr(path)
        b = leaf.shape[1]
        batch_ok = ba and b % _axes_size(mesh, ba) == 0
        bspec = b_axis if batch_ok else None
        if "kv_scale" in key:
            s = leaf.shape[2]
            seq_axes, _ = _seq_axes(mesh, b, s)
            sspec = (
                None if not seq_axes
                else (seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes))
            )
            return NamedSharding(mesh, P(None, bspec, sspec, None, None))
        if "'k'" in key or "'v'" in key:
            s = leaf.shape[2]
            seq_axes, _ = _seq_axes(mesh, b, s)
            sspec = (
                None
                if not seq_axes
                else (seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes))
            )
            return NamedSharding(mesh, P(None, bspec, sspec, None, None))
        if "conv" in key:
            return NamedSharding(
                mesh,
                P(None, bspec, None, "model" if leaf.shape[3] % mesh.shape["model"] == 0 else None),
            )
        if "ssm" in key:
            return NamedSharding(
                mesh,
                P(None, bspec, "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None, None),
            )
        if "shift" in key:
            return NamedSharding(
                mesh,
                P(None, bspec, "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None),
            )
        if "wkv" in key:
            h = leaf.shape[2]
            return NamedSharding(
                mesh,
                P(None, bspec, "model" if h % mesh.shape["model"] == 0 else None, None, None),
            )
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
