"""Mesh-sharded reliability layer (DESIGN.md §13).

The reliability stack from DESIGN.md §9–§12 — the PlaneStore arena, the paged
KV cache, the fused inject+scrub and scrub-on-read kernels, the multi-rail
controller — was single-device: one chip, one fault population, one rail set.
At production scale every replica/shard is its own chip with its own silicon
(MoRS models per-SRAM fault-map variation; the MLP undervolting follow-up
measures per-board V_min spread), so this module makes the layer mesh-native:

  * the flat word arenas are partitioned across the mesh's *reliability
    shard axes* (the batch super-axis — each data-parallel replica is one
    chip whose rails move together; TP inside a replica shares the board);
  * the fused inject+scrub and paged scrub-on-read kernels run under
    ``shard_map``: every shard generates its own ``DeviceFaultField`` masks
    with ``collectives.shard_key`` (``jax.lax.axis_index`` folded into the
    PRNG key), so shards draw independent fault populations — shard 0 keeps
    the unsharded key, the bit-identity anchor for the 1-device mesh;
  * per-shard (n_shards, n_domains, 8) counter blocks come back with NO
    collective inside the step: the per-interval scrub is collective-free,
    and the single cross-shard counter reduction (``fold_counters``, or
    ``make_rail_step(..., with_psum=True)`` for the historical in-step
    ``collectives.psum_counters``) is hoisted out so a soak of N intervals
    pays one reduction instead of N. Both rail policies stay fed: `uniform`
    (one schedule, worst-shard canary via the folded view) and `per_shard`
    (each shard walks its own V_min).

Collective traffic per rail *soak*: one counter reduction of
n_domains x 128 int32 lanes — independent of arena size AND of the number
of intervals in the soak (this is what fixed the d8-below-d4 words/sec dip
in BENCH_mesh.json: at 8 forced host devices the per-interval psum dispatch
dominated the tiny per-shard scrub slices). The plane data itself never
crosses shards (each chip scrubs its own words); the CPU serving engine
additionally gathers the faulty planes to one device because its decode
path is single-device (a real TP mesh would consume them sharded in place).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import codes
from repro.core.faultsim import _check_dtype, _device_chunk_masks
from repro.distributed import collectives
from repro.distributed.sharding import reliability_axes, reliability_shards
from repro.kernels import ops as kops
from repro.obs import profile as obs_profile

__all__ = [
    "arena_sharding",
    "fold_counters",
    "make_kv_scrub_step",
    "make_rail_step",
    "pad_to_shards",
    "reliability_axes",
    "reliability_shards",
    "schedule_rates",
]


@jax.jit
def fold_counters(per_shard):
    """The hoisted once-per-soak counter reduction: sum an
    (n_shards, ...) per-shard counter block over the shard axis on device.
    Replaces the per-interval in-step psum — call it once after a soak (or
    whenever a worst-shard/fleet view is actually needed), not per step."""
    return jnp.sum(per_shard, axis=0)


def _axes_spec(axes) -> P:
    return P(axes[0] if len(axes) == 1 else tuple(axes))


def arena_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding partitioning a flat (n_words,) arena over the
    reliability shard axes (word count must be a multiple of the shard
    count — ``pad_to_shards`` arranges that)."""
    return NamedSharding(mesh, _axes_spec(reliability_axes(mesh)))


def pad_to_shards(n: int, n_shards: int) -> int:
    """Padded word count: the smallest multiple of ``n_shards`` >= n."""
    return -(-n // n_shards) * n_shards


def _chunked_shard_masks(key, local_n, rates_w, sigma, n_check, chunk_words, burst=None):
    """Per-shard flip masks over ``local_n`` flat words, chunked exactly like
    ``DeviceFaultField.masks_for_rates`` (fold_in per chunk index) so the
    1-shard mesh reproduces the unsharded device stream bit-for-bit —
    including under a ``burst`` profile, whose auxiliary draws fold off the
    same per-chunk key (DESIGN.md §14)."""
    los, his, pars = [], [], []
    for ci, start in enumerate(range(0, local_n, chunk_words)):
        m = min(chunk_words, local_n - start)
        lo, hi, par = _device_chunk_masks(
            jax.random.fold_in(key, ci), m, rates_w[start : start + m],
            sigma, n_check=n_check, burst=burst,
        )
        los.append(lo)
        his.append(hi)
        pars.append(par)
    if not los:
        z32 = jnp.zeros((0,), jnp.uint32)
        return z32, z32, jnp.zeros((0,), jnp.dtype(_check_dtype(n_check)))
    if len(los) == 1:
        return los[0], his[0], pars[0]
    return jnp.concatenate(los), jnp.concatenate(his), jnp.concatenate(pars)


@functools.lru_cache(maxsize=None)
def make_rail_step(
    mesh: Mesh,
    local_words: int,
    n_domains: int,
    codec: str,
    seed: int,
    row_sigma: float,
    reencode: bool = False,
    chunk_words: int = 1 << 18,
    burst=None,
    with_psum: bool = False,
):
    """Build the shard_map'd fused inject+scrub step for one codec group.

    Returns a jitted callable
        fn(lo, hi, check, dom, rates) ->
            (faulty_lo, faulty_hi, faulty_check,
             per_shard_counters (n_shards, n_domains, 8))
    where the planes are flat (n_shards * local_words,) arrays sharded over
    the mesh's reliability axes, ``dom`` the per-word domain index (spill
    index ``n_domains`` for pad words), and ``rates`` an
    (n_shards, n_domains + 1) per-(shard, domain) fault-rate table (spill
    column 0.0). Every shard draws its masks from its own stream
    (collectives.shard_key).

    The step itself is collective-free: the per-shard counter block comes
    back sharded and any cross-shard view is the caller's one-per-soak
    ``fold_counters`` call. ``with_psum=True`` restores the historical
    in-step ``collectives.psum_counters`` aggregate as a fifth output
    (``(n_domains, 8)`` replicated) for callers that genuinely need the
    fleet view every interval.

    ``burst`` (a hashable scenario.BurstProfile, static under the cache)
    turns the per-shard draws into correlated multi-bit upsets; environment
    flux and per-shard aging drift arrive through the rate table itself
    (schedule_rates), so the compiled step is reused across a whole soak.
    """
    axes = reliability_axes(mesh)
    codec_obj = codes.get(codec)
    base_key = jax.random.PRNGKey(seed ^ 0xECC)
    sigma = jnp.float32(row_sigma)
    spec = _axes_spec(axes)

    def body(lo, hi, check, dom, rates):
        key = collectives.shard_key(base_key, axes)
        rates_w = rates[0][dom]  # (local_words,) per-word fault rate
        mlo, mhi, mpar = _chunked_shard_masks(
            key, local_words, rates_w, sigma, codec_obj.n_check, chunk_words,
            burst=burst,
        )
        flo, fhi, fpar, cnt = kops.inject_scrub_domains(
            lo, hi, check, mlo, mhi, mpar, dom, n_domains,
            codec=codec, reencode=reencode,
        )
        if with_psum:
            agg = collectives.psum_counters(cnt, axes)
            return flo, fhi, fpar, cnt[None], agg
        return flo, fhi, fpar, cnt[None]

    out_specs = (spec, spec, spec, spec) + ((P(),) if with_psum else ())
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=out_specs,
        check_rep=False,
    )
    # counters come back already sliced to the 8 telemetry lanes:
    # kops.inject_scrub_domains drops the lane padding and the spill row
    jitted = jax.jit(fn)

    def step(*args):
        return obs_profile.call("mesh.rail_step", jitted, *args)

    return step


@functools.lru_cache(maxsize=None)
def make_kv_scrub_step(
    mesh: Mesh,
    words_per_page: int,
    local_words: int,
    table_cols: int,
    codec: str = "secded72",
    with_payload: bool = True,
):
    """Shard_map'd paged scrub-on-read over per-replica KV arenas.

    The planes are the ``n_shards`` replicas' arenas stacked flat
    ((n_shards * local_words,), sharded over the reliability axes); ``table``
    is one (table_cols,) page-id row per shard (scratch-page filler for
    unused columns, ids local to the replica's arena). Each shard gathers
    its own rows, runs the scrub-on-read kernel, writes corrected planes
    back, and contributes its (table_cols, 8) counter rows; no plane word
    ever crosses a shard boundary. Returns a jitted callable
        fn(lo, hi, par, table) -> (lo, hi, par, payload_lo, payload_hi,
                                   counters (n_shards, table_cols, 8))
    ``with_payload=False`` drops the two payload outputs (callable returns
    (lo, hi, par, counters)): a scrub-only soak — the background scrubber
    and the BENCH_mesh throughput record — needs corrected planes and
    counters but never reads the gathered payload, and skipping it removes
    2 * table_cols * words_per_page words of per-step output traffic.
    """
    from repro.kernels import paged_gather

    axes = reliability_axes(mesh)
    spec = _axes_spec(axes)
    interpret = kops.use_interpret()

    def body(lo, hi, par, table):
        idx = table[0][:, None] * words_per_page + jnp.arange(
            words_per_page, dtype=jnp.int32
        )
        olo, ohi, opar, cnt = paged_gather.gather_scrub_pages(
            lo[idx], hi[idx], par[idx], codec=codec, interpret=interpret
        )
        out = (
            lo.at[idx].set(olo),
            hi.at[idx].set(ohi),
            par.at[idx].set(opar),
        )
        if with_payload:
            out += (olo[None], ohi[None])
        return out + (cnt[None],)

    n_out = 6 if with_payload else 4
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec,) * n_out,
        check_rep=False,
    )
    jitted = jax.jit(fn)

    def step(*args):
        return obs_profile.call("mesh.kv_scrub_step", jitted, *args)

    return step


# ---------------------------------------------------------------------------
# Host-side helpers for the per-(shard, domain) rail schedule
# ---------------------------------------------------------------------------
def schedule_rates(
    schedule, domains, profiles, n_shards: int, shard_multipliers=None
) -> np.ndarray:
    """(n_shards, n_domains + 1) fault-rate table for a rail schedule.

    ``schedule``: one {domain: voltage} dict (uniform across shards) or a
    sequence of ``n_shards`` of them (per-shard rails). ``profiles`` maps
    domain -> PlatformProfile. The trailing spill column is rate 0 — pad
    words never fault and never count. ``shard_multipliers`` (length
    n_shards, optional) scales each chip's whole rate row — the per-shard
    aging-drift hook (core/scenario.aging_multiplier); None or all-ones is
    bit-identical to the unscaled table.
    """
    if isinstance(schedule, dict):
        schedule = [schedule] * n_shards
    schedule = list(schedule)
    assert len(schedule) == n_shards, (len(schedule), n_shards)
    rates = np.zeros((n_shards, len(domains) + 1), np.float32)
    for s, volts in enumerate(schedule):
        missing = set(domains) - set(volts)
        assert not missing, f"shard {s} rails missing domains: {sorted(missing)}"
        for i, d in enumerate(domains):
            rates[s, i] = profiles[d].fault_rate(float(volts[d]))
    if shard_multipliers is not None:
        mult = np.asarray(shard_multipliers, np.float32)
        assert mult.shape == (n_shards,), (mult.shape, n_shards)
        # the spill column is 0.0 and stays 0.0 under any multiplier
        rates *= mult[:, None]
    return rates
