"""Distributed collectives: the reliability layer's shard-index / counter-psum
primitives (DESIGN.md §13) plus the int8-compressed gradient all-reduce with
error feedback, as a shard_map'd pure-DP train step.

4x less DP all-reduce traffic; the quantization residual is carried in an
error-feedback buffer so the compression bias vanishes over steps (EF-SGD,
Seide et al. / Karimireddy et al.). This is the pure-data-parallel trainer
mode (params replicated, batch sharded over "data"); under full-GSPMD pjit
the gradient reduction is compiler-inserted and compression is off
(documented trade-off, DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.optim import adamw


def shard_index(axes) -> jnp.ndarray:
    """Row-major linear shard index over one or more mesh axes.

    Only meaningful inside shard_map / pmap over exactly ``axes``. The
    reliability layer folds this into the fault-field PRNG key so every
    shard (chip / replica) draws its own independent fault population
    (DESIGN.md §13); shard 0 keeps the unsharded key so a 1-device mesh is
    bit-identical to the historical stream.
    """
    if isinstance(axes, str):
        axes = (axes,)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def shard_key(base_key, axes):
    """Per-shard PRNG key: the base key on shard 0 (bit-identity anchor for
    the 1-device mesh), ``fold_in(base, shard)`` everywhere else — so no
    shard can reproduce another's fault masks while the unsharded stream is
    preserved exactly where the refactor's correctness anchor needs it."""
    idx = shard_index(axes)
    return jnp.where(idx == 0, base_key, jax.random.fold_in(base_key, idx))


def psum_counters(counters, axes):
    """Cross-shard reduction of an ECC counter block inside shard_map.

    The only collective the reliability layer issues per rail step: a few
    hundred int32 lanes, regardless of arena size (DESIGN.md §13 traffic
    accounting). Accepts one axis name or a tuple (the batch super-axis).
    """
    if isinstance(axes, str):
        axes = (axes,)
    for a in axes:
        counters = jax.lax.psum(counters, a)
    return counters


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g: jnp.ndarray, ef: jnp.ndarray, axis: str):
    """Error-feedback int8 psum of one gradient leaf (inside shard_map).

    The int8 payload is what crosses the links (4x compression vs f32);
    returns (g_avg, new_ef)."""
    n = jax.lax.psum(1, axis)
    target = g.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    sent = q.astype(jnp.float32) * scale
    new_ef = target - sent
    total = jax.lax.psum(sent, axis)
    return total / n, new_ef


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def make_dp_compressed_train_step(cfg, tcfg, mesh, axis: str = "data",
                                  compress: bool = True):
    """Pure-DP train step: params replicated, batch sharded over `axis`,
    gradients all-reduced int8+error-feedback inside shard_map.

    Returns fn(params, opt_state, ef, batch) -> (params, opt_state, ef, loss).
    """

    def local_step(params, opt_state, ef, batch):
        def loss_fn(p):
            loss, _ = lm.train_loss(p, batch, cfg, remat=tcfg.remat)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.pmean(loss, axis)
        if compress:
            pairs = jax.tree_util.tree_map(
                lambda g, e: compressed_psum(g, e, axis), grads, ef
            )
            flat, treedef = jax.tree_util.tree_flatten(
                pairs, is_leaf=lambda t: isinstance(t, tuple)
            )
            grads = jax.tree_util.tree_unflatten(treedef, [t[0] for t in flat])
            ef = jax.tree_util.tree_unflatten(treedef, [t[1] for t in flat])
        else:
            grads = jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axis), grads)
        new_params, new_opt, _ = adamw.update(grads, opt_state, params, tcfg.optimizer)
        return new_params, new_opt, ef, loss

    rep = P()
    return shard_map(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, rep, P(axis)),
        out_specs=(rep, rep, rep, rep),
        check_rep=False,
    )
