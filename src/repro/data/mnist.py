"""Synthetic MNIST-like task for the paper's NN-accelerator case study.

Real MNIST is unavailable offline; this generator produces a 10-class 28x28
image task whose MLP test error lands near the paper's fault-free 2.56%
(paper Fig. 3). Class prototypes are smooth low-frequency images (7x7 noise
bilinearly upsampled); samples add pixel noise + small random shifts so the
task is non-trivially separable.
"""

from __future__ import annotations

import numpy as np

IMG = 28
N_CLASSES = 10


def _upsample(x: np.ndarray, factor: int) -> np.ndarray:
    """Bilinear upsample of a (h, w) grid by `factor`."""
    h, w = x.shape
    out_h, out_w = h * factor, w * factor
    yi = np.linspace(0, h - 1, out_h)
    xi = np.linspace(0, w - 1, out_w)
    y0 = np.floor(yi).astype(int)
    x0 = np.floor(xi).astype(int)
    y1 = np.minimum(y0 + 1, h - 1)
    x1 = np.minimum(x0 + 1, w - 1)
    wy = (yi - y0)[:, None]
    wx = (xi - x0)[None, :]
    return (
        x[np.ix_(y0, x0)] * (1 - wy) * (1 - wx)
        + x[np.ix_(y1, x0)] * wy * (1 - wx)
        + x[np.ix_(y0, x1)] * (1 - wy) * wx
        + x[np.ix_(y1, x1)] * wy * wx
    )


def prototypes(seed: int = 0) -> np.ndarray:
    rng = np.random.Generator(np.random.Philox(key=(seed ^ (0xB10B << 32), 0)))
    protos = []
    for _ in range(N_CLASSES):
        low = rng.standard_normal((7, 7))
        protos.append(_upsample(low, 4))
    p = np.stack(protos)  # (10, 28, 28)
    return (p - p.mean()) / (p.std() + 1e-9)


def make_dataset(n: int, seed: int = 0, noise: float = 1.25, split: str = "train"):
    """Returns (images (n, 784) float32, labels (n,) int32)."""
    salt = {"train": 1, "test": 2}[split]
    rng = np.random.Generator(np.random.Philox(key=(seed ^ (0xDA7A << 32), salt)))
    protos = prototypes(seed)
    labels = rng.integers(0, N_CLASSES, size=n)
    imgs = protos[labels]
    # small random translations (+-2 px) make classes overlap a little
    shifts = rng.integers(-2, 3, size=(n, 2))
    imgs = np.stack(
        [np.roll(np.roll(im, s[0], axis=0), s[1], axis=1) for im, s in zip(imgs, shifts)]
    )
    imgs = imgs + noise * rng.standard_normal(imgs.shape)
    return imgs.reshape(n, -1).astype(np.float32), labels.astype(np.int32)
