"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step): any worker can reproduce any
step's batch with no shared state, which is what makes checkpoint/restart and
elastic rescaling trivially deterministic — the restored trainer consumes the
exact same stream. Per-host sharding slices the global batch by host id.

The token stream is a noisy affine-recurrence language (x_{t+1} = a*x_t + b
mod V with structured noise), so small models show a clearly decreasing loss
— enough signal for end-to-end driver runs and fault-recovery tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.1
    n_codebooks: int = 0  # musicgen-style multi-stream tokens
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=(self.cfg.seed ^ (0xDA7A << 40), (step << 16) | self.cfg.host_id))
        )

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for `step` (host-local slice)."""
        c = self.cfg
        rng = self._rng(step)
        shape = (
            (self.local_batch, c.n_codebooks, c.seq_len + 1)
            if c.n_codebooks
            else (self.local_batch, c.seq_len + 1)
        )
        v = max(c.vocab - 1, 2)
        x = np.empty(shape, np.int64)
        x[..., 0] = rng.integers(0, v, size=shape[:-1])
        a, b = 5, 7
        noise = rng.random(shape) < c.noise
        jumps = rng.integers(0, v, size=shape)
        for t in range(1, shape[-1]):
            nxt = (a * x[..., t - 1] + b) % v
            x[..., t] = np.where(noise[..., t], jumps[..., t], nxt)
        tokens = x[..., :-1].astype(np.int32)
        labels = x[..., 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
