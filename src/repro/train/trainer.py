"""Fault-tolerant training loop.

Production behaviours implemented (and unit-tested):
  * checkpoint/restart — periodic atomic checkpoints (optionally
    SECDED-protected); on *any* step failure (simulated node fault, NaN loss,
    checkpoint corruption) the trainer restores the last good checkpoint and
    replays the deterministic data stream from that step;
  * straggler mitigation — per-step wall-times feed an EMA monitor; steps
    slower than `factor` x median trigger a mitigation callback (on real pods:
    hot-spare swap / re-shard; here: recorded + pluggable);
  * elastic rescale — `rescale(new_mesh)` re-places params/optimizer onto a
    different mesh via the resharding checkpoint path, mid-run.

Because data batches are a pure function of (seed, step), recovery and
rescale are bitwise-deterministic: the loss trajectory after restore matches
an uninterrupted run (asserted in tests/test_trainer.py).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import TokenPipeline
from repro.models import lm
from repro.models.base import ModelConfig
from repro.optim import adamw
from repro.train.train_step import TrainConfig, make_train_step


class FaultInjected(RuntimeError):
    """Simulated node failure (tests / chaos drills)."""


@dataclasses.dataclass(frozen=True)
class RailPolicy:
    """Closed-loop multi-rail undervolting of the training weight memory.

    Every ``scrub_every`` steps the trainer packs the current weights into
    the SECDED plane arena (partitioned into memory domains), scrubs it at
    the controller's per-domain rail schedule, and feeds the per-domain
    telemetry back to the MultiRailController — the paper's runtime DED
    canary, driven from inside the training loop. The scrub is a *read*
    path: faults never enter the optimizer state, so loss trajectories are
    bitwise-identical with the policy on or off (tested).
    """

    platform: str = "vc707"
    scrub_every: int = 10
    step_v: float = 0.01
    # gradients amplify silent corruption, so training defaults to paranoid
    paranoid: bool = True
    start_v: float | None = None
    mask_source: str = "host"
    seed: int = 0


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float


class StragglerMonitor:
    """Flags steps slower than `factor` x running median (window `w`)."""

    def __init__(self, factor: float = 3.0, window: int = 20, warmup: int = 3):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.times: list[float] = []
        self.events: list[StragglerEvent] = []

    def observe(self, step: int, seconds: float) -> bool:
        slow = False
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times[-self.window:])
            if seconds > self.factor * med:
                self.events.append(StragglerEvent(step, seconds, med))
                slow = True
        self.times.append(seconds)
        return slow


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        pipeline: TokenPipeline,
        ckpt_dir: str,
        *,
        mesh=None,
        param_shardings=None,
        ckpt_every: int = 50,
        ecc_checkpoints: bool = False,
        seed: int = 0,
        fault_hook: Callable[[int], None] | None = None,
        straggler_hook: Callable[[StragglerEvent], None] | None = None,
        rails: RailPolicy | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.mesh = mesh
        self.param_shardings = param_shardings
        self.ckpt_every = ckpt_every
        self.ecc_checkpoints = ecc_checkpoints
        self.fault_hook = fault_hook
        self.straggler = StragglerMonitor()
        self.straggler_hook = straggler_hook
        self.recoveries = 0
        self.history: list[dict] = []

        self.rails = rails
        self.rail_controller = None  # built on the first scrub (needs domains)
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt_state = adamw.init(self.params, tcfg.optimizer)
        self.step = 0
        self._step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    # -- multi-rail weight-memory scrub ---------------------------------------
    def _rail_scrub(self):
        """Pack current weights into the domain arena, scrub at the
        controller's schedule, feed per-domain telemetry back (paper §III.A
        run inside the training loop). Read-only w.r.t. training state."""
        from repro.configs import shapes
        from repro.core import MultiRailController, voltage as vmod
        from repro.core.planestore import PlaneStore
        from repro.kernels import ops as kops
        from repro.serving.engine import protect_params_inline

        pol = self.rails
        protected, _ = protect_params_inline(
            self.params, self.cfg, seed=pol.seed, include_embed=True
        )
        flat, _ = jax.tree_util.tree_flatten_with_path(
            protected, is_leaf=lambda x: isinstance(x, kops.EccWeight)
        )
        leaves, keys = [], []
        for path, leaf in flat:
            if isinstance(leaf, kops.EccWeight):
                leaves.append(leaf)
                keys.append(jax.tree_util.keystr(path))
        if not leaves:
            return
        platform = vmod.PLATFORMS[pol.platform]
        store = PlaneStore(
            leaves, keys, platform, seed=pol.seed,
            mask_source=pol.mask_source, domain_key=shapes.domain_of,
        )
        if self.rail_controller is None:
            self.rail_controller = MultiRailController(
                platform, store.domains, step_v=pol.step_v,
                paranoid=pol.paranoid, start_v=pol.start_v,
            )
        _, dstats = store.set_rails(self.rail_controller.voltages)
        self.rail_controller.update(dstats)
        self.history.append(
            {
                "step": self.step,
                "event": "rails",
                "voltages": dict(self.rail_controller.voltages),
                "locked": self.rail_controller.locked,
                "bram_w": vmod.multi_rail_bram_power(
                    self.rail_controller.voltages, store.words_by_domain()
                ),
                "detected": {d: dstats[d].detected for d in store.domains},
            }
        )

    # -- checkpointing -------------------------------------------------------
    def _state(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        ckpt.save(
            self.ckpt_dir, self.step, self._state(), ecc_protect=self.ecc_checkpoints
        )

    def restore(self, step: int | None = None) -> bool:
        steps = sorted(ckpt.all_steps(self.ckpt_dir))
        if not steps:
            return False
        target = step if step is not None else steps[-1]
        while True:
            try:
                state = ckpt.load(self.ckpt_dir, target, self._state())
                break
            except ckpt.CheckpointCorruption:
                idx = steps.index(target)
                if idx == 0:
                    raise
                target = steps[idx - 1]  # fall back to an older checkpoint
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = target
        return True

    # -- main loop -----------------------------------------------------------
    def run(self, n_steps: int) -> list[dict]:
        end = self.step + n_steps
        while self.step < end:
            t0 = time.time()
            try:
                if self.fault_hook:
                    self.fault_hook(self.step)
                batch = self.pipeline.batch_at(self.step)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {self.step}")
            except (FaultInjected, FloatingPointError) as e:
                self.recoveries += 1
                restored = self.restore()
                if not restored:
                    # No checkpoint yet: re-init deterministically.
                    self.params = lm.init_params(self.cfg, jax.random.PRNGKey(0))
                    self.opt_state = adamw.init(self.params, self.tcfg.optimizer)
                    self.step = 0
                self.history.append(
                    {"step": self.step, "event": "recovery", "cause": repr(e)}
                )
                continue

            dt = time.time() - t0
            if self.straggler.observe(self.step, dt) and self.straggler_hook:
                self.straggler_hook(self.straggler.events[-1])
            self.step += 1
            self.history.append({"step": self.step, "loss": loss, "seconds": dt})
            if self.rails is not None and self.step % self.rails.scrub_every == 0:
                self._rail_scrub()
            if self.step % self.ckpt_every == 0:
                self.save()
        return self.history

    # -- elastic -------------------------------------------------------------
    def rescale(self, new_mesh, new_param_shardings=None):
        """Re-place training state onto a different mesh (elastic scaling)."""
        self.mesh = new_mesh
        self.param_shardings = new_param_shardings
        put = (
            (lambda l, s: jax.device_put(l, s))
            if new_param_shardings is not None
            else (lambda l, s: jax.device_put(l))
        )
        if new_param_shardings is not None:
            self.params = jax.tree_util.tree_map(put, self.params, new_param_shardings)
            self.opt_state["m"] = jax.tree_util.tree_map(
                put, self.opt_state["m"], new_param_shardings
            )
            self.opt_state["v"] = jax.tree_util.tree_map(
                put, self.opt_state["v"], new_param_shardings
            )
