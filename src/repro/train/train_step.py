"""Train step: microbatched grad accumulation + remat + AdamW.

`make_train_step(cfg, tcfg)` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for pjit. Microbatching splits the global batch along B inside a
lax.scan, keeping live activation memory at 1/n_micro while the collective
payload per accumulation step stays pipelineable.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.base import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    remat: str | None = "full"  # None | "full" | "dots"


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        loss, metrics = lm.train_loss(params, batch, cfg, remat=tcfg.remat)
        return loss, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    # NOTE: cfg.unroll (dry-run analysis mode) must also unroll this scan, or
    # XLA cost analysis undercounts the step by the microbatch count.

    def train_step(params, opt_state, batch):
        n = tcfg.microbatches
        if n <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n == 0, (b, n)
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            (g_sum, l_sum), _ = jax.lax.scan(
                acc_step, (zero_g, 0.0), micro, unroll=cfg.unroll
            )
            grads = jax.tree_util.tree_map(lambda g: (g / n).astype(jnp.float32), g_sum)
            loss = l_sum / n
            metrics = {}

        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt_state, params, tcfg.optimizer
        )
        out_metrics: dict[str, Any] = {"loss": loss, **opt_metrics}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return new_params, new_opt, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_loss_fn(cfg, tcfg)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
