"""Pure-jnp oracles for every Pallas kernel (bit-exact references)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ecc


def encode_ref(lo, hi):
    """(…,) uint32 planes -> (…,) uint8 parity."""
    return ecc.encode(lo, hi)


def decode_ref(lo, hi, parity):
    """-> (lo', hi', status int32)."""
    return ecc.decode(lo, hi, parity)


def inject_ref(lo, hi, parity, mlo, mhi, mparity):
    return lo ^ mlo, hi ^ mhi, parity ^ mparity


def inject_scrub_ref(lo, hi, parity, mlo, mhi, mparity, reencode=False):
    """Oracle for the fused kernel: separate inject -> (encode) -> decode.

    Returns (faulty_lo, faulty_hi, faulty_parity, counters) with counters in
    telemetry.COUNTER_FIELDS order, built through FaultStats.from_decode so
    the two paths share one classification truth.
    """
    from repro.core.faultsim import FlipMasks
    from repro.core.telemetry import FaultStats

    flo, fhi, fpar = inject_ref(lo, hi, parity, mlo, mhi, mparity)
    if reencode:
        fpar = ecc.encode(flo, fhi)
    _, _, status = ecc.decode(flo, fhi, fpar)
    flips = FlipMasks(
        np.asarray(mlo).reshape(-1),
        np.asarray(mhi).reshape(-1),
        np.asarray(mparity).reshape(-1),
    ).flip_counts()
    counters = FaultStats.from_decode(np.asarray(status), flips).counters()
    return flo, fhi, fpar, counters


def pack_ecc_weights_np(w_int8: np.ndarray):
    """int8 (K, N), K % 8 == 0 -> (lo, hi) uint32 (K/8, N) + parity uint8.

    Codeword i of column n packs W[j*K/8 + i, n] for j = 0..7.
    """
    k, n = w_int8.shape
    assert k % 8 == 0, k
    wr = (w_int8.reshape(8, k // 8, n).astype(np.int64) & 0xFF).astype(np.uint32)
    lo = wr[0] | (wr[1] << 8) | (wr[2] << 16) | (wr[3] << 24)
    hi = wr[4] | (wr[5] << 8) | (wr[6] << 16) | (wr[7] << 24)
    parity = ecc.encode_np(lo, hi)
    return lo, hi, parity


def unpack_ecc_weights(lo, hi):
    """Inverse packing: (K/8, N) planes -> (K, N) int8 (jnp)."""
    planes = []
    for word in (lo, hi):
        for j in range(4):
            planes.append((word >> jnp.uint32(8 * j)) & jnp.uint32(0xFF))
    w = jnp.concatenate(planes, axis=0)  # (K, N), rows j-major: row j*K8 + i
    return ((w.astype(jnp.int32) ^ 128) - 128).astype(jnp.int8)


def ecc_matmul_ref(x, lo, hi, parity, scale=None):
    """Oracle for the fused kernel: decode -> unpack -> dequant -> matmul.

    x is the *unpermuted* (M, K) activation; planes are (K/8, N).
    """
    lo2, hi2, _ = ecc.decode(lo, hi, parity)
    w = unpack_ecc_weights(lo2, hi2).astype(jnp.float32)  # (K, N)
    out = jnp.dot(x.astype(jnp.float32), w)
    if scale is not None:
        out = out * scale
    return out
