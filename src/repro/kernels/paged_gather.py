"""Pallas kernel: paged KV-cache gather with scrub-on-read (DESIGN.md §11).

The paged serving path stores the *dynamic* model state — the KV cache — in
ECC-encoded pages carved out of the `kv` voltage domain (core/kvpages.py).
Every read of a page must travel through the ECC decoder so undervolting
faults in the cache are corrected before they reach attention, and so the
per-page DED counters exist to feed the `kv` rail's canary controller.

This kernel is the read path: given the already-gathered (n_pages, W) word
planes of the pages one batch of requests needs, it

  * recomputes the syndrome per codeword with the page arena's codec
    (DESIGN.md §12 — the same single kernel body serves every registered
    code; SEC-class codes resolve the syndrome gather-free, DEC-TED gathers
    from its dense LUT),
  * corrects correctable faults in registers and writes the *corrected*
    planes out (the scrub write-back the arena commits, so a corrected fault
    does not accumulate into an uncorrectable one at the next rail step), and
  * reduces one (clean, corrected, detected) counter row **per page** — the
    per-page telemetry that is attributed to the request that owns the page
    and aggregated into the `kv` domain's DomainFaultStats row.

Counter row layout matches telemetry.COUNTER_FIELDS lanes 0..2 (clean,
corrected, detected); the ground-truth lanes stay zero because the read path
— like real hardware — only observes syndromes, not injected masks.

Grid: ``page_block`` pages per grid row (per-page counters come from a
within-block row reduction, so the grid stays small — this is what keeps
interpret-mode scrubs usable in CI), `W` column-blocked with accumulation
over column steps; counter rows for a page are written by its row blocks
only, so there are no cross-page races.

Duplicate page rows (prefix sharing, DESIGN.md §16): the kernel itself is
safe under duplicates — identical stored words decode to identical corrected
planes, so the arena's scatter write-back of duplicate rows is idempotent —
but the per-row counters would charge the same physical fault once per
duplicate, and the page would be scrubbed once per reader. Callers that
share pages must therefore scrub the *deduplicated* page set and fan the
rows back out on the host (core/kvpages.dedup_page_table is the canonical
helper; the serving scheduler uses it at admission and at every scrub
interval) — physical work and arena-level telemetry stay per unique page,
while reader-weighted attribution happens on the gathered row mapping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import codes
from repro.kernels.inject_scrub import _lut_specs

_U32 = jnp.uint32

_CNT_LANES = 128  # lane-aligned counter row (lanes 0..2 used)


def _gather_scrub_kernel(*refs, codec, n_luts):
    # refs: lo, hi, par, *lut_tables, olo, ohi, opar, cnt
    lo_ref, hi_ref, par_ref = refs[:3]
    luts = tuple(r[...] for r in refs[3 : 3 + n_luts])
    olo_ref, ohi_ref, opar_ref, cnt_ref = refs[3 + n_luts :]
    lo = lo_ref[...]
    hi = hi_ref[...]
    stored = par_ref[...]
    synd = codec.encode_jnp(lo, hi) ^ stored.astype(_U32)
    flip_lo, flip_hi, _, status = codec.classify_jnp(synd, luts=luts)

    clean = status == 0
    corrected = status == 1
    detected = status == 2
    olo = lo ^ flip_lo
    ohi = hi ^ flip_hi
    olo_ref[...] = olo
    ohi_ref[...] = ohi
    # Scrub write-back check bits: recompute over the corrected data so a
    # corrected check-bit fault is cleared too; *detected* words keep their
    # stored check bits so the DED flag stays latched on re-reads (the data
    # is wrong and must keep flagging, exactly like the hardware).
    opar_ref[...] = jnp.where(
        detected, stored, codec.encode_jnp(olo, ohi).astype(stored.dtype)
    )

    lane = jax.lax.broadcasted_iota(jnp.int32, (lo.shape[0], _CNT_LANES), 1)
    rowsum = lambda t: jnp.sum(t.astype(jnp.int32), axis=1, keepdims=True)
    vals = (
        jnp.where(lane == 0, rowsum(clean), 0)
        + jnp.where(lane == 1, rowsum(corrected), 0)
        + jnp.where(lane == 2, rowsum(detected), 0)
    )
    first = pl.program_id(1) == 0

    @pl.when(first)
    def _():
        cnt_ref[...] = vals

    @pl.when(jnp.logical_not(first))
    def _():
        cnt_ref[...] = cnt_ref[...] + vals


@functools.partial(
    jax.jit, static_argnames=("page_block", "block_cols", "codec", "interpret")
)
def gather_scrub_2d(
    lo, hi, parity, *, page_block=16, block_cols=4096, codec="secded72",
    interpret=False,
):
    """Scrub a stack of gathered pages.

    lo/hi: (P, W) uint32, parity: (P, W) in the codec's check dtype; P a
    multiple of ``page_block``, W a multiple of 128. Returns (corrected_lo,
    corrected_hi, parity, counters (P, 128) int32) where counters[i, 0:3] =
    (clean, corrected, detected) for page i.
    """
    c = codes.get(codec)
    p_rows, w = lo.shape
    bp = min(page_block, p_rows)
    bn = min(block_cols, w)
    grid = (pl.cdiv(p_rows, bp), pl.cdiv(w, bn))
    spec = pl.BlockSpec((bp, bn), lambda i, j: (i, j))
    cnt_spec = pl.BlockSpec((bp, _CNT_LANES), lambda i, j: (i, 0))
    lut_specs, lut_arrays = _lut_specs(c)
    return pl.pallas_call(
        functools.partial(_gather_scrub_kernel, codec=c, n_luts=len(lut_arrays)),
        grid=grid,
        in_specs=[spec] * 3 + lut_specs,
        out_specs=[spec, spec, spec, cnt_spec],
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.dtype(c.check_dtype)),
            jax.ShapeDtypeStruct((p_rows, _CNT_LANES), jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, *lut_arrays)


def gather_scrub_pages(lo, hi, parity, *, codec="secded72", interpret: bool | None = None):
    """Shape-tolerant wrapper: pads P/W to block multiples, trims the result.

    lo/hi: (P, W) uint32 planes of P gathered pages (any P, W >= 1); parity
    (P, W) in the codec's check dtype. Returns (lo', hi', parity', counters
    (P, 8) int32) with counters[:, 0:3] = per-page (clean, corrected,
    detected); pad words and pad pages decode clean (all-zero planes are a
    valid codeword of every registered linear code) and are
    trimmed/subtracted.
    """
    from repro.kernels import backend as _backend
    from repro.kernels import ops as kops

    interpret = _backend.resolve_interpret(interpret)
    kops._count_launch()
    p_rows, w = lo.shape
    pad_w = (-w) % 128
    bp = min(16, max(p_rows, 1))
    pad_p = (-p_rows) % bp
    if pad_w or pad_p:
        zp = lambda a, dt: jnp.pad(a, ((0, pad_p), (0, pad_w))).astype(dt)
        lo, hi = zp(lo, jnp.uint32), zp(hi, jnp.uint32)
        parity = zp(parity, parity.dtype)
    olo, ohi, opar, cnt = gather_scrub_2d(
        lo, hi, parity, page_block=bp, codec=codec, interpret=interpret
    )
    cnt = cnt[:p_rows, :8]
    if pad_p or pad_w:
        olo, ohi, opar = olo[:p_rows, :w], ohi[:p_rows, :w], opar[:p_rows, :w]
    if pad_w:
        cnt = cnt - pad_w * jnp.eye(1, 8, 0, dtype=jnp.int32)
    return olo, ohi, opar, cnt
