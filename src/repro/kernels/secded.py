"""Pallas TPU kernels: codec-generic encode and decode (default SECDED(72,64)).

Layout: word planes are 2D (rows, cols) with cols a multiple of 128 (lane
dimension); `ops.py` handles flattening/padding of arbitrary shapes. All bit
manipulation happens in uint32 VPU lanes. One kernel body serves every
registered code (repro.codes): the codec supplies the check-bit recompute
(`encode_jnp`) and the syndrome->action resolution (`classify_jnp`). For the
SEC-class codes the resolution is gather-free (unrolled compares against the
code's columns, so the kernel lowers to pure vector compare/select chains on
TPU — bit-identical to the historical hard-coded Hsiao kernels); the DEC-TED
code gathers from its dense syndrome LUT instead.

VMEM budget per grid step (default block 256x512, SECDED):
  encode: lo+hi in (1 MiB) + parity out (128 KiB)            ~1.2 MiB
  decode: lo+hi+par in (1.2 MiB) + lo+hi+status out (1.5 MiB) ~2.7 MiB
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import codes

_U32 = jnp.uint32


def _parity32(v):
    v = v ^ (v >> 16)
    v = v ^ (v >> 8)
    v = v ^ (v >> 4)
    v = v ^ (v >> 2)
    v = v ^ (v >> 1)
    return v & _U32(1)


def _compute_parity(lo, hi):
    """Recompute the Hsiao(72,64) check bits; returns uint32 plane in [0,256).

    Kept as the historical name — this is exactly the SECDED codec's
    ``encode_jnp`` and remains the hot path for the default code.
    """
    return codes.get("secded72").encode_jnp(lo, hi)


def _encode_kernel(lo_ref, hi_ref, par_ref, *, codec):
    par_ref[...] = codec.encode_jnp(lo_ref[...], hi_ref[...]).astype(par_ref.dtype)


def _decode_kernel(*refs, codec, n_luts):
    # refs: lo, hi, par, *lut_tables, out_lo, out_hi, status
    lo_ref, hi_ref, par_ref = refs[:3]
    luts = tuple(r[...] for r in refs[3 : 3 + n_luts])
    out_lo_ref, out_hi_ref, status_ref = refs[3 + n_luts :]
    lo = lo_ref[...]
    hi = hi_ref[...]
    synd = codec.encode_jnp(lo, hi) ^ par_ref[...].astype(_U32)
    flip_lo, flip_hi, _, status = codec.classify_jnp(synd, luts=luts)
    out_lo_ref[...] = lo ^ flip_lo
    out_hi_ref[...] = hi ^ flip_hi
    # status: 0 clean, 1 corrected, 2 detected (uncorrectable)
    status_ref[...] = status


def _grid_spec(shape, block, n_in, n_out):
    bm, bn = block
    grid = (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return grid, [spec] * n_in, [spec] * n_out if n_out > 1 else spec


@functools.partial(jax.jit, static_argnames=("block", "codec", "interpret"))
def encode_2d(lo, hi, *, block=(256, 512), codec="secded72", interpret=False):
    """Check plane for 2D word planes. lo/hi: (R, C) uint32 -> (R, C) of the
    codec's check dtype (uint8 up to 8 check bits, uint32 beyond)."""
    c = codes.get(codec)
    grid, in_specs, out_spec = _grid_spec(lo.shape, block, 2, 1)
    return pl.pallas_call(
        functools.partial(_encode_kernel, codec=c),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(lo.shape, jnp.dtype(c.check_dtype)),
        interpret=interpret,
    )(lo, hi)


@functools.partial(jax.jit, static_argnames=("block", "codec", "interpret"))
def decode_2d(lo, hi, parity, *, block=(256, 512), codec="secded72", interpret=False):
    """Codec decode of 2D planes -> (lo', hi', status int32)."""
    from repro.kernels.inject_scrub import _lut_specs

    c = codes.get(codec)
    grid, in_specs, out_specs = _grid_spec(lo.shape, block, 3, 3)
    lut_specs, lut_arrays = _lut_specs(c)
    in_specs = in_specs + lut_specs
    return pl.pallas_call(
        functools.partial(_decode_kernel, codec=c, n_luts=len(lut_arrays)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, *lut_arrays)
