"""Pallas TPU kernels: SECDED(72,64) encode and decode.

Layout: word planes are 2D (rows, cols) with cols a multiple of 128 (lane
dimension); `ops.py` handles flattening/padding of arbitrary shapes. All bit
manipulation happens in uint32 VPU lanes; the syndrome->flip mapping is
gather-free (72 unrolled compares against the Hsiao column constants), so the
kernel lowers to pure vector compare/select chains on TPU.

VMEM budget per grid step (default block 256x512):
  encode: lo+hi in (1 MiB) + parity out (128 KiB)            ~1.2 MiB
  decode: lo+hi+par in (1.2 MiB) + lo+hi+status out (1.5 MiB) ~2.7 MiB
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hsiao

_U32 = jnp.uint32


def _parity32(v):
    v = v ^ (v >> 16)
    v = v ^ (v >> 8)
    v = v ^ (v >> 4)
    v = v ^ (v >> 2)
    v = v ^ (v >> 1)
    return v & _U32(1)


def _compute_parity(lo, hi):
    """Recompute the 8 check bits; returns uint32 plane with parity in [0,256)."""
    p = jnp.zeros_like(lo)
    for r in range(hsiao.N_PARITY):
        mlo = _U32(int(hsiao.MASK_LO[r]))
        mhi = _U32(int(hsiao.MASK_HI[r]))
        # parity(a) ^ parity(b) == parity(a ^ b): one fold per check bit.
        bit = _parity32((lo & mlo) ^ (hi & mhi))
        p = p | (bit << r)
    return p


def _encode_kernel(lo_ref, hi_ref, par_ref):
    par_ref[...] = _compute_parity(lo_ref[...], hi_ref[...]).astype(jnp.uint8)


def _decode_kernel(lo_ref, hi_ref, par_ref, out_lo_ref, out_hi_ref, status_ref):
    lo = lo_ref[...]
    hi = hi_ref[...]
    stored = par_ref[...].astype(_U32)
    synd = _compute_parity(lo, hi) ^ stored

    # Gather-free syndrome resolution: compare against all 72 Hsiao columns.
    flip_lo = jnp.zeros_like(lo)
    flip_hi = jnp.zeros_like(hi)
    matched = jnp.zeros_like(lo, dtype=jnp.bool_)
    for d in range(hsiao.N_DATA):
        col = _U32(int(hsiao.DATA_COLS[d]))
        m = synd == col
        matched = matched | m
        if d < 32:
            flip_lo = jnp.where(m, flip_lo | _U32(1 << d), flip_lo)
        else:
            flip_hi = jnp.where(m, flip_hi | _U32(1 << (d - 32)), flip_hi)
    for r in range(hsiao.N_PARITY):
        matched = matched | (synd == _U32(1 << r))  # parity-bit error: data fine

    clean = synd == _U32(0)
    out_lo_ref[...] = lo ^ flip_lo
    out_hi_ref[...] = hi ^ flip_hi
    # status: 0 clean, 1 corrected, 2 detected (uncorrectable)
    status_ref[...] = jnp.where(
        clean, jnp.int32(0), jnp.where(matched, jnp.int32(1), jnp.int32(2))
    )


def _grid_spec(shape, block, n_in, n_out):
    bm, bn = block
    grid = (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return grid, [spec] * n_in, [spec] * n_out if n_out > 1 else spec


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def encode_2d(lo, hi, *, block=(256, 512), interpret=False):
    """Parity plane for 2D word planes. lo/hi: (R, C) uint32 -> (R, C) uint8."""
    grid, in_specs, out_spec = _grid_spec(lo.shape, block, 2, 1)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(lo.shape, jnp.uint8),
        interpret=interpret,
    )(lo, hi)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def decode_2d(lo, hi, parity, *, block=(256, 512), interpret=False):
    """SECDED decode of 2D planes -> (lo', hi', status int32)."""
    grid, in_specs, out_specs = _grid_spec(lo.shape, block, 3, 3)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity)
