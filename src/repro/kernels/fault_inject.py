"""Pallas TPU kernel: read-time undervolting fault injection (XOR flip masks).

Applies the fault field's flip masks to all three codeword planes in one
streaming pass — the software analogue of the physical bit-error process on
the BRAM read port. Pure elementwise XOR, memory-bound by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inject_kernel(lo_ref, hi_ref, par_ref, mlo_ref, mhi_ref, mpar_ref,
                   olo_ref, ohi_ref, opar_ref):
    olo_ref[...] = lo_ref[...] ^ mlo_ref[...]
    ohi_ref[...] = hi_ref[...] ^ mhi_ref[...]
    opar_ref[...] = par_ref[...] ^ mpar_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def inject_2d(lo, hi, parity, mlo, mhi, mparity, *, block=(256, 512), interpret=False):
    """XOR flip masks into 2D word planes. Shapes all (R, C)."""
    bm, bn = block
    grid = (pl.cdiv(lo.shape[0], bm), pl.cdiv(lo.shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _inject_kernel,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec] * 3,
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint8),
        ),
        interpret=interpret,
    )(lo, hi, parity, mlo, mhi, mparity)
