"""Jit'd public wrappers around the Pallas kernels.

Handle arbitrary plane shapes (flatten/pad/reshape to lane-aligned 2D),
choose interpret mode automatically off-TPU, and expose the weight-packing
helpers used by the serving stack. `fuse=False` paths implement the *naive*
ECC read (separate decode pass materialising corrected weights to HBM) used
as the §Perf baseline against the fused kernel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as _backend
from repro.kernels import ecc_matmul as _mm
from repro.kernels import fault_inject as _fi
from repro.kernels import inject_scrub as _isc
from repro.kernels import ref as _ref
from repro.kernels import secded as _secded

LANES = 512  # default 2D width for flattened planes (multiple of 128)

# Pallas launch accounting (benchmarks/kernel_micro voltage_sweep). Each
# wrapper below executes exactly one pallas_call per eager invocation; calls
# traced inside an outer jit are counted once per trace, so only eager-path
# comparisons (the engine voltage loop) are meaningful.
_launches = {"n": 0}


def reset_launch_count() -> None:
    _launches["n"] = 0


def launch_count() -> int:
    return _launches["n"]


def _count_launch(n: int = 1) -> None:
    _launches["n"] += n


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def use_interpret() -> bool:
    """True when the interpret lane is in force (see kernels/backend.py:
    honors REPRO_KERNEL_BACKEND / set_backend and the compiled-lowering
    probe, falling back to interpret automatically)."""
    return _backend.use_interpret()


def _to_2d(*planes, lanes=LANES, block_rows=256):
    """Flatten + zero-pad planes to a common (rows, lanes) 2D layout.

    Rows are padded to a multiple of the kernel block so no grid step ever
    touches out-of-bounds memory. Returns (planes_2d, n, block) with the
    adapted (block_rows, lanes) block.
    """
    n = planes[0].size
    rows = max(1, -(-n // lanes))
    bm = min(block_rows, rows)
    rows = _round_up(rows, bm)
    pad = rows * lanes - n
    out = []
    for p in planes:
        flat = p.reshape(-1)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), p.dtype)])
        out.append(flat.reshape(rows, lanes))
    return out, n, (bm, lanes)


def encode(lo: jnp.ndarray, hi: jnp.ndarray, *, codec: str = "secded72",
           interpret: bool | None = None):
    """ECC check plane for word planes of any shape (codec's check dtype)."""
    interpret = _backend.resolve_interpret(interpret)
    _count_launch()
    (lo2, hi2), n, block = _to_2d(lo, hi)
    par = _secded.encode_2d(lo2, hi2, block=block, codec=codec, interpret=interpret)
    return par.reshape(-1)[:n].reshape(lo.shape)


def decode(lo, hi, parity, *, codec: str = "secded72", interpret: bool | None = None):
    """ECC decode for planes of any shape -> (lo', hi', status int32)."""
    interpret = _backend.resolve_interpret(interpret)
    _count_launch()
    (lo2, hi2, par2), n, block = _to_2d(lo, hi, parity)
    olo, ohi, st = _secded.decode_2d(
        lo2, hi2, par2, block=block, codec=codec, interpret=interpret
    )
    unpad = lambda a: a.reshape(-1)[:n].reshape(lo.shape)
    return unpad(olo), unpad(ohi), unpad(st)


def inject(lo, hi, parity, mlo, mhi, mparity, *, interpret: bool | None = None):
    """Apply XOR flip masks to planes of any shape."""
    interpret = _backend.resolve_interpret(interpret)
    _count_launch()
    (a, b, c, d, e, f), n, block = _to_2d(lo, hi, parity, mlo, mhi, mparity)
    olo, ohi, opar = _fi.inject_2d(a, b, c, d, e, f, block=block, interpret=interpret)
    unpad = lambda x: x.reshape(-1)[:n].reshape(lo.shape)
    return unpad(olo), unpad(ohi), unpad(opar)


def inject_scrub(
    lo, hi, parity, mlo, mhi, mparity, *, codec: str = "secded72",
    reencode: bool = False, interpret: bool | None = None,
):
    """Fused inject + scrub: one pass over the planes instead of two (three
    with the no-ECC re-encode).

    Returns (faulty_lo, faulty_hi, faulty_parity, counters) where counters is
    an (N_COUNTERS,) int32 device vector ordered like telemetry.COUNTER_FIELDS.
    Zero-padding added by the 2D layout decodes clean with zero flips, so the
    pad count is subtracted from the clean counter before returning.
    """
    interpret = _backend.resolve_interpret(interpret)
    _count_launch()
    (a, b, c, d, e, f), n, block = _to_2d(lo, hi, parity, mlo, mhi, mparity)
    olo, ohi, opar, cnt = _isc.inject_scrub_2d(
        a, b, c, d, e, f, block=block, codec=codec, reencode=reencode,
        interpret=interpret,
    )
    counters = cnt.reshape(-1)[: _isc.N_COUNTERS].at[0].add(n - a.size)
    unpad = lambda x: x.reshape(-1)[:n].reshape(lo.shape)
    return unpad(olo), unpad(ohi), unpad(opar), counters


def inject_scrub_domains(
    lo, hi, parity, mlo, mhi, mparity, domain_ids, n_domains: int, *,
    codec: str = "secded72", reencode: bool = False, interpret: bool | None = None,
):
    """Fused inject + scrub with one counter row per memory domain.

    ``domain_ids``: int32 array shaped like ``lo`` mapping every word to its
    domain index in [0, n_domains). Layout pad words are routed to a spill
    row inside the kernel, so no pad correction is needed. Returns
    (faulty_lo, faulty_hi, faulty_parity, counters (n_domains, N_COUNTERS)).
    """
    interpret = _backend.resolve_interpret(interpret)
    _count_launch()
    (a, b, c, d, e, f), n, block = _to_2d(lo, hi, parity, mlo, mhi, mparity)
    # Pad the domain plane with the spill index (not 0: pad words must not
    # count as domain 0's clean words).
    flat_dom = domain_ids.reshape(-1).astype(jnp.int32)
    pad = a.size - n
    if pad:
        flat_dom = jnp.concatenate(
            [flat_dom, jnp.full((pad,), n_domains, jnp.int32)]
        )
    dom2 = flat_dom.reshape(a.shape)
    olo, ohi, opar, cnt = _isc.inject_scrub_domains_2d(
        a, b, c, d, e, f, dom2, n_domains=n_domains, block=block,
        codec=codec, reencode=reencode, interpret=interpret,
    )
    counters = cnt[:n_domains, : _isc.N_COUNTERS]
    unpad = lambda x: x.reshape(-1)[:n].reshape(lo.shape)
    return unpad(olo), unpad(ohi), unpad(opar), counters


# ---------------------------------------------------------------------------
# ECC-protected weights + fused matmul
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EccWeight:
    """SECDED-encoded int8 weight matrix (K, N) as word planes (K/8, N)."""

    lo: Any  # (K/8, N) uint32
    hi: Any  # (K/8, N) uint32
    parity: Any  # (K/8, N) uint8
    scale: Any  # per-tensor () or per-column (N,) float32
    k: int
    n: int
    fuse: bool = True  # fused Pallas read path vs naive decode-then-matmul

    def tree_flatten(self):
        return (self.lo, self.hi, self.parity, self.scale), (self.k, self.n, self.fuse)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def pack_ecc_weights(w: jnp.ndarray, axis_scale: int | None = 1, fuse: bool = True) -> EccWeight:
    """Quantize a float (K, N) weight to int8 and SECDED-encode it."""
    from repro.core import quantize as q

    k, n = w.shape
    assert k % 8 == 0, f"K={k} must be a multiple of 8 (64-bit codewords)"
    qw, scale = q.quantize(w, axis=axis_scale)
    lo, hi, parity = _ref.pack_ecc_weights_np(np.asarray(qw))
    return EccWeight(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(parity),
        scale.reshape(-1) if axis_scale is not None else scale, k, n, fuse,
    )


def permute_k(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Activation permutation matching the codeword packing (free transpose)."""
    k8 = k // 8
    lead = x.shape[:-1]
    return (
        x.reshape(*lead, 8, k8).swapaxes(-1, -2).reshape(*lead, k)
    )


def ecc_matmul(
    x: jnp.ndarray,
    w: EccWeight,
    *,
    fuse: bool = True,
    block=(128, 512, 256),
    interpret: bool | None = None,
):
    """x @ decode(w) with ECC correction on the read path.

    fuse=True : single-pass Pallas kernel (decode in VMEM, no extra HBM traffic)
    fuse=False: naive baseline — full decode pass materialises corrected int8
                weights to HBM, then a plain matmul re-reads them.
    """
    interpret = _backend.resolve_interpret(interpret)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, w.k)
    if fuse:
        xp = permute_k(x2, w.k)
        m, k8, n = x2.shape[0], w.k // 8, w.n
        # bk8 must divide K/8 exactly: the 8i+j interleave mapping is global,
        # so the K dimension cannot be padded after packing.
        bk8 = block[1] // 8
        while k8 % bk8:
            bk8 //= 2
        # Pad M and N to block multiples (interpret-mode OOB reads are undefined).
        bm = min(block[0], _round_up(m, 8))
        bn = min(block[2], _round_up(n, 128))
        mp, np_ = _round_up(m, bm), _round_up(n, bn)
        xp = jnp.pad(xp, ((0, mp - m), (0, 0)))
        pad_n = ((0, 0), (0, np_ - n))
        _count_launch()
        out = _mm.ecc_matmul_2d(
            xp,
            jnp.pad(w.lo, pad_n), jnp.pad(w.hi, pad_n), jnp.pad(w.parity, pad_n),
            block=(bm, bk8 * 8, bn), interpret=interpret,
        )[:m, :n]
    else:
        lo, hi, _ = decode(w.lo, w.hi, w.parity, interpret=interpret)
        w_i8 = _ref.unpack_ecc_weights(lo, hi)  # materialised (K, N) int8
        out = jnp.dot(x2.astype(jnp.float32), w_i8.astype(jnp.float32))
    out = out * w.scale
    return out.reshape(*lead, w.n)


def scrub(w: EccWeight, *, interpret: bool | None = None):
    """Telemetry pass (memory scrubber): decode all planes, return status."""
    _, _, status = decode(w.lo, w.hi, w.parity, interpret=interpret)
    return status
