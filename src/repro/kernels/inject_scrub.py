"""Pallas TPU kernel: fused undervolt fault injection + ECC scrub (any codec).

The runtime undervolting loop used to pay two full HBM round-trips over every
codeword plane per voltage step — one streaming XOR (``fault_inject``) and one
decode pass (``secded.decode_2d``) whose only consumed output was the per-word
status — plus a third encode pass in the no-ECC baseline. This kernel does all
of it in a single VMEM tile pass (DESIGN.md §9):

  * XOR the flip masks into the (lo, hi, check) planes and write them back
    (the faulty-at-this-voltage view the serving read path consumes),
  * optionally recompute the check bits over the faulty data
    (``reencode=True``, the no-ECC baseline: the decoder becomes a
    syndrome-0 no-op),
  * compute the syndrome and classify every word clean/corrected/detected
    *in registers*, without materialising corrected planes, and
  * reduce the joint (ECC outcome x ground truth) histogram into a single
    (1, 128) int32 counter block accumulated across all grid steps — the only
    telemetry that ever crosses back to the host.

One kernel body serves every registered code (DESIGN.md §12): the codec
supplies ``encode_jnp`` / ``classify_jnp``. SEC-class codes resolve the
syndrome gather-free (the historical SECDED chains, bit-identical); codecs
that correct multi-bit patterns (``exact_tallies``) additionally materialise
the correction in registers so the "corrected" lane counts *genuine*
corrections (delivered data == clean data) rather than the single-flip
approximation that is exact only for SEC codes.

Counter layout (first ``N_COUNTERS`` lanes, rest zero) matches
``telemetry.COUNTER_FIELDS``:
  0 clean (status 0, zero flips)      4 words_1bit
  1 corrected (genuine)               5 words_2bit
  2 detected (DED)                    6 words_multi (>= 3 flips)
  3 silent (faulty, no DED, not corrected)  7 faulty_bits (total flips)

VMEM budget per grid step (default block 256x512, SECDED): 6 input planes
(2x u32 + u8, twice) ~2.25 MiB + 3 output planes ~1.1 MiB + counters
(negligible) ~= 3.4 MiB — comfortably inside a v5e core's 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import codes

_U32 = jnp.uint32

N_COUNTERS = 8
_CNT_LANES = 128  # lane-aligned counter row; only the first N_COUNTERS are used


def _popcount32(v):
    """Per-lane popcount of a uint32 plane -> int32."""
    v = v - ((v >> 1) & _U32(0x55555555))
    v = (v & _U32(0x33333333)) + ((v >> 2) & _U32(0x33333333))
    v = (v + (v >> 4)) & _U32(0x0F0F0F0F)
    return ((v * _U32(0x01010101)) >> 24).astype(jnp.int32)


def outcome_tallies(exact: bool, status, flips, genuine=None):
    """Lanes 0..6 of the counter layout, from per-word ECC status and
    ground-truth flip counts.

    The single definition of the outcome predicates — the fused kernel and
    the scheme-comparison sweep (core/sweep.py) both consume it, so the
    nightly codec table can never silently diverge from the telemetry the
    controller acts on. ``exact`` codecs (multi-bit correctors) supply
    ``genuine``: the plane marking words whose correction restored the
    clean data; SEC codes use the provably-equivalent
    ``status==CORRECTED & flips==1`` predicate instead (any mis-correction
    implies >= 2 flips and lands in the silent lane).
    """
    detected = status == 2
    if exact:
        corrected = genuine
        silent = (flips >= 1) & ~detected & ~corrected
    else:
        corrected = (status == 1) & (flips == 1)
        silent = (flips >= 2) & ~detected
    return (
        (status == 0) & (flips == 0),         # 0: true clean
        corrected,                            # 1: genuinely corrected
        detected,                             # 2: DED flag raised
        silent,                               # 3: silent risk
        flips == 1,                           # 4: ground-truth 1-bit words
        flips == 2,                           # 5: ground-truth 2-bit words
        flips >= 3,                           # 6: ground-truth multi-bit words
    )


def _inject_classify(codec, lo, hi, par, mlo, mhi, mpar, reencode, luts=()):
    """Shared tile body: XOR-inject, (re)encode, classify every word.

    Returns (flo, fhi, fpar, tallies, flips) where tallies are the seven
    boolean planes of the counter layout (lanes 0..6) and flips the per-word
    ground-truth flip count (lane 7 sums it).
    """
    flo = lo ^ mlo
    fhi = hi ^ mhi
    fpar = par ^ mpar
    if reencode:
        # No-ECC baseline: check bits are consistent with the faulty data, so
        # the read-path decoder is a pass-through and faults flow into the
        # matmul.
        fpar = codec.encode_jnp(flo, fhi).astype(par.dtype)

    # Scrub: syndrome + classification (the corrected planes are only
    # materialised — in registers — when the codec needs them for exact
    # genuine-correction accounting; nobody writes them back here).
    synd = codec.encode_jnp(flo, fhi) ^ fpar.astype(_U32)
    exact = codec.exact_tallies
    flip_lo, flip_hi, _, status = codec.classify_jnp(synd, want_flips=exact, luts=luts)
    flips = _popcount32(mlo) + _popcount32(mhi) + _popcount32(mpar.astype(_U32))
    # Genuine correction (exact codecs): the decoder's flip restores the
    # clean data, i.e. equals the injected data-plane mask.
    genuine = (
        (status == 1) & (flip_lo == mlo) & (flip_hi == mhi) if exact else None
    )
    tallies = outcome_tallies(exact, status, flips, genuine)
    return flo, fhi, fpar, tallies, flips


def _counter_row(tallies, flips, sel=None):
    """(1, _CNT_LANES) int32 counter row, optionally masked by ``sel``."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, _CNT_LANES), 1)
    vals = jnp.zeros((1, _CNT_LANES), jnp.int32)
    gate = (lambda t: t & sel) if sel is not None else (lambda t: t)
    for idx, t in enumerate(tallies):
        vals = vals + jnp.where(lane == idx, jnp.sum(gate(t).astype(jnp.int32)), 0)
    gflips = jnp.where(sel, flips, 0) if sel is not None else flips
    return vals + jnp.where(lane == 7, jnp.sum(gflips), 0)


def _accumulate_counters(cnt_ref, vals):
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)

    @pl.when(first)
    def _():
        cnt_ref[...] = vals

    @pl.when(jnp.logical_not(first))
    def _():
        cnt_ref[...] = cnt_ref[...] + vals


def _inject_scrub_kernel(*refs, codec, reencode, n_luts):
    # refs: lo, hi, par, mlo, mhi, mpar, *lut_tables, olo, ohi, opar, cnt
    (lo_ref, hi_ref, par_ref, mlo_ref, mhi_ref, mpar_ref) = refs[:6]
    luts = tuple(r[...] for r in refs[6 : 6 + n_luts])
    olo_ref, ohi_ref, opar_ref, cnt_ref = refs[6 + n_luts :]
    flo, fhi, fpar, tallies, flips = _inject_classify(
        codec, lo_ref[...], hi_ref[...], par_ref[...],
        mlo_ref[...], mhi_ref[...], mpar_ref[...], reencode, luts,
    )
    olo_ref[...] = flo
    ohi_ref[...] = fhi
    opar_ref[...] = fpar
    _accumulate_counters(cnt_ref, _counter_row(tallies, flips))


def _inject_scrub_domains_kernel(*refs, codec, reencode, n_rows, n_luts):
    """Multi-rail variant: one counter row per memory domain.

    The domain plane holds the per-word domain index (int32); row
    ``n_rows - 1`` is the zero-pad spill row the wrapper drops. Domains are
    few (<= 8), so the per-domain masked reductions stay register-resident
    like the global ones.
    """
    # refs: lo, hi, par, mlo, mhi, mpar, dom, *lut_tables, olo, ohi, opar, cnt
    (lo_ref, hi_ref, par_ref, mlo_ref, mhi_ref, mpar_ref, dom_ref) = refs[:7]
    luts = tuple(r[...] for r in refs[7 : 7 + n_luts])
    olo_ref, ohi_ref, opar_ref, cnt_ref = refs[7 + n_luts :]
    flo, fhi, fpar, tallies, flips = _inject_classify(
        codec, lo_ref[...], hi_ref[...], par_ref[...],
        mlo_ref[...], mhi_ref[...], mpar_ref[...], reencode, luts,
    )
    olo_ref[...] = flo
    ohi_ref[...] = fhi
    opar_ref[...] = fpar
    dom = dom_ref[...]
    vals = jnp.concatenate(
        [_counter_row(tallies, flips, sel=dom == d) for d in range(n_rows)], axis=0
    )
    _accumulate_counters(cnt_ref, vals)


def _lut_specs(codec):
    """Full-array BlockSpecs + jnp tensors for the codec's dense LUT inputs."""
    arrays = [jnp.asarray(t) for t in codec.lut_input_arrays()]
    # n=a.ndim binds the rank eagerly — a bare closure over the loop variable
    # would give every index map the *last* array's rank.
    specs = [
        pl.BlockSpec(a.shape, lambda i, j, n=a.ndim: (0,) * n) for a in arrays
    ]
    return specs, arrays


@functools.partial(jax.jit, static_argnames=("block", "codec", "reencode", "interpret"))
def inject_scrub_2d(
    lo, hi, parity, mlo, mhi, mparity, *, block=(256, 512), codec="secded72",
    reencode=False, interpret=False,
):
    """Fused inject + scrub on 2D word planes.

    All planes (R, C); the check planes carry the codec's check dtype.
    Returns (faulty_lo, faulty_hi, faulty_check, counters (1, _CNT_LANES)
    int32) with counters accumulated over the grid.
    """
    c = codes.get(codec)
    bm, bn = block
    grid = (pl.cdiv(lo.shape[0], bm), pl.cdiv(lo.shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    cnt_spec = pl.BlockSpec((1, _CNT_LANES), lambda i, j: (0, 0))
    lut_specs, lut_arrays = _lut_specs(c)
    return pl.pallas_call(
        functools.partial(
            _inject_scrub_kernel, codec=c, reencode=reencode, n_luts=len(lut_arrays)
        ),
        grid=grid,
        in_specs=[spec] * 6 + lut_specs,
        out_specs=[spec, spec, spec, cnt_spec],
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.dtype(c.check_dtype)),
            jax.ShapeDtypeStruct((1, _CNT_LANES), jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, mlo, mhi, mparity, *lut_arrays)


@functools.partial(
    jax.jit, static_argnames=("n_domains", "block", "codec", "reencode", "interpret")
)
def inject_scrub_domains_2d(
    lo, hi, parity, mlo, mhi, mparity, dom, *, n_domains,
    block=(256, 512), codec="secded72", reencode=False, interpret=False,
):
    """Fused inject + scrub with per-domain counter rows.

    ``dom`` is an int32 plane of domain indices in [0, n_domains]; index
    ``n_domains`` is the pad/spill row. Returns (faulty_lo, faulty_hi,
    faulty_check, counters (n_domains + 1, _CNT_LANES) int32).
    """
    c = codes.get(codec)
    n_rows = n_domains + 1
    bm, bn = block
    grid = (pl.cdiv(lo.shape[0], bm), pl.cdiv(lo.shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    cnt_spec = pl.BlockSpec((n_rows, _CNT_LANES), lambda i, j: (0, 0))
    lut_specs, lut_arrays = _lut_specs(c)
    return pl.pallas_call(
        functools.partial(
            _inject_scrub_domains_kernel, codec=c, reencode=reencode,
            n_rows=n_rows, n_luts=len(lut_arrays),
        ),
        grid=grid,
        in_specs=[spec] * 7 + lut_specs,
        out_specs=[spec, spec, spec, cnt_spec],
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.dtype(c.check_dtype)),
            jax.ShapeDtypeStruct((n_rows, _CNT_LANES), jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, mlo, mhi, mparity, dom, *lut_arrays)
