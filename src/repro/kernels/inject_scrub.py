"""Pallas TPU kernel: fused undervolt fault injection + SECDED scrub.

The runtime undervolting loop used to pay two full HBM round-trips over every
codeword plane per voltage step — one streaming XOR (``fault_inject``) and one
decode pass (``secded.decode_2d``) whose only consumed output was the per-word
status — plus a third encode pass in the no-ECC baseline. This kernel does all
of it in a single VMEM tile pass (DESIGN.md §9):

  * XOR the flip masks into the (lo, hi, parity) planes and write them back
    (the faulty-at-this-voltage view the serving read path consumes),
  * optionally recompute parity over the faulty data (``reencode=True``, the
    no-ECC baseline: the decoder becomes a syndrome-0 no-op),
  * compute the SECDED syndrome and classify every word clean/corrected/
    detected *in registers*, without materialising corrected planes,
  * popcount the masks for the ground-truth flip distribution, and
  * reduce the joint (ECC outcome x ground truth) histogram into a single
    (1, 128) int32 counter block accumulated across all grid steps — the only
    telemetry that ever crosses back to the host.

Counter layout (first ``N_COUNTERS`` lanes, rest zero) matches
``telemetry.COUNTER_FIELDS``:
  0 clean (status 0, zero flips)      4 words_1bit
  1 corrected (status 1, one flip)    5 words_2bit
  2 detected (DED)                    6 words_multi (>= 3 flips)
  3 silent (>= 2 flips, no DED)       7 faulty_bits (total flips)

VMEM budget per grid step (default block 256x512): 6 input planes
(2x u32 + u8, twice) ~2.25 MiB + 3 output planes ~1.1 MiB + counters
(negligible) ~= 3.4 MiB — comfortably inside a v5e core's 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hsiao
from repro.kernels.secded import _compute_parity

_U32 = jnp.uint32

N_COUNTERS = 8
_CNT_LANES = 128  # lane-aligned counter row; only the first N_COUNTERS are used


def _popcount32(v):
    """Per-lane popcount of a uint32 plane -> int32."""
    v = v - ((v >> 1) & _U32(0x55555555))
    v = (v & _U32(0x33333333)) + ((v >> 2) & _U32(0x33333333))
    v = (v + (v >> 4)) & _U32(0x0F0F0F0F)
    return ((v * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _inject_classify(lo, hi, par, mlo, mhi, mpar, reencode):
    """Shared tile body: XOR-inject, (re)encode, classify every word.

    Returns (flo, fhi, fpar, tallies, flips) where tallies are the seven
    boolean planes of the counter layout (lanes 0..6) and flips the per-word
    ground-truth flip count (lane 7 sums it).
    """
    flo = lo ^ mlo
    fhi = hi ^ mhi
    fpar = par ^ mpar
    if reencode:
        # No-ECC baseline: parity is consistent with the faulty data, so the
        # read-path decoder is a pass-through and faults flow into the matmul.
        fpar = _compute_parity(flo, fhi).astype(jnp.uint8)

    # Scrub: syndrome + gather-free classification (same chains as decode_2d,
    # minus the corrected-plane construction nobody reads here).
    synd = _compute_parity(flo, fhi) ^ fpar.astype(_U32)
    matched = jnp.zeros_like(flo, dtype=jnp.bool_)
    for d in range(hsiao.N_DATA):
        matched = matched | (synd == _U32(int(hsiao.DATA_COLS[d])))
    for r in range(hsiao.N_PARITY):
        matched = matched | (synd == _U32(1 << r))
    clean = synd == _U32(0)
    status = jnp.where(clean, jnp.int32(0), jnp.where(matched, jnp.int32(1), jnp.int32(2)))

    flips = _popcount32(mlo) + _popcount32(mhi) + _popcount32(mpar.astype(_U32))
    detected = status == 2
    tallies = (
        clean & (flips == 0),                 # 0: true clean
        (status == 1) & (flips == 1),         # 1: genuinely corrected singles
        detected,                             # 2: DED flag raised
        (flips >= 2) & ~detected,             # 3: silent risk
        flips == 1,                           # 4: ground-truth 1-bit words
        flips == 2,                           # 5: ground-truth 2-bit words
        flips >= 3,                           # 6: ground-truth multi-bit words
    )
    return flo, fhi, fpar, tallies, flips


def _counter_row(tallies, flips, sel=None):
    """(1, _CNT_LANES) int32 counter row, optionally masked by ``sel``."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, _CNT_LANES), 1)
    vals = jnp.zeros((1, _CNT_LANES), jnp.int32)
    gate = (lambda t: t & sel) if sel is not None else (lambda t: t)
    for idx, t in enumerate(tallies):
        vals = vals + jnp.where(lane == idx, jnp.sum(gate(t).astype(jnp.int32)), 0)
    gflips = jnp.where(sel, flips, 0) if sel is not None else flips
    return vals + jnp.where(lane == 7, jnp.sum(gflips), 0)


def _accumulate_counters(cnt_ref, vals):
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)

    @pl.when(first)
    def _():
        cnt_ref[...] = vals

    @pl.when(jnp.logical_not(first))
    def _():
        cnt_ref[...] = cnt_ref[...] + vals


def _inject_scrub_kernel(
    lo_ref, hi_ref, par_ref, mlo_ref, mhi_ref, mpar_ref,
    olo_ref, ohi_ref, opar_ref, cnt_ref, *, reencode,
):
    flo, fhi, fpar, tallies, flips = _inject_classify(
        lo_ref[...], hi_ref[...], par_ref[...],
        mlo_ref[...], mhi_ref[...], mpar_ref[...], reencode,
    )
    olo_ref[...] = flo
    ohi_ref[...] = fhi
    opar_ref[...] = fpar
    _accumulate_counters(cnt_ref, _counter_row(tallies, flips))


def _inject_scrub_domains_kernel(
    lo_ref, hi_ref, par_ref, mlo_ref, mhi_ref, mpar_ref, dom_ref,
    olo_ref, ohi_ref, opar_ref, cnt_ref, *, reencode, n_rows,
):
    """Multi-rail variant: one counter row per memory domain.

    ``dom_ref`` holds the per-word domain index (int32); row ``n_rows - 1``
    is the zero-pad spill row the wrapper drops. Domains are few (<= 8), so
    the per-domain masked reductions stay register-resident like the global
    ones.
    """
    flo, fhi, fpar, tallies, flips = _inject_classify(
        lo_ref[...], hi_ref[...], par_ref[...],
        mlo_ref[...], mhi_ref[...], mpar_ref[...], reencode,
    )
    olo_ref[...] = flo
    ohi_ref[...] = fhi
    opar_ref[...] = fpar
    dom = dom_ref[...]
    vals = jnp.concatenate(
        [_counter_row(tallies, flips, sel=dom == d) for d in range(n_rows)], axis=0
    )
    _accumulate_counters(cnt_ref, vals)


@functools.partial(jax.jit, static_argnames=("block", "reencode", "interpret"))
def inject_scrub_2d(
    lo, hi, parity, mlo, mhi, mparity, *, block=(256, 512), reencode=False,
    interpret=False,
):
    """Fused inject + scrub on 2D word planes.

    All planes (R, C). Returns (faulty_lo, faulty_hi, faulty_parity,
    counters (1, _CNT_LANES) int32) with counters accumulated over the grid.
    """
    bm, bn = block
    grid = (pl.cdiv(lo.shape[0], bm), pl.cdiv(lo.shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    cnt_spec = pl.BlockSpec((1, _CNT_LANES), lambda i, j: (0, 0))
    return pl.pallas_call(
        functools.partial(_inject_scrub_kernel, reencode=reencode),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec, spec, spec, cnt_spec],
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint8),
            jax.ShapeDtypeStruct((1, _CNT_LANES), jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, mlo, mhi, mparity)


@functools.partial(
    jax.jit, static_argnames=("n_domains", "block", "reencode", "interpret")
)
def inject_scrub_domains_2d(
    lo, hi, parity, mlo, mhi, mparity, dom, *, n_domains,
    block=(256, 512), reencode=False, interpret=False,
):
    """Fused inject + scrub with per-domain counter rows.

    ``dom`` is an int32 plane of domain indices in [0, n_domains]; index
    ``n_domains`` is the pad/spill row. Returns (faulty_lo, faulty_hi,
    faulty_parity, counters (n_domains + 1, _CNT_LANES) int32).
    """
    n_rows = n_domains + 1
    bm, bn = block
    grid = (pl.cdiv(lo.shape[0], bm), pl.cdiv(lo.shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    cnt_spec = pl.BlockSpec((n_rows, _CNT_LANES), lambda i, j: (0, 0))
    return pl.pallas_call(
        functools.partial(
            _inject_scrub_domains_kernel, reencode=reencode, n_rows=n_rows
        ),
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=[spec, spec, spec, cnt_spec],
        out_shape=(
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint32),
            jax.ShapeDtypeStruct(lo.shape, jnp.uint8),
            jax.ShapeDtypeStruct((n_rows, _CNT_LANES), jnp.int32),
        ),
        interpret=interpret,
    )(lo, hi, parity, mlo, mhi, mparity, dom)
