"""Pallas lowering selection: the compiled lane vs the interpret lane.

Every kernel wrapper in ``kernels/ops.py`` (and the mesh/paged callers that
bake ``interpret`` into a jit cache key) routes its lowering decision through
this module (DESIGN.md §18):

  * ``resolve()`` returns the backend in force — ``"compiled"`` when the
    runtime platform has a Pallas lowering (TPU Mosaic, GPU Triton) that
    passes a one-time compile probe, ``"interpret"`` otherwise.
  * The choice can be forced with the ``REPRO_KERNEL_BACKEND`` environment
    variable (``auto`` | ``compiled`` | ``interpret``) or ``set_backend()``.
    Forcing ``compiled`` on a host whose platform cannot lower Pallas does
    NOT error: the probe fails, the interpret lane engages automatically,
    and ``fallback_engaged()`` reports it — CI asserts exactly this on
    CPU-only runners (kernel-backend-smoke).
  * Per-call ``interpret=False`` requests go through ``resolve_interpret``:
    an explicit compiled request is honored when the probe passes and falls
    back to interpret (recorded) when it cannot, so no call site ever has to
    guard on the platform.

The probe compiles and runs one tiny SECDED encode with ``interpret=False``
and caches the verdict per JAX platform; it is the only place a compiled
lowering is attempted speculatively.
"""

from __future__ import annotations

import os

import jax

VALID = ("auto", "compiled", "interpret")

# Platforms with a real Pallas lowering (Mosaic / Triton). Everything else
# (cpu, plugin backends without Pallas) auto-selects the interpret lane
# without even running the probe.
_COMPILED_PLATFORMS = ("tpu", "gpu", "cuda", "rocm")

_override: list[str | None] = [None]  # set_backend() beats the env var
_probe_cache: dict[str, bool] = {}  # platform -> compiled lowering works
_fallback: dict[str, bool] = {"engaged": False}


def set_backend(mode: str | None) -> None:
    """Force the lane programmatically (tests); ``None`` restores auto."""
    if mode is not None and mode not in VALID:
        raise ValueError(f"backend must be one of {VALID}, got {mode!r}")
    _override[0] = mode
    _fallback["engaged"] = False


def requested() -> str:
    """The requested mode: set_backend() > REPRO_KERNEL_BACKEND > auto."""
    if _override[0] is not None:
        return _override[0]
    mode = os.environ.get("REPRO_KERNEL_BACKEND", "auto").strip().lower()
    return mode if mode in VALID else "auto"


def compiled_available() -> bool:
    """Whether a compiled Pallas lowering works on this platform (cached
    one-time probe; never raises)."""
    platform = jax.default_backend()
    if platform in _probe_cache:
        return _probe_cache[platform]
    ok = False
    if platform in _COMPILED_PLATFORMS:
        try:
            import jax.numpy as jnp

            from repro.kernels import secded as _secded

            z = jnp.zeros((8, 128), jnp.uint32)
            jax.block_until_ready(
                _secded.encode_2d(z, z, block=(8, 128), codec="secded72",
                                  interpret=False)
            )
            ok = True
        except Exception:  # lowering/compile failure -> interpret lane
            ok = False
    _probe_cache[platform] = ok
    return ok


def resolve() -> str:
    """The lane in force: ``"compiled"`` or ``"interpret"``.

    ``auto``: compiled wherever the probe passes. ``compiled``: same, but a
    probe failure records the fallback (CI asserts it engaged on CPU).
    ``interpret``: always the interpret lane, even on TPU/GPU.
    """
    mode = requested()
    if mode == "interpret":
        return "interpret"
    if compiled_available():
        return "compiled"
    if mode == "compiled":
        _fallback["engaged"] = True
    return "interpret"


def use_interpret() -> bool:
    """Backwards-compatible boolean view of ``resolve()``."""
    return resolve() == "interpret"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a per-call ``interpret`` request to a concrete lowering.

    ``None``  -> the lane in force (``resolve()``).
    ``False`` -> explicit compiled request: honored when the platform can
                 lower Pallas, otherwise the interpret fallback engages
                 (recorded via ``fallback_engaged()``) instead of erroring.
    ``True``  -> interpret, always honored.
    """
    if interpret is None:
        return use_interpret()
    if interpret is False and not compiled_available():
        _fallback["engaged"] = True
        return True
    return bool(interpret)


def fallback_engaged() -> bool:
    """True once any compiled request has fallen back to interpret."""
    return _fallback["engaged"]


def reset_fallback() -> None:
    _fallback["engaged"] = False


def tag() -> str:
    """Row tag for benchmarks/profiler: the lane in force."""
    return resolve()
