"""Pallas TPU kernel: fused SECDED-decode + dequant + matmul (the ECC read path).

This is the TPU-native adaptation of the FPGA BRAM hard-core ECC port
(DESIGN.md §2): encoded int8 weights stream HBM->VMEM as (lo, hi, parity)
planes, are syndrome-checked and corrected with VPU bitwise ops *inside* the
tile loop, unpacked to int8, dequantised, and fed straight to the MXU — one
HBM pass, zero extra weight traffic for ECC beyond the 12.5% parity plane.

Weight packing (see ops.pack_ecc_weights): codeword i of column n holds the 8
int8 weights W[j*K/8 + i, n], j=0..7 (j<4 in `lo`, j>=4 in `hi`). The matching
activation permutation x_perm[:, 8i+j] = x[:, j*K/8 + i] is a free
reshape-transpose applied once per call in ops.py; the dot product is
permutation-invariant so outputs are bit-identical to the plain matmul.

Grid: (M/bm, N/bn, K/bk), k innermost, f32 accumulator in VMEM scratch.
VMEM per step (bm=128, bk=512, bn=256): x 256K + planes 148K + w 512K
+ acc 128K ~= 1.1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hsiao

_U32 = jnp.uint32


def _parity32(v):
    v = v ^ (v >> 16)
    v = v ^ (v >> 8)
    v = v ^ (v >> 4)
    v = v ^ (v >> 2)
    v = v ^ (v >> 1)
    return v & _U32(1)


def _decode_planes(lo, hi, stored_parity):
    """Syndrome + single-bit correction (no status plane — fused fast path)."""
    synd = jnp.zeros_like(lo)
    for r in range(hsiao.N_PARITY):
        mlo = _U32(int(hsiao.MASK_LO[r]))
        mhi = _U32(int(hsiao.MASK_HI[r]))
        synd = synd | (_parity32((lo & mlo) ^ (hi & mhi)) << r)
    synd = synd ^ stored_parity.astype(_U32)

    flip_lo = jnp.zeros_like(lo)
    flip_hi = jnp.zeros_like(hi)
    for d in range(hsiao.N_DATA):
        col = _U32(int(hsiao.DATA_COLS[d]))
        m = synd == col
        if d < 32:
            flip_lo = jnp.where(m, flip_lo | _U32(1 << d), flip_lo)
        else:
            flip_hi = jnp.where(m, flip_hi | _U32(1 << (d - 32)), flip_hi)
    return lo ^ flip_lo, hi ^ flip_hi


def _unpack_int8(lo, hi, out_dtype):
    """(bk8, bn) u32 planes -> (bk, bn) weights, rows interleaved 8i+j."""
    planes = []
    for word in (lo, hi):
        for j in range(4):
            b = (word >> _U32(8 * j)) & _U32(0xFF)
            planes.append(b)
    w = jnp.stack(planes, axis=1)  # (bk8, 8, bn); plane order j then lo/hi = byte j
    # reorder: plane index p in [0,8) corresponds to byte j=p%4 of lo (p<4) / hi.
    # byte j of lo = weight row offset j; of hi = offset 4+j -> already in order.
    w = (w.astype(jnp.int32) ^ 128) - 128  # sign-extend int8 stored as raw byte
    bk8, _, bn = w.shape
    return w.reshape(bk8 * 8, bn).astype(out_dtype)


def _matmul_kernel(x_ref, lo_ref, hi_ref, par_ref, out_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = _decode_planes(lo_ref[...], hi_ref[...], par_ref[...])
    w = _unpack_int8(lo, hi, jnp.float32)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ecc_matmul_2d(x, lo, hi, parity, *, block=(128, 512, 256), interpret=False):
    """x: (M, K) [K-permuted], planes: (K/8, N). Returns (M, N) float32."""
    m, kdim = x.shape
    k8, n = lo.shape
    assert kdim == 8 * k8, (x.shape, lo.shape)
    bm, bk, bn = block
    bk8 = bk // 8
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kdim, bk))
    plane_spec = pl.BlockSpec((bk8, bn), lambda i, j, k: (k, j))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            plane_spec,
            plane_spec,
            plane_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, lo, hi, parity)
