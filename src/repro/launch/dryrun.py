import os

# 512 fake hosts by default. An externally-forced device count wins (the CI
# mesh smoke job runs this module with 8 and --mesh host), but unrelated
# pre-set XLA flags are preserved rather than treated as an override — a
# developer's exported tuning flag must not silently drop the mesh to one
# real CPU device.
_FORCE_FLAG = "--xla_force_host_platform_device_count"
if _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in (os.environ.get("XLA_FLAGS", ""), f"{_FORCE_FLAG}=512") if f
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16,16) and/or (2,16,16),
  2. constructs ShapeDtypeStruct stand-ins for every step input (no
     allocation anywhere — params included),
  3. jit-lowers and compiles the step (train_step / prefill_step / serve_step),
  4. records memory_analysis(), cost_analysis(), and collective link bytes
     parsed from the optimized SPMD HLO,
  5. derives the three roofline terms (TPU v5e constants) and appends the
     record to benchmarks/out/dryrun.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import json
import math
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config, input_specs, supported_shapes
from repro.configs.shapes import SHAPES
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.serving import steps as serve_steps
from repro.train import train_step as ts

# TPU v5e roofline constants
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per link (ICI)

FSDP_THRESHOLD = 6e9  # params above this are FSDP-sharded
BF16_OPT_THRESHOLD = 60e9  # params above this use bf16 adam moments

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<sig>[^=]*?)\s*(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_link_bytes(hlo_text: str, n_devices: int = 256) -> dict:
    """Per-device link-byte estimate per collective class, from optimized HLO.

    Ring estimates from output size O and group size G:
      all-reduce 2*O*(G-1)/G | all-gather O*(G-1)/G | reduce-scatter O*(G-1)
      all-to-all O*(G-1)/G   | collective-permute O
    """
    per_op: dict[str, float] = {}
    counts: dict[str, int] = {}
    comment_re = re.compile(r"/\*.*?\*/")
    for line in hlo_text.splitlines():
        # XLA prints /*index=N*/ markers inside long tuple types; the "="
        # inside them breaks the signature capture — strip comments first.
        line = comment_re.sub("", line)
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("sig"))
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        elif "replica_groups={}" in line:
            g = n_devices  # empty group list = ALL devices participate
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if g <= 1:
            continue
        if op == "all-reduce":
            b = 2 * out_bytes * (g - 1) / g
        elif op == "all-gather":
            b = out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            b = out_bytes * (g - 1)
        elif op == "all-to-all":
            b = out_bytes * (g - 1) / g
        else:  # collective-permute
            b = out_bytes
        per_op[op] = per_op.get(op, 0.0) + b
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_per_device": per_op, "counts": counts,
            "total_per_device": sum(per_op.values())}


def model_flops(cfg, shape_name: str) -> float:
    total, active = lm.param_count(cfg)
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        return 6.0 * active * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * active * sh.global_batch * sh.seq_len
    return 2.0 * active * sh.global_batch  # decode: per emitted token


def build_cell(cfg, shape_name: str, mesh, *, fsdp=None, microbatches=1,
               remat="full", opt_dtype=None, sharding_mode="tp_dp",
               ecc_serve=False):
    """Returns (fn, args_struct, in_shardings, donate) for one cell.

    Hillclimb knobs:
      sharding_mode: "tp_dp" (baseline rules) | "fsdp" (pure ZeRO-3, no TP)
      ecc_serve:     serve cells read weights through the SECDED path
                     (naive decode HLO; fused path modeled per kernel_micro)
      microbatches / remat / fsdp / opt_dtype: as named.
    """
    total, _ = lm.param_count(cfg)
    if fsdp is None:
        fsdp = total >= FSDP_THRESHOLD
    if opt_dtype is None:
        opt_dtype = jnp.bfloat16 if total >= BF16_OPT_THRESHOLD else jnp.float32

    if ecc_serve:
        from repro.launch import ecc_struct

        pstruct = ecc_struct.ecc_param_struct(cfg)
        pshard = ecc_struct.ecc_param_shardings(cfg, mesh, fsdp)
    elif sharding_mode in ("fsdp", "zero3"):
        pstruct = lm.param_struct(cfg)
        pshard = shd.param_shardings_fsdp_only(cfg, mesh)
    elif sharding_mode == "dp":
        pstruct = lm.param_struct(cfg)
        pshard = jax.tree_util.tree_map(
            lambda _: shd.replicated(mesh), lm.param_struct(cfg)
        )
    else:
        pstruct = lm.param_struct(cfg)
        pshard = shd.param_shardings(cfg, mesh, fsdp)
    sh = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    if sharding_mode in ("fsdp", "dp"):
        # batch over every mesh axis; replaces the default batch sharders
        def _ds(mesh_, b):
            return shd.data_sharding_all_axes(mesh_, b)
    else:
        _ds = shd.data_sharding

    if sh.kind == "train":
        tcfg = ts.TrainConfig(
            optimizer=adamw.AdamWConfig(state_dtype=opt_dtype),
            microbatches=microbatches,
            remat=remat,
        )
        fn = ts.make_train_step(cfg, tcfg)
        opt_struct = jax.eval_shape(
            lambda p: adamw.init(p, tcfg.optimizer), pstruct
        )
        opt_shard = {
            "m": pshard, "v": pshard, "step": shd.replicated(mesh),
        }
        batch_struct = specs
        batch_shard = jax.tree_util.tree_map(
            lambda leaf: _ds(mesh, leaf.shape[0]), batch_struct
        )
        args = (pstruct, opt_struct, batch_struct)
        shards = (pshard, opt_shard, batch_shard)
        donate = (0, 1)
        return fn, args, shards, donate

    if sh.kind == "prefill":
        fn = serve_steps.make_prefill_step(cfg)
        cache_struct = specs["cache"]
        cache_shard = shd.cache_shardings(cfg, mesh, cache_struct)
        args = [pstruct, specs["tokens"], cache_struct]
        shards = [pshard, _ds(mesh, sh.global_batch), cache_shard]
        if "img" in specs:
            args.append(specs["img"])
            shards.append(_ds(mesh, sh.global_batch))
        return fn, tuple(args), tuple(shards), (2,)

    fn = serve_steps.make_serve_step(cfg)
    cache_struct = specs["cache"]
    cache_shard = shd.cache_shardings(cfg, mesh, cache_struct)
    args = [pstruct, specs["tokens"], cache_struct, specs["pos"]]
    shards = [
        pshard,
        _ds(mesh, sh.global_batch),
        cache_shard,
        shd.replicated(mesh),
    ]
    if "img" in specs:
        args.append(specs["img"])
        shards.append(_ds(mesh, sh.global_batch))
    return fn, tuple(args), tuple(shards), (2,)


def _lower_compile(cfg, shape_name, mesh, overrides):
    fn, args, shards, donate = build_cell(cfg, shape_name, mesh, **overrides)
    with mesh:
        jitted = jax.jit(fn, in_shardings=shards, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def ssm_correction_flops(cfg, shape_name: str) -> float:
    """Analytic FLOPs of the mamba/rwkv inner recurrence scans (global).

    These stay lax.scan (While) even in analysis mode — unrolling 64-step
    recurrences across 63 layers would blow up GSPMD compile time — so their
    trip counts are restored analytically here.
    """
    sh = SHAPES[shape_name]
    b = sh.global_batch
    s = 1 if sh.kind == "decode" else sh.seq_len
    if s == 1:
        return 0.0  # decode path is a single recurrence step, counted by HLO
    mult = 4.0 if sh.kind == "train" else 1.0  # fwd + remat-fwd + ~2x bwd
    total = 0.0
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)["mixer"]
        if kind == "mamba":
            per_layer = 4.0 * b * s * cfg.d_inner * cfg.d_state  # update+cumprod
        elif kind == "rwkv":
            n = cfg.rwkv_head_dim
            per_layer = 6.0 * b * s * cfg.d_model * n  # H*N^2 state ops + cumprod
        else:
            continue
        total += per_layer * cfg.n_groups * mult
    return total


def analytic_memory_bytes(cfg, shape_name: str, mesh, fsdp: bool, opt_bytes_per_param: int) -> dict:
    """Fusion-aware per-device HBM traffic model (bytes per step).

    XLA:CPU barely fuses, so cost_analysis 'bytes accessed' wildly
    overestimates what a TPU (which fuses elementwise chains into the matmul
    pipelines) would move. This model counts the irreducible streams:
    weight shards, optimizer state, gradient traffic, remat boundaries,
    KV-cache reads/writes. Reported alongside the raw HLO number.
    """
    sh = SHAPES[shape_name]
    total, _ = lm.param_count(cfg)
    p_item = jnp.dtype(cfg.param_dtype).itemsize
    model_n = mesh.shape["model"]
    batch_n = math.prod(v for k, v in mesh.shape.items() if k != "model")
    chips = model_n * batch_n

    p_stream = total * p_item / model_n / (batch_n if fsdp else 1)  # local shard
    # weights move through each device once per pass regardless of who owns
    # them (FSDP gathers are collective-term traffic; HBM sees the gathered
    # copy once): per-pass weight bytes = TP-shard size.
    w_pass = total * p_item / model_n / (1 if not fsdp else 1)

    b_local = sh.global_batch / batch_n if sh.global_batch % batch_n == 0 else sh.global_batch
    d = cfg.d_model
    act_item = jnp.dtype(cfg.compute_dtype).itemsize

    if sh.kind == "train":
        bound = cfg.n_groups * b_local * sh.seq_len * d * act_item  # remat carries
        opt = total * opt_bytes_per_param / model_n / (batch_n if fsdp else 1)
        grads = p_stream
        traffic = 3 * w_pass + 4 * opt + 2 * grads + 2 * bound
        traffic += b_local * sh.seq_len * 8  # tokens+labels
    elif sh.kind == "prefill":
        kv_cache = _cache_bytes(cfg, sh, chips)
        bound = cfg.n_groups * b_local * sh.seq_len * d * act_item
        traffic = w_pass + kv_cache + bound
    else:  # decode
        kv_cache = _cache_bytes(cfg, sh, chips)
        traffic = w_pass + kv_cache  # weights once + full cache read
    return {"per_device": float(traffic)}


def _cache_bytes(cfg, sh, chips) -> float:
    """Per-device bytes of the decode cache (sharded over all chips)."""
    act_item = jnp.dtype(cfg.compute_dtype).itemsize
    if cfg.kv_quant:
        # int8 planes + f32 per-(token,head) scales ~= 1 + 8/hd bytes/elem
        act_item = 1.0 + 8.0 / max(cfg.hd, 1)
    s = min(sh.seq_len, cfg.sliding_window) if cfg.sliding_window else sh.seq_len
    total = 0.0
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)["mixer"]
        if kind == "attn":
            total += 2 * sh.global_batch * s * cfg.n_kv_heads * cfg.hd
        elif kind == "cross":
            total += 2 * sh.global_batch * cfg.n_img_tokens * cfg.n_kv_heads * cfg.hd
        elif kind == "mamba":
            total += sh.global_batch * cfg.d_inner * (cfg.d_state + cfg.d_conv - 1)
        elif kind == "rwkv":
            n = cfg.rwkv_head_dim
            total += sh.global_batch * cfg.d_model * (n + 2)
    return total * cfg.n_groups * act_item / chips


def _analysis_counts(cfg, shape_name, mesh, overrides):
    """FLOPs + collective bytes via 1-group/2-group unrolled extrapolation."""
    import dataclasses as _dc

    def counts(groups: int):
        c = _dc.replace(
            cfg, n_layers=cfg.period * groups, scan_unroll=True, flash_chunk=4096
        )
        compiled = _lower_compile(c, shape_name, mesh, overrides)
        ca = compiled.cost_analysis() or {}
        coll = collective_link_bytes(
            compiled.as_text(), n_devices=math.prod(mesh.shape.values())
        )
        return (
            float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total_per_device"]),
            coll["counts"],
        )

    f1, b1, c1, n1 = counts(1)
    f2, b2, c2, n2 = counts(2)
    g = cfg.n_groups
    # Per-group deltas clamped at 0: tiny compiler-noise differences between
    # the 1- and 2-group modules must not extrapolate negative.
    flops = f1 + (g - 1) * max(f2 - f1, 0.0)
    hbytes = b1 + (g - 1) * max(b2 - b1, 0.0)
    cbytes = c1 + (g - 1) * max(c2 - c1, 0.0)
    counts_x = {
        k: n1.get(k, 0) + (g - 1) * max(n2.get(k, 0) - n1.get(k, 0), 0)
        for k in set(n1) | set(n2)
    }
    return flops, hbytes, cbytes, counts_x


def run_cell(arch: str, shape_name: str, multi_pod: bool, kv_quant=False,
             pad_heads: int = 0, label: str | None = None,
             mesh_kind: str = "production", host_model: int = 1,
             smoke: bool = False, memory_only: bool = False,
             **overrides) -> dict:
    """Per cell:
      * memory pass — full depth, scans intact: memory_analysis + the
        compile-success proof (this is what would run on the pod);
      * analysis passes — 1-group and 2-group modules with every outer scan
        unrolled (XLA HloCostAnalysis visits While bodies once), linearly
        extrapolated to full depth; SSM inner-recurrence FLOPs added
        analytically; memory term from a fusion-aware analytic model.
    """
    import dataclasses as _dc

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if kv_quant:
        cfg = _dc.replace(cfg, kv_quant=True)
    if pad_heads:
        # beyond-paper optimization: pad q-heads up to a TP-divisible count
        # (zero-initialised extra heads; +pad/H FLOPs, restores 16-way TP)
        cfg = _dc.replace(cfg, n_heads=pad_heads)
    if mesh_kind == "host":
        # CI mesh smoke: whatever fake host devices the job forced, so the
        # sharding rules and SPMD lowering run on every PR, not just at 512.
        mesh = make_host_mesh(model=host_model)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.shape.values()),
        "chips": chips, "status": "ok",
    }
    if label:
        rec["label"] = label
    # Degraded cells must be distinguishable from (and never cache-block)
    # the full-size, full-analysis record for the same (arch, shape, mesh).
    if smoke:
        rec["smoke"] = True
    if memory_only:
        rec["analysis"] = "memory_only"
    t0 = time.time()
    try:
        compiled = _lower_compile(cfg, shape_name, mesh, overrides)
        ma = compiled.memory_analysis()
        t_mem_pass = time.time() - t0
        if memory_only:
            # Compile-success proof + memory pass only (the CI smoke lane):
            # the roofline analysis passes triple the compile count.
            arg_b = ma.argument_size_in_bytes if ma else 0
            tmp_b = ma.temp_size_in_bytes if ma else 0
            out_b = ma.output_size_in_bytes if ma else 0
            rec.update(
                memory=dict(
                    argument_bytes=arg_b, temp_bytes=tmp_b, output_bytes=out_b,
                    peak_est_gib=(arg_b + tmp_b) / 2**30,
                    fits_16g=(arg_b + tmp_b) < 16 * 2**30,
                ),
                seconds=dict(memory_pass=t_mem_pass, build=time.time() - t0),
            )
            rec.setdefault("seconds", {})["total"] = time.time() - t0
            return rec

        flops_dev, hlo_bytes_dev, coll_dev, coll_counts = _analysis_counts(
            cfg, shape_name, mesh, overrides
        )
        flops_dev += ssm_correction_flops(cfg, shape_name) / chips

        total_p, _ = lm.param_count(cfg)
        fsdp = overrides.get("fsdp") or total_p >= FSDP_THRESHOLD
        opt_b = 8 if total_p < BF16_OPT_THRESHOLD else 4
        mem_model = analytic_memory_bytes(cfg, shape_name, mesh, fsdp, opt_b)
        mf = model_flops(cfg, shape_name)

        t_comp = flops_dev / PEAK_FLOPS
        t_mem = mem_model["per_device"] / HBM_BW
        t_mem_hlo = hlo_bytes_dev / HBM_BW
        t_coll = coll_dev / LINK_BW
        dom = max(
            ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
            key=lambda kv: kv[1],
        )[0]
        arg_b = ma.argument_size_in_bytes if ma else 0
        tmp_b = ma.temp_size_in_bytes if ma else 0
        out_b = ma.output_size_in_bytes if ma else 0
        rec.update(
            flops_per_device=flops_dev,
            hlo_bytes_per_device=hlo_bytes_dev,
            analytic_bytes_per_device=mem_model["per_device"],
            collective_link_bytes_per_device=coll_dev,
            collective_counts=coll_counts,
            model_flops_global=mf,
            useful_flops_ratio=(mf / (flops_dev * chips)) if flops_dev else None,
            t_compute_s=t_comp,
            t_memory_s=t_mem,
            t_memory_hlo_s=t_mem_hlo,
            t_collective_s=t_coll,
            bottleneck=dom,
            roofline_bound_s=max(t_comp, t_mem, t_coll),
            compute_fraction=(t_comp / max(t_comp, t_mem, t_coll, 1e-30)),
            memory=dict(
                argument_bytes=arg_b, temp_bytes=tmp_b, output_bytes=out_b,
                peak_est_gib=(arg_b + tmp_b) / 2**30,
                fits_16g=(arg_b + tmp_b) < 16 * 2**30,
            ),
            seconds=dict(memory_pass=t_mem_pass, build=time.time() - t0),
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec.setdefault("seconds", {})["total"] = time.time() - t0
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--sharding", default="tp_dp",
                    choices=["tp_dp", "fsdp", "zero3", "dp"])
    ap.add_argument("--ecc", action="store_true", help="ECC-protected serve cells")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache")
    ap.add_argument("--pad-heads", type=int, default=0, help="pad q-heads to N")
    ap.add_argument("--label", default=None, help="tag for hillclimb records")
    ap.add_argument("--mesh", default="production", choices=["production", "host"],
                    help="host: mesh over the forced host devices (CI smoke)")
    ap.add_argument("--host-mesh-model", type=int, default=1,
                    help="TP ways of the host mesh (--mesh host)")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-size configs (CI: lowering coverage, not scale)")
    ap.add_argument("--memory-only", action="store_true",
                    help="skip the roofline analysis passes (1 compile per cell)")
    ap.add_argument("--out", default="benchmarks/out/dryrun.json")
    args = ap.parse_args()

    archs = [a for a in ARCHS if a != "paper-nn"] if args.arch == "all" else [args.arch]
    if args.mesh == "host":
        # one host mesh shape regardless of the pod flags — --both-meshes
        # would lower every cell twice under the same record key
        meshes = [False]
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    def key(r):
        return (
            r["arch"], r["shape"], r["mesh"], r.get("label"),
            r.get("smoke", False), r.get("analysis"),
        )

    done = {key(r) for r in results if r.get("status") == "ok"}

    for arch in archs:
        shapes = (
            supported_shapes(arch) if args.shape == "all" else [args.shape]
        )
        for shape in shapes:
            if shape not in supported_shapes(arch):
                print(f"SKIP {arch} x {shape} (not applicable)")
                continue
            for mp in meshes:
                if args.mesh == "host":
                    n = len(jax.devices())
                    mesh_name = (
                        f"{n // args.host_mesh_model}x{args.host_mesh_model}"
                    )
                else:
                    mesh_name = "2x16x16" if mp else "16x16"
                cur = (
                    arch, shape, mesh_name, args.label, args.smoke,
                    "memory_only" if args.memory_only else None,
                )
                if cur in done:
                    print(f"CACHED {arch} x {shape} @ {mesh_name}")
                    continue
                print(f"RUN {arch} x {shape} @ {mesh_name} ...", flush=True)
                rec = run_cell(
                    arch, shape, mp,
                    microbatches=args.microbatches, remat=args.remat,
                    sharding_mode=args.sharding,
                    ecc_serve=args.ecc and SHAPES[shape].kind != "train",
                    kv_quant=args.kv_quant, pad_heads=args.pad_heads,
                    label=args.label, mesh_kind=args.mesh,
                    host_model=args.host_mesh_model, smoke=args.smoke,
                    memory_only=args.memory_only,
                )
                results = [r for r in results if key(r) != key(rec)] + [rec]
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec["status"] == "ok" and "t_compute_s" in rec:
                    print(
                        f"  ok: t_comp={rec['t_compute_s']:.3e}s "
                        f"t_mem={rec['t_memory_s']:.3e}s "
                        f"t_coll={rec['t_collective_s']:.3e}s "
                        f"bottleneck={rec['bottleneck']} "
                        f"mem/chip={rec['memory']['peak_est_gib']:.2f}GiB "
                        f"({rec['seconds']['total']:.0f}s)",
                        flush=True,
                    )
                elif rec["status"] == "ok":
                    print(
                        f"  ok (memory-only): "
                        f"mem/chip={rec['memory']['peak_est_gib']:.2f}GiB "
                        f"({rec['seconds']['total']:.0f}s)",
                        flush=True,
                    )
                else:
                    print(f"  FAIL: {rec['error']}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
