"""Struct-level ECC parameter transform for the dry-run.

Mirrors `serving.engine.protect_params_inline` on ShapeDtypeStructs: selected
weight matrices become `EccWeight` nodes whose planes are ShapeDtypeStructs —
no allocation — so ECC-protected serve cells can be lowered at full scale.
Shardings for the planes derive from the original weight's logical axes:
(K/8, N) inherits (axes_K, axes_N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.distributed import sharding as shd
from repro.kernels.ops import EccWeight
from repro.models import lm
from repro.models.base import Spec


def _protectable(key: str, shape) -> bool:
    # stacked (L, K, N) weight matrices of attention/MLP blocks
    return (
        ("attn" in key or "mlp" in key)
        and len(shape) == 3
        and shape[1] % 8 == 0
        and min(shape[1:]) >= 64
    )


def ecc_param_struct(cfg, *, fuse: bool = False):
    """ShapeDtypeStruct tree with EccWeight nodes replacing protected leaves.

    fuse=False lowers the naive decode-then-matmul HLO (the measurable
    baseline); the fused Pallas path is modeled analytically (kernel_micro).
    """
    specs = lm.init_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, Spec)
    )
    out = []
    for path, s in flat:
        key = jax.tree_util.keystr(path)
        if _protectable(key, s.shape):
            l, k, n = s.shape
            out.append(
                EccWeight(
                    lo=jax.ShapeDtypeStruct((l, k // 8, n), jnp.uint32),
                    hi=jax.ShapeDtypeStruct((l, k // 8, n), jnp.uint32),
                    parity=jax.ShapeDtypeStruct((l, k // 8, n), jnp.uint8),
                    scale=jax.ShapeDtypeStruct((l, n), jnp.float32),
                    k=k, n=n, fuse=fuse,
                )
            )
        else:
            out.append(jax.ShapeDtypeStruct(s.shape, cfg.param_dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def ecc_param_shardings(cfg, mesh, fsdp: bool, *, fuse: bool = False):
    """NamedSharding tree matching ecc_param_struct."""
    specs = lm.init_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, Spec)
    )
    out = []
    for path, s in flat:
        key = jax.tree_util.keystr(path)
        if _protectable(key, s.shape):
            lax_, kax, nax = s.axes  # ("layers", axes_K, axes_N)
            plane_shape = (s.shape[0], s.shape[1] // 8, s.shape[2])
            plane = NamedSharding(
                mesh, shd.spec_for((lax_, kax, nax), plane_shape, mesh, fsdp)
            )
            scale = NamedSharding(
                mesh,
                shd.spec_for((lax_, nax), (s.shape[0], s.shape[2]), mesh, fsdp),
            )
            out.append(
                EccWeight(lo=plane, hi=plane, parity=plane, scale=scale,
                          k=s.shape[1], n=s.shape[2], fuse=fuse)
            )
        else:
            out.append(NamedSharding(mesh, shd.spec_for(s.axes, s.shape, mesh, fsdp)))
    return jax.tree_util.tree_unflatten(treedef, out)
