"""Production meshes.

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) = ("pod", "data", "model") —
the "pod" axis composes with "data" into the batch/FSDP super-axis (DCN-class
links carry only data-parallel collectives, the TPU-pod-topology-aware choice).

Defined as functions so importing this module never touches jax device state
(device count is locked at first jax init; the dry-run forces 512 host
devices *before* any import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh(
        (n // model, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
