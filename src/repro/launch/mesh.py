"""Production meshes.

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) = ("pod", "data", "model") —
the "pod" axis composes with "data" into the batch/FSDP super-axis (DCN-class
links carry only data-parallel collectives, the TPU-pod-topology-aware choice).

Defined as functions so importing this module never touches jax device state
(device count is locked at first jax init; the dry-run forces 512 host
devices *before* any import).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across versions: axis_types only exists on newer jax."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def compat_abstract_mesh(shape, axes):
    """AbstractMesh across versions: older jax takes ((name, size), ...)."""
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return compat_make_mesh((n // model, model), ("data", "model"))


def make_reliability_mesh(n_shards: int | None = None, model: int = 1):
    """Mesh for the sharded reliability layer (DESIGN.md §13).

    ``n_shards`` data-parallel replicas (default: every available device) x
    ``model`` TP ways; the "data" axis is the reliability shard axis — one
    replica = one chip with its own rails and fault population. Unlike
    ``make_host_mesh`` this may use a *subset* of the devices, so a 1-shard
    mesh (the bit-identity anchor) can be built in a forced-8-device
    process alongside the full-width one.
    """
    import numpy as np

    n = len(jax.devices())
    if n_shards is None:
        assert n % model == 0, (n, model)
        n_shards = n // model
    assert n_shards * model <= n, (n_shards, model, n)
    devs = np.array(jax.devices()[: n_shards * model]).reshape(n_shards, model)
    return jax.sharding.Mesh(devs, ("data", "model"))
