"""Trainer fault tolerance, checkpoint ECC, determinism, grad compression."""

import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.launch.mesh import compat_make_mesh
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import FaultInjected, Trainer
from tests.conftest import tiny_cfg

CFG = tiny_cfg(vocab=64)
DC = DataConfig(vocab=64, global_batch=8, seq_len=32)
TC = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100), remat=None)


def test_loss_decreases_and_resume_is_deterministic():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, TC, TokenPipeline(DC), d, ckpt_every=5)
        h = tr.run(12)
        losses = [r["loss"] for r in h if "loss" in r]
        assert losses[-1] < losses[0]
        tr2 = Trainer(CFG, TC, TokenPipeline(DC), d, ckpt_every=5)
        assert tr2.restore() and tr2.step == 10
        h2 = tr2.run(2)
        l2 = [r["loss"] for r in h2 if "loss" in r]
        np.testing.assert_allclose(losses[-2:], l2, rtol=1e-5)


def test_fault_recovery_restores_and_continues():
    with tempfile.TemporaryDirectory() as d:
        armed = {"on": True}

        def chaos(step):
            if step == 7 and armed["on"]:
                armed["on"] = False
                raise FaultInjected("boom")

        tr = Trainer(CFG, TC, TokenPipeline(DC), d, ckpt_every=5, fault_hook=chaos)
        tr.run(10)
        assert tr.recoveries == 1
        assert tr.step == 10
        events = [r for r in tr.history if r.get("event") == "recovery"]
        assert len(events) == 1 and events[0]["step"] == 5  # restored to ckpt 5


def test_straggler_monitor():
    from repro.train.trainer import StragglerMonitor

    mon = StragglerMonitor(factor=3.0, warmup=3)
    for i in range(6):
        mon.observe(i, 0.1)
    assert not mon.events
    assert mon.observe(6, 1.0)  # 10x median
    assert mon.events[0].step == 6


def test_checkpoint_ecc_corrects_single_bit_corruption():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
        ckpt.save(d, 1, tree, ecc_protect=True)
        # flip one bit in the stored leaf
        path = os.path.join(d, "step_000001", "leaf_00000.npy")
        raw = bytearray(open(path, "rb").read())
        raw[-100] ^= 0x04
        open(path, "wb").write(bytes(raw))
        out = ckpt.load(d, 1, tree)
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])  # corrected


def test_checkpoint_ecc_detects_multi_bit_and_falls_back():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.arange(1024, dtype=np.float32)}
        ckpt.save(d, 1, tree, ecc_protect=True)
        tree2 = {"w": np.arange(1024, dtype=np.float32) * 2}
        ckpt.save(d, 2, tree2, ecc_protect=True)
        # corrupt 2 bits in one 64-bit word of step 2
        path = os.path.join(d, "step_000002", "leaf_00000.npy")
        raw = bytearray(open(path, "rb").read())
        raw[-8] ^= 0x03
        open(path, "wb").write(bytes(raw))
        with pytest.raises(ckpt.CheckpointCorruption):
            ckpt.load(d, 2, tree)
        # trainer restore() falls back to step 1
        tr = Trainer(CFG, TC, TokenPipeline(DC), d, ckpt_every=5)
        # build matching checkpoints for trainer state
        ckpt.save(d, 3, tr._state(), ecc_protect=True)
        assert tr.restore()


def test_checkpoint_reshard_on_load():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
        ckpt.save(d, 1, tree)
        mesh = compat_make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        shard = {"w": NamedSharding(mesh, P("data"))}
        out = ckpt.load(d, 1, tree, shardings=shard)
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
        assert out["w"].sharding == shard["w"]


def test_elastic_rescale_keeps_state():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, TC, TokenPipeline(DC), d, ckpt_every=100)
        tr.run(3)
        l3 = tr.history[-1]["loss"]
        mesh = compat_make_mesh((1,), ("data",))
        tr.rescale(mesh)  # re-place on a "new" mesh
        h = tr.run(1)
        assert np.isfinite(h[-1]["loss"]) and h[-1]["loss"] < l3 + 1.0


def test_compressed_dp_step_matches_uncompressed():
    from repro.distributed.collectives import (
        init_error_feedback,
        make_dp_compressed_train_step,
    )
    from repro.models import lm as lm_mod

    mesh = compat_make_mesh((1,), ("data",))
    params = lm_mod.init_params(CFG, jax.random.PRNGKey(0))
    from repro.optim import adamw

    opt = adamw.init(params, TC.optimizer)
    ef = init_error_feedback(params)
    batch = {k: jnp.asarray(v) for k, v in TokenPipeline(DC).batch_at(0).items()}

    step_c = make_dp_compressed_train_step(CFG, TC, mesh, compress=True)
    step_u = make_dp_compressed_train_step(CFG, TC, mesh, compress=False)
    p1, _, ef1, loss_c = step_c(params, opt, ef, batch)
    p2, _, _, loss_u = step_u(params, opt, ef, batch)
    assert float(loss_c) == pytest.approx(float(loss_u), rel=1e-5)
    # int8 compression: params close but not identical; error feedback non-zero
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2))
    )
    assert d < 5e-3
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in jax.tree_util.tree_leaves(ef1))
