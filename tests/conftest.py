import numpy as np
import pytest

import jax

# Tests run on the single real CPU device (the 512-device override is ONLY in
# repro.launch.dryrun, which must be executed as its own process).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_cfg(**kw):
    from repro.models.base import ModelConfig

    base = dict(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
    )
    base.update(kw)
    return ModelConfig(**base)
