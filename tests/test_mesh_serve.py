"""Mesh-sharded serving (DESIGN.md §13): engine-level bit-identity on a
1-device mesh, and the 8-fake-device acceptance path in a subprocess
(forced host-device count is locked at jax init, so multi-device mesh
behaviour cannot run inside the pytest process)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from conftest import tiny_cfg
from repro.launch.mesh import make_reliability_mesh
from repro.models import lm
from repro.serving.engine import ReliabilityConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg(d_model=64, n_layers=2, d_ff=128, vocab=128)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        (rng.integers(1, 100, size=s).astype(np.int32), n)
        for s, n in [(5, 6), (3, 4), (7, 5), (4, 8)]
    ]
    return cfg, params, reqs


def _rel(**kw):
    base = dict(
        mode="inline", multi_rail=True, mask_source="device", voltage=0.60,
        seed=1,
    )
    base.update(kw)
    return ReliabilityConfig(**base)


def test_engine_mesh_1dev_bit_identical(setup):
    """The serve acceptance anchor: a 1-shard mesh engine reproduces the
    unsharded engine exactly — decoded tokens, kv counters, weight-rail
    counters, autotuned schedules, and the power report."""
    cfg, params, reqs = setup
    e1 = ServingEngine(cfg, params, _rel(), max_len=64)
    r1 = e1.serve(reqs, n_lanes=2, scrub_interval=2, kv_voltage=0.57, walk_kv=True)
    e2 = ServingEngine(
        cfg, params, _rel(rail_policy="per_shard"), max_len=64,
        mesh=make_reliability_mesh(1),
    )
    r2 = e2.serve(reqs, n_lanes=2, scrub_interval=2, kv_voltage=0.57, walk_kv=True)

    assert set(r1.outputs) == set(r2.outputs)
    for rid in r1.outputs:
        assert np.array_equal(r1.outputs[rid], r2.outputs[rid]), rid
    assert r1.kv_stats.counters().tolist() == r2.kv_stats.counters().tolist()
    assert r2.shard_of == {rid: 0 for rid in r2.outputs}
    for d in e1.rail_stats.domains:
        assert (
            e1.rail_stats[d].counters().tolist()
            == e2.rail_stats[d].counters().tolist()
        ), d
    # per-shard telemetry rows exist and carry the shard dimension
    assert e2.shard_stats.n_shards == 1
    assert e2.shard_stats[0].shard == 0

    v1, _ = e1.autotune_voltage(max_rounds=8)
    v2, _ = e2.autotune_voltage(max_rounds=8)
    assert v2[0] == v1
    p1, p2 = e1.power_report(), e2.power_report()
    assert p2["n_shards"] == 1 and p2["policy"] == "per_shard"
    assert abs(p1["total_w"] - p2["total_w"]) < 1e-9
    assert abs(p1["saving_vs_nominal"] - p2["saving_vs_nominal"]) < 1e-9


def test_engine_mesh_guards(setup):
    cfg, params, _ = setup
    mesh = make_reliability_mesh(1)
    with pytest.raises(AssertionError):
        ServingEngine(cfg, params, _rel(mask_source="host"), mesh=mesh)
    with pytest.raises(AssertionError):
        ServingEngine(cfg, params, _rel(multi_rail=False), mesh=mesh)
    with pytest.raises(AssertionError):
        ServingEngine(
            cfg, params,
            _rel(rail_policy="per_shard", escalation=("secded72", "dected79")),
            mesh=mesh,
        )
    with pytest.raises(AssertionError):
        ServingEngine(cfg, params, _rel(rail_policy="per_chip"), mesh=mesh)


def test_mesh8_serve_acceptance(tmp_path):
    """ISSUE 5 acceptance: on a forced 8-host-device mesh, serve(walk_kv)
    under per_shard rails completes a mixed-length stream with per-shard DED
    counters differing across shards, and the aggregated power_report lands
    within noise of 8x the 1-device report at equal voltage."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        from conftest import tiny_cfg
        from repro.launch.mesh import make_reliability_mesh
        from repro.models import lm
        from repro.serving.engine import ReliabilityConfig, ServingEngine

        cfg = tiny_cfg(d_model=64, n_layers=2, d_ff=128, vocab=128)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        reqs = [
            (rng.integers(1, 100, size=int(s), dtype=np.int32), int(n))
            for s, n in zip(
                rng.integers(3, 10, size=16), rng.integers(8, 17, size=16)
            )
        ]
        mesh = make_reliability_mesh(8)
        rel = ReliabilityConfig(
            mode="inline", multi_rail=True, mask_source="device", voltage=0.60,
            seed=1, rail_policy="per_shard", controller_start_v=0.60,
        )
        e = ServingEngine(cfg, params, rel, max_len=64, mesh=mesh)
        r = e.serve(reqs, n_lanes=2, scrub_interval=1, walk_kv=True)
        rows = [st.counters().tolist() for st in r.kv_stats_by_shard]

        # equal-voltage power comparison vs the unsharded 1-device engine
        e.set_rails({d: 0.56 for d in e._store.domains})
        e1 = ServingEngine(cfg, params, ReliabilityConfig(
            mode="inline", multi_rail=True, mask_source="device", voltage=0.60,
            seed=1,
        ), max_len=64)
        r1 = e1.serve(reqs, n_lanes=2, scrub_interval=1, kv_voltage=0.56)
        e1.set_rails({d: 0.56 for d in e1._store.domains})
        e1.rails["kv"] = 0.56
        for s in range(8):
            e.rails[s]["kv"] = 0.56
        print(json.dumps({
            "served": sorted(r.outputs),
            "n_requests": len(reqs),
            "detected": [st.detected for st in r.kv_stats_by_shard],
            "shards_tagged": [st.shard for st in r.kv_stats_by_shard],
            "distinct_rows": len({tuple(x) for x in rows}),
            "kv_locks": [s["kv"] for s in e.rails],
            "p8": e.power_report()["total_w"],
            "p1": e1.power_report()["total_w"],
        }))
        """
    )
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here]
    )
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["served"] == list(range(res["n_requests"]))  # stream completed
    assert res["shards_tagged"] == list(range(8))
    # per-shard DED canaries saw different chips: counters differ
    assert len(set(res["detected"])) > 1, res["detected"]
    assert sum(res["detected"]) > 0
    assert res["distinct_rows"] >= 2
    # fleet power at equal voltage == 8x one chip, within noise (per-shard
    # arena padding shifts domain fractions by well under a percent)
    assert res["p8"] == pytest.approx(8 * res["p1"], rel=0.02)


def test_mesh_uniform_policy_shared_walk(setup):
    """Uniform policy on a 1-shard mesh: one schedule, same walk as the
    unsharded controller; rails list still has one entry per shard."""
    cfg, params, reqs = setup
    e = ServingEngine(
        cfg, params, _rel(rail_policy="uniform", controller_start_v=0.62),
        max_len=64, mesh=make_reliability_mesh(1),
    )
    schedules, history = e.autotune_voltage(max_rounds=40)
    assert len(schedules) == 1
    ref = ServingEngine(
        cfg, params, _rel(controller_start_v=0.62), max_len=64
    )
    v_ref, _ = ref.autotune_voltage(max_rounds=40)
    assert schedules[0] == v_ref
    assert all(shard in (-1,) for shard, _ in history)  # shared walk, no shard tag
