"""Accuracy campaign: divergence scorers, harness, and canary rail retreat.

DESIGN.md §15. Three layers:
  * scorer fixtures — hand-computed greedy-match / KL / perplexity values,
    exact-zero invariance on clean-vs-clean;
  * the tiny-config campaign — nominal rows bit-identical, divergence
    monotone as the rail descends, ileave88 holding zero strictly deeper
    than parity65 (the checked-in BENCH_accuracy.json's acceptance shape);
  * the accuracy canary — a rail retreat driven purely by canary divergence
    in a configuration where the DED counters never fire (ecc=False
    re-encodes parity over faulty data, so detection is structurally blind).
"""

import math
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import campaign
from repro.core.controller import (
    EscalationPolicy,
    MultiRailController,
    UndervoltController,
)
from repro.core.sweep import campaign_voltage_grid
from repro.core.telemetry import FaultStats
from repro.core.voltage import PLATFORMS

VC707 = PLATFORMS["vc707"]


# ---------------------------------------------------------------------------
# Scorers: hand-computed fixtures + exact-zero invariance
# ---------------------------------------------------------------------------
def test_greedy_match_len_fixture():
    ref = np.array([[1, 2, 3, 4], [5, 6, 7, 8], [9, 9, 9, 9]])
    test = np.array([[1, 2, 9, 4], [5, 6, 7, 8], [0, 9, 9, 9]])
    assert campaign.greedy_match_len(ref, test).tolist() == [2, 4, 0]
    # a later re-match does not extend the prefix: row 0 scores 2, not 3
    assert campaign.token_divergence(ref, test) == pytest.approx(
        1.0 - (2 + 4 + 0) / 3 / 4
    )


def test_token_divergence_exact_zero_on_identity():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 256, size=(4, 24))
    assert campaign.token_divergence(toks, toks.copy()) == 0.0  # exact


def test_logit_kl_fixture():
    # ref uniform over 2 classes, test = softmax([ln 3, 0]) = (3/4, 1/4):
    # KL = 0.5 ln(0.5/0.75) + 0.5 ln(0.5/0.25) = 0.5 ln(4/3)
    ref = np.zeros((1, 1, 2))
    test = np.array([[[math.log(3.0), 0.0]]])
    assert campaign.logit_kl(ref, test) == pytest.approx(
        0.5 * math.log(4.0 / 3.0), rel=1e-12
    )
    assert campaign.logit_kl(ref, ref.copy()) == 0.0  # exact
    # shift-invariance of the softmax: adding a constant changes nothing
    assert campaign.logit_kl(ref, ref + 7.0) == pytest.approx(0.0, abs=1e-12)


def test_perplexity_fixture():
    # uniform logits over V classes: NLL = ln V, perplexity = V
    v = 16
    logits = np.zeros((2, 3, v))
    tokens = np.arange(6).reshape(2, 3)
    assert campaign.token_nll(logits, tokens) == pytest.approx(math.log(v))
    assert campaign.perplexity(logits, tokens) == pytest.approx(float(v))


def test_label_divergence_fixture():
    assert campaign.label_divergence(
        np.array([1, 2, 3, 4]), np.array([1, 2, 0, 4])
    ) == 0.25
    assert campaign.label_divergence(np.array([1, 2]), np.array([1, 2])) == 0.0


def test_score_clean_vs_clean_is_exactly_zero():
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 32, size=(2, 8))
    logits = rng.normal(size=(2, 8, 32))
    rep = campaign.score(toks, toks.copy(), logits, logits.copy(), toks)
    assert rep.divergence == 0.0
    assert rep.kl == 0.0
    assert rep.ppl_delta == 0.0
    assert rep.match_frac == 1.0
    assert rep.scorer_version == campaign.SCORER_VERSION


def test_eval_prompts_deterministic():
    a = campaign.eval_prompts(256, 4, 8, seed=3)
    b = campaign.eval_prompts(256, 4, 8, seed=3)
    assert a.shape == (4, 8) and a.dtype == np.int32
    assert (a == b).all()
    assert a.min() >= 0 and a.max() < 256
    assert not (a == campaign.eval_prompts(256, 4, 8, seed=4)).all()


def test_campaign_voltage_grid_vc707():
    grid = campaign_voltage_grid(VC707)
    assert grid == (1.0, 0.61, 0.59, 0.57, 0.55, 0.54)
    assert grid == tuple(sorted(grid, reverse=True))
    assert min(grid) == VC707.v_crash  # never below the crash rail


def test_campaign_model_names():
    tiny = campaign.campaign_model("tiny")
    assert tiny.name == "tiny"
    smoke = campaign.campaign_model("qwen2-7b-smoke")
    assert tiny.vocab == smoke.vocab and tiny.n_layers == smoke.n_layers


# ---------------------------------------------------------------------------
# Controller: divergence SLO as a trip signal
# ---------------------------------------------------------------------------
def test_acc_trip_retreats_with_zero_ded():
    """The canary acceptance property at the controller level: a rail backs
    off on divergence alone — every counter the DED canary watches is 0.
    (start_v warm starts clamp to the fault-free guardband edge v_min.)"""
    c = UndervoltController(VC707, start_v=VC707.v_min, divergence_slo=0.05)
    clean = FaultStats(words=1000)
    assert c.update(clean, divergence=0.0) == pytest.approx(VC707.v_min - 0.01)
    v = c.update(clean, divergence=0.4)  # counters still silent
    assert c.locked and v == pytest.approx(VC707.v_min)
    assert [h.action for h in c.history] == ["lower", "acc+backoff"]
    assert all(h.detected == 0 for h in c.history)
    assert c.history[-1].divergence == pytest.approx(0.4)


def test_divergence_ignored_without_slo():
    c = UndervoltController(VC707, start_v=0.58)
    c.update(FaultStats(words=1000), divergence=0.9)
    assert not c.locked
    assert c.history[-1].action == "lower"
    assert c.history[-1].divergence == pytest.approx(0.9)  # recorded anyway


def test_acc_trip_escalates_codec_before_retreating():
    """With ladder steps left, an SLO violation steps the code up (voltage
    holds); once exhausted, the next violation retreats — the policy trades
    check-bit overhead against the divergence SLO."""
    c = UndervoltController(
        VC707, start_v=0.57, divergence_slo=0.1,
        escalation=EscalationPolicy(ladder=("secded72", "dected79")),
    )
    v0 = c.voltage
    c.update(FaultStats(words=1000), divergence=0.5)
    assert c.history[-1].action == "escalate"
    assert c.codec == "dected79" and c.pop_codec_change() == "dected79"
    assert not c.locked and c.voltage == pytest.approx(v0)
    c.update(FaultStats(words=1000), divergence=0.5)  # ladder exhausted
    assert c.history[-1].action == "acc+backoff" and c.locked


def test_multirail_broadcasts_scalar_divergence():
    """Canary divergence is whole-model: a scalar retreats every rail; a
    {domain: score} dict trips only the attributed rails."""
    stats = {"attn": FaultStats(words=100), "mlp": FaultStats(words=100)}
    m = MultiRailController(VC707, ("attn", "mlp"), divergence_slo=0.1)
    m.update(stats, divergence=0.5)
    assert all(
        c.locked and c.history[-1].action == "acc+backoff"
        for c in m.rails.values()
    )
    m2 = MultiRailController(VC707, ("attn", "mlp"), divergence_slo=0.1)
    m2.update(stats, divergence={"mlp": 0.5})
    assert m2.rails["mlp"].locked and not m2.rails["attn"].locked


# ---------------------------------------------------------------------------
# The tiny-config campaign (module-scoped: one compile set for the file)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def campaign_rows():
    spec = campaign.CampaignSpec(
        codecs=("parity65", "ileave88"),
        voltages=(1.0, 0.57, 0.55, 0.54),
        n_prompts=2,
        n_tokens=12,
        proxy_words=0,
    )
    return campaign.run_campaign(spec)


def test_campaign_nominal_rows_exactly_clean(campaign_rows):
    nominal = [r for r in campaign_rows if r["nominal"]]
    assert nominal, "grid must include the nominal anchor"
    for r in nominal:
        assert r["divergence"] == 0.0 and r["kl"] == 0.0
        assert r["ppl_delta"] == 0.0 and r["faulty_words"] == 0


def test_campaign_divergence_monotone_under_fault_rate(campaign_rows):
    """Monotonicity under increasing injected fault rate: descending the
    rail strictly grows the injected damage (faulty_words, deterministic in
    the seed), and the zero-divergence region is a contiguous prefix from
    nominal — once a codec's output diverges it never recovers to exactly
    zero at a deeper point. (The raw prefix-length score itself saturates
    noisily once rollouts fully diverge, so point-wise ordering below the
    first divergence is not a property; the zero/nonzero boundary is.)"""
    for codec in ("parity65", "ileave88"):
        by_v = sorted(
            (r["voltage"], r["divergence"], r["faulty_words"])
            for r in campaign_rows
            if r["codec"] == codec
        )
        faults = [f for _, _, f in by_v]  # ascending voltage: deep -> nominal
        assert faults == sorted(faults, reverse=True), (codec, by_v)
        assert faults[0] > 0  # the deep end injects real damage
        first_zero = next(
            i for i, (_, d, _) in enumerate(by_v) if d == 0.0
        )
        assert all(d == 0.0 for _, d, _ in by_v[first_zero:]), (codec, by_v)
    deep_parity = [
        r for r in campaign_rows
        if r["codec"] == "parity65" and r["voltage"] == 0.54
    ][0]
    assert deep_parity["divergence"] > 0.0


def test_campaign_ileave88_holds_deeper_than_parity65(campaign_rows):
    """The paper-shaped codec ordering BENCH_accuracy.json is gated on:
    at 0.55 V the 4-way interleaved code still matches the clean rollout
    bit-for-bit while the detect-only code has already diverged."""
    at = {
        (r["codec"], r["voltage"]): r["divergence"] for r in campaign_rows
    }
    assert at[("ileave88", 0.55)] == 0.0
    assert at[("parity65", 0.55)] > 0.0

    def floor(codec):
        zero = [
            r["voltage"] for r in campaign_rows
            if r["codec"] == codec and r["divergence"] == 0.0
        ]
        return min(zero)

    assert floor("ileave88") < floor("parity65")


def test_campaign_rows_carry_contract_columns(campaign_rows):
    r = campaign_rows[0]
    for col in (
        "model", "arch", "platform", "codec", "voltage", "nominal",
        "divergence", "match_len", "kl", "ppl_delta", "scorer_version",
        "detected", "faulty_words", "bram_saving_vs_nominal", "seed",
    ):
        assert col in r, col
    assert r["scorer_version"] == campaign.SCORER_VERSION


# ---------------------------------------------------------------------------
# Accuracy canary in the serving engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_setup():
    import jax

    from repro.models import lm

    cfg = campaign.campaign_model("tiny")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **rel_kw):
    from repro.serving.engine import ReliabilityConfig, ServingEngine

    rel = ReliabilityConfig(platform="vc707", mode="inline", **rel_kw)
    return ServingEngine(cfg, params, rel=rel, max_len=32)


def test_canary_divergence_disabled_and_clean(tiny_setup):
    cfg, params = tiny_setup
    eng = _engine(cfg, params)
    assert eng.canary_divergence() is None  # off by default
    eng2 = _engine(cfg, params, canary_prompts=2, canary_tokens=8)
    assert eng2.canary_divergence() == 0.0  # nominal == clean, exactly


def test_canary_retreat_where_ded_counters_are_blind(tiny_setup):
    """THE acceptance scenario: with ecc=False the inject path re-encodes
    parity over the faulty planes, so scrub syndromes are structurally
    clean — detected stays 0 at any depth and the DED canary can never
    trip. The accuracy canary still sees the corrupted outputs and
    retreats the rail."""
    cfg, params = tiny_setup
    # control: DED-only walk from the guardband edge never retreats — it
    # descends straight to the crash floor
    ctl = _engine(cfg, params, ecc=False, controller_start_v=VC707.v_min)
    v_ctl, hist_ctl = ctl.autotune_voltage(max_rounds=12)
    assert all(h.detected == 0 for h in hist_ctl)
    assert not any("backoff" in h.action for h in hist_ctl)
    # locked at the crash floor (within one fp-accumulated 0.01 step)
    assert hist_ctl[-1].action == "floor"
    assert v_ctl < VC707.v_crash + 0.015

    # canary: same blind counters, but the divergence SLO trips the rail
    eng = _engine(
        cfg, params, ecc=False, controller_start_v=VC707.v_min,
        canary_prompts=2, canary_tokens=8, divergence_slo=0.05,
    )
    v, hist = eng.autotune_voltage(max_rounds=12)
    assert all(h.detected == 0 for h in hist)  # DED never fired
    assert any(h.action == "acc+backoff" for h in hist)
    assert eng.controller.locked
    assert v > v_ctl + 1e-9  # retreated strictly above the control's floor
    assert hist[-1].divergence > 0.05


def test_canary_multirail_retreats_all_rails(tiny_setup):
    """Multi-rail engines broadcast the whole-model canary score: every
    arena rail backs off on the SLO violation, counters silent."""
    cfg, params = tiny_setup
    eng = _engine(
        cfg, params, ecc=False, multi_rail=True,
        controller_start_v=VC707.v_min, canary_prompts=2, canary_tokens=8,
        divergence_slo=0.05,
    )
    eng.autotune_voltage(max_rounds=12)
    tripped = [
        d for d, c in eng.controller.rails.items()
        if any(h.action == "acc+backoff" for h in c.history)
    ]
    assert set(tripped) == set(eng._store.domains)
    assert all(
        h.detected == 0 for c in eng.controller.rails.values()
        for h in c.history
    )
