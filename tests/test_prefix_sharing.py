"""Prefix-sharing copy-on-write KV pages + speculative decode (DESIGN.md §16).

The invariants driven here are the ones the refcounted allocator and the
prefix trie assert internally:

  * no page is ever freed (recycled) while it still has readers,
  * copy-on-write never mutates a shared page — codec escalation *refuses*
    to re-encode a shared page with a latched DED,
  * trie lookup returns exactly the longest cached full-page prefix,
  * preemption-recompute under sharing reproduces the private-serve tokens,

plus the two end-to-end acceptance properties: a shared-prefix serve is
bit-identical to the private serve at nominal voltage, and speculative
decode emits exactly the greedy rollout no matter how bad the draft is.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.core import voltage as vmod
from repro.core.kvpages import (
    KVGeometry,
    KVPageArena,
    PageAllocator,
    PrefixTrie,
    SharedPageDEDError,
    dedup_page_table,
)
from repro.models import lm
from repro.serving import (
    CanaryConfig,
    FaultModelConfig,
    ProtectionConfig,
    RailsConfig,
    ReliabilityConfig,
    ReliabilityConfigError,
    ServingEngine,
)
import repro.serving.engine as engine_mod


# ---------------------------------------------------------------------------
# refcounted allocator
# ---------------------------------------------------------------------------


def test_allocator_share_free_refcounts():
    alloc = PageAllocator(3)
    p = alloc.alloc("a")
    alloc.share(p, "b")
    assert alloc.refcount(p) == 2 and alloc.is_shared(p)
    assert alloc.shared_pages() == [p]
    assert alloc.owner_of(p) == frozenset({"a", "b"})
    alloc.free([p], "a")
    # surviving reader keeps the page live: not dirty, not recyclable
    assert alloc.refcount(p) == 1 and alloc.dirty_pages == 0
    assert alloc.owner_of(p) == "b"
    alloc.free([p], "b")
    assert alloc.dirty_pages == 1 and alloc.refcount(p) == 0
    with pytest.raises(AssertionError):
        alloc.share(p, "c")  # share of an unallocated page
    q = alloc.alloc("a")
    alloc.share(q, "b")
    with pytest.raises(AssertionError):
        alloc.share(q, "b")  # double reference by the same owner
    with pytest.raises(AssertionError):
        alloc.free([q], "c")  # foreign free


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000))
def test_allocator_no_page_recycled_with_readers(seed):
    """Model check vs a reference refcount map: under random alloc / share /
    free traffic a page reaches the dirty list exactly when its last
    reference drops, and never before."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(8)
    refs: dict[int, set] = {}
    owners = ["r%d" % i for i in range(5)]
    for _ in range(120):
        op = rng.integers(0, 3)
        if op == 0:
            who = owners[rng.integers(0, len(owners))]
            page = alloc.alloc(who)
            if page is None:
                alloc.recycle()
                continue
            assert page not in refs, "allocator handed out a live page"
            refs[page] = {who}
        elif op == 1 and refs:
            page = list(refs)[rng.integers(0, len(refs))]
            candidates = [o for o in owners if o not in refs[page]]
            if not candidates:
                continue
            who = candidates[rng.integers(0, len(candidates))]
            alloc.share(page, who)
            refs[page].add(who)
        elif op == 2 and refs:
            page = list(refs)[rng.integers(0, len(refs))]
            who = list(refs[page])[rng.integers(0, len(refs[page]))]
            before_dirty = alloc.dirty_pages
            alloc.free([page], who)
            refs[page].discard(who)
            if refs[page]:
                # freed with surviving readers: must NOT have gone dirty
                assert alloc.dirty_pages == before_dirty
                assert alloc.refcount(page) == len(refs[page])
            else:
                assert alloc.dirty_pages == before_dirty + 1
                del refs[page]
    for page, expect in refs.items():
        assert alloc.refcount(page) == len(expect)
    assert alloc.used_pages == len(refs)


# ---------------------------------------------------------------------------
# prefix trie
# ---------------------------------------------------------------------------


def _trie(pt=4, n_pages=16):
    alloc = PageAllocator(n_pages)
    return PrefixTrie(alloc, pt), alloc


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 500),
    pt=st.sampled_from([2, 4]),
    n_common=st.integers(0, 12),
    n_tail=st.integers(1, 8),
)
def test_trie_lookup_is_longest_common_fullpage_prefix(seed, pt, n_common, n_tail):
    """Insert one sequence, look up a probe sharing exactly ``n_common``
    leading tokens: the hit must cover min(n_common, len(probe)-1) // pt
    pages — the longest *full-page* common prefix, never more, capped so at
    least one probe token is left to prefill."""
    rng = np.random.default_rng(seed)
    trie, alloc = _trie(pt, n_pages=32)
    base = rng.integers(0, 97, size=6 * pt).astype(np.int32)
    pages = [alloc.alloc("writer") for _ in range(6)]
    trie.insert(base, pages)
    probe = np.concatenate(
        [base[:n_common], 100 + rng.integers(0, 50, size=n_tail).astype(np.int32)]
    )
    hit = trie.lookup(probe)
    want = min(n_common, len(probe) - 1) // pt if len(probe) >= 2 else 0
    assert hit == pages[:want]
    # every hit page gained no reference from lookup alone
    for p in pages:
        assert alloc.refcount(p) == 2  # writer + trie


def test_trie_insert_shares_and_drain_releases():
    trie, alloc = _trie(pt=2, n_pages=8)
    toks = np.arange(6, dtype=np.int32)
    pages = [alloc.alloc("w") for _ in range(3)]
    trie.insert(toks, pages)
    assert len(trie) == 3 and trie.pages() == sorted(pages)
    for p in pages:
        assert alloc.is_shared(p)
    # the writer retires; the trie reference keeps every page live
    alloc.free(pages, "w")
    assert alloc.dirty_pages == 0
    # re-inserting the same prefix only stamps (no double reference)
    trie.insert(toks, pages)
    assert all(alloc.refcount(p) == 1 for p in pages)
    assert trie.drain() == pages
    assert alloc.dirty_pages == 3 and len(trie) == 0


def test_trie_evict_lru_skips_shared_leaves():
    trie, alloc = _trie(pt=2, n_pages=8)
    a = np.asarray([1, 2, 3, 4], np.int32)
    b = np.asarray([1, 2, 9, 9], np.int32)
    pa = [alloc.alloc("wa") for _ in range(2)]
    trie.insert(a, pa)
    pb_tail = alloc.alloc("wb")
    trie.insert(b, [pa[0], pb_tail])
    alloc.free([pa[1]], "wa")  # leaf [3,4] now sole-referenced by the trie
    # leaf [9,9] still has its writer attached: eviction must skip it, and
    # the shared interior node [1,2] is not a leaf at all
    freed = trie.evict_lru(3)
    assert freed == [pa[1]]
    assert sorted(trie.pages()) == sorted([pa[0], pb_tail])


# ---------------------------------------------------------------------------
# copy-on-write: codec escalation refuses shared pages with latched DED
# ---------------------------------------------------------------------------


def _committed_arena(page_tokens=2, n_pages=3):
    cfg = get_smoke_config("qwen3-0.6b")
    geom = KVGeometry.from_config(cfg, page_tokens)
    arena = KVPageArena(geom, vmod.PLATFORMS["vc707"], n_pages)
    rng = np.random.default_rng(0)
    n_tok = geom.page_tokens * n_pages
    payload = rng.standard_normal((n_tok, geom.token_f32)).astype(np.float32)
    pages = np.repeat(np.arange(n_pages), geom.page_tokens)
    slots = np.tile(np.arange(geom.page_tokens), n_pages)
    arena.commit_tokens(payload, pages, slots)
    return arena, geom


def test_change_codec_refuses_shared_page_with_latched_ded():
    """Regression for the correlated-failure hazard: re-encoding a shared
    page with an uncorrectable word would seal the corruption as clean data
    for every reader. The change must refuse (arena untouched), name the
    offending pages, and succeed once the shared set shrinks."""
    arena, geom = _committed_arena()
    w = geom.words_per_page
    # double-bit (uncorrectable) fault in page 1; page 0 stays clean
    arena.hi = arena.hi.at[w + 3].set(arena.hi[w + 3] ^ np.uint32(0b11))
    with pytest.raises(SharedPageDEDError) as ei:
        arena.change_codec("ileave88", shared_pages=[0, 1])
    assert ei.value.pages == (1,) and ei.value.codec == "ileave88"
    assert arena.codec_name == "secded72"  # untouched
    # the DED is still latched (visible), not sealed
    _, cnt = arena.scrub_pages([1])
    assert cnt[0, 2] == 1
    # once page 1 is no longer shared (evicted + readers preempted), the
    # sweep proceeds: the clean shared page re-encodes fine
    arena.change_codec("ileave88", shared_pages=[0])
    assert arena.codec_name == "ileave88"
    _, cnt = arena.scrub_pages([0])
    assert cnt[0, 1] == 0 and cnt[0, 2] == 0


def test_change_codec_clean_shared_pages_pass():
    arena, _ = _committed_arena()
    arena.change_codec("dected79", shared_pages=[0, 1, 2])
    assert arena.codec_name == "dected79"
    _, cnt = arena.scrub_pages(np.arange(arena.n_pages))
    assert cnt[:, 1].sum() == 0 and cnt[:, 2].sum() == 0


# ---------------------------------------------------------------------------
# dedup_page_table
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 500), m=st.integers(1, 5), k=st.integers(1, 6))
def test_dedup_page_table_roundtrip(seed, m, k):
    rng = np.random.default_rng(seed)
    scratch = 64
    table = rng.integers(0, 12, size=(m, k)).astype(np.int32)
    table[rng.random(table.shape) < 0.3] = scratch
    upad, rows, n_u = dedup_page_table(table, scratch)
    # every non-scratch entry maps back to itself; scratch maps to the pad
    got = upad[rows.reshape(-1)].reshape(table.shape)
    np.testing.assert_array_equal(got, table)
    assert n_u == len(np.unique(table[table != scratch]))
    assert (upad[n_u:] == scratch).all()
    # pow2-padded, and a scratch slot exists whenever the table needs one
    assert len(upad) & (len(upad) - 1) == 0
    if (table == scratch).any():
        assert (upad[rows.reshape(-1)[table.reshape(-1) == scratch]] == scratch).all()


# ---------------------------------------------------------------------------
# end-to-end: shared serve bit-identity, preemption-recompute, speculative
# ---------------------------------------------------------------------------

_STATE = {}


def _shared_state():
    if not _STATE:
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prefix = rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32)
        reqs = [
            (
                np.concatenate(
                    [prefix, rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)]
                ),
                6,
            )
            for _ in range(6)
        ]
        eng = ServingEngine(cfg, params, rel=None, max_len=48)
        _STATE["v"] = (cfg, params, reqs, eng)
    return _STATE["v"]


def test_shared_serve_bit_identical_to_private_at_nominal():
    """The §16 acceptance property: with a shared-heavy stream the trie path
    must change *nothing* observable at nominal voltage — same tokens, same
    (zero) fault counters, same kv rail trajectory — while actually hitting
    the trie and returning every page to the pool."""
    cfg, params, reqs, eng = _shared_state()
    private = eng.serve(reqs, n_lanes=2, scrub_interval=2)
    shared = eng.serve(reqs, n_lanes=2, scrub_interval=2, share_prefix=True)
    assert sorted(shared.outputs) == sorted(private.outputs)
    for rid in private.outputs:
        np.testing.assert_array_equal(shared.outputs[rid], private.outputs[rid])
    # the trie was actually exercised: 2 full pages (16 tokens) per later
    # admission; the first 2 lanes prefill privately
    assert shared.prefix_hit_tokens == 16 * (len(reqs) - 2)
    assert private.prefix_hit_tokens == 0
    # nominal voltage: scrubs run and observe zero faults on both paths
    assert shared.kv_stats.corrected == 0 and shared.kv_stats.detected == 0
    assert private.kv_stats.corrected == 0 and private.kv_stats.detected == 0
    assert shared.kv_stats.words > 0
    assert shared.kv_voltages == private.kv_voltages
    # teardown drained the trie: no page leaked behind a cached prefix
    assert shared.pages_free_at_end == shared.arena.n_pages


@settings(max_examples=6, deadline=None)
@given(n_pages_extra=st.integers(0, 3), seed=st.integers(0, 3))
def test_preemption_recompute_under_sharing(n_pages_extra, seed):
    """Page pressure with the trie on: cached prefixes yield (LRU eviction),
    the youngest reader preempts and recomputes — and the emitted tokens
    still match the roomy private serve exactly."""
    cfg, params, base_reqs, eng = _shared_state()
    rng = np.random.default_rng(seed)
    reqs = [base_reqs[i] for i in rng.permutation(len(base_reqs))]
    pt = 8
    geom = KVGeometry.from_config(cfg, pt)
    longest = max(geom.pages_for(len(p) + n) for p, n in reqs)
    tight = eng.serve(
        reqs,
        n_lanes=2,
        page_tokens=pt,
        n_pages=longest + n_pages_extra,
        scrub_interval=2,
        share_prefix=True,
    )
    roomy = eng.serve(reqs, n_lanes=2, page_tokens=pt, scrub_interval=2)
    for rid, toks in roomy.outputs.items():
        np.testing.assert_array_equal(tight.outputs[rid], toks)
    assert tight.pages_free_at_end == tight.arena.n_pages


def test_speculative_emits_exactly_greedy_rollout():
    """Accepted-prefix property: with the target as its own draft every
    block fully accepts; with a garbage draft almost nothing does — either
    way the emitted stream is exactly the plain greedy serve."""
    cfg, params, reqs, eng = _shared_state()
    plain = eng.serve(reqs, n_lanes=2, scrub_interval=2)
    good = eng.serve(
        reqs, n_lanes=2, scrub_interval=2,
        speculative=4, draft_params=params, draft_cfg=cfg,
    )
    bad_params = lm.init_params(cfg, jax.random.PRNGKey(7))
    bad = eng.serve(
        reqs, n_lanes=2, scrub_interval=2,
        speculative=4, draft_params=bad_params, draft_cfg=cfg,
    )
    for rid in plain.outputs:
        np.testing.assert_array_equal(good.outputs[rid], plain.outputs[rid])
        np.testing.assert_array_equal(bad.outputs[rid], plain.outputs[rid])
    assert good.spec_dispatches > 0 and bad.spec_dispatches > 0
    # a perfect draft accepts more per dispatch than a garbage one, and
    # strictly more than the 1 token/dispatch a rejected block falls back to
    assert good.spec_emitted / good.spec_dispatches > 2.0
    assert (
        good.spec_emitted / good.spec_dispatches
        >= bad.spec_emitted / bad.spec_dispatches
    )


def test_speculative_composes_with_prefix_sharing():
    cfg, params, reqs, eng = _shared_state()
    plain = eng.serve(reqs, n_lanes=2, scrub_interval=2)
    spec = eng.serve(
        reqs, n_lanes=2, scrub_interval=2, share_prefix=True,
        speculative=3, draft_params=params, draft_cfg=cfg,
    )
    for rid in plain.outputs:
        np.testing.assert_array_equal(spec.outputs[rid], plain.outputs[rid])
    assert spec.prefix_hit_tokens > 0 and spec.spec_dispatches > 0
    assert spec.pages_free_at_end == spec.arena.n_pages


# ---------------------------------------------------------------------------
# ReliabilityConfig redesign (satellite: grouped sub-configs + shim)
# ---------------------------------------------------------------------------


def test_grouped_subconfigs_equal_flat_kwargs():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        flat = ReliabilityConfig(
            platform="vc707", mode="inline", multi_rail=True,
            controller_start_v=0.6, mask_source="device",
            codecs={"mlp": "dected79"}, canary_prompts=2,
        )
    grouped = ReliabilityConfig(
        platform="vc707", mode="inline",
        fault_model=FaultModelConfig(mask_source="device"),
        rails=RailsConfig(multi_rail=True, start_v=0.6),
        protection=ProtectionConfig(codecs={"mlp": "dected79"}),
        canary=CanaryConfig(prompts=2),
    )
    assert flat == grouped
    # flat mirrors stay readable either way
    assert grouped.multi_rail and grouped.controller_start_v == 0.6
    assert grouped.rails.start_v == 0.6
    assert grouped.canary.prompts == 2 and grouped.canary_prompts == 2


def test_flat_kwargs_warn_once_per_process():
    engine_mod._FLAT_KWARG_WARNED = False
    with pytest.warns(DeprecationWarning, match="multi_rail"):
        ReliabilityConfig(multi_rail=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ReliabilityConfig(multi_rail=True)  # second use: silent
        # grouped construction never warms the shim at all
        engine_mod._FLAT_KWARG_WARNED = False
        ReliabilityConfig(rails=RailsConfig(multi_rail=True))
    assert not engine_mod._FLAT_KWARG_WARNED


def test_dataclasses_replace_roundtrip():
    rel = ReliabilityConfig(rails=RailsConfig(multi_rail=True), mode="inline")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        flipped = dataclasses.replace(rel, batched=False)
    # a non-default flat override wins and re-synthesizes its sub-config
    assert flipped.batched is False and flipped.fault_model.batched is False
    assert flipped.multi_rail and flipped.rails.multi_rail
    # flipping *back* through the flat name hands in the default value,
    # which is indistinguishable from "unspecified" — the sub-config wins
    # (documented shim limitation); the grouped field restores exactly
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert dataclasses.replace(flipped, batched=True) == flipped
    restored = dataclasses.replace(
        flipped, batched=True, fault_model=FaultModelConfig(batched=True)
    )
    assert restored == rel


def test_validate_raises_typed_errors():
    with pytest.raises(ReliabilityConfigError, match="mode"):
        ReliabilityConfig(mode="nope").validate()
    with pytest.raises(ReliabilityConfigError, match="platform"):
        ReliabilityConfig(platform="nope").validate()
    with pytest.raises(ReliabilityConfigError, match="rail"):
        ReliabilityConfig(
            rails=RailsConfig(policy="sideways"), mode="inline"
        ).validate()
    with pytest.raises(ReliabilityConfigError):
        # per-domain codec dict needs the multi-rail domain partition
        ReliabilityConfig(
            mode="inline", protection=ProtectionConfig(codecs={"mlp": "dected79"})
        ).validate()
    # the typed error IS both historical exception types
    assert issubclass(ReliabilityConfigError, ValueError)
    assert issubclass(ReliabilityConfigError, AssertionError)
    # a valid config returns itself for chaining
    ok = ReliabilityConfig(mode="inline", rails=RailsConfig(multi_rail=True))
    assert ok.validate() is ok
