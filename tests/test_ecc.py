"""SECDED(72,64) code: construction invariants + codec properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import ecc, hsiao


def test_hsiao_construction():
    code = hsiao.build_code()
    cols = list(code["data_cols"]) + list(code["parity_cols"])
    # 72 distinct odd-weight columns
    assert len(set(int(c) for c in cols)) == 72
    assert all(bin(int(c)).count("1") % 2 == 1 for c in cols)
    # balanced rows (hardware XOR-tree depth)
    assert code["row_weight"].min() == code["row_weight"].max() == 26


def test_roundtrip_and_all_single_bit_corrections():
    rng = np.random.default_rng(1)
    lo = jnp.asarray(rng.integers(0, 2**32, 32, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, 32, dtype=np.uint32))
    par = ecc.encode(lo, hi)
    dlo, dhi, st_ = ecc.decode(lo, hi, par)
    assert (np.asarray(st_) == ecc.STATUS_CLEAN).all()
    for b in range(72):
        flo, fhi, fpar = np.asarray(lo).copy(), np.asarray(hi).copy(), np.asarray(par).copy()
        if b < 32:
            flo ^= np.uint32(1 << b)
        elif b < 64:
            fhi ^= np.uint32(1 << (b - 32))
        else:
            fpar ^= np.uint8(1 << (b - 64))
        dlo, dhi, st_ = ecc.decode(jnp.asarray(flo), jnp.asarray(fhi), jnp.asarray(fpar))
        assert np.array_equal(np.asarray(dlo), np.asarray(lo)), b
        assert np.array_equal(np.asarray(dhi), np.asarray(hi)), b
        assert (np.asarray(st_) == ecc.STATUS_CORRECTED).all(), b


@settings(max_examples=200, deadline=None)
@given(
    lo=st.integers(0, 2**32 - 1),
    hi=st.integers(0, 2**32 - 1),
    b1=st.integers(0, 71),
    b2=st.integers(0, 71),
)
def test_double_bit_always_detected(lo, hi, b1, b2):
    if b1 == b2:
        return
    lo_a = jnp.asarray([lo], jnp.uint32)
    hi_a = jnp.asarray([hi], jnp.uint32)
    par = ecc.encode(lo_a, hi_a)
    flo, fhi, fpar = np.asarray(lo_a), np.asarray(hi_a), np.asarray(par)
    for b in (b1, b2):
        if b < 32:
            flo = flo ^ np.uint32(1 << b)
        elif b < 64:
            fhi = fhi ^ np.uint32(1 << (b - 32))
        else:
            fpar = fpar ^ np.uint8(1 << (b - 64))
    _, _, st_ = ecc.decode(jnp.asarray(flo), jnp.asarray(fhi), jnp.asarray(fpar))
    assert int(st_[0]) == ecc.STATUS_DETECTED


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**64 - 1))
def test_encode_matches_numpy_reference(word):
    lo = jnp.asarray([word & 0xFFFFFFFF], jnp.uint32)
    hi = jnp.asarray([word >> 32], jnp.uint32)
    assert np.asarray(ecc.encode(lo, hi))[0] == ecc.encode_np(
        np.asarray(lo), np.asarray(hi)
    )[0]
