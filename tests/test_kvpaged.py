"""Paged SECDED KV cache + continuous batching (DESIGN.md §11).

Pins down the tentpole contracts:
  * paged serve at nominal voltage is bit-identical to the dense decode loop
    on the same batch composition, with the scrub-on-read path exercised
    every step;
  * per-request outputs are independent of lane count, page pressure, and
    preemption (greedy decode is deterministic; recompute preemption must
    reproduce the same tokens) — hypothesis-driven;
  * the page allocator never double-books and never leaks;
  * per-page DED counters account injected single/double-bit faults exactly
    and feed the `kv` rail so it walks independently of the weight rails.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.configs.shapes import supports_paged_kv
from repro.core import voltage as vmod
from repro.core.kvpages import KVGeometry, KVPageArena, PageAllocator
from repro.models import lm
from repro.serving.engine import ReliabilityConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8)).astype(np.int32)
    return cfg, params, prompts


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params, _ = setup
    return ServingEngine(cfg, params, rel=None, max_len=48)


def test_supports_paged_kv_applicability():
    assert supports_paged_kv(get_smoke_config("qwen3-0.6b"))
    assert not supports_paged_kv(get_smoke_config("rwkv6-3b"))
    assert not supports_paged_kv(get_smoke_config("mixtral-8x22b"))  # SWA


def test_paged_bit_identical_to_dense_at_nominal(setup, engine):
    """Same batch composition, scrub-on-read every step: tokens must match
    the dense decode loop bit-for-bit."""
    cfg, params, prompts = setup
    ref = engine.generate(prompts, n_tokens=12)
    rep = engine.serve(
        [(prompts[i], 12) for i in range(4)], n_lanes=4, scrub_interval=1
    )
    out = np.stack([rep.outputs[i] for i in range(4)])
    np.testing.assert_array_equal(ref, out)
    # every word that crossed the read path decoded clean
    s = rep.kv_stats
    assert s.words > 0 and s.clean == s.words
    assert s.corrected == 0 and s.detected == 0


def test_paged_scrub_cadence_is_bit_stable(setup, engine):
    """The page round-trip is the identity at nominal: any scrub cadence
    (including none) and any block size produce identical tokens."""
    cfg, params, prompts = setup
    reqs = [(prompts[i][: 4 + i], 4 + 3 * i) for i in range(4)]
    ref = engine.serve(reqs, n_lanes=2, scrub_interval=0).outputs
    for scrub, block in ((1, 1), (3, 4), (7, 16)):
        out = engine.serve(
            reqs, n_lanes=2, scrub_interval=scrub, max_block=block
        ).outputs
        for rid, toks in ref.items():
            np.testing.assert_array_equal(toks, out[rid], err_msg=f"{scrub}/{block}")


def test_paged_matches_dense_single_request(setup, engine):
    """Each request's stream output equals its own dense batch-of-1 rollout,
    even with mixed lengths and lane reuse."""
    cfg, params, prompts = setup
    reqs = [(prompts[i][: 4 + 2 * i], 5 + 3 * i) for i in range(4)]
    rep = engine.serve(reqs, n_lanes=2, page_tokens=4, n_pages=8, scrub_interval=2)
    assert rep.preemptions >= 1  # tight arena: page pressure actually bit
    for i, (p, n) in enumerate(reqs):
        ref = engine.generate(p[None], n_tokens=n)[0]
        np.testing.assert_array_equal(ref, rep.outputs[i])


@settings(max_examples=12, deadline=None)
@given(
    n_lanes=st.integers(1, 4),
    n_pages=st.integers(4, 24),
    page_tokens=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 6),
)
def test_scheduler_invariants_under_pressure(n_lanes, n_pages, page_tokens, seed):
    """Admission/eviction/preemption invariants: every request completes with
    exactly its budget, outputs are independent of page pressure, and the
    allocator ends the run with every page back in the free pool."""
    cfg, params, prompts, engine = _shared_state()
    rng = np.random.default_rng(seed)
    reqs = [
        (
            prompts[rng.integers(0, 4)][: int(rng.integers(3, 9))],
            int(rng.integers(1, 10)),
        )
        for _ in range(int(rng.integers(2, 7)))
    ]
    longest = max(-(-(len(p) + n) // page_tokens) for p, n in reqs)
    n_pages = max(n_pages, longest)  # below this the stream cannot be served
    rep = engine.serve(
        reqs,
        n_lanes=n_lanes,
        page_tokens=page_tokens,
        n_pages=n_pages,
        scrub_interval=2,
        max_block=4,
    )
    assert sorted(rep.outputs) == list(range(len(reqs)))
    for i, (p, n) in enumerate(reqs):
        assert len(rep.outputs[i]) == n
    # page accounting: every page back in the pool (nothing leaked; the
    # allocator's own asserts catch double-alloc/foreign-free during the run)
    assert rep.pages_free_at_end == rep.arena.n_pages
    # outputs independent of pressure: a roomy arena gives identical tokens
    roomy = engine.serve(
        reqs, n_lanes=n_lanes, page_tokens=page_tokens, scrub_interval=2,
        max_block=4,
    )
    for rid, toks in roomy.outputs.items():
        np.testing.assert_array_equal(toks, rep.outputs[rid])


_STATE = {}


def _shared_state():
    """Module-scope state for the hypothesis test (fixtures can't be given)."""
    if not _STATE:
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        prompts = (
            np.random.default_rng(0).integers(0, cfg.vocab, (4, 8)).astype(np.int32)
        )
        _STATE["v"] = (cfg, params, prompts, ServingEngine(cfg, params, rel=None, max_len=48))
    return _STATE["v"]


def test_allocator_invariants():
    alloc = PageAllocator(4)
    a = alloc.alloc("a")
    b = alloc.alloc("b")
    assert a != b and alloc.used_pages == 2
    with pytest.raises(AssertionError):
        alloc.free([a], "b")  # foreign free
    alloc.free([a], "a")
    assert alloc.dirty_pages == 1 and alloc.free_pages == 3
    # freed pages are not reusable until recycled (they need a zero-wipe)
    got = {alloc.alloc("c") for _ in range(2)}
    assert alloc.alloc("d") is None and a not in got
    assert alloc.recycle() == [a]
    assert alloc.alloc("d") == a


def _mk_arena(page_tokens=2, n_pages=3):
    cfg = get_smoke_config("qwen3-0.6b")
    geom = KVGeometry.from_config(cfg, page_tokens)
    return KVPageArena(geom, vmod.PLATFORMS["vc707"], n_pages), geom


def test_per_page_counters_single_and_double_bit():
    """Scrub-on-read accounting: a 1-bit fault corrects (and the payload
    round-trips clean), a 2-bit fault raises DED in exactly its page's
    counter row, and the corrected planes are written back (second read is
    clean)."""
    arena, geom = _mk_arena()
    rng = np.random.default_rng(1)
    n_tok = geom.page_tokens * arena.n_pages
    payload = jnp.asarray(
        rng.standard_normal((n_tok, geom.token_f32)).astype(np.float32)
    )
    pages = np.repeat(np.arange(arena.n_pages), geom.page_tokens)
    slots = np.tile(np.arange(geom.page_tokens), arena.n_pages)
    arena.commit_tokens(payload, pages, slots)

    w = geom.words_per_page
    # single-bit fault in page 0, double-bit fault in one word of page 2
    arena.lo = arena.lo.at[3].set(arena.lo[3] ^ np.uint32(1 << 7))
    arena.hi = arena.hi.at[2 * w + 5].set(arena.hi[2 * w + 5] ^ np.uint32(0b101))

    out, cnt = arena.scrub_pages(np.arange(arena.n_pages))
    assert cnt.shape == (arena.n_pages, 8)
    assert cnt[0, 1] == 1 and cnt[0, 2] == 0  # corrected, in page 0 only
    assert cnt[2, 2] == 1 and cnt[2, 1] == 0  # detected, in page 2 only
    assert cnt[1, 1] == 0 and cnt[1, 2] == 0
    assert (cnt[:, 0] + cnt[:, 1] + cnt[:, 2] == w).all()
    # corrected payload round-trips the committed values everywhere except
    # the uncorrectable word: word 5 of page 2 is token 0's f32 lane 11
    # (codeword j holds f32 lanes 2j / 2j+1; both flips hit the hi lane)
    got = np.asarray(out).reshape(n_tok, geom.token_f32)
    ref = np.asarray(payload)
    bad = np.flatnonzero(got != ref)
    assert set(bad) == {(2 * geom.page_tokens) * geom.token_f32 + 11}
    # scrub write-back: single-bit fault is gone, DED stays latched
    _, cnt2 = arena.scrub_pages(np.arange(arena.n_pages))
    assert cnt2[0, 1] == 0 and cnt2[0, 0] == w
    assert cnt2[2, 2] == 1


def test_fresh_page_wipe_clears_accumulated_free_page_faults():
    """tick() faults the whole arena, allocated or not: a page that sat free
    through many undervolt intervals accumulates faults (possibly latched
    DED) that must never be attributed to its next owner. The allocation-
    time zero-wipe (scheduler.drain_fresh_pages) guarantees a wiped page
    scrubs fully clean."""
    arena, geom = _mk_arena(page_tokens=2, n_pages=3)
    arena.set_voltage(0.54)  # crash-adjacent: ~2% of words fault per interval
    for _ in range(10):
        arena.tick()
    assert arena.faulted
    # without the wipe, the never-written page is not clean (the repro)
    _, cnt = arena.scrub_pages([1])
    assert cnt[0, 1] + cnt[0, 2] > 0
    arena.zero_pages([2])
    _, cnt2 = arena.scrub_pages([2])
    assert cnt2[0, 0] == geom.words_per_page
    assert cnt2[0, 1] == 0 and cnt2[0, 2] == 0


def test_kv_rail_walks_independently_of_weight_rails(setup):
    cfg, params, prompts = setup
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            multi_rail=True, controller_start_v=0.60,
        ),
        max_len=48,
    )
    w_volts, _ = eng.autotune_voltage()
    w_locked = {d: c.voltage for d, c in eng.controller.rails.items()}
    reqs = [(prompts[i % 4], 16) for i in range(6)]
    rep = eng.serve(reqs, n_lanes=3, scrub_interval=1, walk_kv=True, kv_voltage=0.60)
    kv = eng.controller.rails["kv"]
    # the kv canary saw real DED telemetry from the page arena and locked...
    assert kv.locked and rep.kv_stats.detected > 0
    assert kv.voltage >= vmod.PLATFORMS["vc707"].v_crash
    # ...while no weight rail moved
    for d, v in w_locked.items():
        assert eng.controller.rails[d].voltage == v
    # the kv domain now carries real words in the power accounting
    words = eng._store.words_by_domain()
    assert words.get("kv", 0) == rep.arena.n_words
    assert "kv" in eng.power_report()["rails"]
    # a later uniform weight-rail step must not drop the kv rail from the
    # power accounting (its words stay in the denominator either way)
    eng.set_voltage(0.60)
    assert eng.rails["kv"] == rep.arena.voltage
    assert "kv" in eng.power_report()["bram_w_by_domain"]


def test_undervolted_kv_cache_corrects_and_serves(setup, engine):
    """Moderate undervolt on the cache only: ECC corrects every single-bit
    fault on the live stream and the outputs stay usable (the weights are
    clean, so any token drift comes from cache faults alone)."""
    cfg, params, prompts = setup
    reqs = [(prompts[i], 12) for i in range(4)]
    ref = engine.serve(reqs, n_lanes=4, scrub_interval=1).outputs
    rep = engine.serve(reqs, n_lanes=4, scrub_interval=1, kv_voltage=0.58)
    assert rep.kv_stats.corrected > 0
    agree = np.mean(
        [np.mean(rep.outputs[i] == ref[i]) for i in range(4)]
    )
    assert agree > 0.9
