"""Multi-rail undervolting: memory domains, per-domain ECC counters,
MultiRailController convergence, and the vmapped sweep harness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import shapes
from repro.core import (
    MultiRailController,
    PLATFORMS,
    UndervoltController,
    ecc,
    sweep,
)
from repro.core.faultsim import DeviceFaultField, _popcount32
from repro.core.planestore import PlaneStore
from repro.core.telemetry import DomainFaultStats, FaultStats
from repro.core.voltage import (
    bram_power,
    derive_domain_profiles,
    multi_rail_bram_power,
    multi_rail_power_saving,
)
from repro.kernels import ops, ref
from _hypothesis_compat import given, settings, st


# -- domain registry ----------------------------------------------------------
def test_domain_classifier():
    assert shapes.domain_of("['embed']") == "embedding"
    assert shapes.domain_of("['blocks']['p0']['attn']['wq']") == "attention"
    assert shapes.domain_of("['blocks']['p0']['mlp']['w1']") == "mlp"
    assert shapes.domain_of("['kv_cache']['k']") == "kv"
    assert shapes.domain_of("['whatever']") == "mlp"  # default bucket
    for d in ("embedding", "attention", "mlp", "kv"):
        assert d in shapes.MEMORY_DOMAINS


# -- per-domain counter kernel ------------------------------------------------
def test_domain_counters_match_reference(rng):
    n = 3000
    lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    par = ops.encode(lo, hi)
    mlo = rng.integers(0, 2**32, n, dtype=np.uint32)
    for _ in range(4):
        mlo &= rng.integers(0, 2**32, n, dtype=np.uint32)
    mhi = np.zeros(n, np.uint32)
    mpar = np.zeros(n, np.uint8)
    dom = rng.integers(0, 3, n).astype(np.int32)

    flo, _, _, cnt = ops.inject_scrub_domains(
        lo, hi, par, jnp.asarray(mlo), jnp.asarray(mhi), jnp.asarray(mpar),
        jnp.asarray(dom), 3,
    )
    cnt = np.asarray(cnt)
    # each domain row equals the separate-pass oracle on that domain's words
    for d in range(3):
        idx = dom == d
        *_, rcnt = ref.inject_scrub_ref(
            np.asarray(lo)[idx], np.asarray(hi)[idx], np.asarray(par)[idx],
            mlo[idx], mhi[idx], mpar[idx],
        )
        assert np.array_equal(cnt[d], rcnt)
    # rows sum to the single-rail fused kernel's counters; planes identical
    slo, _, _, c1 = ops.inject_scrub(
        lo, hi, par, jnp.asarray(mlo), jnp.asarray(mhi), jnp.asarray(mpar)
    )
    assert np.array_equal(cnt.sum(0), np.asarray(c1))
    assert np.array_equal(np.asarray(flo), np.asarray(slo))


# -- plane store rails --------------------------------------------------------
def _toy_store(mask_source, seed=3, profiles=None):
    rng = np.random.default_rng(7)
    leaves = [
        ops.pack_ecc_weights(jnp.asarray(rng.standard_normal((64, 96)), jnp.float32))
        for _ in range(4)
    ]
    keys = ["a_attn", "b_mlp", "c_attn", "d_embed"]
    return PlaneStore(
        leaves, keys, PLATFORMS["vc707"], seed=seed, mask_source=mask_source,
        domain_key=shapes.domain_of, profiles=profiles,
    ), leaves


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_set_rails_uniform_is_bit_identical_to_set_voltage(mask_source):
    store, leaves = _toy_store(mask_source)
    flat, _ = _toy_store(mask_source)  # fresh store: set_voltage consumes masks
    lv1, st1 = flat.set_voltage(0.55)
    lv2, st2 = store.set_rails({d: 0.55 for d in store.domains})
    for a, b in zip(lv1, lv2):
        assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
        assert np.array_equal(np.asarray(a.hi), np.asarray(b.hi))
        assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))
    assert st1.counters().tolist() == st2.total().counters().tolist()
    assert sum(store.words_by_domain().values()) == store.n_words


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_set_rails_faults_stay_in_their_domain(mask_source):
    store, _ = _toy_store(mask_source)
    _, st = store.set_rails({"attention": 1.0, "mlp": 0.54, "embedding": 1.0})
    assert st["attention"].faulty_bits == 0
    assert st["embedding"].faulty_bits == 0
    assert st["mlp"].faulty_bits > 0
    assert st["mlp"].words == store.words_by_domain()["mlp"]


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_set_rails_uniform_matches_set_voltage_with_domain_profiles(mask_source):
    """The bit-identity invariant must also hold when domains carry their
    own fault curves (the scalar path has to consult per-word rates then)."""
    profs = derive_domain_profiles(
        PLATFORMS["vc707"], shapes.MEMORY_DOMAINS, spread=0.5, seed=1
    )
    s1, _ = _toy_store(mask_source, profiles=profs)
    s2, _ = _toy_store(mask_source, profiles=profs)
    lv1, st1 = s1.set_voltage(0.55)
    lv2, st2 = s2.set_rails({d: 0.55 for d in s2.domains})
    for a, b in zip(lv1, lv2):
        assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
    assert st1.counters().tolist() == st2.total().counters().tolist()


def test_derived_domain_profiles_vary_rates_not_envelope():
    base = PLATFORMS["vc707"]
    profs = derive_domain_profiles(base, shapes.MEMORY_DOMAINS, spread=0.5, seed=0)
    again = derive_domain_profiles(base, shapes.MEMORY_DOMAINS, spread=0.5, seed=0)
    assert {d: p.rate_crash for d, p in profs.items()} == {
        d: p.rate_crash for d, p in again.items()
    }  # deterministic in (seed, domain)
    rates = [p.rate_crash for p in profs.values()]
    assert len(set(rates)) == len(rates)  # domains actually differ
    for p in profs.values():
        assert (p.v_min, p.v_crash) == (base.v_min, base.v_crash)


# -- controller ---------------------------------------------------------------
def _stats(detected=0, silent=0):
    return FaultStats(words=100, detected=detected, silent=silent)


def test_multirail_trips_are_independent():
    ctrl = MultiRailController(
        PLATFORMS["vc707"], ("attention", "mlp"), step_v=0.01, start_v=0.60
    )
    # attention sees a DED, mlp stays clean
    volts = ctrl.update({"attention": _stats(detected=1), "mlp": _stats()})
    assert ctrl.rails["attention"].locked
    assert not ctrl.rails["mlp"].locked
    v_att = volts["attention"]
    for _ in range(3):
        volts = ctrl.update({"attention": _stats(), "mlp": _stats()})
    assert volts["attention"] == v_att  # locked rail holds
    assert volts["mlp"] < v_att - 0.02  # free rail keeps descending
    assert not ctrl.locked
    ctrl.update({"attention": _stats(), "mlp": _stats(detected=2)})
    assert ctrl.locked


def test_multirail_paranoid_trips_on_silent():
    relaxed = MultiRailController(PLATFORMS["vc707"], ("mlp",), start_v=0.60)
    paranoid = MultiRailController(
        PLATFORMS["vc707"], ("mlp",), paranoid=True, start_v=0.60
    )
    stats = {"mlp": _stats(silent=1)}
    relaxed.update(stats)
    paranoid.update(stats)
    assert not relaxed.rails["mlp"].locked
    assert paranoid.rails["mlp"].locked
    assert paranoid.rails["mlp"].history[-1].action == "trip+backoff"


def test_multirail_missing_domain_telemetry_holds_rail():
    ctrl = MultiRailController(PLATFORMS["vc707"], ("a", "b"), start_v=0.60)
    v0 = ctrl.voltages["b"]
    ctrl.update({"a": _stats()})  # no telemetry for b this interval
    assert ctrl.voltages["b"] == v0
    assert ctrl.voltages["a"] < v0


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.floats(min_value=0.56, max_value=1.0),
    st.sampled_from([1, 2, 3]),
)
def test_rail_walk_monotone_until_trip_then_locked(seed, start_v, backoff):
    """Property: every rail's voltage is non-increasing until its trip, never
    leaves [v_crash, v_nom], and is constant once locked (backoff included)."""
    prof = PLATFORMS["vc707"]
    rng = np.random.default_rng(seed)
    ctrl = MultiRailController(
        prof, ("a", "b", "c"), step_v=0.01, backoff_steps=backoff, start_v=start_v
    )
    seen = {d: [ctrl.voltages[d]] for d in ctrl.domains}
    for _ in range(40):
        stats = {
            d: _stats(detected=int(rng.random() < 0.15)) for d in ctrl.domains
        }
        volts = ctrl.update(stats)
        for d in ctrl.domains:
            seen[d].append(volts[d])
        if ctrl.locked:
            break
    for d in ctrl.domains:
        vs = seen[d]
        c = ctrl.rails[d]
        assert all(prof.v_crash <= v <= prof.v_nom for v in vs)
        tripped = [i for i, r in enumerate(c.history) if r.action == "trip+backoff"]
        upto = tripped[0] + 1 if tripped else len(vs) - 1
        # non-increasing descent strictly before the trip step
        assert all(vs[i + 1] <= vs[i] + 1e-12 for i in range(max(upto - 1, 0)))
        if tripped:
            # backoff is bounded and the rail never moves again
            assert vs[upto] <= vs[upto - 1] + backoff * c.step_v + 1e-12
            assert all(v == vs[upto] for v in vs[upto:])
            assert c.locked


# -- telemetry contract -------------------------------------------------------
def test_faultstats_accumulate_contract():
    a = FaultStats(words=1, corrected=2)
    b = FaultStats(words=3, corrected=5)
    assert a.accumulate(b) is None  # explicitly in-place, no alias to return
    assert (a.words, a.corrected) == (4, 7)
    assert (b.words, b.corrected) == (3, 5)  # other side untouched
    pure = FaultStats.summed([a, b])
    assert pure.words == 7 and a.words == 4  # inputs untouched
    d = DomainFaultStats({"x": FaultStats(words=2, detected=1)})
    d.accumulate(DomainFaultStats({"x": FaultStats(words=1), "y": FaultStats(silent=3)}))
    assert d["x"].words == 3 and d["y"].silent == 3
    tot = d.total()
    tot.accumulate(FaultStats(words=100))
    assert d["x"].words == 3  # total() is a fresh instance, not a view


# -- vmapped sweep ------------------------------------------------------------
def test_vmapped_sweep_matches_per_voltage_loop():
    """The vmapped grid equals the per-voltage device loop bit-for-bit, in
    fewer compiled dispatches, and tracks the host-oracle curve."""
    n = 1 << 16
    voltages = [0.56, 0.55, 0.54]
    grid = [(p, v) for p in PLATFORMS.values() for v in voltages]
    sweep.reset_dispatch_count()
    pts = sweep.sweep_platform_grid(grid, n, seed=11)
    vmapped_dispatches = sweep.dispatch_count()

    loop_dispatches = 0
    for (prof, v), pt in zip(grid, pts):
        dev = DeviceFaultField(prof, n, seed=11)
        mlo, mhi, mpar = dev.masks(v)
        loop_dispatches += 1
        _, _, status = ecc.decode(mlo, mhi, mpar)
        flips = (
            _popcount32(np.asarray(mlo))
            + _popcount32(np.asarray(mhi))
            + _popcount32(np.asarray(mpar).astype(np.uint32))
        )
        st = FaultStats.from_decode(np.asarray(status), flips)
        assert pt.stats.counters().tolist() == st.counters().tolist(), (prof.name, v)
    assert vmapped_dispatches < loop_dispatches

    # statistical agreement with the host-oracle loop (different PRNG stream)
    from benchmarks.fig1_fault_rate import _stats_at
    from repro.core.faultsim import FaultField

    host = FaultField(PLATFORMS["vc707"], n, seed=11)
    for v in voltages:
        h = _stats_at(host, v)
        d = next(
            p.stats for (pr, pv), p in zip(grid, pts)
            if pr.name == "vc707" and pv == v
        )
        assert h.faulty_bits > 50
        assert 0.5 < d.faulty_bits / h.faulty_bits < 2.0, (v, h, d)


def test_schedule_sweep_matches_planestore_device_path():
    """sweep_rail_schedules on the store's geometry reproduces the store's
    own device-path telemetry exactly (same stream, same thresholds)."""
    store, _ = _toy_store("device", seed=5)
    volts = {"attention": 0.55, "mlp": 0.56, "embedding": 0.54}
    _, st_store = store.set_rails(volts)
    res = sweep.sweep_rail_schedules(
        [volts], store.domains, store._dom_ids_np, PLATFORMS["vc707"], seed=5
    )[0]
    for d in store.domains:
        assert (
            st_store[d].counters().tolist() == res[d].counters().tolist()
        ), d


# -- serving engine end-to-end ------------------------------------------------
@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 6)).astype(np.int32)
    return cfg, params, prompts


def test_engine_multirail_beats_single_rail_and_is_clean_at_nominal(lm_setup):
    """Acceptance: per-domain autotune locks at least one domain below the
    global single-rail lock, saves at least as much power, and the multi-rail
    machinery is bit-invisible at nominal voltage."""
    from repro.serving.engine import ReliabilityConfig, ServingEngine

    cfg, params, prompts = lm_setup
    single = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            protect_embed=True, controller_start_v=0.62,
        ),
        max_len=32,
    )
    v_single, _ = single.autotune_voltage()
    saving_single = single.power_report()["saving_vs_nominal"]

    multi = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            multi_rail=True, controller_start_v=0.62,
        ),
        max_len=32,
    )
    volts, history = multi.autotune_voltage()
    assert multi.controller.locked
    assert set(volts) == set(multi._store.domains)
    prof = PLATFORMS["vc707"]
    assert all(prof.v_crash <= v <= prof.v_min for v in volts.values())
    # every rail's own telemetry drove its lock: per-domain histories exist
    assert all(len(history[d]) > 0 for d in volts)

    # (a) at least one domain locks below the global single-rail lock
    assert any(v < v_single - 1e-9 for v in volts.values()), (volts, v_single)
    # (b) total power saving dominates the single-rail baseline
    report = multi.power_report()
    assert report["saving_vs_nominal"] >= saving_single - 1e-12
    assert report["bram_w"] <= bram_power(v_single, ecc=True) + 1e-12
    # (c) nominal schedule is bit-identical to the single-rail engine
    multi.set_rails({d: 1.0 for d in multi._store.domains})
    single.set_voltage(1.0)
    out_m = multi.generate(prompts, 8)
    out_s = single.generate(prompts, 8)
    np.testing.assert_array_equal(out_m, out_s)


def test_engine_multirail_generates_under_locked_schedule(lm_setup):
    from repro.serving.engine import ReliabilityConfig, ServingEngine

    cfg, params, prompts = lm_setup
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", mode="inline", multi_rail=True,
            mask_source="device", controller_start_v=0.62,
        ),
        max_len=32,
    )
    volts, _ = eng.autotune_voltage()
    out = eng.generate(prompts, 6)
    assert out.shape == (2, 6)
    # the locked schedule was DED-free on its final scrub
    assert all(eng._last_scrub[d].detected == 0 for d in eng._store.domains)
    # cumulative per-domain telemetry accounts every scrubbed word
    assert eng.rail_stats.total().words == eng.stats.words


# -- trainer integration ------------------------------------------------------
def test_trainer_rail_policy_is_read_only_and_walks_rails():
    import tempfile

    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import RailPolicy, Trainer
    from conftest import tiny_cfg

    cfg = tiny_cfg(vocab=64)
    dc = DataConfig(vocab=64, global_batch=8, seq_len=32)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100), remat=None
    )
    with tempfile.TemporaryDirectory() as d:
        plain = Trainer(cfg, tc, TokenPipeline(dc), d, ckpt_every=100)
        h0 = plain.run(4)
    with tempfile.TemporaryDirectory() as d:
        railed = Trainer(
            cfg, tc, TokenPipeline(dc), d, ckpt_every=100,
            rails=RailPolicy(scrub_every=2, start_v=0.60),
        )
        h1 = railed.run(4)
    events = [r for r in railed.history if r.get("event") == "rails"]
    assert len(events) == 2  # steps 2 and 4
    assert events[0]["voltages"]["mlp"] == pytest.approx(0.60)
    assert events[1]["voltages"]["mlp"] < 0.60  # the walk is live
    assert set(events[0]["voltages"]) >= {"attention", "mlp", "embedding"}
    # scrubbing is a read path: training is bitwise unaffected
    np.testing.assert_array_equal(
        [r["loss"] for r in h0 if "loss" in r],
        [r["loss"] for r in h1 if "loss" in r],
    )


# -- power accounting ---------------------------------------------------------
def test_multi_rail_power_dominates_single_rail():
    words = {"attention": 1000, "mlp": 3000, "embedding": 500}
    single = {d: 0.56 for d in words}
    hetero = {"attention": 0.55, "mlp": 0.56, "embedding": 0.54}
    p_single = multi_rail_bram_power(single, words)
    assert p_single == pytest.approx(bram_power(0.56, ecc=True), rel=1e-12)
    assert multi_rail_bram_power(hetero, words) < p_single
    assert multi_rail_power_saving(hetero, words) > multi_rail_power_saving(
        single, words
    )
