"""Codec subsystem wired through the system: generalized kernels, the
codec-grouped PlaneStore, the paged KV arena, the controller escalation
ladder, and the scheme-comparison sweep (DESIGN.md §12)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import codes
from repro.configs import shapes
from repro.core import sweep
from repro.core.controller import EscalationPolicy, UndervoltController
from repro.core.kvpages import KVGeometry, KVPageArena
from repro.core.planestore import PlaneStore
from repro.core.telemetry import FaultStats
from repro.core.voltage import PLATFORMS
from repro.kernels import ops, paged_gather

ALL = ("parity65", "secded72", "ileave88", "dected79")


def _sparse_masks(rng, c, n, p=0.01):
    mlo = (rng.random(n) < p).astype(np.uint32) << rng.integers(0, 32, n).astype(np.uint32)
    mhi = (rng.random(n) < p).astype(np.uint32) << rng.integers(0, 32, n).astype(np.uint32)
    mch = (
        (rng.random(n) < p / 2).astype(np.uint64)
        << rng.integers(0, c.n_check, n).astype(np.uint64)
    ).astype(c.check_dtype)
    return jnp.asarray(mlo), jnp.asarray(mhi), jnp.asarray(mch)


# ---------------------------------------------------------------------------
# generalized kernels vs the numpy oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ALL)
def test_fused_inject_scrub_counters_match_oracle(codec):
    c = codes.get(codec)
    rng = np.random.default_rng(3)
    n = 4096
    lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    par = ops.encode(lo, hi, codec=codec)
    assert np.asarray(par).dtype == c.check_dtype
    mlo, mhi, mch = _sparse_masks(rng, c, n)
    flo, fhi, fpar, cnt = ops.inject_scrub(lo, hi, par, mlo, mhi, mch, codec=codec)
    cnt = np.asarray(cnt)
    nlo, nhi, nst = c.decode_np(np.asarray(flo), np.asarray(fhi), np.asarray(fpar))
    assert cnt[2] == int((nst == 2).sum())
    # genuinely-corrected lane: the decode restores the clean data
    restored = (nlo == np.asarray(lo)) & (nhi == np.asarray(hi))
    assert cnt[1] == int(((nst == 1) & restored).sum())
    # every word lands in exactly one outcome lane
    assert cnt[0] + cnt[1] + cnt[2] + cnt[3] == n
    # the decode kernel agrees with the oracle bit-for-bit
    dlo, dhi, dst = ops.decode(flo, fhi, fpar, codec=codec)
    assert np.array_equal(np.asarray(dlo), nlo)
    assert np.array_equal(np.asarray(dst), nst)


@pytest.mark.parametrize("codec", ALL)
def test_gather_scrub_pages_counters_and_writeback(codec):
    c = codes.get(codec)
    rng = np.random.default_rng(4)
    pages, w = 5, 384
    n = pages * w
    lo = jnp.asarray(rng.integers(0, 2**32, (pages, w), dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, (pages, w), dtype=np.uint32))
    par = ops.encode(lo, hi, codec=codec)
    mlo, mhi, mch = _sparse_masks(rng, c, n)
    flo = lo ^ mlo.reshape(pages, w)
    fhi = hi ^ mhi.reshape(pages, w)
    fpar = par ^ mch.reshape(pages, w)
    olo, ohi, opar, cnt = paged_gather.gather_scrub_pages(flo, fhi, fpar, codec=codec)
    nlo, nhi, nst = c.decode_np(np.asarray(flo), np.asarray(fhi), np.asarray(fpar))
    exp = np.stack([(nst == 0).sum(1), (nst == 1).sum(1), (nst == 2).sum(1)], 1)
    assert np.array_equal(np.asarray(cnt)[:, :3], exp)
    assert np.array_equal(np.asarray(olo), nlo)
    assert np.array_equal(np.asarray(ohi), nhi)
    # DED latch: detected words keep their stored check bits; all others
    # re-encode clean over the corrected data
    opar = np.asarray(opar)
    det = nst == 2
    assert np.array_equal(opar[det], np.asarray(fpar)[det])
    re = c.encode_np(nlo, nhi)
    assert np.array_equal(opar[~det], re[~det])


# ---------------------------------------------------------------------------
# PlaneStore codec groups
# ---------------------------------------------------------------------------
def _toy_store(mask_source, codecs=None, seed=3):
    rng = np.random.default_rng(7)
    leaves = [
        ops.pack_ecc_weights(jnp.asarray(rng.standard_normal((64, 96)), jnp.float32))
        for _ in range(4)
    ]
    keys = ["a_attn", "b_mlp", "c_attn", "d_embed"]
    return PlaneStore(
        leaves, keys, PLATFORMS["vc707"], seed=seed, mask_source=mask_source,
        domain_key=shapes.domain_of, codecs=codecs,
    )


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_store_explicit_secded_is_default(mask_source):
    s1 = _toy_store(mask_source)
    s2 = _toy_store(mask_source, codecs="secded72")
    lv1, st1 = s1.set_rails({d: 0.55 for d in s1.domains})
    lv2, st2 = s2.set_rails({d: 0.55 for d in s2.domains})
    for a, b in zip(lv1, lv2):
        assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
        assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))
    assert st1.total().counters().tolist() == st2.total().counters().tolist()


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_store_mixed_codecs_partition_and_dtypes(mask_source):
    store = _toy_store(
        mask_source, codecs={"attention": "dected79", "mlp": "ileave88"}
    )
    assert store.codecs_by_domain() == {
        "attention": "dected79", "mlp": "ileave88", "embedding": "secded72"
    }
    assert store.check_bits_by_domain() == {
        "attention": 15, "mlp": 24, "embedding": 8
    }
    lv, st = store.set_rails({"attention": 0.55, "mlp": 0.55, "embedding": 1.0})
    assert lv[0].parity.dtype == np.uint32  # attention -> dected79
    assert lv[1].parity.dtype == np.uint32  # mlp -> ileave88
    assert lv[3].parity.dtype == np.uint8  # embedding stays secded
    assert st["embedding"].faulty_bits == 0  # nominal rail
    assert st["attention"].words == store.words_by_domain()["attention"]
    # the stronger codes at 0.55 V should be correcting, not detecting much
    assert st["attention"].corrected > 0 or st["mlp"].corrected > 0


@pytest.mark.parametrize("mask_source", ["host", "device"])
def test_set_domain_codec_rebuild_preserves_other_groups(mask_source):
    store = _toy_store(mask_source, codecs={"attention": "dected79"})
    lv1, _ = store.set_rails({"attention": 0.55, "mlp": 0.55, "embedding": 0.55})
    store.set_domain_codec("mlp", "ileave88")
    lv2, _ = store.set_rails({"attention": 0.55, "mlp": 0.55, "embedding": 0.55})
    # attention's group (membership unchanged) reproduces identical planes
    assert np.array_equal(np.asarray(lv1[0].lo), np.asarray(lv2[0].lo))
    assert np.array_equal(np.asarray(lv1[2].hi), np.asarray(lv2[2].hi))
    # mlp re-encoded under the new scheme
    assert lv2[1].parity.dtype == np.uint32
    assert store.codec_of("mlp") == "ileave88"


def test_store_stronger_codes_beat_secded_on_deep_undervolt():
    """Same arena, same voltage: DEC-TED leaves strictly fewer uncorrected
    faulty words than SECDED (the escalation pay-off, device masks)."""
    def uncorrected(codecs):
        store = _toy_store("device", codecs=codecs, seed=11)
        _, st = store.set_rails({d: 0.54 for d in store.domains})
        t = st.total()
        return t.detected + t.silent, t.faulty_words

    weak, fw1 = uncorrected(None)
    strong, fw2 = uncorrected("dected79")
    assert fw1 > 0 and fw2 > 0
    assert strong < weak


# ---------------------------------------------------------------------------
# paged KV arena with a codec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["parity65", "ileave88", "dected79"])
def test_kv_arena_roundtrip_nominal_any_codec(codec):
    geom = KVGeometry((0,), n_groups=1, n_kv_heads=2, head_dim=8, page_tokens=4)
    arena = KVPageArena(geom, PLATFORMS["vc707"], n_pages=3, codec=codec)
    assert np.asarray(arena.parity).dtype == codes.get(codec).check_dtype
    rng = np.random.default_rng(0)
    payload = rng.standard_normal((8, geom.token_f32)).astype(np.float32)
    pages = np.array([0, 0, 0, 0, 2, 2, 2, 2], np.int32)
    slots = np.array([0, 1, 2, 3, 0, 1, 2, 3], np.int32)
    arena.commit_tokens(jnp.asarray(payload), pages, slots)
    got, cnt = arena.scrub_pages(np.array([0, 2], np.int32))
    assert np.array_equal(
        np.asarray(got).reshape(8, geom.token_f32), payload
    )
    assert cnt[:, 2].sum() == 0  # nothing detected at nominal


def test_kv_arena_change_codec_preserves_contents():
    geom = KVGeometry((0,), n_groups=1, n_kv_heads=2, head_dim=8, page_tokens=4)
    arena = KVPageArena(geom, PLATFORMS["vc707"], n_pages=2, codec="secded72")
    rng = np.random.default_rng(1)
    payload = rng.standard_normal((4, geom.token_f32)).astype(np.float32)
    arena.commit_tokens(
        jnp.asarray(payload), np.zeros(4, np.int32), np.arange(4, dtype=np.int32)
    )
    arena.change_codec("dected79")
    assert np.asarray(arena.parity).dtype == np.uint32
    got, cnt = arena.scrub_pages(np.array([0], np.int32))
    assert np.array_equal(np.asarray(got).reshape(4, -1), payload)
    assert cnt[:, 2].sum() == 0


# ---------------------------------------------------------------------------
# controller escalation
# ---------------------------------------------------------------------------
def _stats(words=1000, detected=0, silent=0):
    return FaultStats(words=words, detected=detected, silent=silent)


def test_escalation_steps_code_up_instead_of_retreating():
    ctrl = UndervoltController(
        PLATFORMS["vc707"], start_v=0.57,
        escalation=EscalationPolicy(ladder=("secded72", "dected79")),
    )
    assert ctrl.codec == "secded72"
    v0 = ctrl.voltage
    ctrl.update(_stats(detected=5))  # trip -> escalate, voltage holds
    assert ctrl.codec == "dected79"
    assert not ctrl.locked and ctrl.voltage == v0
    assert ctrl.pop_codec_change() == "dected79"
    assert ctrl.pop_codec_change() is None  # one-shot
    ctrl.update(_stats())  # clean interval: the walk resumes
    assert ctrl.voltage < v0
    ctrl.update(_stats(detected=3))  # ladder exhausted -> retreat + lock
    assert ctrl.locked
    assert ctrl.history[-1].action == "trip+backoff"
    assert [r.action for r in ctrl.history[:2]] == ["escalate", "lower"]


def test_escalation_respects_ded_rate_threshold():
    ctrl = UndervoltController(
        PLATFORMS["vc707"], start_v=0.57,
        escalation=EscalationPolicy(ladder=("secded72", "dected79"), ded_rate=0.01),
    )
    ctrl.update(_stats(words=1000, detected=5))  # 0.5% <= 1%: retreat, not escalate
    assert ctrl.locked and ctrl.codec == "secded72"
    ctrl2 = UndervoltController(
        PLATFORMS["vc707"], start_v=0.57,
        escalation=EscalationPolicy(ladder=("secded72", "dected79"), ded_rate=0.01),
    )
    ctrl2.update(_stats(words=1000, detected=50))  # 5% > 1%: escalate
    assert not ctrl2.locked and ctrl2.codec == "dected79"


def test_paranoid_silent_trip_never_escalates():
    ctrl = UndervoltController(
        PLATFORMS["vc707"], start_v=0.57, paranoid=True,
        escalation=EscalationPolicy(ladder=("secded72", "dected79")),
    )
    ctrl.update(_stats(silent=2))  # silent-only trip: the code can't see it
    assert ctrl.locked and ctrl.codec == "secded72"


# ---------------------------------------------------------------------------
# scheme-comparison sweep (the acceptance table)
# ---------------------------------------------------------------------------
def test_scheme_sweep_stronger_codes_cover_more_at_crash():
    p = PLATFORMS["vc707"]
    rows = sweep.sweep_codec_schemes(
        ("secded72", "dected79", "ileave88"), [(p, p.v_crash)], 1 << 16, seed=0
    )
    cov = {r["codec"]: r["coverage_correctable"] for r in rows}
    assert all(r["faulty_words"] > 0 for r in rows)
    assert cov["dected79"] > cov["secded72"]
    assert cov["ileave88"] > cov["secded72"]
    # overhead ordering is the price side of the trade-off
    bits = {r["codec"]: r["check_bits"] for r in rows}
    assert bits["secded72"] < bits["dected79"] < bits["ileave88"]


def test_scheme_sweep_secded_matches_platform_sweep():
    """The codec sweep's secded72 row reproduces the historical platform
    sweep exactly (same stream, same classification)."""
    p = PLATFORMS["vc707"]
    pts = sweep.sweep_platform_grid([(p, 0.55)], 1 << 15, seed=2)
    rows = sweep.sweep_codec_schemes(("secded72",), [(p, 0.55)], 1 << 15, seed=2)
    st = pts[0].stats
    r = rows[0]
    assert (st.corrected, st.detected, st.silent, st.faulty_bits) == (
        r["corrected"], r["detected"], r["silent"], r["faulty_bits"]
    )
