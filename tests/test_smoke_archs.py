"""Per-architecture smoke tests (required deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config, run one forward/train step and one prefill+decode step on
CPU, assert output shapes and finiteness, and check decode-vs-full-forward
consistency (the strongest cheap invariant: cache semantics, ring buffers,
recurrent states and routing all agree with the train path).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.models import lm

LM_ARCHS = [a for a in ARCHS if a != "paper-nn"]


def _batch(cfg, b=2, s=16, seed=1):
    tok_shape = (b, cfg.n_codebooks, s) if cfg.n_codebooks else (b, s)
    tokens = jax.random.randint(jax.random.PRNGKey(seed), tok_shape, 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.n_img_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:  # no-drop capacity so decode/train routing agree
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = lm.train_loss(params, batch, cfg, remat=None)
    assert np.isfinite(float(loss)) and float(loss) > 0
    hidden, _, _ = lm.forward(params, batch["tokens"], cfg, img=batch.get("img"))
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[-1]
    assert hidden.shape == (b, s, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    tokens = batch["tokens"]
    img = batch.get("img")
    s = tokens.shape[-1]
    cache = lm.init_cache(
        cfg, tokens.shape[0], max_len=s + 4,
        img_tokens=img.shape[1] if img is not None else 0,
    )
    pre = tokens[..., : s - 1]
    dec = tokens[..., s - 1 :]
    _, cache = lm.prefill(params, pre, cfg, cache, img=img)
    logits, _ = lm.decode_step(params, dec, cfg, cache, pos=s - 1, img=img)
    hidden, _, _ = lm.forward(params, tokens, cfg, img=img)
    un = lm._unembed_matrix(params, cfg)
    if cfg.n_codebooks:
        ref = jnp.einsum("bd,kdv->bkv", hidden[:, -1].astype(jnp.float32), un.astype(jnp.float32))
    else:
        ref = jnp.einsum("bd,dv->bv", hidden[:, -1].astype(jnp.float32), un.astype(jnp.float32))
    err = float(jnp.max(jnp.abs(logits - ref)))
    assert err < 5e-3, f"{arch}: decode/full mismatch {err}"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_parameters_match_published(arch):
    """Exact configs: parameter counts land on the published sizes."""
    from repro.configs import get_config

    expected_total = {
        "qwen3-0.6b": (0.55e9, 0.65e9),
        "qwen1.5-4b": (3.7e9, 4.2e9),
        "minitron-8b": (7.3e9, 8.3e9),
        "qwen2-7b": (7.0e9, 7.9e9),
        "llama-3.2-vision-11b": (9.0e9, 10.6e9),
        "rwkv6-3b": (2.8e9, 3.3e9),
        "musicgen-medium": (1.2e9, 1.6e9),
        "llama4-scout-17b-a16e": (1.00e11, 1.15e11),
        "mixtral-8x22b": (1.35e11, 1.45e11),
        "jamba-1.5-large-398b": (3.90e11, 4.05e11),
    }[arch]
    total, active = lm.param_count(get_config(arch))
    assert expected_total[0] <= total <= expected_total[1]
    if arch == "llama4-scout-17b-a16e":
        assert 1.6e10 <= active <= 1.8e10  # 17B active
    if arch == "mixtral-8x22b":
        assert 3.7e10 <= active <= 4.1e10  # 39B active
    if arch == "jamba-1.5-large-398b":
        assert 9.0e10 <= active <= 9.9e10  # 94B active
