"""Batched (arena) voltage stepping + scan decode vs the per-leaf/Python
reference paths: identical counters, identical planes, identical tokens."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.nn_accel import EccMLP
from repro.core.planestore import PlaneStore
from repro.kernels import ops as kops
from repro.models import lm
from repro.serving.engine import ReliabilityConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    return cfg, params, prompts


def _ecc_leaves(params):
    return [
        l
        for l in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, kops.EccWeight)
        )
        if isinstance(l, kops.EccWeight)
    ]


@pytest.mark.parametrize("ecc", [True, False])
def test_engine_batched_identical_to_perleaf(setup, ecc):
    cfg, params, prompts = setup
    rel = ReliabilityConfig(platform="vc707", ecc=ecc, voltage=0.55, mode="inline")
    eng_b = ServingEngine(cfg, params, rel=rel, max_len=48)
    eng_p = ServingEngine(
        cfg, params, rel=dataclasses.replace(rel, batched=False), max_len=48
    )
    assert np.array_equal(eng_b.stats.counters(), eng_p.stats.counters())
    assert eng_b.stats.words == eng_p.stats.words
    for lb, lp in zip(_ecc_leaves(eng_b.params), _ecc_leaves(eng_p.params)):
        assert np.array_equal(np.asarray(lb.lo), np.asarray(lp.lo))
        assert np.array_equal(np.asarray(lb.hi), np.asarray(lp.hi))
        assert np.array_equal(np.asarray(lb.parity), np.asarray(lp.parity))
    np.testing.assert_array_equal(
        eng_b.generate(prompts, 6), eng_p.generate(prompts, 6, use_scan=False)
    )


def test_engine_batched_identity_across_voltage_walk(setup):
    """The paths stay identical when the rail moves (field reuse, not rebuild)."""
    cfg, params, prompts = setup
    rel = ReliabilityConfig(platform="vc707", ecc=True, voltage=0.57, mode="inline")
    eng_b = ServingEngine(cfg, params, rel=rel, max_len=48)
    eng_p = ServingEngine(
        cfg, params, rel=dataclasses.replace(rel, batched=False), max_len=48
    )
    for v in (0.56, 0.54, 0.56):  # down, crash-adjacent, back up
        eng_b.set_voltage(v)
        eng_p.set_voltage(v)
        assert np.array_equal(
            eng_b._last_scrub.counters(), eng_p._last_scrub.counters()
        ), v


def test_scan_generate_matches_python_loop(setup):
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, rel=None, max_len=48)
    ref = eng.generate(prompts, 8, use_scan=False)
    np.testing.assert_array_equal(eng.generate(prompts, 8, use_scan=True), ref)
    # degenerate rollouts
    np.testing.assert_array_equal(
        eng.generate(prompts, 1, use_scan=True), ref[:, :1]
    )


def test_device_mask_source_serves(setup):
    cfg, params, prompts = setup
    rel = ReliabilityConfig(
        platform="vc707", ecc=True, voltage=0.55, mode="inline", mask_source="device"
    )
    eng = ServingEngine(cfg, params, rel=rel, max_len=48)
    assert eng.stats.words == eng._store.n_words > 0
    assert eng.stats.faulty_bits > 0  # 0.55 V is well below the guardband
    out = eng.generate(prompts, 4)
    assert out.shape == (2, 4)


def test_eccmlp_batched_identical_to_perleaf():
    mlp = EccMLP((64, 32, 10), platform="vc707", seed=3)
    mlp.store()
    for v, ecc in ((0.56, True), (0.55, False), (0.54, True)):
        mlp.set_voltage(v, ecc=ecc, batched=False)
        ref_stats = mlp.stats.counters()
        ref_planes = [
            (np.asarray(l.faulty.lo), np.asarray(l.faulty.hi), np.asarray(l.faulty.parity))
            for l in mlp.layers
        ]
        mlp.set_voltage(v, ecc=ecc, batched=True)
        assert np.array_equal(mlp.stats.counters(), ref_stats), (v, ecc)
        for l, (rlo, rhi, rpar) in zip(mlp.layers, ref_planes):
            assert np.array_equal(np.asarray(l.faulty.lo), rlo)
            assert np.array_equal(np.asarray(l.faulty.hi), rhi)
            assert np.array_equal(np.asarray(l.faulty.parity), rpar)


def test_planestore_empty():
    from repro.core.voltage import PLATFORMS

    store = PlaneStore([], [], PLATFORMS["vc707"], seed=0)
    leaves, stats = store.set_voltage(0.54)
    assert leaves == [] and stats.words == 0
