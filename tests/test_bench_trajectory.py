"""benchmarks/run.py trajectory files: CSV-row parsing + BENCH_<name>.json."""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.run import SECTIONS, parse_rows, write_trajectory  # noqa: E402


def test_parse_rows_drops_noise():
    text = "\n".join(
        [
            "name,us_per_call,derived",  # header
            "# === kernels ===",  # section marker
            "fused_inject_scrub,123.4,ratio=0.91",
            "mesh_scrub_d8,99.0,words_per_s=1.2e+07",
            "not a csv line",
            "bad,notafloat,x",
            "trailing,5.0,a,b,c",  # derived keeps embedded commas
        ]
    )
    rows = parse_rows(text)
    assert rows == [
        {"name": "fused_inject_scrub", "us_per_call": 123.4, "derived": "ratio=0.91"},
        {"name": "mesh_scrub_d8", "us_per_call": 99.0, "derived": "words_per_s=1.2e+07"},
        {"name": "trailing", "us_per_call": 5.0, "derived": "a,b,c"},
    ]


def test_write_trajectory_at_root(tmp_path):
    rows = [{"name": "x", "us_per_call": 1.0, "derived": "d"}]
    path = write_trajectory("kernels", rows, 12.34, root=str(tmp_path))
    assert os.path.basename(path) == "BENCH_kernels.json"
    with open(path) as f:
        data = json.load(f)
    assert data == {"suite": "kernels", "rows": rows, "seconds": 12.3}


def test_mesh_section_registered():
    assert "mesh" in dict(SECTIONS)


def test_accuracy_section_registered():
    """`python -m benchmarks.run accuracy` must stay wired to the campaign
    (the nightly lane and BENCH_accuracy.json depend on the section name)."""
    assert "accuracy" in dict(SECTIONS)
