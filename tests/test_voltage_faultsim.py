"""Voltage/power model anchors + fault-field properties (FIP, calibration)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import voltage
from repro.core.faultsim import FaultField

VC = voltage.PLATFORMS["vc707"]


def test_power_model_exact_at_paper_anchors():
    assert voltage.bram_power(0.54) == pytest.approx(0.198, abs=1e-3)
    assert voltage.bram_power(0.61) == pytest.approx(0.310, abs=1e-3)
    assert voltage.bram_power(1.00) == pytest.approx(2.400, abs=1e-3)
    assert voltage.bram_power(0.54, ecc=True) == pytest.approx(0.211, abs=1e-3)


def test_paper_derived_savings():
    assert voltage.power_saving(0.61, 0.54) == pytest.approx(0.361, abs=0.002)
    assert voltage.power_saving(0.61, 0.54, ecc=True) == pytest.approx(0.319, abs=0.002)
    accel = 1 - voltage.accelerator_power(0.54) / voltage.accelerator_power(1.0, ecc=False)
    assert accel == pytest.approx(0.252, abs=0.002)


def test_guardband_and_rates():
    gb = np.mean([p.guardband for p in voltage.PLATFORMS.values()])
    assert gb == pytest.approx(0.39, abs=0.005)  # paper: 39% average
    assert VC.faults_per_mbit(0.54) == pytest.approx(652, rel=1e-6)
    assert VC.fault_rate(0.61) == 0.0  # no faults at/above V_min
    assert VC.fault_rate(0.75) == 0.0
    # exponential growth below V_min
    r = [VC.fault_rate(v) for v in (0.60, 0.58, 0.56, 0.54)]
    assert all(b > 3 * a for a, b in zip(r, r[1:]))
    # KC705 die-to-die variation: 4.1x
    ka = voltage.PLATFORMS["kc705a"].rate_crash
    kb = voltage.PLATFORMS["kc705b"].rate_crash
    assert ka / kb == pytest.approx(4.1, rel=1e-6)


N_WORDS = 1 << 17


@pytest.fixture(scope="module")
def field():
    return FaultField(VC, N_WORDS, seed=7)


def test_rate_calibration_at_crash(field):
    counts = field.masks(0.54).flip_counts()
    per_mbit = counts.sum() / (N_WORDS * 72 / 2**20)
    assert per_mbit == pytest.approx(652, rel=0.10)


def test_coverage_split_matches_paper(field):
    counts = field.masks(0.54).flip_counts()
    fw = (counts > 0).sum()
    assert 0.88 <= (counts == 1).sum() / fw <= 0.94  # paper >90%
    assert 0.05 <= (counts == 2).sum() / fw <= 0.10  # paper ~7%
    assert (counts >= 3).sum() / fw <= 0.05


@settings(max_examples=10, deadline=None)
@given(
    v_pair=st.tuples(
        st.floats(0.54, 0.61), st.floats(0.54, 0.61)
    )
)
def test_fault_inclusion_property(v_pair):
    v_lo, v_hi = min(v_pair), max(v_pair)
    f = FaultField(VC, 1 << 14, seed=3)
    m_hi = f.masks(v_hi)
    m_lo = f.masks(v_lo)
    # every bit faulty at the higher voltage is still faulty at the lower one
    assert int((m_hi.lo & ~m_lo.lo).sum()) == 0
    assert int((m_hi.hi & ~m_lo.hi).sum()) == 0
    assert int((m_hi.parity & ~m_lo.parity).sum()) == 0


def test_masks_deterministic(field):
    a = field.masks(0.56)
    b = FaultField(VC, N_WORDS, seed=7).masks(0.56)
    assert np.array_equal(a.lo, b.lo) and np.array_equal(a.hi, b.hi)


def test_chunking_invariance():
    f1 = FaultField(VC, 10000, seed=5, chunk_words=10000)
    f2 = FaultField(VC, 10000, seed=5, chunk_words=10000)
    # NOTE: chunk size is part of the deterministic layout; equality holds for
    # same chunking (documented), and masks are reproducible across instances.
    assert np.array_equal(f1.masks(0.55).lo, f2.masks(0.55).lo)
