"""Fused inject+scrub kernel vs the separate-pass oracle; device PRNG field."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.faultsim import DeviceFaultField, FaultField, _popcount32
from repro.core.telemetry import COUNTER_FIELDS, FaultStats
from repro.core.voltage import PLATFORMS
from repro.kernels import ops, ref


def _sparse_masks(rng, shape, density_rounds=4):
    mlo = rng.integers(0, 2**32, shape, dtype=np.uint32)
    mhi = rng.integers(0, 2**32, shape, dtype=np.uint32)
    mpar = rng.integers(0, 256, shape).astype(np.uint8)
    for _ in range(density_rounds):
        mlo &= rng.integers(0, 2**32, shape, dtype=np.uint32)
        mhi &= rng.integers(0, 2**32, shape, dtype=np.uint32)
        mpar &= rng.integers(0, 256, shape).astype(np.uint8)
    return mlo, mhi, mpar


@pytest.mark.parametrize("shape", [(64,), (1000,), (256, 512), (7, 13)])
@pytest.mark.parametrize("reencode", [False, True])
def test_fused_matches_separate_inject_decode(shape, reencode, rng):
    lo = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    par = ops.encode(lo, hi)
    mlo, mhi, mpar = _sparse_masks(rng, shape)
    # craft known fault classes in the first words: double-bit (DED),
    # single data bit (corrected), single parity bit (corrected, data fine)
    flat = lambda m: m.reshape(-1)
    flat(mlo)[0], flat(mhi)[0], flat(mpar)[0] = 0b11, 0, 0
    flat(mlo)[1], flat(mhi)[1], flat(mpar)[1] = 0b1, 0, 0
    flat(mlo)[2], flat(mhi)[2], flat(mpar)[2] = 0, 0, 0b100
    mlo, mhi, mpar = jnp.asarray(mlo), jnp.asarray(mhi), jnp.asarray(mpar)

    flo, fhi, fpar, cnt = ops.inject_scrub(lo, hi, par, mlo, mhi, mpar, reencode=reencode)
    rlo, rhi, rpar, rcnt = ref.inject_scrub_ref(lo, hi, par, mlo, mhi, mpar, reencode=reencode)
    assert np.array_equal(np.asarray(flo), np.asarray(rlo))
    assert np.array_equal(np.asarray(fhi), np.asarray(rhi))
    assert np.array_equal(np.asarray(fpar), np.asarray(rpar))
    assert np.array_equal(np.asarray(cnt), rcnt)
    # the separate kernels agree too (inject then decode status histogram)
    ilo, ihi, ipar = ops.inject(lo, hi, par, mlo, mhi, mpar)
    assert np.array_equal(np.asarray(flo), np.asarray(ilo))
    if not reencode:
        assert np.array_equal(np.asarray(fpar), np.asarray(ipar))
        _, _, status = ops.decode(ilo, ihi, ipar)
        stats = FaultStats.from_counters(np.asarray(cnt), words=int(np.prod(shape)))
        assert stats.detected == int((np.asarray(status) == 2).sum())
        assert stats.corrected <= int((np.asarray(status) == 1).sum())
    if reencode:
        # no-ECC baseline: parity consistent with faulty data => no DED ever
        assert np.array_equal(np.asarray(fpar), np.asarray(ops.encode(flo, fhi)))
        assert FaultStats.from_counters(np.asarray(cnt), words=1).detected == 0


def test_counters_roundtrip_faultstats(rng):
    shape = (4096,)
    lo = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    par = ops.encode(lo, hi)
    mlo, mhi, mpar = (jnp.asarray(m) for m in _sparse_masks(rng, shape, 5))
    *_, cnt = ops.inject_scrub(lo, hi, par, mlo, mhi, mpar)
    stats = FaultStats.from_counters(np.asarray(cnt), words=shape[0])
    assert stats.words == shape[0]
    assert np.array_equal(stats.counters(), np.asarray(cnt))
    assert len(COUNTER_FIELDS) == np.asarray(cnt).size
    # totals are conserved: every word is in exactly one ECC-outcome class
    assert stats.clean + stats.corrected + stats.detected + stats.silent == stats.words


@pytest.mark.parametrize("voltage", [0.56, 0.55, 0.54])
def test_device_faultfield_statistics_vs_oracle(voltage):
    plat = PLATFORMS["vc707"]
    n = 1 << 18
    host = FaultField(plat, n, seed=11)
    dev = DeviceFaultField(plat, n, seed=11)
    hm = host.masks(voltage)
    dlo, dhi, dpar = (np.asarray(x) for x in dev.masks(voltage))
    dflips = (
        _popcount32(dlo) + _popcount32(dhi) + _popcount32(dpar.astype(np.uint32))
    )
    h_total, d_total = hm.total_flips(), int(dflips.sum())
    assert h_total > 100  # meaningful sample at these voltages
    # same model, different PRNG stream: totals within sampling noise
    # (lognormal row clustering inflates variance ~e^{sigma^2} over Poisson)
    assert 0.6 < d_total / h_total < 1.6, (voltage, h_total, d_total)
    # faulty-word class mix also matches
    h_counts, d_counts = hm.flip_counts(), dflips
    h_frac = (h_counts >= 2).sum() / max((h_counts >= 1).sum(), 1)
    d_frac = (d_counts >= 2).sum() / max((d_counts >= 1).sum(), 1)
    assert abs(h_frac - d_frac) < 0.1, (voltage, h_frac, d_frac)


def test_faultfield_public_api_and_device_bridge():
    """sweep_histogram stays on the host field; device_field bridges across."""
    plat = PLATFORMS["vc707"]
    host = FaultField(plat, 4096, seed=2)
    hist = host.sweep_histogram([0.8, 0.54])
    assert hist[0]["faulty_bits"] == 0  # inside the guardband
    assert hist[1]["faulty_bits"] > 0
    dev = host.device_field()
    assert isinstance(dev, DeviceFaultField)
    assert (dev.n_words, dev.seed) == (host.n_words, host.seed)


def test_device_faultfield_multichunk():
    """Chunked generation (bounded transients): deterministic, FIP across
    chunk boundaries, later chunks populated. Like the host field, the mask
    pattern is a function of (seed, chunk_words) — chunking is part of the
    stream, so chunk_words must stay fixed for a given store."""
    plat = PLATFORMS["vc707"]
    n = 3000
    f = DeviceFaultField(plat, n, seed=9, chunk_words=1024)  # 3 chunks
    a = tuple(np.asarray(x) for x in f.masks(0.54))
    b = tuple(np.asarray(x) for x in f.masks(0.54))
    hi_v = tuple(np.asarray(x) for x in f.masks(0.56))
    for x, y, z in zip(a, b, hi_v):
        assert x.shape == (n,)
        assert np.array_equal(x, y)  # repeated calls identical
        assert not np.any(z & ~x)  # FIP holds under chunking
    assert a[0][2048:].any() or a[1][2048:].any()  # last chunk populated


def test_device_faultfield_fip():
    """Fault Inclusion Property: lower rail => superset fault pattern."""
    plat = PLATFORMS["vc707"]
    dev = DeviceFaultField(plat, 1 << 16, seed=5)
    prev = None
    for v in (0.58, 0.56, 0.55, 0.54):
        cur = tuple(np.asarray(x) for x in dev.masks(v))
        if prev is not None:
            for p, c in zip(prev, cur):
                assert not np.any(p & ~c), v
        prev = cur
    # inside the guardband: zero faults
    for m in (np.asarray(x) for x in dev.masks(0.8)):
        assert not m.any()
