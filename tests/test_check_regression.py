"""The benchmark regression gate: thresholds, serve floor, and --retries."""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import check_regression as cr  # noqa: E402


def _kernel_rows(ratios):
    return [
        {"kernel": "inject_scrub", "words": w, "fused_over_pair": r}
        for w, r in ratios.items()
    ]


def _serve_rows(ratio):
    return [{"kernel": "serve_throughput", "cont_over_fixed": ratio}]


def _mesh_rows(words_per_s_by_devices):
    return [
        {"kernel": "sharded_scrub", "devices": d, "us_per_call": 1.0,
         "words_per_s": wps}
        for d, wps in words_per_s_by_devices.items()
    ]


def _acc_rows(floors, nominal_div=0.0):
    """Campaign rows: per codec, zero divergence down to its floor voltage,
    0.5 below; one shared nominal row per codec at 1.0 V."""
    rows = []
    grid = (1.0, 0.61, 0.59, 0.57, 0.55, 0.54)
    for codec, floor in floors.items():
        for v in grid:
            rows.append({
                "codec": codec, "voltage": v, "nominal": v >= 0.61,
                "divergence": (nominal_div if v >= 0.61 else
                               0.0 if v >= floor else 0.5),
            })
    return rows


@pytest.fixture
def gate(tmp_path, monkeypatch):
    """Point the gate at throwaway baseline/current files; returns writers."""
    paths = {
        "BASELINE": tmp_path / "base_kernel.json",
        "CURRENT": tmp_path / "cur_kernel.json",
        "SERVE_BASELINE": tmp_path / "base_serve.json",
        "SERVE_CURRENT": tmp_path / "cur_serve.json",
        "MESH_CURRENT": tmp_path / "cur_mesh.json",
        "ACC_CURRENT": tmp_path / "cur_accuracy.json",
    }
    for attr, p in paths.items():
        monkeypatch.setattr(cr, attr, str(p))

    def write(attr, rows):
        paths[attr].write_text(json.dumps(rows))

    return write


def test_gate_passes_within_threshold(gate):
    gate("BASELINE", _kernel_rows({1: 1.0, 2: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.1, 2: 1.05}))
    assert cr.check(threshold=0.20) == 0


def test_gate_fails_beyond_threshold(gate):
    gate("BASELINE", _kernel_rows({1: 1.0, 2: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.5, 2: 1.4}))
    assert cr.check(threshold=0.20) == 1


def test_serve_gate_requires_beating_fixed(gate):
    """cont_over_fixed below 1.0 fails even if within the relative band:
    continuous batching beating fixed batching is an acceptance property."""
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    gate("SERVE_BASELINE", _serve_rows(1.10))
    gate("SERVE_CURRENT", _serve_rows(0.97))
    assert cr.check(threshold=0.20) == 1
    gate("SERVE_CURRENT", _serve_rows(1.02))
    assert cr.check(threshold=0.20) == 0


def test_retries_remeasure_and_recover(gate):
    """A flaky first measurement recovers after the injected re-measure; the
    re-measure hook runs exactly once per retry and not on success."""
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 2.0}))  # flaky sample

    calls = []

    def remeasure():
        calls.append(1)
        gate("CURRENT", _kernel_rows({1: 1.02}))  # healthy re-measurement

    assert cr.check(threshold=0.20, retries=1, remeasure=remeasure) == 0
    assert calls == [1]
    # success path never re-measures
    assert cr.check(threshold=0.20, retries=3, remeasure=remeasure) == 0
    assert calls == [1]


def test_retries_exhausted_still_fails(gate):
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 2.0}))
    calls = []
    assert cr.check(threshold=0.20, retries=2, remeasure=lambda: calls.append(1)) == 1
    assert calls == [1, 1]


def test_missing_rows_is_an_error(gate):
    gate("BASELINE", _kernel_rows({1: 1.0, 2: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    assert cr.check() == 2


def test_step_summary_table_reports_every_gate(gate, tmp_path):
    """The markdown table written for $GITHUB_STEP_SUMMARY names each gated
    benchmark with its final-attempt status — this is what makes the
    nightly lane's continue-on-error gate visible on the run page."""
    summary = tmp_path / "summary.md"
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 2.0}))  # kernel gate fails
    gate("SERVE_BASELINE", _serve_rows(1.10))
    gate("SERVE_CURRENT", _serve_rows(1.15))  # serve gate passes
    assert cr.check(threshold=0.20, summary_path=str(summary)) == 1
    text = summary.read_text()
    assert "| inject_scrub fused_over_pair | ❌ FAIL |" in text
    assert "| serve_throughput cont_over_fixed | ✅ pass |" in text
    # appends (Actions semantics), and the pass path writes a table too
    gate("CURRENT", _kernel_rows({1: 1.02}))
    assert cr.check(threshold=0.20, summary_path=str(summary)) == 0
    assert summary.read_text().count("### Benchmark regression gate") == 2
    assert "| inject_scrub fused_over_pair | ✅ pass |" in summary.read_text()


def test_mesh_gate_fails_on_shrinking_scaling(gate):
    """The exact regression BENCH_mesh.json recorded — d8 throughput below
    d4 — must fail loudly, not sit silently in a JSON artifact."""
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    gate(
        "MESH_CURRENT",
        _mesh_rows({1: 6.527e6, 2: 8.844e6, 4: 1.071e7, 8: 8.747e6}),
    )
    assert cr.check(threshold=0.20) == 1  # d4 -> d8 is x0.82 < floor 0.95
    # monotone (or mildly noisy) scaling passes
    gate(
        "MESH_CURRENT",
        _mesh_rows({1: 6.5e6, 2: 8.8e6, 4: 1.07e7, 8: 1.05e7}),
    )
    assert cr.check(threshold=0.20) == 0  # x0.98 dip tolerated by the floor
    # the floor is a flag, not a constant
    assert cr.check(threshold=0.20, mesh_floor=0.99) == 1


def test_mesh_gate_skipped_without_run_and_errors_on_one_row(gate, tmp_path):
    summary = tmp_path / "summary.md"
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    assert cr.check(threshold=0.20, summary_path=str(summary)) == 0
    assert "| sharded_scrub scaling | ➖ skipped | no current run |" in (
        summary.read_text()
    )
    gate("MESH_CURRENT", _mesh_rows({1: 6.5e6}))
    assert cr.check(threshold=0.20) == 2  # one device count gates nothing


def test_only_restricts_gates(gate):
    """`--only mesh` lanes produce just sharded_scrub.json; the kernel gate
    must not crash on the artifacts they never measured."""
    gate("MESH_CURRENT", _mesh_rows({1: 1.0e6, 8: 7.5e6}))
    # no kernel baseline/current files exist in this lane at all
    assert cr.check(threshold=0.20, only=("mesh",)) == 0
    gate("MESH_CURRENT", _mesh_rows({1: 1.0e6, 8: 0.5e6}))
    assert cr.check(threshold=0.20, only=("mesh",)) == 1
    with pytest.raises(AssertionError):
        cr.check(only=("mesh", "turbo"))


def test_accuracy_gate_shape(gate):
    """The accuracy suite gates on curve *shape*: clean nominal rows and the
    interleaved code's zero-divergence floor strictly below parity65's."""
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    # paper-shaped: parity65 loses the clean output at 0.59 V, ileave88
    # holds it to 0.55 V
    gate("ACC_CURRENT", _acc_rows({"parity65": 0.59, "ileave88": 0.55}))
    assert cr.check(threshold=0.20) == 0
    # inverted codec ordering is a harness/codec regression
    gate("ACC_CURRENT", _acc_rows({"parity65": 0.55, "ileave88": 0.59}))
    assert cr.check(threshold=0.20) == 1
    # equal floors fail too: "strictly deeper" is the acceptance property
    gate("ACC_CURRENT", _acc_rows({"parity65": 0.57, "ileave88": 0.57}))
    assert cr.check(threshold=0.20) == 1


def test_accuracy_gate_nominal_must_be_clean(gate):
    """Nonzero divergence above v_min means the clean reference itself is
    broken (the guardband is fault-free by construction) — always a fail,
    whatever the codec floors look like."""
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    gate(
        "ACC_CURRENT",
        _acc_rows({"parity65": 0.59, "ileave88": 0.55}, nominal_div=0.1),
    )
    assert cr.check(threshold=0.20) == 1


def test_accuracy_gate_skipped_without_run(gate, tmp_path):
    """Like the mesh gate, accuracy is opt-in via its artifact: lanes that
    never ran the campaign must not fail on it. A single-codec campaign
    (the ci.yml smoke) passes on the nominal-clean clause alone."""
    summary = tmp_path / "summary.md"
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    assert cr.check(threshold=0.20, summary_path=str(summary)) == 0
    assert "| accuracy campaign shape | ➖ skipped | no current run |" in (
        summary.read_text()
    )
    gate("ACC_CURRENT", _acc_rows({"secded72": 0.57}))
    assert cr.check(threshold=0.20, only=("accuracy",)) == 0
    gate("ACC_CURRENT", [])
    assert cr.check(threshold=0.20, only=("accuracy",)) == 2


def test_summary_skipped_serve_row(gate, tmp_path):
    summary = tmp_path / "summary.md"
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    assert cr.check(threshold=0.20, summary_path=str(summary)) == 0
    assert "| serve_throughput cont_over_fixed | ➖ skipped | no baseline |" in (
        summary.read_text()
    )


def test_overlap_gate_holds_floor(gate):
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    gate("SERVE_BASELINE", _serve_rows(1.3))
    rows = _serve_rows(1.3) + [
        {"kernel": "serve_scrub_overlap", "overlapped_over_serialized": 1.02}
    ]
    gate("SERVE_CURRENT", rows)
    assert cr.check(threshold=0.20) == 0
    rows[-1]["overlapped_over_serialized"] = 0.90  # overlap became a tax
    gate("SERVE_CURRENT", rows)
    assert cr.check(threshold=0.20) == 1


def test_overlap_gate_skips_old_artifacts(gate):
    gate("BASELINE", _kernel_rows({1: 1.0}))
    gate("CURRENT", _kernel_rows({1: 1.0}))
    gate("SERVE_BASELINE", _serve_rows(1.3))
    gate("SERVE_CURRENT", _serve_rows(1.3))  # predates the overlap row
    assert cr.check(threshold=0.20) == 0


def test_backend_ratio_gate(gate):
    gate("BASELINE", _kernel_rows({1: 1.0}))
    # interpret lane: ratio ~1.0 passes trivially whatever its value
    gate("CURRENT", _kernel_rows({1: 1.0}) + [
        {"kernel": "backend_ratio", "compiled_over_interpret": 1.4,
         "backend": "interpret"},
    ])
    assert cr.check(threshold=0.20) == 0
    # compiled lane slower than the interpreter by > threshold: regression
    gate("CURRENT", _kernel_rows({1: 1.0}) + [
        {"kernel": "backend_ratio", "compiled_over_interpret": 1.4,
         "backend": "compiled"},
    ])
    assert cr.check(threshold=0.20) == 1
    # compiled lane faster than interpret: the expected state, passes
    gate("CURRENT", _kernel_rows({1: 1.0}) + [
        {"kernel": "backend_ratio", "compiled_over_interpret": 0.1,
         "backend": "compiled"},
    ])
    assert cr.check(threshold=0.20) == 0
