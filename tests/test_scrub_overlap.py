"""Off-critical-path async scrub must be invisible (DESIGN.md §18).

The scheduler's overlapped scrub dispatches the fused inject+scrub launch
asynchronously and harvests its counters just before the *next* interval's
tick. The deferred harvest is purely a host-side reordering: the controller
still sees interval N's telemetry before interval N+1's injection, so every
observable — tokens, per-request stats, aggregate cache stats, the kv rail
trajectory — must be byte-identical to the serialized path. These tests pin
that contract, including under preemption-recompute and live rail walks.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import ReliabilityConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = (
        np.random.default_rng(0).integers(0, cfg.vocab, (4, 8)).astype(np.int32)
    )
    return cfg, params, prompts


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params, _ = setup
    return ServingEngine(cfg, params, rel=None, max_len=48)


def _assert_reports_identical(a, b):
    assert sorted(a.outputs) == sorted(b.outputs)
    for rid, toks in a.outputs.items():
        np.testing.assert_array_equal(toks, b.outputs[rid])
    assert a.kv_voltages == b.kv_voltages
    assert a.kv_stats == b.kv_stats
    assert a.request_stats == b.request_stats
    assert a.preemptions == b.preemptions


def test_overlap_identical_under_undervolt(setup, engine):
    """Undervolted cache (real corrections on the read path): overlapped
    scrub produces byte-identical tokens, counters, and voltages."""
    cfg, params, prompts = setup
    reqs = [(prompts[i][: 4 + i], 6 + 3 * i) for i in range(4)]
    kw = dict(n_lanes=2, scrub_interval=1, kv_voltage=0.58)
    ser = engine.serve(reqs, scrub_overlap=False, **kw)
    ovl = engine.serve(reqs, scrub_overlap=True, **kw)
    assert ser.kv_stats.words > 0  # the scrub path actually ran
    _assert_reports_identical(ser, ovl)


def test_overlap_identical_under_preemption_recompute(setup, engine):
    """A tight arena forces preemption + prefill-recompute between a scrub
    dispatch and its deferred harvest; attribution is captured at dispatch
    time, so the reports still match bit for bit."""
    cfg, params, prompts = setup
    reqs = [(prompts[i][: 4 + 2 * i], 5 + 3 * i) for i in range(4)]
    kw = dict(
        n_lanes=2, page_tokens=4, n_pages=8, scrub_interval=2,
        kv_voltage=0.58,
    )
    ser = engine.serve(reqs, scrub_overlap=False, **kw)
    ovl = engine.serve(reqs, scrub_overlap=True, **kw)
    assert ser.preemptions >= 1  # page pressure actually bit
    _assert_reports_identical(ser, ovl)


def test_overlap_identical_rail_walk(setup):
    """walk_kv: the canary-driven kv rail walks on live telemetry. The
    overlapped path must produce the exact same rail trajectory (each move
    lands before the next interval's injection, as serialized)."""
    cfg, params, prompts = setup

    def run(overlap):
        eng = ServingEngine(
            cfg, params,
            rel=ReliabilityConfig(
                platform="vc707", ecc=True, voltage=1.0, mode="inline",
                multi_rail=True, controller_start_v=0.60,
            ),
            max_len=48,
        )
        reqs = [(prompts[i % 4], 12) for i in range(5)]
        rep = eng.serve(
            reqs, n_lanes=3, scrub_interval=1, walk_kv=True,
            kv_voltage=0.60, scrub_overlap=overlap,
        )
        kv = eng.controller.rails["kv"]
        return rep, (kv.voltage, kv.locked)

    ser, ser_rail = run(False)
    ovl, ovl_rail = run(True)
    assert len(set(ser.kv_voltages)) > 1  # the rail actually moved
    assert ser_rail == ovl_rail
    _assert_reports_identical(ser, ovl)


def test_overlap_auto_demotes_under_escalation(setup):
    """With a codec-escalation controller bound, the commit path can be
    rebound mid-stream, so scrub_overlap=None must demote to serialized —
    and still serve the stream correctly."""
    cfg, params, prompts = setup
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            multi_rail=True, controller_start_v=0.60,
            escalation=("secded72", "dected79"),
        ),
        max_len=48,
    )
    reqs = [(prompts[i % 4], 10) for i in range(4)]
    rep = eng.serve(
        reqs, n_lanes=2, scrub_interval=1, walk_kv=True, kv_voltage=0.60,
    )
    assert sorted(rep.outputs) == list(range(len(reqs)))
    for i, (_, n) in enumerate(reqs):
        assert len(rep.outputs[i]) == n
