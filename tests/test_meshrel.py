"""Mesh-sharded reliability layer (DESIGN.md §13).

In-process tests pin the two load-bearing properties on a 1-device mesh —
bit-identity with the unsharded path, and per-shard PRNG stream disjointness
— plus the controller policies and telemetry containers. The 8-fake-device
acceptance path (per-shard rails actually diverging) runs in a subprocess in
tests/test_mesh_serve.py (device count is locked at jax init).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny_cfg
from repro.core.controller import MeshRailController
from repro.core.kvpages import KVGeometry, KVPageArena
from repro.core.planestore import PlaneStore
from repro.core.telemetry import DomainFaultStats, FaultStats, ShardFaultStats
from repro.core.voltage import PLATFORMS
from repro.distributed import meshrel
from repro.distributed.sharding import reliability_axes, reliability_shards
from repro.kernels import ops as kops
from repro.launch.mesh import compat_abstract_mesh, make_reliability_mesh


# ---------------------------------------------------------------------------
# axis conventions
# ---------------------------------------------------------------------------
def test_reliability_axes_conventions():
    m = compat_abstract_mesh((2, 4), ("data", "model"))
    assert reliability_axes(m) == ("data",)
    assert reliability_shards(m) == 2
    mp = compat_abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    assert reliability_axes(mp) == ("pod", "data")
    assert reliability_shards(mp) == 8
    bare = compat_abstract_mesh((4,), ("shard",))
    assert reliability_axes(bare) == ("shard",)
    assert reliability_shards(bare) == 4
    assert meshrel.pad_to_shards(10, 4) == 12
    assert meshrel.pad_to_shards(8, 4) == 8


def test_rail_policy_validation():
    from repro.configs import shapes

    assert shapes.rail_policy("uniform") == "uniform"
    assert shapes.rail_policy("per_shard") == "per_shard"
    with pytest.raises(AssertionError):
        shapes.rail_policy("per_chip")


# ---------------------------------------------------------------------------
# telemetry: shard dimension
# ---------------------------------------------------------------------------
def test_shard_fault_stats_container():
    cnt = np.zeros((2, 2, 8), np.int64)
    cnt[0, 0, 2] = 3  # shard 0, domain a: detected
    cnt[1, 1, 1] = 5  # shard 1, domain b: corrected
    words = [{"a": 10, "b": 20}, {"a": 10, "b": 20}]
    st = ShardFaultStats.from_counter_blocks(cnt, ("a", "b"), words)
    assert st.n_shards == 2 and st.domains == ("a", "b")
    assert st[0]["a"].detected == 3 and st[0]["a"].shard == 0
    assert st[1]["b"].corrected == 5 and st[1]["b"].shard == 1
    red = st.reduced()
    assert red["a"].detected == 3 and red["b"].corrected == 5
    assert red.shard == -1 and red["a"].shard == -1  # aggregate, not a shard row
    assert red["a"].words == 20  # summed across both chips' arrays
    assert st.total().detected == 3 and st.total().corrected == 5
    # accumulate keeps per-shard rows separate
    st.accumulate(st)
    assert st[0]["a"].detected == 6 and st[1]["b"].corrected == 10
    assert st[0]["a"].shard == 0  # same-shard accumulate keeps the tag


def test_summed_accepts_containers():
    d0 = DomainFaultStats({"a": FaultStats(words=1, detected=2, shard=0)}, shard=0)
    d1 = DomainFaultStats({"a": FaultStats(words=1, corrected=3, shard=1)}, shard=1)
    tot = FaultStats.summed([d0, d1])
    assert tot.detected == 2 and tot.corrected == 3 and tot.shard == -1
    sh = ShardFaultStats([d0, d1])
    assert FaultStats.summed([sh]).detected == 2
    # cross-shard reduction of domain rows
    red = DomainFaultStats.summed([d0, d1])
    assert red["a"].detected == 2 and red["a"].corrected == 3
    assert red.shard == -1


# ---------------------------------------------------------------------------
# sharded plane arena: 1-device-mesh bit-identity (the correctness anchor)
# ---------------------------------------------------------------------------
def _mk_store(mesh=None, seed=3):
    rng = np.random.default_rng(0)

    def leaf(k, n):
        return kops.pack_ecc_weights(
            jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        )

    leaves = [leaf(64, 128), leaf(64, 64), leaf(128, 64)]
    keys = ["w_attn", "w_mlp", "w_embed"]
    return PlaneStore(
        leaves,
        keys,
        PLATFORMS["vc707"],
        seed=seed,
        mask_source="device",
        domain_key=lambda k: k.split("_")[1],
        mesh=mesh,
    )


def test_sharded_1dev_bit_identical_to_unsharded():
    """Property: on a 1-device mesh the shard_map'd scrub equals the
    unsharded device path bit-for-bit — counters AND corrected words — for
    uniform and non-uniform rail schedules, across repeated steps."""
    ref = _mk_store()
    mesh = make_reliability_mesh(1)
    sh = _mk_store(mesh=mesh)
    assert sh.n_shards == 1
    schedules = [
        {"attn": 0.58, "mlp": 0.58, "embed": 0.58},
        {"attn": 0.55, "mlp": 0.60, "embed": 0.57},
        {"attn": 0.545, "mlp": 0.545, "embed": 0.58},
    ]
    for volts in schedules:
        l1, d1 = ref.set_rails(volts)
        l2, s2 = sh.set_rails_sharded(volts)
        assert s2.n_shards == 1
        for a, b in zip(l1, l2):
            assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
            assert np.array_equal(np.asarray(a.hi), np.asarray(b.hi))
            assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))
        for d in d1.domains:
            assert d1[d].counters().tolist() == s2[0][d].counters().tolist(), d
            assert d1[d].words == s2[0][d].words
            assert s2[0][d].shard == 0


def test_sharded_1dev_bit_identical_multi_codec_groups():
    """Per-domain codecs split the arena into several codec groups, each
    with its own stream and its own shard_map'd launch — the 1-device mesh
    must still match the unsharded device path group-for-group."""
    rng = np.random.default_rng(2)

    def leaf(k, n):
        return kops.pack_ecc_weights(
            jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        )

    leaves = [leaf(64, 128), leaf(64, 64)]

    def store(mesh=None):
        return PlaneStore(
            leaves,
            ["w_attn", "w_mlp"],
            PLATFORMS["vc707"],
            seed=9,
            mask_source="device",
            domain_key=lambda k: k.split("_")[1],
            codecs={"mlp": "dected79"},
            mesh=mesh,
        )

    ref, sh = store(), store(make_reliability_mesh(1))
    volts = {"attn": 0.55, "mlp": 0.55}
    l1, d1 = ref.set_rails(volts)
    l2, s2 = sh.set_rails_sharded(volts)
    for a, b in zip(l1, l2):
        assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
        assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))
    for d in d1.domains:
        assert d1[d].counters().tolist() == s2[0][d].counters().tolist(), d


def test_sharded_schedule_forms_equivalent():
    mesh = make_reliability_mesh(1)
    store = _mk_store(mesh=mesh)
    volts = {"attn": 0.56, "mlp": 0.58, "embed": 0.57}
    _, a = store.set_rails_sharded(volts)
    _, b = store.set_rails_sharded([volts])
    _, c = store.set_rails_sharded({d: np.array([v]) for d, v in volts.items()})
    for d in a.domains:
        assert (
            a[0][d].counters().tolist()
            == b[0][d].counters().tolist()
            == c[0][d].counters().tolist()
        )


def test_sharded_store_guards():
    mesh = make_reliability_mesh(1)
    with pytest.raises(AssertionError):
        _ = PlaneStore([], [], PLATFORMS["vc707"], mask_source="host", mesh=mesh)
    store = _mk_store(mesh=mesh)
    with pytest.raises(AssertionError):
        store.set_rails({"attn": 0.6, "mlp": 0.6, "embed": 0.6})
    with pytest.raises(AssertionError):
        store.set_voltage(0.6)


# ---------------------------------------------------------------------------
# per-shard PRNG stream disjointness
# ---------------------------------------------------------------------------
def test_weight_shard_streams_disjoint_100_step_walk():
    """No shard reproduces another's fault mask at any step of a 100-step
    voltage walk. Shard keys here are exactly what collectives.shard_key
    computes inside shard_map: base for shard 0, fold_in(base, s) above."""
    from repro.core.faultsim import _device_chunk_masks

    base = jax.random.PRNGKey(3 ^ 0xECC)
    n_shards, n_words = 4, 4096
    prof = PLATFORMS["vc707"]
    # the critical region: shallow steps draw empty fault populations, and
    # an empty mask is trivially shared — disjointness is a property of the
    # *faults*, so every compared step must be non-empty for every shard
    voltages = np.linspace(0.57, prof.v_crash, 100)
    keys = [base] + [jax.random.fold_in(base, s) for s in range(1, n_shards)]
    nonzero_steps = 0
    for vi, v in enumerate(voltages):
        rate = jnp.float32(prof.fault_rate(float(v)))
        sigs, empty = set(), False
        for key in keys:
            chunk_key = jax.random.fold_in(key, 0)  # chunk 0, as the step folds
            mlo, mhi, mpar = _device_chunk_masks(
                chunk_key, n_words, rate, jnp.float32(prof.row_sigma)
            )
            mlo, mhi, mpar = np.asarray(mlo), np.asarray(mhi), np.asarray(mpar)
            if not (mlo.any() or mhi.any() or mpar.any()):
                empty = True
                continue
            sig = (mlo.tobytes(), mhi.tobytes(), mpar.tobytes())
            assert sig not in sigs, (
                f"shard mask collision at step {vi} (v={v:.3f})"
            )
            sigs.add(sig)
        if not empty:
            nonzero_steps += 1
    # the walk genuinely exercised the property on most of its 100 steps
    assert nonzero_steps >= 60, nonzero_steps


def test_kv_shard_streams_disjoint_100_intervals():
    """Replica KV arenas: shard 0 is bit-identical to the historical
    stream; no shard's interval masks ever equal another's."""
    cfg = tiny_cfg()
    geom = KVGeometry.from_config(cfg, page_tokens=4)
    prof = PLATFORMS["vc707"]

    def arena(shard):
        a = KVPageArena(geom, prof, n_pages=2, seed=7, shard=shard)
        a.set_voltage(0.55)
        return a

    legacy = KVPageArena(geom, prof, n_pages=2, seed=7)  # pre-mesh signature
    s0 = arena(0)
    assert np.array_equal(
        np.asarray(jax.random.key_data(s0._key) if hasattr(jax.random, "key_data") else s0._key),
        np.asarray(jax.random.key_data(legacy._key) if hasattr(jax.random, "key_data") else legacy._key),
    )
    arenas = [arena(s) for s in range(3)]
    for step in range(100):
        sigs = set()
        for a in arenas:
            before = (np.asarray(a.lo), np.asarray(a.hi), np.asarray(a.parity))
            a.tick()
            mask = tuple(
                (np.asarray(x) ^ b).tobytes()
                for x, b in zip((a.lo, a.hi, a.parity), before)
            )
            assert mask not in sigs, f"kv mask collision at interval {step}"
            sigs.add(mask)


def test_sweep_sharded_shard0_matches_unsharded():
    from repro.core import sweep

    prof = PLATFORMS["vc707"]
    grid = [(prof, v) for v in (0.58, 0.56, 0.545)]
    ref = sweep.sweep_platform_grid(grid, n_words=4096, seed=5)
    per_shard = sweep.sweep_platform_grid_sharded(grid, 4096, n_shards=3, seed=5)
    assert len(per_shard) == 3
    for a, b in zip(ref, per_shard[0]):
        assert a.stats.counters().tolist() == b.stats.counters().tolist()
        assert b.stats.shard == 0
    # other shards draw different fault populations
    diffs = [
        per_shard[s][-1].stats.counters().tolist() != ref[-1].stats.counters().tolist()
        for s in (1, 2)
    ]
    assert any(diffs)
    vmins = sweep.shard_vmin_spread(
        prof, np.round(np.arange(0.60, 0.539, -0.005), 3), 4096, 3, seed=5
    )
    assert len(vmins) == 3
    assert all(v is not None and prof.v_crash <= v <= 0.60 for v in vmins)
    # a grid whose top voltage already DEDs holds no safe point: None, not
    # the faulting top-of-grid voltage
    deep = sweep.shard_vmin_spread(prof, [prof.v_crash], 1 << 16, 2, seed=5)
    assert deep == [None, None]


# ---------------------------------------------------------------------------
# shard_map'd paged scrub-on-read vs the per-replica arena
# ---------------------------------------------------------------------------
def test_kv_scrub_step_matches_arena_scrub():
    cfg = tiny_cfg()
    geom = KVGeometry.from_config(cfg, page_tokens=4)
    prof = PLATFORMS["vc707"]
    arena = KVPageArena(geom, prof, n_pages=3, seed=11)
    payload = np.random.default_rng(1).standard_normal(
        (4, geom.token_f32)
    ).astype(np.float32)
    arena.commit_tokens(payload, np.array([0, 0, 1, 2]), np.array([0, 1, 0, 0]))
    arena.set_voltage(0.545)
    arena.tick()
    table = np.array([0, 1, 2, arena.scratch_page], np.int32)

    mesh = make_reliability_mesh(1)
    step = meshrel.make_kv_scrub_step(
        mesh, geom.words_per_page, arena._total_words, table.size
    )
    lo, hi, par = arena.lo, arena.hi, arena.parity
    slo, shi, spar, _, _, cnt = step(lo, hi, par, jnp.asarray(table[None]))
    _, acnt = arena.scrub_pages(table)
    assert np.array_equal(np.asarray(cnt)[0], acnt)
    assert np.array_equal(np.asarray(slo), np.asarray(arena.lo))
    assert np.array_equal(np.asarray(shi), np.asarray(arena.hi))
    assert np.array_equal(np.asarray(spar), np.asarray(arena.parity))


# ---------------------------------------------------------------------------
# mesh rail controller policies
# ---------------------------------------------------------------------------
def _shard_stats(per_shard_detected, domain="mlp", words=1000):
    return ShardFaultStats(
        [
            DomainFaultStats(
                {domain: FaultStats(words=words, detected=d, shard=s)}, shard=s
            )
            for s, d in enumerate(per_shard_detected)
        ]
    )


def test_mesh_controller_uniform_worst_shard_lock():
    prof = PLATFORMS["vc707"]
    ctrl = MeshRailController(prof, ("mlp",), n_shards=4, policy="uniform")
    ctrl.update(_shard_stats([0, 0, 0, 0]))
    assert not ctrl.locked
    v_before = ctrl.voltages[0]["mlp"]
    # one shard trips -> the aggregate canary trips -> ALL shards back off
    ctrl.update(_shard_stats([0, 0, 7, 0]))
    assert ctrl.locked
    volts = ctrl.voltages
    assert len(volts) == 4
    assert all(v["mlp"] == volts[0]["mlp"] for v in volts)
    assert volts[0]["mlp"] > v_before - 0.01  # backed off, not descended
    # a reduced DomainFaultStats is accepted too (the psum view)
    ctrl2 = MeshRailController(prof, ("mlp",), n_shards=4, policy="uniform")
    ctrl2.update(_shard_stats([0, 0, 7, 0]).reduced())
    assert ctrl2.locked


def test_mesh_controller_per_shard_independent_walks():
    prof = PLATFORMS["vc707"]
    ctrl = MeshRailController(prof, ("mlp",), n_shards=3, policy="per_shard")
    ctrl.update(_shard_stats([0, 5, 0]))  # only shard 1 trips
    assert ctrl.shard(1).rails["mlp"].locked
    assert not ctrl.shard(0).rails["mlp"].locked
    assert not ctrl.locked
    ctrl.update(_shard_stats([0, 0, 0]))
    volts = ctrl.voltages
    assert volts[0]["mlp"] < volts[1]["mlp"]  # 0 kept walking, 1 held
    # history records carry the shard dimension
    recs = ctrl.history[(1, "mlp")]
    assert recs and all(r.shard == 1 for r in recs)
    with pytest.raises(AssertionError):
        ctrl.update(_shard_stats([0, 0]))  # wrong shard count
    with pytest.raises(AssertionError):
        ctrl.update(_shard_stats([0, 0, 0]).reduced())  # collapsed rows
    with pytest.raises(AssertionError):
        ctrl.pop_codec_changes()  # per-shard ladders unsupported

    one = MeshRailController(prof, ("mlp",), n_shards=1, policy="per_shard")
    from repro.core.controller import MultiRailController

    solo = MultiRailController(prof, ("mlp",))
    for det in (0, 0, 3, 0):
        one.update(_shard_stats([det]))
        solo.update({"mlp": FaultStats(words=1000, detected=det)})
    assert one.voltages[0]["mlp"] == solo.voltages["mlp"]
    assert one.locked == solo.locked


# ---------------------------------------------------------------------------
# request partitioning / merged reports
# ---------------------------------------------------------------------------
def test_partition_requests_round_robin():
    from repro.serving import scheduler as sched

    reqs = sched.normalize_requests(
        [(np.arange(1, 4, dtype=np.int32), 2) for _ in range(7)]
    )
    assert [r.rid for r in reqs] == list(range(7))
    parts = sched.partition_requests(reqs, 3)
    assert [[r.rid for r in p] for p in parts] == [[0, 3, 6], [1, 4], [2, 5]]
    # 1-shard: the whole stream, in order (serve bit-identity anchor)
    assert [r.rid for r in sched.partition_requests(reqs, 1)[0]] == list(range(7))


def test_mesh_serve_report_merge_rejects_duplicates():
    from repro.serving import scheduler as sched

    def rep(rids, detected):
        return sched.ServeReport(
            outputs={r: np.zeros(2, np.int32) for r in rids},
            request_stats={r: FaultStats() for r in rids},
            kv_stats=FaultStats(words=10, detected=detected),
            steps=3,
            preemptions=1,
            kv_voltages=[1.0],
            arena=None,
            pages_free_at_end=0,
        )

    merged = sched.MeshServeReport.merge([rep([0, 2], 1), rep([1], 5)])
    assert set(merged.outputs) == {0, 1, 2}
    assert merged.shard_of == {0: 0, 2: 0, 1: 1}
    assert merged.kv_stats.detected == 6 and merged.steps == 6
    assert [s.detected for s in merged.kv_stats_by_shard] == [1, 5]
    assert [s.shard for s in merged.kv_stats_by_shard] == [0, 1]
    with pytest.raises(AssertionError):
        sched.MeshServeReport.merge([rep([0], 0), rep([0], 0)])
