"""Correlated bursts, the environment matrix, and aging drift (DESIGN.md §14).

Four property groups:
  * **default-off bit-identity** — a disabled BurstProfile / neutral
    environment must reproduce the historical i.i.d. stream bit-for-bit at
    the fault-field, mesh-step, and KV-arena level (the seed contract every
    earlier PR's replay tests depend on);
  * **replayability** — same key/counter -> identical burst masks, and the
    single xp-generic expansion is bit-identical between its numpy-oracle
    and jax paths on shared draws;
  * **distribution** — the per-word burst-size histogram matches the
    configured anchor-class probabilities within sampling tolerance;
  * **scenario acceptance** — interleaved SECDED strictly beats plain SECDED
    correctable coverage under every environment's burst shape, and
    per-shard aging drift makes `per_shard` rail V_mins diverge while
    `uniform` locks the fleet at the worst shard.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import scenario, sweep
from repro.core.controller import MeshRailController, UndervoltController
from repro.core.faultsim import DeviceFaultField, FaultField
from repro.core.kvpages import KVGeometry, KVPageArena
from repro.core.scenario import BurstProfile, expand_bursts
from repro.core.telemetry import DomainFaultStats, FaultStats, ShardFaultStats
from repro.core.voltage import PLATFORMS
from repro.distributed import meshrel

from conftest import tiny_cfg

PROF = PLATFORMS["vc707"]
MBU = scenario.MBU_DISTRIBUTION


# ---------------------------------------------------------------------------
# default-off bit-identity (the seed contract)
# ---------------------------------------------------------------------------
def test_disabled_burst_is_bit_identical_host_and_device():
    """burst=None and a disabled BurstProfile() are the same constructor,
    and both reproduce the historical stream bit-for-bit on each path."""
    n, v = 1 << 14, 0.57
    base = FaultField(PROF, n, seed=3)
    off = FaultField(PROF, n, seed=3, burst=BurstProfile())
    assert off.burst is None  # normalized: shares jit/lru cache entries
    mb, mo = base.masks(v), off.masks(v)
    assert np.array_equal(mb.lo, mo.lo)
    assert np.array_equal(mb.hi, mo.hi)
    assert np.array_equal(mb.parity, mo.parity)

    rates = np.full(n, PROF.fault_rate(v), np.float32)
    dv = base.device_field().masks_for_rates(rates)
    do = off.device_field().masks_for_rates(rates)
    for a, b in zip(dv, do):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_neutral_environment_kv_arena_bit_identical():
    """env=resolve(None, drift=0.0) (neutral: 1x flux, no burst, no drift)
    must be bit-identical to env=None on the KV fault stream."""
    geom = KVGeometry.from_config(tiny_cfg(), page_tokens=4)
    arenas = [
        KVPageArena(geom, PROF, n_pages=3, seed=7, env=e)
        for e in (None, scenario.resolve(None, drift=0.0))
    ]
    for a in arenas:
        a.set_voltage(0.55)
        a.tick()
    a, b = arenas
    assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
    assert np.array_equal(np.asarray(a.hi), np.asarray(b.hi))
    assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))


def test_mesh_chunked_masks_default_matches_device_field():
    """The shard-0 mesh mask stream with burst unset stays bit-identical to
    the unsharded DeviceFaultField — the PR-5 anchor, untouched by the
    burst plumbing."""
    n, v = 3000, 0.55
    field = DeviceFaultField(PROF, n, seed=9, chunk_words=1024)
    rates = jnp.full((n,), PROF.fault_rate(v), jnp.float32)
    ref = field.masks_for_rates(rates)
    got = meshrel._chunked_shard_masks(
        jax.random.PRNGKey(9 ^ 0xECC), n, rates, jnp.float32(PROF.row_sigma),
        8, 1024,
    )
    for a, b in zip(ref, got):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# replayability
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=10_000))
def test_burst_masks_replayable(seed):
    """Same (seed, chunk counter, rate) -> bit-identical burst masks, and
    the burst set is a superset of the base anchors (monotone expansion:
    FIP's ordering survives)."""
    n = 2048
    rates = np.full(n, PROF.fault_rate(0.55), np.float32)
    base = DeviceFaultField(PROF, n, seed=seed).masks_for_rates(rates)
    f = DeviceFaultField(PROF, n, seed=seed, burst=MBU)
    m1 = f.masks_for_rates(rates)
    m2 = f.masks_for_rates(rates)
    for a, b in zip(m1, m2):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(base, m1):  # anchors survive: OR-expansion, never XOR
        a, b = np.asarray(a), np.asarray(b)
        assert np.array_equal(a & b, a)


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=10_000))
def test_expand_bursts_numpy_jax_bit_identical(seed):
    """One implementation, two array namespaces: on shared draws the host
    oracle and the device path agree bit-for-bit."""
    rng = np.random.default_rng(seed)
    nb, m = 72, 1024
    faulty = rng.random((nb, m)) < 0.002
    cu = rng.random((nb, m)).astype(np.float32)
    wu = rng.random((nb, m)).astype(np.float32)
    eb = rng.integers(0, nb, m)
    outn = expand_bursts(faulty, MBU, cu, wu, eb, xp=np)
    outj = expand_bursts(
        jnp.asarray(faulty), MBU, jnp.asarray(cu), jnp.asarray(wu),
        jnp.asarray(eb), xp=jnp,
    )
    assert np.array_equal(outn, np.asarray(outj))
    # disabled profile is the identity, not a zero-probability draw
    assert expand_bursts(faulty, BurstProfile(), xp=np) is faulty


# ---------------------------------------------------------------------------
# burst-size distribution
# ---------------------------------------------------------------------------
def test_burst_histogram_matches_configured_distribution():
    """Sparse anchors (<= 1 per word, mostly) expanded under the MoRS-style
    distribution: the fraction of single-anchor words that end up with 2 and
    3 flipped bits must match the configured class probabilities within
    sampling tolerance (edge truncation costs ~1/72 of promotions)."""
    burst = BurstProfile(double_adjacent=0.12, triple_adjacent=0.02,
                         random_double=0.01)
    rng = np.random.default_rng(0)
    nb, m = 72, 1 << 16
    faulty = rng.random((nb, m)) < 3e-4  # ~1415 anchors, ~0.02/word
    cu = rng.random((nb, m)).astype(np.float32)
    eb = rng.integers(0, nb, m)
    out = expand_bursts(faulty, burst, cu, None, eb, xp=np)

    base_cnt = faulty.sum(axis=0)
    out_cnt = out.sum(axis=0)
    single = base_cnt == 1  # isolate words whose histogram is one anchor's
    n1 = int(single.sum())
    assert n1 > 800  # enough samples for the tolerances below
    sizes = out_cnt[single]
    frac2 = float((sizes == 2).sum()) / n1
    frac3 = float((sizes == 3).sum()) / n1
    # doubles: double_adjacent + random_double = 0.13 (minus edge loss)
    assert 0.08 < frac2 < 0.18, frac2
    # triples: triple_adjacent = 0.02
    assert 0.005 < frac3 < 0.045, frac3
    # promoted bit budget overall: E[extra] = 0.12*1 + 0.02*2 + 0.01*1 = 0.17
    extra = int(out.sum() - faulty.sum())
    anchors = int(faulty.sum())
    assert 0.12 * anchors < extra < 0.22 * anchors, (extra, anchors)


def test_word_adjacent_spills_into_next_word():
    burst = BurstProfile(word_adjacent=1.0)  # every anchor repeats next word
    faulty = np.zeros((72, 8), bool)
    faulty[5, 2] = True
    faulty[9, 7] = True  # last word: truncated, nowhere to spill
    wu = np.zeros((72, 8), np.float32)
    out = expand_bursts(faulty, burst, None, wu, None, xp=np)
    assert out[5, 2] and out[5, 3]  # same bitplane, next word
    assert out[9, 7] and out.sum() == 3  # edge truncation, no wraparound


# ---------------------------------------------------------------------------
# scenario acceptance: interleaving must win under bursts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("env_name", sorted(scenario.ENVIRONMENTS))
def test_ileave_beats_secded_under_bursts(env_name):
    """Under every environment's burst shape, 4-way interleaved SECDED
    corrects strictly more than plain SECDED: adjacent flips land one per
    subcode. This is the design-space result the burst model exists to
    show; it is an acceptance criterion, not just a benchmark row."""
    env = scenario.ENVIRONMENTS[env_name]
    v = scenario.scenario_voltage(PROF, env)
    rows = sweep.sweep_codec_schemes(
        ("secded72", "ileave88"), [(PROF, v)], 1 << 16, seed=0, env=env
    )
    by = {r["codec"]: r for r in rows}
    assert by["secded72"]["environment"] == env_name
    sec, ilv = by["secded72"], by["ileave88"]
    assert sec["faulty_words"] > 50, "scenario voltage drew too few faults"
    assert ilv["coverage_correctable"] > sec["coverage_correctable"]
    # and the bursts are why: SECDED flags the doubles it cannot fix
    assert ilv["detected"] < sec["detected"]


def test_scenario_rows_without_env_are_historical():
    """env=None keeps sweep_codec_schemes bit-for-bit: no environment key,
    same counters as before the scenario axis existed."""
    rows = sweep.sweep_codec_schemes(("secded72",), [(PROF, 0.55)], 4096, seed=0)
    assert "environment" not in rows[0]


# ---------------------------------------------------------------------------
# aging drift: per-shard divergence vs the uniform worst-shard lock
# ---------------------------------------------------------------------------
def test_drift_diverges_per_shard_vmins_and_collapses_at_zero():
    drift_env = scenario.resolve(None, drift=0.5)  # neutral flux, drift only
    voltages = np.round(np.arange(0.60, 0.539, -0.005), 3)
    aged = sweep.shard_vmin_spread(
        PROF, voltages, 1 << 14, 8, seed=5, env=drift_env, age=300.0
    )
    assert len(aged) == 8
    # chips fan out lognormally (e^{1.5 z_s} rate spread at age 300): the
    # per-shard lock points cannot all coincide
    assert len({v for v in aged if v is not None}) >= 2, aged
    # drift=0 collapse: threading the neutral env at age 0/sigma 0 is
    # bit-identical to not threading an env at all
    base = sweep.shard_vmin_spread(PROF, voltages, 1 << 14, 8, seed=5)
    zero = sweep.shard_vmin_spread(
        PROF, voltages, 1 << 14, 8, seed=5,
        env=scenario.resolve(None, drift=0.0), age=300.0,
    )
    assert zero == base
    # weakest aged chip faults earlier (higher lock) than the no-drift walk
    # of the same silicon or at least never later on every chip at once
    assert any(a != b for a, b in zip(aged, base))


def test_soak_per_shard_diverges_uniform_locks_worst_shard():
    """8-shard soak driven by per-(shard, voltage) sweep telemetry under
    drift: `per_shard` rails walk to distinct V_mins; `uniform` locks the
    whole fleet at the worst shard's first DED."""
    drift_env = scenario.resolve(None, drift=0.5)
    voltages = [round(0.60 - 0.005 * i, 3) for i in range(13)]
    grid = [(PROF, v) for v in voltages]
    per_shard_points = sweep.sweep_platform_grid_sharded(
        grid, 1 << 14, 8, seed=5, env=drift_env, age=300.0
    )
    telem = [  # telem[s][v] -> detected count of chip s at voltage v
        {v: p.stats for v, p in zip(voltages, pts)}
        for pts in per_shard_points
    ]

    def stats_at(volts_by_shard):
        def near(v):  # controller steps are 0.005-aligned by construction
            return min(telem[0], key=lambda g: abs(g - v))

        return ShardFaultStats(
            [
                DomainFaultStats(
                    {
                        "mlp": FaultStats(
                            words=1 << 14,
                            detected=telem[s][near(volts_by_shard[s])].detected,
                            shard=s,
                        )
                    },
                    shard=s,
                )
                for s in range(8)
            ]
        )

    kw = dict(step_v=0.005, start_v=0.60)
    per = MeshRailController(PROF, ("mlp",), 8, policy="per_shard", **kw)
    uni = MeshRailController(PROF, ("mlp",), 8, policy="uniform", **kw)
    for _ in range(40):
        per.update(stats_at([v["mlp"] for v in per.voltages]))
        uni.update(stats_at([v["mlp"] for v in uni.voltages]))
        if per.locked and uni.locked:
            break
    assert per.locked and uni.locked
    per_vmins = [v["mlp"] for v in per.voltages]
    uni_vmins = [v["mlp"] for v in uni.voltages]
    # per-shard rails fan out with the drifted silicon...
    assert len(set(per_vmins)) >= 2, per_vmins
    # ...the uniform fleet runs one voltage, pinned by its worst chip
    assert len(set(uni_vmins)) == 1
    assert uni_vmins[0] >= max(per_vmins) - 1e-9, (uni_vmins[0], per_vmins)


def test_adaptive_rail_retreats_when_drift_retrips_locked_canary():
    """Default rails hold once locked; adaptive rails retreat another
    backoff step when the canary re-trips under rising flux (aging drift,
    environment change) — and still never resume descending on their own."""
    quiet = FaultStats(words=1000)
    trip = FaultStats(words=1000, detected=3)
    fixed = UndervoltController(PROF, start_v=PROF.v_min)
    adaptive = UndervoltController(PROF, start_v=PROF.v_min, adaptive=True)
    for c in (fixed, adaptive):
        c.update(quiet)
        c.update(trip)  # first DED: back off + lock
        assert c.locked
    v_lock = adaptive.voltage
    assert fixed.update(trip) == v_lock  # historical: locked means hold
    assert fixed.history[-1].action == "hold"
    assert adaptive.update(trip) == pytest.approx(v_lock + 0.01)
    assert adaptive.history[-1].action == "drift+backoff"
    assert adaptive.locked  # retreat, not a resumed walk
    assert adaptive.update(quiet) == pytest.approx(v_lock + 0.01)
    assert adaptive.history[-1].action == "hold"


def test_kv_arena_burst_stream_replayable_and_denser():
    """Two arenas under the same environment draw bit-identical burst
    streams; the avionics flux+burst stream flips strictly more bits than
    the bare profile at the same voltage."""
    geom = KVGeometry.from_config(tiny_cfg(), page_tokens=4)
    env = scenario.ENVIRONMENTS["avionics"]
    prof = env.scale_profile(PROF)  # engine convention: flux in the profile
    mk = lambda e, p: KVPageArena(geom, p, n_pages=3, seed=7, env=e)
    a, b = mk(env, prof), mk(env, prof)
    v = scenario.scenario_voltage(PROF, env)
    for arena in (a, b):
        arena.set_voltage(v)
        arena.tick()
    assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
    assert np.array_equal(np.asarray(a.hi), np.asarray(b.hi))
    assert np.array_equal(np.asarray(a.parity), np.asarray(b.parity))

    bare = mk(None, PROF)
    bare.set_voltage(v)
    bare.tick()
    flips = lambda x: int(
        np.unpackbits(np.asarray(x.lo).view(np.uint8)).sum()
        + np.unpackbits(np.asarray(x.hi).view(np.uint8)).sum()
    )
    assert flips(a) > flips(bare)
