"""End-to-end behaviour tests for the paper's system (top level).

The paper's pipeline: undervolt -> faults -> ECC -> application metric.
These tests run the whole chain at reduced scale.
"""

import numpy as np

from repro.core import EccMemoryDomain, PLATFORMS


def test_end_to_end_undervolt_read_chain():
    dom = EccMemoryDomain("vc707", seed=0)
    w = np.random.default_rng(0).standard_normal((128, 512)).astype(np.float32)
    dom.write("w", w)
    # guardband: bit-exact
    out, st = dom.read("w", voltage=0.61)
    assert np.array_equal(np.asarray(out), w) and st.faulty_words == 0
    # critical region: faults appear, most are corrected
    out, st = dom.read("w", voltage=0.54)
    assert st.faulty_words > 0
    assert st.corrected / max(st.faulty_words, 1) > 0.8
    wrong_ecc = (np.asarray(out) != w).sum()
    dom2 = EccMemoryDomain("vc707", seed=0, ecc_enabled=False)
    dom2.write("w", w)
    out2, _ = dom2.read("w", voltage=0.54)
    wrong_raw = (np.asarray(out2) != w).sum()
    assert wrong_ecc < wrong_raw  # ECC strictly reduces corrupted values


def test_platform_ordering_matches_paper_fig1():
    """VC707 >> KC705-A > KC705-B at their crash voltages."""
    rates = {}
    for name, prof in PLATFORMS.items():
        rates[name] = prof.faults_per_mbit(prof.v_crash)
    assert rates["vc707"] > rates["kc705a"] > rates["kc705b"]
