"""Sharding rules + an 8-fake-device end-to-end lowering (subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import compat_abstract_mesh, compat_make_mesh


@pytest.fixture(scope="module")
def mesh():
    # single-device mesh: rule logic only depends on axis names/sizes
    return compat_make_mesh((1, 1), ("data", "model"))


def test_spec_rules_basic(mesh):
    # TP axes map to model; embed replicated without fsdp
    assert shd.spec_for(("embed", "heads"), (64, 64), mesh, False) == P(None, "model")
    assert shd.spec_for(("vocab", "embed"), (128, 64), mesh, True) == P("model", "data")
    # one mesh axis never used twice
    assert shd.spec_for(("experts", "embed", "ffn"), (4, 8, 16), mesh, False) == P(
        "model", None, None
    )


def test_spec_divisibility_fallback():
    # AbstractMesh: rule logic only needs axis names/sizes, no devices
    m = compat_abstract_mesh((1, 2), ("data", "model"))
    # 3 not divisible by model=2 -> replicate, next axis picks model up
    assert shd.spec_for(("experts", "ffn"), (3, 8), m, False) == P(None, "model")


def test_dryrun_8dev_subprocess(tmp_path):
    """End-to-end: lower+compile a smoke config on 8 fake devices."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses, json, sys
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import compat_make_mesh
        from repro.models import lm
        from repro.optim import adamw
        from repro.train import train_step as ts

        cfg = get_smoke_config("qwen3-0.6b")
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        tcfg = ts.TrainConfig(optimizer=adamw.AdamWConfig(), remat="full")
        fn = ts.make_train_step(cfg, tcfg)
        pstruct = lm.param_struct(cfg)
        pshard = shd.param_shardings(cfg, mesh, fsdp=False)
        opt_struct = jax.eval_shape(lambda p: adamw.init(p, tcfg.optimizer), pstruct)
        opt_shard = {"m": pshard, "v": pshard, "step": shd.replicated(mesh)}
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
        bshard = shd.batch_shardings(mesh, batch)
        with mesh:
            compiled = jax.jit(
                fn, in_shardings=(pshard, opt_shard, bshard)
            ).lower(pstruct, opt_struct, batch).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one entry per program
            ca = ca[0]
        print(json.dumps({"flops": float(ca.get("flops", 0)), "ok": True}))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["flops"] > 0


def test_cache_shardings_flash_decoding(mesh):
    from repro.configs import get_config
    from repro.configs.shapes import cache_struct

    cfg = get_config("qwen3-0.6b")
    cs = cache_struct(cfg, 128, 1024)
    shards = shd.cache_shardings(cfg, mesh, cs)
    kv = shards["p0"]["k"].spec
    assert kv == P(None, "data", "model", None, None)  # B on data, S on model
