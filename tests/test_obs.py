"""Reliability flight recorder (docs/OBSERVABILITY.md): event schema,
metrics registry, deterministic step-clock traces, export round-trips, and
the two acceptance properties — byte-identical traces across identical runs
and bit-identical serving with the recorder off vs absent."""

import json

import numpy as np
import pytest

import jax

from conftest import tiny_cfg
from repro.core.telemetry import DomainFaultStats, FaultStats, ShardFaultStats
from repro.models import lm
from repro.obs import (
    EVENT_KINDS,
    EventSchemaError,
    KernelProfiler,
    MetricsRegistry,
    TraceRecorder,
    read_jsonl,
    summary_markdown,
    to_chrome_trace,
    to_jsonl,
    validate_events,
)
from repro.obs import profile as obs_profile
from repro.serving import ReliabilityConfig, ServingEngine


# ---------------------------------------------------------------------------
# events + recorder core
# ---------------------------------------------------------------------------
def test_emit_validates_and_orders():
    rec = TraceRecorder()
    rec.emit("serve_begin", n_requests=2, n_lanes=2, scrub_interval=4)
    rec.advance(3)
    ev = rec.emit("gauge", name="queue_depth", value=1)
    assert ev["seq"] == 1 and ev["step"] == 3
    assert validate_events(rec.events) == 2


def test_emit_rejects_unknown_kind_and_missing_payload():
    rec = TraceRecorder()
    with pytest.raises(EventSchemaError):
        rec.emit("not_a_kind")
    with pytest.raises(EventSchemaError):
        rec.emit("gauge", name="only_half")  # missing `value`
    # non-strict recorder defers validation to export/report time
    loose = TraceRecorder(strict=False)
    loose.emit("gauge", name="only_half")
    with pytest.raises(EventSchemaError):
        validate_events(loose.events)


def test_validate_events_rejects_seq_disorder():
    rec = TraceRecorder()
    rec.emit("canary_probe", divergence=0.0)
    rec.emit("canary_probe", divergence=0.1)
    evs = [rec.events[1], rec.events[0]]
    with pytest.raises(EventSchemaError):
        validate_events(evs)


def test_extra_payload_fields_allowed():
    rec = TraceRecorder()
    rec.emit("trie_evict", pages=3, reason="lru")  # extra field rides along
    assert rec.events[0]["reason"] == "lru"
    assert validate_events(rec.events) == 1


def test_every_kind_has_envelope_free_payload():
    # payload field names must never collide with the envelope
    from repro.obs import ENVELOPE_FIELDS

    for kind, fields in EVENT_KINDS.items():
        assert not set(fields) & set(ENVELOPE_FIELDS), kind


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_metrics_counter_gauge_histogram():
    m = MetricsRegistry()
    m.counter("hits").inc()
    m.counter("hits").inc(4)
    m.gauge("depth", shard=0).set(3)
    m.gauge("depth", shard=0).set(1)
    h = m.histogram("lat", buckets=(1, 2, 4))
    for v in (1, 3, 9):
        h.observe(v)
    snap = m.to_dict()
    assert snap["hits"]["value"] == 5
    assert snap["depth{shard=0}"]["value"] == 1
    assert snap["depth{shard=0}"]["max"] == 3
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["counts"][-1] == 1  # 9 overflows the last bucket


def test_metrics_label_identity_and_type_guard():
    m = MetricsRegistry()
    assert m.counter("x", a=1, b=2) is m.counter("x", b=2, a=1)
    assert m.counter("x", a=1, b=2) is not m.counter("x", a=1)
    with pytest.raises(AssertionError):
        m.gauge("x", a=1, b=2)  # same name+labels, different type


def test_observe_fault_stats_folds_containers():
    m = MetricsRegistry()
    st = FaultStats(words=10, corrected=3, detected=1, shard=2)
    dom = DomainFaultStats({"mlp": st, "kv": FaultStats(words=5, silent=2)})
    m.observe_fault_stats("scrub", dom)
    assert m.get("scrub.corrected", domain="mlp", shard=2).value == 3
    assert m.get("scrub.silent", domain="kv").value == 2
    sh = ShardFaultStats([DomainFaultStats({"kv": st}, shard=2)])
    m2 = MetricsRegistry()
    m2.observe_fault_stats("scrub", sh)
    assert m2.get("scrub.words", domain="kv", shard=2).value == 10


# ---------------------------------------------------------------------------
# ShardFaultStats reduction symmetry (satellite: growth-path shard tags)
# ---------------------------------------------------------------------------
def _shard_stats(shard_ids):
    return ShardFaultStats(
        [
            DomainFaultStats(
                {"kv": FaultStats(words=10 * (s + 1), corrected=s, shard=s)},
                shard=s,
            )
            for s in shard_ids
        ]
    )


def test_shardfaultstats_growth_preserves_tags():
    """Accumulating a sub-fleet slice (shards 4..7) into an empty container
    must keep the rows' own shard ids, not collapse them to -1."""
    acc = ShardFaultStats()
    acc.accumulate(_shard_stats([4, 5, 6, 7]))
    assert [d.shard for d in acc.by_shard] == [4, 5, 6, 7]
    assert [d["kv"].shard for d in acc.by_shard] == [4, 5, 6, 7]
    # and the adopted rows are copies, not aliases
    acc.by_shard[0]["kv"].corrected += 100
    fresh = _shard_stats([4, 5, 6, 7])
    assert fresh.by_shard[0]["kv"].corrected == 4


def test_shardfaultstats_summed_matches_accumulate():
    """summed() is the pure partner of accumulate(): same totals, same
    per-row tags, no input mutated."""
    a, b = _shard_stats([0, 1]), _shard_stats([0, 1])
    pure = ShardFaultStats.summed([a, b])
    inplace = _shard_stats([0, 1])
    inplace.accumulate(_shard_stats([0, 1]))
    assert pure.n_shards == inplace.n_shards == 2
    for s in range(2):
        assert pure[s]["kv"].counters().tolist() == inplace[s]["kv"].counters().tolist()
        assert pure[s].shard == inplace[s].shard == s
    # inputs untouched by the pure reduction
    assert a[0]["kv"].corrected == 0 and b[1]["kv"].corrected == 1


def test_faultstats_to_dict_and_coverage_row():
    st = FaultStats(
        words=100, corrected=3, detected=2, silent=1,
        words_1bit=3, words_2bit=2, words_multi=1, faulty_bits=10,
    )
    d = st.to_dict()
    assert d["words"] == 100 and d["faulty_words"] == 6
    assert "shard" not in d  # untagged stats serialize untagged
    assert FaultStats(words=1, shard=3).to_dict()["shard"] == 3
    row = st.coverage_row()
    assert row["coverage_correctable"] == 3 / 6
    assert row["coverage_silent"] == 1 / 6


# ---------------------------------------------------------------------------
# profiler (wall-clock strictly quarantined from the event log)
# ---------------------------------------------------------------------------
def test_profiler_records_only_when_enabled():
    calls = []
    fn = lambda x: (calls.append(x), x * 2)[1]
    assert obs_profile.active() is None
    assert obs_profile.call("noop", fn, 3) == 6  # off: passthrough
    prof = obs_profile.enable(KernelProfiler())
    try:
        assert obs_profile.call("timed", fn, 4) == 8
    finally:
        obs_profile.disable()
    assert obs_profile.active() is None
    rows = prof.to_rows()
    assert [r["name"] for r in rows] == ["timed"]
    assert rows[0]["calls"] == 1 and rows[0]["total_ms"] >= 0.0
    assert rows[0]["backend"] in ("interpret", "compiled")
    assert calls == [3, 4]


# ---------------------------------------------------------------------------
# serve traces: determinism, bit-identity, export round-trips
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    cfg = tiny_cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        (rng.integers(1, 100, size=s).astype(np.int32), n)
        for s, n in [(5, 6), (3, 4), (7, 5), (4, 8)]
    ]
    return cfg, params, reqs


def _serve(cfg, params, reqs, recorder=None):
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            mode="inline", voltage=0.58, multi_rail=True,
            mask_source="device", seed=1,
        ),
        max_len=64, recorder=recorder,
    )
    return eng.serve(reqs, n_lanes=2, scrub_interval=2, walk_kv=True,
                     kv_voltage=0.57)


def test_trace_jsonl_byte_identical_across_runs(serve_setup, tmp_path):
    """The tentpole acceptance property: two identical runs produce
    byte-identical JSONL traces (deterministic step-clock, no wall-clock)."""
    cfg, params, reqs = serve_setup
    texts = []
    for i in range(2):
        rec = TraceRecorder()
        _serve(cfg, params, reqs, recorder=rec)
        p = tmp_path / f"run{i}.jsonl"
        rec.to_jsonl(p)
        texts.append(p.read_bytes())
    assert texts[0] == texts[1]
    evs = read_jsonl(tmp_path / "run0.jsonl")
    assert validate_events(evs) == len(evs) > 0


def test_recorder_off_bit_identical(serve_setup):
    """Recorder absent vs attached: same tokens, same fault counters —
    tracing only reads host values the serve loop already computed."""
    cfg, params, reqs = serve_setup
    r_off = _serve(cfg, params, reqs)
    rec = TraceRecorder()
    r_on = _serve(cfg, params, reqs, recorder=rec)
    assert set(r_off.outputs) == set(r_on.outputs)
    for rid in r_off.outputs:
        assert np.array_equal(r_off.outputs[rid], r_on.outputs[rid]), rid
    assert r_off.kv_stats.counters().tolist() == r_on.kv_stats.counters().tolist()
    assert r_off.kv_voltages == r_on.kv_voltages
    assert r_off.steps == r_on.steps
    assert len(rec.events) > 0


def test_trace_covers_serve_lifecycle(serve_setup):
    cfg, params, reqs = serve_setup
    rec = TraceRecorder()
    rep = _serve(cfg, params, reqs, recorder=rec)
    kinds = {e["kind"] for e in rec.events}
    assert {"serve_begin", "admit", "retire", "kv_scrub", "gauge",
            "rail_step", "serve_end"} <= kinds
    # every request admits exactly once and retires exactly once
    admits = rec.of_kind("admit")
    retires = rec.of_kind("retire")
    assert len(admits) == len(retires) == len(reqs)
    for ev in retires:
        assert ev["latency_steps"] >= ev["tokens"] - 1 >= 0
    # serve_end joins the report
    end = rec.of_kind("serve_end")[-1]
    assert end["steps"] == rep.steps
    assert end["finished"] == len(rep.outputs)
    # kv rail_step events join their causing DED counters inline
    for ev in rec.of_kind("rail_step"):
        assert ev["domain"] == "kv"
        assert ev["words"] >= 0 and ev["corrected"] >= 0
    # metrics fed alongside events
    assert rec.metrics.get("serve.admissions").value == len(reqs)


def test_chrome_trace_layout(serve_setup, tmp_path):
    cfg, params, reqs = serve_setup
    rec = TraceRecorder()
    _serve(cfg, params, reqs, recorder=rec)
    path = tmp_path / "trace.json"
    ct = to_chrome_trace(rec, path)
    loaded = json.loads(path.read_text())
    assert loaded == ct
    evs = ct["traceEvents"]
    # one complete-span per request lifetime (admit -> retire)
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == len(reqs)
    assert all(e["dur"] >= 1 for e in spans)
    # rail voltages exported as counter tracks, gauges too
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert any(n.startswith("V[") for n in counters)
    assert "sched.queue_depth" in counters
    # process metadata names every shard track
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)


def test_summary_markdown_renders(serve_setup, tmp_path):
    cfg, params, reqs = serve_setup
    rec = TraceRecorder()
    _serve(cfg, params, reqs, recorder=rec)
    md = rec.summary_markdown()
    assert "## Rail trajectories" in md
    assert "## Requests" in md
    assert "| kv " in md or "| kv |" in md
    # the report CLI renders the same thing from the written file
    from repro.obs import report as report_cli

    p = tmp_path / "t.jsonl"
    rec.to_jsonl(p)
    out = tmp_path / "t.md"
    assert report_cli.main([str(p), "--out", str(out), "--validate"]) == 0
    assert "## Event counts" in out.read_text()


def test_to_jsonl_accepts_events_or_recorder(tmp_path):
    rec = TraceRecorder()
    rec.emit("canary_probe", divergence=0.5)
    s1 = to_jsonl(rec)
    s2 = to_jsonl(rec.events)
    assert s1 == s2 and s1.endswith("\n")
    assert json.loads(s1.splitlines()[0])["kind"] == "canary_probe"


def test_mesh_serve_single_causal_trace():
    """The mesh acceptance property (ISSUE 9): serving on a forced 2-shard
    host mesh with one recorder yields a single causally-ordered trace in
    which every shard's serve lifecycle appears and every kv rail_step
    joins to its own shard's DED counters inline."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import json
        import numpy as np
        import jax
        from conftest import tiny_cfg
        from repro.launch.mesh import make_reliability_mesh
        from repro.models import lm
        from repro.obs import TraceRecorder, validate_events
        from repro.serving.engine import ReliabilityConfig, ServingEngine

        cfg = tiny_cfg(d_model=64, n_layers=2, d_ff=128, vocab=128)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        reqs = [
            (rng.integers(1, 100, size=int(s), dtype=np.int32), int(n))
            for s, n in zip(
                rng.integers(3, 10, size=8), rng.integers(6, 12, size=8)
            )
        ]
        rec = TraceRecorder()
        e = ServingEngine(
            cfg, params,
            ReliabilityConfig(
                mode="inline", multi_rail=True, mask_source="device",
                voltage=0.58, seed=1, rail_policy="per_shard",
                controller_start_v=0.58,
            ),
            max_len=64, mesh=make_reliability_mesh(2), recorder=rec,
        )
        r = e.serve(reqs, n_lanes=2, scrub_interval=1, walk_kv=True)
        validate_events(rec.events)
        rails = [ev for ev in rec.events if ev["kind"] == "rail_step"]
        print(json.dumps({
            "served": sorted(r.outputs),
            "n_requests": len(reqs),
            "n_events": len(rec.events),
            "serve_shards": sorted({
                ev["shard"] for ev in rec.events
                if ev["kind"] in ("serve_begin", "serve_end", "kv_scrub")
            }),
            "rail_shards": sorted({ev["shard"] for ev in rails}),
            "rail_join": all(
                ev["words"] >= 0 and "detected" in ev for ev in rails
            ),
            "seqs_ordered": all(
                a["seq"] < b["seq"]
                for a, b in zip(rec.events, rec.events[1:])
            ),
        }))
        """
    )
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here]
    )
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["served"] == list(range(res["n_requests"]))
    assert res["n_events"] > 0 and res["seqs_ordered"]
    # both shards' serve lifecycles and rail walks are in the ONE trace
    assert res["serve_shards"] == [0, 1]
    assert set(res["rail_shards"]) >= {0, 1}
    assert res["rail_join"]


def test_autotune_rail_steps_advance_clock(serve_setup):
    """Autotune rounds advance the step clock; rail_step events join the
    controller walk to its counters (one event per round per rail)."""
    cfg, params, reqs = serve_setup
    rec = TraceRecorder()
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            mode="inline", voltage=0.62, mask_source="device", seed=1
        ),
        max_len=64, recorder=rec,
    )
    eng.autotune_voltage(max_rounds=4)
    steps = rec.of_kind("rail_step")
    assert steps and len(steps) == len(eng.controller.history)
    assert [e["step"] for e in steps] == sorted(e["step"] for e in steps)
    assert rec.step >= len(steps)
    acts = {e["action"] for e in steps}
    assert acts <= {
        "hold", "lower", "drift+backoff", "escalate", "acc+backoff",
        "trip+backoff", "floor",
    }
