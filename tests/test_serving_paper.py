"""Serving engine + paper-results regression bands (Figs. 1-3, Table I)."""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core import EccMemoryDomain, FaultStats, PLATFORMS, UndervoltController
from repro.core.nn_accel import EccMLP
from repro.data import mnist
from repro.models import lm
from repro.serving.engine import ReliabilityConfig, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    return cfg, params, prompts


def test_engine_matches_reference_rollout(engine_setup):
    cfg, params, prompts = engine_setup
    eng = ServingEngine(cfg, params, rel=None, max_len=48)
    out = eng.generate(prompts, n_tokens=8)
    # reference: manual greedy rollout through lm primitives
    import jax.numpy as jnp

    cache = lm.init_cache(cfg, prompts.shape[0], 48)
    logits, cache = lm.prefill(params, jnp.asarray(prompts), cfg, cache)
    toks = [np.asarray(jnp.argmax(logits, -1))[:, None]]
    for i in range(7):
        logits, cache = lm.decode_step(
            params, jnp.asarray(toks[-1]), cfg, cache, prompts.shape[1] + i
        )
        toks.append(np.asarray(jnp.argmax(logits, -1))[:, None])
    np.testing.assert_array_equal(out, np.concatenate(toks, 1))


def test_engine_inline_ecc_corrects_moderate_undervolt(engine_setup):
    cfg, params, prompts = engine_setup
    ref = ServingEngine(cfg, params, rel=None, max_len=48).generate(prompts, 8)
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(platform="vc707", ecc=True, voltage=0.57, mode="inline"),
        max_len=48,
    )
    out = eng.generate(prompts, 8)
    # at 0.57 V faults are single-bit & fully corrected -> int8-level agreement
    base = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(platform="vc707", ecc=True, voltage=1.0, mode="inline"),
        max_len=48,
    ).generate(prompts, 8)
    np.testing.assert_array_equal(out, base)
    assert eng.stats.detected == 0


def test_domain_mode_protects_weights(engine_setup):
    cfg, params, prompts = engine_setup
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(platform="vc707", ecc=True, voltage=0.56, mode="domain"),
        max_len=48,
    )
    out = eng.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert eng.stats.corrected > 0 or eng.stats.faulty_words == 0


def test_controller_locks_above_crash():
    prof = PLATFORMS["vc707"]
    dom = EccMemoryDomain("vc707", seed=9)
    dom.write("w", np.random.default_rng(1).standard_normal((256, 256)).astype(np.float32))
    ctrl = UndervoltController(prof, step_v=0.01)
    while not ctrl.locked:
        dom.stats = FaultStats()
        _, stats = dom.read("w", voltage=ctrl.voltage)
        ctrl.update(stats)
    assert prof.v_crash <= ctrl.voltage <= prof.v_min
    # locked voltage is fault-DED-free
    _, stats = dom.read("w", voltage=ctrl.voltage)
    assert stats.detected == 0


# -- paper case study regression bands ----------------------------------------
@pytest.fixture(scope="module")
def trained_mlp():
    xtr, ytr = mnist.make_dataset(6000, split="train")
    xte, yte = mnist.make_dataset(1500, split="test")
    mlp = EccMLP((784, 128, 10), platform="vc707", seed=0)
    mlp.train(xtr, ytr, steps=250)
    return mlp, xte, yte


def test_nn_accelerator_error_ordering(trained_mlp):
    mlp, xte, yte = trained_mlp
    mlp.set_voltage(1.0, ecc=True)
    e_free = mlp.error_rate(xte, yte)
    assert e_free < 0.10  # synthetic task is learnable
    mlp.set_voltage(0.54, ecc=True)
    e_ecc = mlp.error_rate(xte, yte)
    cov = mlp.stats.coverage()
    mlp.set_voltage(0.54, ecc=False)
    e_raw = mlp.error_rate(xte, yte)
    # paper Fig. 3 ordering: free <= ecc << no-ecc
    assert e_ecc <= e_raw + 1e-9
    assert e_ecc - e_free < 0.03
    assert cov["correctable"] > 0.85
    # fused and naive read paths agree bit-exactly
    assert mlp.error_rate(xte, yte, fuse=True) == mlp.error_rate(xte, yte, fuse=False)


def test_power_numbers(trained_mlp):
    mlp, _, _ = trained_mlp
    mlp.set_voltage(0.54, ecc=True)
    assert mlp.bram_power_w() == pytest.approx(0.211, abs=1e-3)
    mlp.set_voltage(1.0, ecc=False)
    assert mlp.bram_power_w() == pytest.approx(2.4, abs=1e-2)
