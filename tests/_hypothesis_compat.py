"""Import hypothesis, or fall back to a tiny deterministic shim.

The test container does not always ship hypothesis; property tests then run a
fixed-seed sampled loop (25 examples) instead of failing collection. Only the
strategy surface the suite actually uses is implemented: ``integers``,
``sampled_from``, ``floats``, and ``tuples``.
"""

from __future__ import annotations


import numpy as np

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi, endpoint=True, dtype=np.uint64))

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng):
            return self.elements[int(rng.integers(0, len(self.elements)))]

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _Tuples:
        def __init__(self, strats):
            self.strats = strats

        def sample(self, rng):
            return tuple(s.sample(rng) for s in self.strats)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def tuples(*strats):
            return _Tuples(strats)

    def settings(**_kw):
        return lambda f: f

    def given(*arg_strats, **kw_strats):
        def deco(f):
            # No functools.wraps: pytest must see a zero-arg signature, not
            # the wrapped function's parameters (it would demand fixtures).
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(25):
                    args = [s.sample(rng) for s in arg_strats]
                    kwargs = {k: s.sample(rng) for k, s in kw_strats.items()}
                    f(*args, **kwargs)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco

    st = _Strategies()

__all__ = ["given", "settings", "st"]
