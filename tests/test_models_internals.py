"""Model internals: attention variants, chunked scans, MoE dispatch."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.models import layers, lm, moe
from tests.conftest import tiny_cfg


# -- attention ---------------------------------------------------------------
@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_flash_matches_full(hkv, rng):
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, hkv, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, hkv, 16)), jnp.float32)
    a = layers.full_attention(q, k, v, causal=True)
    b = layers.flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_noncausal_ragged_kv(rng):
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 24, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 24, 2, 16)), jnp.float32)
    a = layers.full_attention(q, k, v, causal=False)
    b = layers.flash_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=24)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("window", [8, 32])
def test_banded_matches_full_window(window, rng):
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    a = layers.full_attention(q, k, v, causal=True, window=window)
    b = layers.banded_attention(q, k, v, window=window, q_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_decode_attention_grouped_matches_full(rng):
    # decode vs full attention on the last position
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 40, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 40, 2, 16)), jnp.float32)
    out = layers.decode_attention(q, k, v, cur_len=40)
    ref = layers.full_attention(q, k, v, causal=False)  # q sees all 40 slots
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# -- SSM chunked scans vs sequential reference --------------------------------
def _mamba_sequential(decay, inp, c):
    b, s, di, ds = decay.shape
    h = np.zeros((b, di, ds), np.float32)
    ys = []
    for t in range(s):
        h = np.asarray(decay[:, t]) * h + np.asarray(inp[:, t])
        ys.append(np.einsum("bdk,bk->bd", h, np.asarray(c[:, t])))
    return np.stack(ys, 1), h


@settings(max_examples=5, deadline=None)
@given(s=st.sampled_from([64, 128, 192]))
def test_mamba_chunked_exact(s):
    from repro.models.mamba import _ssm_scan_chunked

    rng = np.random.default_rng(s)
    b, di, ds = 2, 8, 4
    decay = jnp.asarray(rng.random((b, s, di, ds)) * 0.9 + 0.05, jnp.float32)
    inp = jnp.asarray(rng.standard_normal((b, s, di, ds)) * 0.1, jnp.float32)
    c = jnp.asarray(rng.standard_normal((b, s, ds)), jnp.float32)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, hf = _ssm_scan_chunked(decay, inp, c, h0)
    y_ref, h_ref = _mamba_sequential(decay, inp, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, atol=1e-4)


def test_rwkv_chunked_exact():
    from repro.models.rwkv6 import _wkv_chunked

    rng = np.random.default_rng(0)
    b, s, h, n = 2, 128, 2, 8
    r = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    w = jnp.asarray(rng.random((b, s, h, n)) * 0.5 + 0.45, jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, n)) * 0.3, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, n, n)) * 0.1, jnp.float32)
    y, sf = _wkv_chunked(r, k, v, w, u, s0)

    # sequential reference
    st_ = np.asarray(s0).copy()
    ys = []
    for t in range(s):
        kv = np.asarray(k[:, t])[..., :, None] * np.asarray(v[:, t])[..., None, :]
        ys.append(
            np.einsum("bhi,bhij->bhj", np.asarray(r[:, t]), st_ + np.asarray(u)[:, :, None] * kv)
        )
        st_ = np.asarray(w[:, t])[..., :, None] * st_ + kv
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), st_, atol=1e-4)


# -- MoE dispatch --------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(4, 64),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    cap=st.integers(1, 64),
)
def test_sort_dispatch_invariants(t, e, k, cap):
    rng = np.random.default_rng(t * 100 + e)
    idx = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    slot_src, slot_valid, kept = moe.sort_dispatch(idx, e, cap)
    slot_src = np.asarray(slot_src)
    slot_valid = np.asarray(slot_valid)
    # every valid slot points to a real token-slot with the right expert
    flat_e = np.asarray(idx).reshape(-1)
    for s, (src, ok) in enumerate(zip(slot_src, slot_valid)):
        if ok:
            assert flat_e[src] == s // cap
    # no token-slot appears twice; capacity respected per expert
    srcs = slot_src[slot_valid]
    assert len(np.unique(srcs)) == len(srcs)
    per_e = slot_valid.reshape(e, cap).sum(1)
    counts = np.bincount(flat_e, minlength=e)
    np.testing.assert_array_equal(per_e, np.minimum(counts, cap))


def test_moe_matches_dense_reference(rng):
    cfg = tiny_cfg(family="moe", n_experts=4, top_k=2, capacity_factor=8.0)
    from repro.models.lm import _moe_spec  # params via spec machinery
    from repro.models import base

    spec = _moe_spec(cfg)
    p = base.materialize(spec, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.5, jnp.float32)
    out, router_logits = moe.moe_ffn(x, p, cfg)

    # dense reference: full softmax-top2 mixture with no capacity drops
    x2 = np.asarray(x).reshape(-1, cfg.d_model)
    logits = x2 @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top2 = np.argsort(-probs, axis=-1)[:, :2]
    ref = np.zeros_like(x2)
    for ti in range(x2.shape[0]):
        pr = probs[ti, top2[ti]]
        pr = pr / pr.sum()
        for j, e in enumerate(top2[ti]):
            h = x2[ti] @ np.asarray(p["w1"][e])
            g = h * (1 / (1 + np.exp(-h)))  # silu
            up = x2[ti] @ np.asarray(p["w3"][e])
            ref[ti] += pr[j] * ((g * up) @ np.asarray(p["w2"][e]))
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, cfg.d_model), ref, atol=2e-3, rtol=2e-3
    )
