"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "shape", [(64,), (1000,), (256, 512), (7, 13), (3, 5, 7)]
)
def test_encode_decode_inject_match_oracle(shape, rng):
    lo = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    par_k = ops.encode(lo, hi)
    par_r = ref.encode_ref(lo, hi)
    assert np.array_equal(np.asarray(par_k), np.asarray(par_r))

    mask = rng.integers(0, 2**32, shape, dtype=np.uint32)
    for _ in range(4):  # sparsify
        mask &= rng.integers(0, 2**32, shape, dtype=np.uint32)
    z32 = jnp.zeros(shape, jnp.uint32)
    zp = jnp.zeros(shape, jnp.uint8)
    flo, fhi, fpar = ops.inject(lo, hi, par_k, jnp.asarray(mask), z32, zp)
    r = ref.inject_ref(lo, hi, par_k, jnp.asarray(mask), z32, zp)
    assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip((flo, fhi, fpar), r))

    out_k = ops.decode(flo, fhi, fpar)
    out_r = ref.decode_ref(flo, fhi, fpar)
    for a, b in zip(out_k, out_r):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mkn", [(8, 64, 128), (33, 512, 256), (128, 1024, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ecc_matmul_fused_naive_oracle(mkn, dtype, rng):
    m, k, n = mkn
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    ew = ops.pack_ecc_weights(w)
    out_f = np.asarray(ops.ecc_matmul(x, ew, fuse=True))
    out_n = np.asarray(ops.ecc_matmul(x, ew, fuse=False))
    out_r = np.asarray(ref.ecc_matmul_ref(x, ew.lo, ew.hi, ew.parity, ew.scale))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out_f, out_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(out_n, out_r, rtol=tol, atol=tol)


def test_fused_kernel_corrects_all_single_bit_faults(rng):
    m, k, n = 16, 512, 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    ew = ops.pack_ecc_weights(w)
    clean = np.asarray(ops.ecc_matmul(x, ew, fuse=True))
    sel = rng.random(ew.lo.shape) < 0.2
    bit = rng.integers(0, 64, ew.lo.shape)
    mlo = np.where(sel & (bit < 32), np.uint32(1) << bit.astype(np.uint32), 0).astype(np.uint32)
    mhi = np.where(sel & (bit >= 32), np.uint32(1) << (bit - 32).astype(np.uint32), 0).astype(np.uint32)
    faulty = dataclasses.replace(ew, lo=ew.lo ^ jnp.asarray(mlo), hi=ew.hi ^ jnp.asarray(mhi))
    out = np.asarray(ops.ecc_matmul(x, faulty, fuse=True))
    np.testing.assert_array_equal(out, clean)
    status = np.asarray(ops.scrub(faulty))
    assert (status == 1).sum() == sel.sum()


def test_int8_word_packing_roundtrip(rng):
    from repro.core import quantize

    q = jnp.asarray(rng.integers(-127, 128, 333, dtype=np.int8))
    lo, hi = quantize.pack_int8_to_words(q)
    q2 = quantize.unpack_words_to_int8(lo, hi, q.size)
    assert np.array_equal(np.asarray(q2), np.asarray(q))


@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.uint32, np.float64])
def test_bit_exact_array_words_roundtrip(dtype, rng):
    from repro.core import quantize

    arr = rng.standard_normal(97).astype(dtype) if dtype != np.uint32 else rng.integers(
        0, 2**32, 97, dtype=np.uint32
    )
    lo, hi, nbytes = quantize.array_to_words_np(arr)
    back = np.asarray(quantize.words_to_array(jnp.asarray(lo), jnp.asarray(hi), nbytes, arr.shape, arr.dtype))
    assert np.array_equal(back.view(np.uint8), arr.view(np.uint8))
