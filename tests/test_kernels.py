"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import backend, ops, ref


@pytest.mark.parametrize(
    "shape", [(64,), (1000,), (256, 512), (7, 13), (3, 5, 7)]
)
def test_encode_decode_inject_match_oracle(shape, rng):
    lo = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    par_k = ops.encode(lo, hi)
    par_r = ref.encode_ref(lo, hi)
    assert np.array_equal(np.asarray(par_k), np.asarray(par_r))

    mask = rng.integers(0, 2**32, shape, dtype=np.uint32)
    for _ in range(4):  # sparsify
        mask &= rng.integers(0, 2**32, shape, dtype=np.uint32)
    z32 = jnp.zeros(shape, jnp.uint32)
    zp = jnp.zeros(shape, jnp.uint8)
    flo, fhi, fpar = ops.inject(lo, hi, par_k, jnp.asarray(mask), z32, zp)
    r = ref.inject_ref(lo, hi, par_k, jnp.asarray(mask), z32, zp)
    assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip((flo, fhi, fpar), r))

    out_k = ops.decode(flo, fhi, fpar)
    out_r = ref.decode_ref(flo, fhi, fpar)
    for a, b in zip(out_k, out_r):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mkn", [(8, 64, 128), (33, 512, 256), (128, 1024, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ecc_matmul_fused_naive_oracle(mkn, dtype, rng):
    m, k, n = mkn
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    ew = ops.pack_ecc_weights(w)
    out_f = np.asarray(ops.ecc_matmul(x, ew, fuse=True))
    out_n = np.asarray(ops.ecc_matmul(x, ew, fuse=False))
    out_r = np.asarray(ref.ecc_matmul_ref(x, ew.lo, ew.hi, ew.parity, ew.scale))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out_f, out_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(out_n, out_r, rtol=tol, atol=tol)


def test_fused_kernel_corrects_all_single_bit_faults(rng):
    m, k, n = 16, 512, 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    ew = ops.pack_ecc_weights(w)
    clean = np.asarray(ops.ecc_matmul(x, ew, fuse=True))
    sel = rng.random(ew.lo.shape) < 0.2
    bit = rng.integers(0, 64, ew.lo.shape)
    mlo = np.where(sel & (bit < 32), np.uint32(1) << bit.astype(np.uint32), 0).astype(np.uint32)
    mhi = np.where(sel & (bit >= 32), np.uint32(1) << (bit - 32).astype(np.uint32), 0).astype(np.uint32)
    faulty = dataclasses.replace(ew, lo=ew.lo ^ jnp.asarray(mlo), hi=ew.hi ^ jnp.asarray(mhi))
    out = np.asarray(ops.ecc_matmul(x, faulty, fuse=True))
    np.testing.assert_array_equal(out, clean)
    status = np.asarray(ops.scrub(faulty))
    assert (status == 1).sum() == sel.sum()


def test_int8_word_packing_roundtrip(rng):
    from repro.core import quantize

    q = jnp.asarray(rng.integers(-127, 128, 333, dtype=np.int8))
    lo, hi = quantize.pack_int8_to_words(q)
    q2 = quantize.unpack_words_to_int8(lo, hi, q.size)
    assert np.array_equal(np.asarray(q2), np.asarray(q))


@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.uint32, np.float64])
def test_bit_exact_array_words_roundtrip(dtype, rng):
    from repro.core import quantize

    arr = rng.standard_normal(97).astype(dtype) if dtype != np.uint32 else rng.integers(
        0, 2**32, 97, dtype=np.uint32
    )
    lo, hi, nbytes = quantize.array_to_words_np(arr)
    back = np.asarray(quantize.words_to_array(jnp.asarray(lo), jnp.asarray(hi), nbytes, arr.shape, arr.dtype))
    assert np.array_equal(back.view(np.uint8), arr.view(np.uint8))


# ---------------------------------------------------------------------------
# backend selection (DESIGN.md §18): compiled lane vs interpret lane


def _backend_case_arrays(rng):
    shape = (64, 512)
    lo = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    par = ops.encode(lo, hi, interpret=True)
    mask = rng.integers(0, 2**32, shape, dtype=np.uint32)
    for _ in range(4):  # sparsify
        mask &= rng.integers(0, 2**32, shape, dtype=np.uint32)
    mlo = jnp.asarray(mask)
    z32 = jnp.zeros(shape, jnp.uint32)
    zp = jnp.zeros(shape, jnp.uint8)
    return lo, hi, par, mlo, z32, zp


_BACKEND_CASES = {
    "encode": lambda a, i: ops.encode(a[0], a[1], interpret=i),
    "decode": lambda a, i: ops.decode(a[0], a[1], a[2], interpret=i),
    "inject": lambda a, i: ops.inject(*a, interpret=i),
    "inject_scrub": lambda a, i: ops.inject_scrub(*a, interpret=i),
}


@pytest.mark.skipif(
    not backend.compiled_available(),
    reason="no compiled Pallas lowering on this host (interpret-only)",
)
@pytest.mark.parametrize("name", sorted(_BACKEND_CASES))
def test_compiled_matches_interpret_bit_for_bit(name, rng):
    """On hosts with a real Pallas lowering, the compiled lane is
    bit-identical to the interpret lane for every kernel entry point."""
    arrays = _backend_case_arrays(rng)
    fn = _BACKEND_CASES[name]
    got = jax.tree.leaves(fn(arrays, False))
    want = jax.tree.leaves(fn(arrays, True))
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), name


def test_forced_compiled_falls_back_cleanly_on_cpu(rng):
    """Forcing backend=compiled on a host without a Pallas lowering must not
    error: the interpret lane engages, fallback is recorded, and results are
    bit-identical to an explicit interpret run. (On hosts where compiled IS
    available this degenerates to the identity test above — fallback stays
    false.)"""
    arrays = _backend_case_arrays(rng)
    want = jax.tree.leaves(ops.inject_scrub(*arrays, interpret=True))
    backend.set_backend("compiled")
    try:
        backend.reset_fallback()
        got = jax.tree.leaves(ops.inject_scrub(*arrays, interpret=None))
        assert backend.fallback_engaged() == (not backend.compiled_available())
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g), np.asarray(w))
    finally:
        backend.set_backend(None)


def test_backend_modes_and_tag():
    assert backend.requested() in backend.VALID
    assert backend.tag() in ("compiled", "interpret")
    with pytest.raises(ValueError):
        backend.set_backend("mosaic")
    backend.set_backend("interpret")
    try:
        assert backend.use_interpret() is True
        assert backend.resolve() == "interpret"
        # an explicit per-call interpret=False is a *request*: honored only
        # when the probe passes, silent interpret fallback otherwise
        assert backend.resolve_interpret(False) == (
            not backend.compiled_available()
        )
    finally:
        backend.set_backend(None)
