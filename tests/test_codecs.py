"""Codec subsystem properties: every registered code honours its guarantees.

The round-trip law under k injected flips, per codec:
  k = 0             -> CLEAN everywhere
  k = 1             -> CORRECTED (SECDED / DEC-TED / interleaved), data
                       restored; DETECTED for parity (corrects nothing)
  k = 2 (distinct)  -> DETECTED for SECDED; CORRECTED + restored for DEC-TED;
                       interleaved: CORRECTED iff the flips land in different
                       subcodes, DETECTED otherwise — never silent
  burst of 4        -> CORRECTED + restored for the 4-way interleaved code
  k = 3 (distinct)  -> DETECTED for DEC-TED (the TED property)

plus: numpy oracle and jnp path bit-identical on random words, and the
construction invariants (Hsiao odd-weight columns, BCH syndrome
distinctness — the latter is proven at build time by codes.base.build_luts).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro import codes
from repro.codes import base, interleaved as il

ALL = ("parity65", "secded72", "ileave88", "dected79")


def _flip(codec, lo, hi, ch, bits):
    """XOR codeword bit positions (data 0..63, check 64..) into planes."""
    lo, hi = np.uint32(lo), np.uint32(hi)
    ch = np.uint32(ch)
    for b in bits:
        if b < 32:
            lo ^= np.uint32(1 << b)
        elif b < 64:
            hi ^= np.uint32(1 << (b - 32))
        else:
            ch ^= np.uint32(1 << (b - 64))
    return lo, hi, codec.check_dtype(ch)


def _decode(codec, lo, hi, ch):
    dlo, dhi, st_ = codec.decode_np(
        np.array([lo], np.uint32), np.array([hi], np.uint32),
        np.array([ch], codec.check_dtype),
    )
    return int(dlo[0]), int(dhi[0]), int(st_[0])


def _encode1(codec, lo, hi):
    return codec.encode_np(np.array([lo], np.uint32), np.array([hi], np.uint32))[0]


# ---------------------------------------------------------------------------
# registry + geometry
# ---------------------------------------------------------------------------
def test_registry_and_geometry():
    assert set(ALL) <= set(codes.names())
    for name in ALL:
        c = codes.get(name)
        assert c.name == name
        assert c.n_bits == 64 + c.n_check
        assert c.check_dtype == (np.uint8 if c.n_check <= 8 else np.uint32)
        assert 0 < c.overhead < 0.5
        assert codes.get(name) is c  # factory is cached
    with pytest.raises(KeyError):
        codes.get("hamming31")


def test_secded_tables_are_the_hsiao_reexport():
    from repro.core import hsiao

    c = codes.get("secded72")
    assert np.array_equal(c.mask_lo, hsiao.MASK_LO)
    assert np.array_equal(c.mask_hi, hsiao.MASK_HI)
    # the dense status table agrees with the historical action LUT
    for synd in range(256):
        action = int(hsiao.SYNDROME_LUT[synd])
        expect = (
            base.STATUS_CLEAN if action == hsiao.LUT_CLEAN
            else base.STATUS_DETECTED if action == hsiao.LUT_DETECT
            else base.STATUS_CORRECTED
        )
        assert int(c.lut_status[synd]) == expect, synd


# ---------------------------------------------------------------------------
# round-trip guarantees
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(word=st.integers(0, 2**64 - 1), codec=st.sampled_from(ALL))
def test_clean_roundtrip(word, codec):
    c = codes.get(codec)
    lo, hi = word & 0xFFFFFFFF, word >> 32
    ch = _encode1(c, lo, hi)
    dlo, dhi, status = _decode(c, lo, hi, ch)
    assert status == base.STATUS_CLEAN and (dlo, dhi) == (lo, hi)


@settings(max_examples=80, deadline=None)
@given(word=st.integers(0, 2**64 - 1), b=st.integers(0, 255), codec=st.sampled_from(ALL))
def test_single_flip(word, b, codec):
    c = codes.get(codec)
    lo, hi = word & 0xFFFFFFFF, word >> 32
    ch = _encode1(c, lo, hi)
    b = b % c.n_bits
    flo, fhi, fch = _flip(c, lo, hi, ch, [b])
    dlo, dhi, status = _decode(c, flo, fhi, fch)
    if c.corrects_random >= 1:
        assert status == base.STATUS_CORRECTED, (codec, b)
        assert (dlo, dhi) == (lo, hi), (codec, b)
    else:  # parity: detect, never touch the data
        assert status == base.STATUS_DETECTED
        assert (dlo, dhi) == (int(flo), int(fhi))


@settings(max_examples=80, deadline=None)
@given(
    word=st.integers(0, 2**64 - 1),
    b1=st.integers(0, 200),
    b2=st.integers(0, 200),
)
def test_double_flip_secded_detects_dected_corrects(word, b1, b2):
    lo, hi = word & 0xFFFFFFFF, word >> 32
    for codec, want in (("secded72", "detect"), ("dected79", "correct")):
        c = codes.get(codec)
        p1, p2 = b1 % c.n_bits, b2 % c.n_bits
        if p1 == p2:
            continue
        ch = _encode1(c, lo, hi)
        flo, fhi, fch = _flip(c, lo, hi, ch, [p1, p2])
        dlo, dhi, status = _decode(c, flo, fhi, fch)
        if want == "detect":
            assert status == base.STATUS_DETECTED, (codec, p1, p2)
        else:
            assert status == base.STATUS_CORRECTED, (codec, p1, p2)
            assert (dlo, dhi) == (lo, hi), (codec, p1, p2)


@settings(max_examples=60, deadline=None)
@given(
    word=st.integers(0, 2**64 - 1),
    b1=st.integers(0, 78),
    b2=st.integers(0, 78),
    b3=st.integers(0, 78),
)
def test_triple_flip_dected_detects(word, b1, b2, b3):
    if len({b1, b2, b3}) != 3:
        return
    c = codes.get("dected79")
    lo, hi = word & 0xFFFFFFFF, word >> 32
    ch = _encode1(c, lo, hi)
    flo, fhi, fch = _flip(c, lo, hi, ch, [b1, b2, b3])
    _, _, status = _decode(c, flo, fhi, fch)
    assert status == base.STATUS_DETECTED, (b1, b2, b3)


@settings(max_examples=60, deadline=None)
@given(word=st.integers(0, 2**64 - 1), start=st.integers(0, 84))
def test_interleaved_corrects_bursts_of_four(word, start):
    c = codes.get("ileave88")
    lo, hi = word & 0xFFFFFFFF, word >> 32
    ch = _encode1(c, lo, hi)
    start = min(start, c.n_bits - 4)
    flo, fhi, fch = _flip(c, lo, hi, ch, [start, start + 1, start + 2, start + 3])
    dlo, dhi, status = _decode(c, flo, fhi, fch)
    assert status == base.STATUS_CORRECTED, start
    assert (dlo, dhi) == (lo, hi), start


@settings(max_examples=60, deadline=None)
@given(
    word=st.integers(0, 2**64 - 1),
    b1=st.integers(0, 87),
    b2=st.integers(0, 87),
)
def test_interleaved_doubles_never_silent(word, b1, b2):
    """2 random flips: corrected when they split across subcodes, detected
    when they share one — SECDED's guarantee is never weakened."""
    if b1 == b2:
        return
    c = codes.get("ileave88")
    lo, hi = word & 0xFFFFFFFF, word >> 32
    ch = _encode1(c, lo, hi)
    flo, fhi, fch = _flip(c, lo, hi, ch, [b1, b2])
    dlo, dhi, status = _decode(c, flo, fhi, fch)
    if b1 % il.N_WAYS == b2 % il.N_WAYS:  # same subcode: a double there
        assert status == base.STATUS_DETECTED, (b1, b2)
    else:
        assert status == base.STATUS_CORRECTED, (b1, b2)
        assert (dlo, dhi) == (lo, hi), (b1, b2)


# ---------------------------------------------------------------------------
# numpy oracle == jnp path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ALL)
def test_numpy_oracle_matches_jnp(codec):
    c = codes.get(codec)
    rng = np.random.default_rng(5)
    n = 512
    lo = rng.integers(0, 2**32, n, dtype=np.uint32)
    hi = rng.integers(0, 2**32, n, dtype=np.uint32)
    ch = c.encode_np(lo, hi)
    assert np.array_equal(
        ch.astype(np.uint32),
        np.asarray(c.encode_jnp(jnp.asarray(lo), jnp.asarray(hi))),
    )
    # corrupt with 0..3 random codeword-bit flips per word
    k = rng.integers(0, 4, n)
    flo, fhi, fch = lo.copy(), hi.copy(), ch.astype(np.uint32)
    for i in range(n):
        for b in rng.choice(c.n_bits, size=k[i], replace=False):
            if b < 32:
                flo[i] ^= np.uint32(1 << b)
            elif b < 64:
                fhi[i] ^= np.uint32(1 << (b - 32))
            else:
                fch[i] ^= np.uint32(1 << (b - 64))
    fch = fch.astype(c.check_dtype)
    nlo, nhi, nst = c.decode_np(flo, fhi, fch)
    jlo, jhi, jst = (
        np.asarray(x)
        for x in c.decode_jnp(jnp.asarray(flo), jnp.asarray(fhi), jnp.asarray(fch))
    )
    assert np.array_equal(nlo, jlo) and np.array_equal(nhi, jhi), codec
    assert np.array_equal(nst, jst), codec


# ---------------------------------------------------------------------------
# construction invariants
# ---------------------------------------------------------------------------
def test_hsiao_generalised_construction():
    from repro.codes.secded import build_hsiao

    for n_data, n_check in ((64, 8), (16, 6)):
        code = build_hsiao(n_data, n_check)
        cols = [int(c) for c in code["data_cols"]] + [
            int(c) for c in code["parity_cols"]
        ]
        assert len(set(cols)) == n_data + n_check
        assert all(bin(c).count("1") % 2 == 1 for c in cols)


def test_dected_systematic_form():
    from repro.codes.dected import build_dected

    code = build_dected()
    # every check bit's mask covers some data bits; LUT corrects 79 singles
    # + C(79,2) doubles, everything else (but 0) detects
    n_corr = int((code["lut_status"] == base.STATUS_CORRECTED).sum())
    assert n_corr == 79 + 79 * 78 // 2
    assert int(code["lut_status"][0]) == base.STATUS_CLEAN


def test_interleaved_bit_ownership_is_a_partition():
    c = codes.get("ileave88")
    # every data bit is covered by exactly one subcode's masks
    owner = np.full(64, -1)
    for b in range(c.n_check):
        s = b % il.N_WAYS
        mask = (int(c.mask_lo[b]), int(c.mask_hi[b]))
        for j in range(64):
            half, bit = (0, j) if j < 32 else (1, j - 32)
            if (mask[half] >> bit) & 1:
                assert owner[j] in (-1, s), j
                owner[j] = s
    assert np.array_equal(owner, np.arange(64) % il.N_WAYS)
