"""§Perf helper: compare baseline vs hillclimb-variant dry-run records."""

from __future__ import annotations

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt(r):
    return (
        f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
        f"t_coll={r['t_collective_s']:.3e} bound={r['bottleneck']} "
        f"roofline_bound={r['roofline_bound_s']:.3e}s "
        f"mem/chip={r['memory']['peak_est_gib']:.2f}GiB "
        f"useful={r.get('useful_flops_ratio', 0) or 0:.2f}"
    )


def main():
    paths = sys.argv[1:] or ["benchmarks/out/dryrun.json", "benchmarks/out/hillclimb.json"]
    rows = []
    for p in paths:
        try:
            rows += load(p)
        except FileNotFoundError:
            pass
    by_cell: dict = {}
    for r in rows:
        if r["status"] != "ok":
            continue
        by_cell.setdefault((r["arch"], r["shape"], r["mesh"]), []).append(r)
    for (arch, shape, mesh), rs in sorted(by_cell.items()):
        if len(rs) < 2 and not any(r.get("label") for r in rs):
            continue
        print(f"\n== {arch} x {shape} @ {mesh} ==")
        base = next((r for r in rs if not r.get("label")), None)
        for r in sorted(rs, key=lambda x: (x.get("label") or "")):
            tag = r.get("label") or "baseline"
            line = f"  {tag:28s} {fmt(r)}"
            if base and r is not base:
                speedup = base["roofline_bound_s"] / max(r["roofline_bound_s"], 1e-30)
                line += f"  [{speedup:.2f}x vs baseline]"
            print(line)


if __name__ == "__main__":
    main()
