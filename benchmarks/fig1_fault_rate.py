"""Paper Fig. 1: fault rate vs voltage for VC707 / KC705-A / KC705-B,
with and without built-in ECC.

The tested memory matches the paper's hardware design: 512 memories of
1024 x 64-bit words (full BRAM utilization on VC707). For each voltage in the
critical region we count raw faulty words and the residual (uncorrected)
faulty words after SECDED — the ECC bars of Fig. 1.

Two execution paths:
  * vmapped (default) — all (platform, voltage) grid points in one compiled
    `core.sweep` call per arena chunk (the fault field is generated once and
    thresholded V times, instead of V mask+decode dispatches);
  * loop — the historical per-voltage Python loop over the host FaultField
    oracle, kept as the reference the vmapped path is tolerance-checked
    against (tests/test_multirail.py).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_line, emit, timed
from repro.core import ecc, sweep, voltage
from repro.core.faultsim import FaultField
from repro.core.telemetry import FaultStats

N_WORDS = 512 * 1024  # 512 x (1024 x 64-bit) words


def _stats_at(field: FaultField, v: float) -> FaultStats:
    masks = field.masks(v)
    # ECC outcome: a 1-flip word corrects, >=2-flip words detect or alias.
    # Build statuses via the decoder on a zero memory (content-independent:
    # syndromes depend only on the flip pattern).
    import jax.numpy as jnp

    lo = jnp.asarray(masks.lo)
    hi = jnp.asarray(masks.hi)
    par = ecc.encode(jnp.zeros_like(lo), jnp.zeros_like(hi)) ^ jnp.asarray(masks.parity)
    _, _, status = ecc.decode(lo, hi, par)
    return FaultStats.from_decode(np.asarray(status), masks.flip_counts())


def _grid():
    """The paper's critical-region grid as flat (profile, voltage) pairs."""
    pairs = []
    for prof in voltage.PLATFORMS.values():
        vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
        pairs.extend((prof, float(v)) for v in vs)
    return pairs


def _row(pname: str, v: float, st: FaultStats, prof, us: float) -> dict:
    mbits = N_WORDS * 72 / (1024 * 1024)
    # raw counters come from the shared serialization (telemetry.to_dict);
    # only the Fig. 1 derived metrics are computed here
    return {
        "platform": pname,
        "voltage": float(v),
        **st.to_dict(),
        "faults_per_mbit": st.faulty_bits / mbits,
        "residual_after_ecc": st.detected + st.silent,
        "ecc_reduction": 1.0 - (st.detected + st.silent) / max(st.faulty_words, 1),
        "model_rate_per_mbit": prof.faults_per_mbit(float(v)),
        "us": us,
    }


def run(vmapped: bool = True) -> list[dict]:
    if not vmapped:
        return run_loop()
    grid = _grid()
    sweep.sweep_platform_grid(grid, N_WORDS, 17)  # warmup / compile
    sweep.reset_dispatch_count()  # count exactly one sweep's dispatches
    t0 = time.perf_counter()
    points = sweep.sweep_platform_grid(grid, N_WORDS, 17)
    us = (time.perf_counter() - t0) * 1e6 / max(len(points), 1)
    rows = [
        _row(pt.platform, pt.voltage, pt.stats, prof, us)
        for (prof, _), pt in zip(grid, points)
    ]
    emit(rows, "fig1_fault_rate")
    return rows


def run_loop() -> list[dict]:
    """Reference path: per-voltage Python loop over the host oracle."""
    rows = []
    for pname, prof in voltage.PLATFORMS.items():
        field = FaultField(prof, N_WORDS, seed=17)
        vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
        for v in vs:
            st, us = timed(_stats_at, field, float(v), repeat=1)
            rows.append(_row(pname, float(v), st, prof, us))
    emit(rows, "fig1_fault_rate")
    return rows


def main():
    rows = run()
    for r in rows:
        print(
            csv_line(
                f"fig1/{r['platform']}@{r['voltage']:.2f}V",
                r["us"],
                f"faults_per_mbit={r['faults_per_mbit']:.1f};"
                f"ecc_reduction={100 * r['ecc_reduction']:.1f}%",
            )
        )
    # headline anchors vs paper
    vc = [r for r in rows if r["platform"] == "vc707"]
    crash = vc[0]
    print(
        f"# VC707 @V_crash: {crash['faults_per_mbit']:.0f} faults/Mbit "
        f"(paper 652); ECC removes {100 * crash['ecc_reduction']:.1f}% "
        f"(paper >90% corrected)"
    )
    print(
        f"# vmapped sweep: {len(rows)} grid points in "
        f"{sweep.dispatch_count()} compiled dispatch(es) "
        f"(loop path: {len(rows)} mask+decode dispatches)"
    )


if __name__ == "__main__":
    main()
