"""Shared benchmark helpers: timing + CSV emission."""

from __future__ import annotations

import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def timed(fn, *args, repeat=3, **kwargs):
    """Returns (result, microseconds_per_call) — result from the last call."""
    fn(*args, **kwargs)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        res = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return res, us


def emit(rows: list[dict], name: str):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def csv_line(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
