"""Paper Fig. 3: NN classification error vs BRAM voltage, with/without ECC.

Trains the paper's MLP accelerator on the synthetic-MNIST task (DESIGN.md
§10: real MNIST unavailable offline; fault-free error calibrated near the
paper's 2.56%), stores int8 weights SECDED-encoded, then sweeps V_CCBRAM
through the critical region measuring classification error and modeled
power. The `fuse=True` read path exercises the Pallas decode-matmul kernel
in interpret mode.

Divergence rows: each point also carries ``divergence_vs_clean`` — the
shared campaign scorer (core/campaign.label_divergence, the classifier form
of the LM campaign's token divergence) against the fault-free predictions —
and the scorer version, so this figure and BENCH_accuracy.json measure
quality loss in the same units (DESIGN.md §15).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, emit, timed
from repro.core import campaign, voltage
from repro.core.nn_accel import EccMLP
from repro.data import mnist

N_TRAIN, N_TEST, STEPS = 20000, 4000, 600


def run() -> list[dict]:
    xtr, ytr = mnist.make_dataset(N_TRAIN, split="train")
    xte, yte = mnist.make_dataset(N_TEST, split="test")
    mlp = EccMLP((784, 256, 128, 10), platform="vc707", seed=0)
    mlp.train(xtr, ytr, steps=STEPS)
    prof = voltage.PLATFORMS["vc707"]

    rows = []
    mlp.set_voltage(prof.v_nom, ecc=True)
    pred0, us0 = timed(mlp.predict, xte, repeat=1)
    err0 = float((pred0 != yte).mean())
    rows.append(
        {"voltage": prof.v_nom, "err_free": err0, "us": us0,
         "power_w": mlp.power_w(),
         "scorer_version": campaign.SCORER_VERSION}
    )
    vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
    for v in vs[::-1]:
        mlp.set_voltage(float(v), ecc=True)
        pred_ecc, us = timed(mlp.predict, xte, repeat=1)
        err_ecc = float((pred_ecc != yte).mean())
        p_ecc = mlp.power_w()
        mlp.set_voltage(float(v), ecc=False)
        pred_raw = mlp.predict(xte)
        err_raw = float((pred_raw != yte).mean())
        rows.append(
            {
                "voltage": float(v),
                "err_ecc": err_ecc,
                "err_no_ecc": err_raw,
                "err_free": err0,
                # quality loss in the campaign's units: prediction churn vs
                # the clean run, not error vs labels (a faulty model can get
                # lucky on labels; it cannot get lucky on the clean output)
                "divergence_vs_clean": campaign.label_divergence(pred0, pred_ecc),
                "divergence_no_ecc": campaign.label_divergence(pred0, pred_raw),
                "scorer_version": campaign.SCORER_VERSION,
                **mlp.stats.coverage_row(),
                "power_w": p_ecc,
                "bram_saving_vs_vmin": voltage.power_saving(prof.v_min, float(v), ecc=True),
                "us": us,
            }
        )
    emit(rows, "fig3_nn_accuracy")
    return rows


def main():
    rows = run()
    for r in rows[1:]:
        print(
            csv_line(
                f"fig3/vc707@{r['voltage']:.2f}V", r["us"],
                f"err_ecc={100 * r['err_ecc']:.2f}%;err_no_ecc={100 * r['err_no_ecc']:.2f}%;"
                f"divergence={r['divergence_vs_clean']:.4f};power={r['power_w']:.2f}W",
            )
        )
    last = rows[-1]
    d_ecc = 100 * (last["err_ecc"] - last["err_free"])
    d_raw = 100 * (last["err_no_ecc"] - last["err_free"])
    print(
        f"# fault-free err {100 * last['err_free']:.2f}% (paper 2.56%); @V_crash "
        f"ECC overhead {d_ecc:+.2f}% vs no-ECC {d_raw:+.2f}% "
        f"(paper +0.56% vs +3.59%); ECC advantage {d_raw / max(d_ecc, 1e-9):.1f}x "
        f"(paper 6.1x); BRAM saving Vmin->Vcrash "
        f"{100 * last['bram_saving_vs_vmin']:.1f}% (paper ~40% incl. guardband ref)"
    )


if __name__ == "__main__":
    main()
