"""Paper Fig. 2b (fault-type histogram vs voltage) and Fig. 2c (FIP).

Fig. 2b: per voltage level in the critical region, counts of correctable
(1-bit) / detectable (2-bit) / undetectable (>=3-bit) faulty words on VC707.

Fig. 2c: Fault Inclusion Property — for each voltage pair (v_hi > v_lo) the
fraction of v_hi's faulty bits still faulty at v_lo (must be 1.0 by
construction; reported as evidence, plus the growth factor).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, emit, timed
from repro.core import voltage
from repro.core.faultsim import FaultField

N_WORDS = 512 * 1024


def run() -> list[dict]:
    prof = voltage.PLATFORMS["vc707"]
    field = FaultField(prof, N_WORDS, seed=17)
    vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
    rows = []
    prev_bits = None
    for v in vs[::-1]:  # scan downward: v_min -> v_crash (paper's sweep order)
        masks, us = timed(field.masks, float(v), repeat=1)
        counts = masks.flip_counts()
        fw = int((counts > 0).sum())
        row = {
            "figure": "2b",
            "voltage": float(v),
            "correctable_1bit": int((counts == 1).sum()),
            "detectable_2bit": int((counts == 2).sum()),
            "undetectable_multi": int((counts >= 3).sum()),
            "faulty_words": fw,
            "us": us,
        }
        # FIP check vs the previous (higher) voltage
        bits = (masks.lo, masks.hi, masks.parity)
        if prev_bits is not None:
            inc = all(
                int((p & ~c).sum()) == 0 for p, c in zip(prev_bits, bits)
            )
            row["fip_holds_vs_prev"] = bool(inc)
            row["growth_factor"] = float(
                counts.sum() / max(prev_count, 1)
            )
        prev_bits = bits
        prev_count = counts.sum()
        rows.append(row)
    emit(rows, "fig2_fault_types")
    return rows


def main():
    rows = run()
    for r in rows:
        frac = (
            f"1bit={r['correctable_1bit']};2bit={r['detectable_2bit']};"
            f"multi={r['undetectable_multi']};fip={r.get('fip_holds_vs_prev', '-')}"
        )
        print(csv_line(f"fig2b/vc707@{r['voltage']:.2f}V", r["us"], frac))
    last = rows[-1]
    fw = max(last["faulty_words"], 1)
    print(
        f"# @V_crash: correctable {100 * last['correctable_1bit'] / fw:.1f}% "
        f"(paper >90%), detectable {100 * last['detectable_2bit'] / fw:.1f}% "
        f"(paper ~7%), FIP holds at every step: "
        f"{all(r.get('fip_holds_vs_prev', True) for r in rows)}"
    )


if __name__ == "__main__":
    main()
