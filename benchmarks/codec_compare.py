"""Scheme-comparison benchmark: ECC codec coverage vs overhead vs throughput.

Runs every registered codec (repro.codes) across the three platform fault
curves (DESIGN.md §12):

  * **coverage** — the vmapped scheme sweep (core/sweep.sweep_codec_schemes)
    classifies one fault field per (platform, voltage) grid point under each
    codec; all codecs share the per-word weakness draw, so the comparison
    isolates the code design.
  * **overhead** — check bits per 64-bit word (the redundancy the power
    model charges via voltage.redundancy_factor).
  * **scrub throughput** — wall time of the generalized scrub-on-read kernel
    (kernels/paged_gather.py) over a fixed page stack, reported relative to
    SECDED in the same process (machine-normalized, like the fused/pair CI
    ratio). Interpret-mode numbers off-TPU.

The emitted JSON (benchmarks/out/codec_compare.json) is the nightly-lane
artifact; the `acceptance` rows record whether DEC-TED and interleaved
SECDED beat plain SECDED's correctable coverage at each platform's deepest
voltage step — the design-space result this subsystem exists to show.

A second table covers the **scenario matrix** (DESIGN.md §14): the same
codec sweep under every named environment (consumer / avionics / space),
each with its flux multiplier and correlated-burst shape, at a
rate-matched voltage per platform (scenario.scenario_voltage — comparable
fault density across environments despite 1x..50000x flux). Its
`scenario_acceptance` rows record whether the 4-way interleaved code beats
plain SECDED's correctable coverage under bursts — per environment, the
result the burst model exists to show.

``--smoke --codec NAME`` runs one codec through the generalized fused
inject+scrub and scrub-on-read kernels on a tiny arena and verifies both
against the codec's numpy oracle — the CI codec-matrix job.
``--scenario-smoke --env NAME`` does the same under one environment's
burst-shaped masks: DeviceFaultField burst masks at the scenario voltage
through the fused kernel, DED lane checked against the codec's numpy
decode oracle plus a mask-replay check — the CI scenario-matrix job.

Usage: python -m benchmarks.codec_compare [--words N] [--seed S]
       python -m benchmarks.codec_compare --smoke --codec dected79
       python -m benchmarks.codec_compare --scenario-smoke --env avionics
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

import numpy as np

import jax.numpy as jnp

from benchmarks.common import csv_line, emit, timed
from repro import codes
from repro.core import scenario, sweep, voltage
from repro.kernels import ops, paged_gather


def scheme_grid():
    """Every platform's critical-region voltage steps (the paper grid)."""
    pairs = []
    for prof in voltage.PLATFORMS.values():
        vs = np.round(np.arange(prof.v_crash, prof.v_min + 1e-9, 0.01), 3)
        pairs.extend((prof, float(v)) for v in vs)
    return pairs


def scrub_throughput(codec_names, pages=16, words_per_page=4096, seed=0):
    """Interpret-mode scrub-on-read wall time per codec on one page stack."""
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.integers(0, 2**32, (pages, words_per_page), dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, (pages, words_per_page), dtype=np.uint32))
    rows = []
    for name in codec_names:
        par = ops.encode(lo, hi, codec=name)

        def scrub():
            import jax

            return jax.block_until_ready(
                paged_gather.gather_scrub_pages(lo, hi, par, codec=name)[3]
            )

        _, us = timed(scrub, repeat=3)
        rows.append(
            {
                "kernel": "codec_scrub",
                "codec": name,
                "pages": pages,
                "words": pages * words_per_page,
                "us": us,
                "words_per_s": pages * words_per_page / (us * 1e-6),
            }
        )
    base = next(r["us"] for r in rows if r["codec"] == "secded72")
    for r in rows:
        r["us_over_secded"] = r["us"] / base
    return rows


SCENARIO_CODECS = ("secded72", "ileave88")


def scenario_grid(env):
    """One rate-matched (platform, voltage) point per platform.

    The environments span 1x..50000x flux; sweeping them at the *same*
    voltage steps saturates space at P_MAX while consumer barely faults.
    scenario_voltage bisects each platform's env-scaled curve to a common
    target fault density, so the codec comparison isolates the burst shape.
    """
    return [
        (prof, scenario.scenario_voltage(prof, env))
        for prof in voltage.PLATFORMS.values()
    ]


def scenario_rows(words: int, seed: int = 0) -> list[dict]:
    """Codec coverage under every environment's burst shape + acceptance."""
    out = []
    for name, env in scenario.ENVIRONMENTS.items():
        cov = sweep.sweep_codec_schemes(
            SCENARIO_CODECS, scenario_grid(env), words, seed=seed, env=env
        )
        for r in cov:
            r["kernel"] = "scenario_coverage"
        out.extend(cov)
        # Acceptance per environment: interleaving must win under bursts on
        # every platform — adjacent flips land one per subcode (codes/
        # interleaved.py), so ileave88 corrects the doubles SECDED only
        # detects. Aggregated across the env's rate-matched grid points.
        cover = {
            c: sum(r["corrected"] for r in cov if r["codec"] == c)
            / max(sum(r["faulty_words"] for r in cov if r["codec"] == c), 1)
            for c in SCENARIO_CODECS
        }
        out.append(
            {
                "kernel": "scenario_acceptance",
                "environment": name,
                "burst": dataclasses.asdict(env.burst),
                "rate_multiplier": env.rate_multiplier,
                "correctable": cover,
                "ileave_beats_secded": cover["ileave88"] > cover["secded72"],
            }
        )
    return out


def acceptance_rows(coverage_rows):
    """Per-platform: do the stronger codes beat SECDED at the deepest step?"""
    out = []
    platforms = sorted({r["platform"] for r in coverage_rows})
    for p in platforms:
        deepest = min(r["voltage"] for r in coverage_rows if r["platform"] == p)
        at = {
            r["codec"]: r["coverage_correctable"]
            for r in coverage_rows
            if r["platform"] == p and r["voltage"] == deepest
        }
        out.append(
            {
                "kernel": "codec_acceptance",
                "platform": p,
                "voltage": deepest,
                "coverage": at,
                "dected_beats_secded": at.get("dected79", 0) > at.get("secded72", 0),
                "ileave_beats_secded": at.get("ileave88", 0) > at.get("secded72", 0),
            }
        )
    return out


def run(words: int = 1 << 18, seed: int = 0) -> list[dict]:
    names = list(codes.names())
    cov = sweep.sweep_codec_schemes(names, scheme_grid(), words, seed=seed)
    for r in cov:
        r["kernel"] = "codec_coverage"
    rows = (
        cov
        + acceptance_rows(cov)
        + scenario_rows(words, seed=seed)
        + scrub_throughput(names, seed=seed)
    )
    emit(rows, "codec_compare")
    return rows


def smoke(codec: str, words: int = 1 << 12, seed: int = 0) -> int:
    """One codec through the generalized kernels vs its numpy oracle."""
    c = codes.get(codec)
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.integers(0, 2**32, words, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, words, dtype=np.uint32))
    par = ops.encode(lo, hi, codec=codec)
    sel = rng.random(words)
    mlo = jnp.asarray((sel < 0.02).astype(np.uint32) << rng.integers(0, 32, words).astype(np.uint32))
    mhi = jnp.asarray(((sel > 0.3) & (sel < 0.32)).astype(np.uint32) << rng.integers(0, 32, words).astype(np.uint32))
    mpar = jnp.asarray(
        ((sel > 0.6) & (sel < 0.61)).astype(np.uint64)
        << rng.integers(0, c.n_check, words).astype(np.uint64)
    ).astype(jnp.dtype(c.check_dtype))

    flo, fhi, fpar, cnt = ops.inject_scrub(lo, hi, par, mlo, mhi, mpar, codec=codec)
    nlo, nhi, nst = c.decode_np(np.asarray(flo), np.asarray(fhi), np.asarray(fpar))
    cnt = np.asarray(cnt)
    ok = cnt[2] == int((nst == 2).sum())

    pages, w = 8, words // 8
    olo, ohi, opar, pcnt = paged_gather.gather_scrub_pages(
        jnp.asarray(np.asarray(flo).reshape(pages, w)),
        jnp.asarray(np.asarray(fhi).reshape(pages, w)),
        jnp.asarray(np.asarray(fpar).reshape(pages, w)),
        codec=codec,
    )
    st = nst.reshape(pages, w)
    exp = np.stack([(st == 0).sum(1), (st == 1).sum(1), (st == 2).sum(1)], 1)
    ok &= np.array_equal(np.asarray(pcnt)[:, :3], exp)
    ok &= np.array_equal(np.asarray(olo), nlo.reshape(pages, w))
    ok &= np.array_equal(np.asarray(ohi), nhi.reshape(pages, w))
    print(
        f"codec-smoke {codec}: {words} words, "
        f"detected={int(cnt[2])} corrected={int(cnt[1])} "
        f"-> {'OK' if ok else 'MISMATCH'}"
    )
    return 0 if ok else 1


def scenario_smoke(env_name: str, words: int = 1 << 13, seed: int = 0) -> int:
    """One environment's burst masks through the fused kernel vs the oracle.

    For each scenario codec: draw the env-scaled DeviceFaultField burst
    masks at the platform's rate-matched scenario voltage, push a random
    clean memory through ops.inject_scrub, and check the kernel's DED lane
    against the codec's numpy decode oracle on the faulted planes — plus a
    replay check (same field, same voltage -> bit-identical masks), the
    determinism contract CI pins per environment.
    """
    from repro.core.faultsim import DeviceFaultField

    env = scenario.ENVIRONMENTS[env_name]
    prof = voltage.PLATFORMS["vc707"]
    v = scenario.scenario_voltage(prof, env)
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.integers(0, 2**32, words, dtype=np.uint32))
    hi = jnp.asarray(rng.integers(0, 2**32, words, dtype=np.uint32))
    ok = True
    for cname in SCENARIO_CODECS:
        c = codes.get(cname)
        field = DeviceFaultField(
            env.scale_profile(prof), words, seed=seed,
            n_check=c.n_check, burst=env.burst,
        )
        mlo, mhi, mpar = field.masks(v)
        rlo, rhi, rpar = field.masks(v)
        replay = (
            bool(jnp.all(mlo == rlo))
            and bool(jnp.all(mhi == rhi))
            and bool(jnp.all(mpar == rpar))
        )
        par = ops.encode(lo, hi, codec=cname)
        flo, fhi, fpar, cnt = ops.inject_scrub(
            lo, hi, par, mlo, mhi, mpar, codec=cname
        )
        _, _, nst = c.decode_np(
            np.asarray(lo ^ mlo), np.asarray(hi ^ mhi),
            np.asarray(par ^ mpar.astype(par.dtype)),
        )
        cnt = np.asarray(cnt)
        match = cnt[2] == int((nst == 2).sum())
        ok &= replay and match
        print(
            f"scenario-smoke {env_name}/{cname}: v={v} "
            f"faulty={int(jnp.count_nonzero(mlo | mhi))} "
            f"detected={int(cnt[2])} corrected={int(cnt[1])} "
            f"replay={'OK' if replay else 'MISMATCH'} "
            f"oracle={'OK' if match else 'MISMATCH'}"
        )
    return 0 if ok else 1


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--words", type=int, default=1 << 18)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--codec", default=None, help="smoke mode: codec to exercise")
    ap.add_argument("--scenario-smoke", action="store_true")
    ap.add_argument("--env", default=None, help="scenario smoke: environment name")
    # parse_known_args: benchmarks.run passes its section name through argv
    args, _ = ap.parse_known_args(argv)
    if args.scenario_smoke:
        targets = [args.env] if args.env else sorted(scenario.ENVIRONMENTS)
        sys.exit(max(scenario_smoke(t, seed=args.seed) for t in targets))
    if args.smoke:
        targets = [args.codec] if args.codec else list(codes.names())
        sys.exit(max(smoke(t) for t in targets))
    rows = run(words=args.words, seed=args.seed)
    for r in rows:
        if r["kernel"] == "codec_scrub":
            print(
                csv_line(
                    f"codec/scrub_{r['codec']}", r["us"],
                    f"words_per_s={r['words_per_s']:.3e};"
                    f"vs_secded={r['us_over_secded']:.2f}",
                )
            )
        elif r["kernel"] == "codec_acceptance":
            print(
                csv_line(
                    f"codec/acceptance_{r['platform']}", 0.0,
                    f"v={r['voltage']:.2f};"
                    f"dected_beats_secded={r['dected_beats_secded']};"
                    f"ileave_beats_secded={r['ileave_beats_secded']}",
                )
            )
        elif r["kernel"] == "scenario_acceptance":
            print(
                csv_line(
                    f"codec/scenario_{r['environment']}", 0.0,
                    f"flux={r['rate_multiplier']:.0f}x;"
                    f"ileave_beats_secded={r['ileave_beats_secded']}",
                )
            )


if __name__ == "__main__":
    main()
