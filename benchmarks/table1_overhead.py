"""Paper Table I: area and power overhead of the built-in ECC.

(a) Area: reproduced as reported (hard-core ECC consumes no extra BRAM; the
    LUT increase is the read/write glue of the test design) — these are
    physical-FPGA constants, quoted for completeness and used by the energy
    model's documentation.
(b) Power: from the calibrated model (exact at the paper's anchors) plus the
    ECC adder; we additionally report the undervolting savings the paper
    derives from it.
"""

from __future__ import annotations

from benchmarks.common import csv_line, emit, timed
from repro.core import voltage

AREA = {  # paper Table I(a), %
    "without_ecc": {"BRAM": 96, "LUT": 3, "FF": 0.25},
    "with_ecc": {"BRAM": 100, "LUT": 12, "FF": 0.25},
}


def run() -> list[dict]:
    rows = []
    for v in (1.0, 0.61, 0.54):
        p_no, us = timed(voltage.bram_power, v, ecc=False)
        p_ecc = voltage.bram_power(v, ecc=True) if v <= 0.61 else None
        rows.append(
            {
                "voltage": v,
                "bram_power_no_ecc_w": p_no,
                "bram_power_ecc_w": p_ecc,
                "ecc_overhead_w": (p_ecc - p_no) if p_ecc else None,
                "us": us,
            }
        )
    rows.append(
        {
            "derived": {
                "saving_vmin_to_vcrash_no_ecc": voltage.power_saving(0.61, 0.54),
                "saving_vmin_to_vcrash_ecc": voltage.power_saving(0.61, 0.54, ecc=True),
                "saving_nom_to_vmin": voltage.power_saving(1.0, 0.61),
                "accel_saving_nom_to_crash": 1.0
                - voltage.accelerator_power(0.54) / voltage.accelerator_power(1.0, ecc=False),
                "area": AREA,
            },
            "us": 0.0,
        }
    )
    emit(rows, "table1_overhead")
    return rows


def main():
    rows = run()
    for r in rows[:-1]:
        e = f"{r['bram_power_ecc_w']:.3f}" if r["bram_power_ecc_w"] else "-"
        print(
            csv_line(
                f"table1/power@{r['voltage']:.2f}V", r["us"],
                f"no_ecc={r['bram_power_no_ecc_w']:.3f}W;ecc={e}W",
            )
        )
    d = rows[-1]["derived"]
    print(
        f"# savings: Vmin->Vcrash {100 * d['saving_vmin_to_vcrash_no_ecc']:.1f}% no-ECC "
        f"(paper 36.1%), {100 * d['saving_vmin_to_vcrash_ecc']:.1f}% ECC (paper 31.9%); "
        f"accelerator nom->crash {100 * d['accel_saving_nom_to_crash']:.1f}% (paper 25.2%)"
    )


if __name__ == "__main__":
    main()
