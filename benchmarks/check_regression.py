"""CI benchmark-regression gate: fused inject+scrub kernel + serving throughput.

Compares fresh ``benchmarks/out/*.json`` against the checked-in
``benchmarks/baseline/*.json`` and exits non-zero on a regression.

Gated metrics (both machine-normalized in-process ratios — raw wall-clocks
are useless across runners, which differ 3-5x):

  * ``fused_over_pair`` (kernel_micro.json): fused inject+scrub time over
    the separate inject->decode pair it replaced. Lower is better; fails
    when the pooled geomean ratio degrades more than ``--threshold``.
  * ``cont_over_fixed`` (serve_throughput.json, when a baseline exists):
    continuous-batching tokens/s over the fixed-batch loop. Higher is
    better; fails when it degrades more than ``--threshold`` vs baseline
    *or* drops below 1.0 — continuous batching beating fixed batching on
    the mixed-length stream is an acceptance property, not just a trend.
    Chains the shared-prefix (> 1.0), traced (>= 0.95) and overlapped-scrub
    (>= 0.98, DESIGN.md §18) absolute floors from the same artifact.
  * ``compiled_over_interpret`` (kernel_micro.json ``backend_ratio`` row):
    the flagship fused kernel timed under backend.resolve()'s lane vs
    forced interpret. Trivially ~1.0 on interpret-only hosts (same code
    path twice — the row records which lane the suite ran under); on a
    compiled-lane host it fails when the real lowering runs more than
    ``--threshold`` slower than the Python emulator.
  * mesh scaling (sharded_scrub.json, when a current run exists): scrub
    words/s must not *shrink* when devices are added. Growing the mesh and
    going slower (the d4 -> d8 dip BENCH_mesh.json once recorded, fixed by
    the collective-free donated steady-state step) is a sharding bug, not
    noise — each step up in device count must keep at least ``--mesh-floor``
    (default 0.97) of the previous count's throughput. No baseline file
    needed: like the cont-over-fixed >= 1.0 clause this is an absolute
    acceptance property of the in-process measurement.
  * accuracy curve shape (accuracy_campaign.json, when a current run
    exists): every nominal-voltage row must score exactly zero divergence
    (the guardband is fault-free by construction — any nonzero score is a
    harness bug), and when both parity65 and ileave88 rows are present the
    interleaved code's zero-divergence floor must reach strictly deeper
    than the detect-only code's (the paper-shaped codec ordering). These
    are deterministic properties of the fixed-seed campaign, not timing
    ratios, so there is no threshold knob.

``--retries N`` re-measures and re-checks up to N times on failure: the
ratios cancel machine speed but a badly descheduled CI runner can still
flake a single measurement. (This used to be a YAML shell `||` retry; as a
flag it is unit-testable and the nightly lane reuses it.)

When ``$GITHUB_STEP_SUMMARY`` is set (or ``--summary PATH`` given), a
pass/fail markdown table of the final attempt is appended there — the
nightly lane runs this gate with ``continue-on-error``, and without the
table an advisory failure is invisible unless someone opens the log.

Usage: python -m benchmarks.check_regression [--threshold 0.20] [--retries 1]
                                             [--summary PATH]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
BASELINE = os.path.join(HERE, "baseline", "kernel_micro.json")
CURRENT = os.path.join(HERE, "out", "kernel_micro.json")
SERVE_BASELINE = os.path.join(HERE, "baseline", "serve_throughput.json")
SERVE_CURRENT = os.path.join(HERE, "out", "serve_throughput.json")
MESH_CURRENT = os.path.join(HERE, "out", "sharded_scrub.json")
ACC_CURRENT = os.path.join(HERE, "out", "accuracy_campaign.json")


def _gated_rows(rows: list[dict]) -> dict:
    return {
        r["words"]: r["fused_over_pair"]
        for r in rows
        if r.get("kernel") == "inject_scrub"
    }


def _check_kernel(threshold: float, results: list | None = None) -> int:
    results = [] if results is None else results
    with open(BASELINE) as f:
        base = _gated_rows(json.load(f))
    with open(CURRENT) as f:
        cur = _gated_rows(json.load(f))
    if not base:
        print("FAIL: baseline has no inject_scrub rows", file=sys.stderr)
        results.append(("inject_scrub fused_over_pair", "error", "baseline has no rows"))
        return 2
    missing = sorted(set(base) - set(cur))
    if missing:
        print(f"FAIL: current run lacks inject_scrub rows for {missing}", file=sys.stderr)
        results.append(
            ("inject_scrub fused_over_pair", "error", f"current run lacks rows {missing}")
        )
        return 2
    # Per-size ratios are reported for debugging; the gate is the geometric
    # mean across sizes — residual timer noise per size is uncorrelated, so
    # the pooled metric is ~sqrt(n) tighter than any single row.
    logs = 0.0
    for words, ref in sorted(base.items()):
        now = cur[words]
        logs += math.log(now / ref)
        print(
            f"inject_scrub {words}w: fused_over_pair {now:.3f} "
            f"(baseline {ref:.3f}, {now / ref - 1.0:+.1%})"
        )
    rel = math.exp(logs / len(base)) - 1.0
    print(f"inject_scrub pooled: {rel:+.1%} vs baseline (gate at +{threshold:.0%})")
    detail = f"pooled {rel:+.1%} vs baseline (gate +{threshold:.0%})"
    rc = 0
    if rel > threshold:
        print(
            f"FAIL: fused inject+scrub slowed down > {threshold:.0%} vs baseline",
            file=sys.stderr,
        )
        results.append(("inject_scrub fused_over_pair", "fail", detail))
        rc = 1
    else:
        results.append(("inject_scrub fused_over_pair", "pass", detail))
    return _check_backend_ratio(threshold, results) or rc


def _check_backend_ratio(threshold: float, results: list) -> int:
    """Compiled-lane trajectory row (DESIGN.md #18), no baseline file.

    On a host whose rows were measured under the interpret lane the ratio is
    the same code path twice and passes trivially (that IS the row's value:
    it records which lane the whole suite ran under). On a compiled-lane
    host, compiled running slower than interpret by more than ``threshold``
    means the real lowering regressed past the Python emulator — fail loudly
    rather than letting the BENCH trajectory silently absorb it. Skips on
    artifacts that predate the row."""
    with open(CURRENT) as f:
        rows = json.load(f)
    row = next((r for r in rows if r.get("kernel") == "backend_ratio"), None)
    if row is None:
        results.append(
            ("kernel backend_ratio", "skipped", "no backend_ratio row")
        )
        return 0
    ratio = float(row["compiled_over_interpret"])
    backend = row.get("backend", "interpret")
    limit = 1.0 + threshold
    print(
        f"kernel backend_ratio: compiled_over_interpret {ratio:.3f} "
        f"(backend {backend}, limit {limit:.2f} when compiled)"
    )
    detail = f"{ratio:.3f} under {backend} lane (limit {limit:.2f})"
    if backend == "compiled" and ratio > limit:
        print(
            f"FAIL: compiled Pallas lane is slower than interpret "
            f"(x{ratio:.2f} > {limit:.2f})",
            file=sys.stderr,
        )
        results.append(("kernel backend_ratio", "fail", detail))
        return 1
    results.append(("kernel backend_ratio", "pass", detail))
    return 0


def _serve_metric(path: str, kernel: str, field: str) -> float | None:
    with open(path) as f:
        rows = json.load(f)
    for r in rows:
        if r.get("kernel") == kernel and field in r:
            return float(r[field])
    return None


def _serve_ratio(path: str) -> float | None:
    return _serve_metric(path, "serve_throughput", "cont_over_fixed")


def _check_serve(threshold: float, results: list | None = None) -> int:
    results = [] if results is None else results
    if not os.path.exists(SERVE_BASELINE):
        results.append(("serve_throughput cont_over_fixed", "skipped", "no baseline"))
        return 0  # throughput gate is opt-in via its baseline file
    if not os.path.exists(SERVE_CURRENT):
        print("FAIL: serve_throughput baseline exists but no current run", file=sys.stderr)
        results.append(("serve_throughput cont_over_fixed", "error", "no current run"))
        return 2
    ref = _serve_ratio(SERVE_BASELINE)
    now = _serve_ratio(SERVE_CURRENT)
    if ref is None or now is None:
        print("FAIL: serve_throughput rows missing", file=sys.stderr)
        results.append(("serve_throughput cont_over_fixed", "error", "rows missing"))
        return 2
    rc = 0
    floor = max(1.0, ref * (1.0 - threshold))
    print(
        f"serve_throughput: cont_over_fixed {now:.3f} "
        f"(baseline {ref:.3f}, floor {floor:.3f})"
    )
    detail = f"{now:.3f} (baseline {ref:.3f}, floor {floor:.3f})"
    if now < floor:
        print(
            f"FAIL: continuous batching no longer beats fixed batching by enough "
            f"(ratio {now:.3f} < floor {floor:.3f})",
            file=sys.stderr,
        )
        results.append(("serve_throughput cont_over_fixed", "fail", detail))
        rc = 1
    else:
        results.append(("serve_throughput cont_over_fixed", "pass", detail))
    rc = _check_shared_prefix(threshold, results) or rc
    rc = _check_traced(results) or rc
    rc = _check_overlap(results) or rc
    return rc


# Flight-recorder overhead floor: traced serving must retain at least this
# fraction of untraced tokens/s. Absolute (no baseline trend): tracing is an
# always-on-capable diagnostic, so its cost budget is "in the noise" forever,
# not "no worse than last time".
TRACE_FLOOR = 0.95


def _check_traced(results: list) -> int:
    """Observability overhead gate: traced_over_untraced >= TRACE_FLOOR.

    Skips when the current run predates the serve_traced row (older
    serve_throughput.json artifacts), exactly like the shared-prefix gate
    skips metric-less baselines."""
    tnow = _serve_metric(SERVE_CURRENT, "serve_traced", "traced_over_untraced")
    if tnow is None:
        results.append(
            ("serve traced_over_untraced", "skipped", "no serve_traced row")
        )
        return 0
    print(
        f"serve_traced: traced_over_untraced {tnow:.3f} "
        f"(absolute floor {TRACE_FLOOR:.2f})"
    )
    detail = f"{tnow:.3f} (absolute floor {TRACE_FLOOR:.2f})"
    if tnow < TRACE_FLOOR:
        print(
            f"FAIL: tracing costs serving throughput "
            f"(ratio {tnow:.3f} < floor {TRACE_FLOOR:.2f})",
            file=sys.stderr,
        )
        results.append(("serve traced_over_untraced", "fail", detail))
        return 1
    results.append(("serve traced_over_untraced", "pass", detail))
    return 0


# Async-scrub floor (DESIGN.md #18): overlapped scrub must retain at least
# this fraction of serialized tokens/s. Absolute, like TRACE_FLOOR: moving a
# launch the serialized path blocks on off the critical path must never cost
# throughput — 0.98 leaves timer noise, not a real tax.
OVERLAP_FLOOR = 0.98


def _check_overlap(results: list) -> int:
    """Overlapped-vs-serialized scrub gate: overlapped_over_serialized >=
    OVERLAP_FLOOR. Skips artifacts that predate the serve_scrub_overlap
    row, exactly like the traced gate."""
    onow = _serve_metric(
        SERVE_CURRENT, "serve_scrub_overlap", "overlapped_over_serialized"
    )
    if onow is None:
        results.append(
            ("serve overlapped_over_serialized", "skipped",
             "no serve_scrub_overlap row")
        )
        return 0
    print(
        f"serve_scrub_overlap: overlapped_over_serialized {onow:.3f} "
        f"(absolute floor {OVERLAP_FLOOR:.2f})"
    )
    detail = f"{onow:.3f} (absolute floor {OVERLAP_FLOOR:.2f})"
    if onow < OVERLAP_FLOOR:
        print(
            f"FAIL: overlapped scrub costs serving throughput "
            f"(ratio {onow:.3f} < floor {OVERLAP_FLOOR:.2f})",
            file=sys.stderr,
        )
        results.append(("serve overlapped_over_serialized", "fail", detail))
        return 1
    results.append(("serve overlapped_over_serialized", "pass", detail))
    return 0


def _check_shared_prefix(threshold: float, results: list) -> int:
    """Prefix-sharing floor (DESIGN.md §16): shared_over_private > 1.0
    absolutely — the copy-on-write trie must never cost throughput on the
    shared-heavy stream — plus the usual baseline-relative clause once a
    baseline row exists. Baselines written before the metric existed skip
    the relative clause instead of erroring (the absolute floor still
    gates)."""
    sref = _serve_metric(SERVE_BASELINE, "serve_shared_prefix", "shared_over_private")
    snow = _serve_metric(SERVE_CURRENT, "serve_shared_prefix", "shared_over_private")
    if snow is None:
        if sref is None:
            results.append(
                ("serve shared_over_private", "skipped", "no shared-prefix rows")
            )
            return 0
        print(
            "FAIL: baseline has a shared-prefix row but the current run "
            "does not measure it",
            file=sys.stderr,
        )
        results.append(("serve shared_over_private", "error", "no current row"))
        return 2
    floor = 1.0 if sref is None else max(1.0, sref * (1.0 - threshold))
    base_note = "absolute" if sref is None else f"baseline {sref:.3f}"
    print(
        f"serve_shared_prefix: shared_over_private {snow:.3f} "
        f"({base_note}, floor {floor:.3f})"
    )
    detail = f"{snow:.3f} ({base_note}, floor {floor:.3f})"
    # the absolute clause is strict (> 1.0); the relative clause allows == floor
    failed = (snow <= floor) if sref is None else (snow < floor)
    if failed:
        print(
            f"FAIL: prefix sharing no longer beats private pages "
            f"(ratio {snow:.3f}, floor {floor:.3f})",
            file=sys.stderr,
        )
        results.append(("serve shared_over_private", "fail", detail))
        return 1
    results.append(("serve shared_over_private", "pass", detail))
    return 0


def _check_mesh(mesh_floor: float, results: list | None = None) -> int:
    """Scaling-ratio floor on the sharded scrub step (no baseline file).

    Reads the per-device-count rows sharded_scrub.json emits and requires
    every step up in device count to retain at least ``mesh_floor`` of the
    previous count's words/s. Adding chips must never lose throughput:
    the historical d8-below-d4 measurement (8.75e6 vs 1.07e7 words/s)
    is exactly the regression this gate turns from a silent JSON row into
    a red CI lane.
    """
    results = [] if results is None else results
    if not os.path.exists(MESH_CURRENT):
        results.append(("sharded_scrub scaling", "skipped", "no current run"))
        return 0  # mesh gate is opt-in via running benchmarks.sharded_scrub
    with open(MESH_CURRENT) as f:
        rows = [r for r in json.load(f) if "devices" in r and "words_per_s" in r]
    by_dev = {int(r["devices"]): float(r["words_per_s"]) for r in rows}
    if len(by_dev) < 2:
        print("FAIL: sharded_scrub.json has < 2 device counts", file=sys.stderr)
        results.append(("sharded_scrub scaling", "error", "< 2 device counts"))
        return 2
    devs = sorted(by_dev)
    rc, worst = 0, 1.0
    for lo_d, hi_d in zip(devs, devs[1:]):
        ratio = by_dev[hi_d] / by_dev[lo_d]
        worst = min(worst, ratio)
        print(
            f"sharded_scrub d{lo_d}->d{hi_d}: {by_dev[lo_d]:.3e} -> "
            f"{by_dev[hi_d]:.3e} words/s (x{ratio:.2f}, floor {mesh_floor:.2f})"
        )
        if ratio < mesh_floor:
            print(
                f"FAIL: scrub throughput shrinks {lo_d}->{hi_d} devices "
                f"(x{ratio:.2f} < floor {mesh_floor:.2f})",
                file=sys.stderr,
            )
            rc = 1
    detail = f"worst step ratio x{worst:.2f} (floor {mesh_floor:.2f})"
    results.append(("sharded_scrub scaling", "fail" if rc else "pass", detail))
    return rc


def _zero_floor(rows: list[dict], codec: str) -> float | None:
    """Deepest (lowest) voltage at which ``codec`` still scores exactly zero
    divergence, or None when the codec never holds a clean point."""
    zero = [
        float(r["voltage"]) for r in rows
        if r.get("codec") == codec and r.get("divergence") == 0.0
    ]
    return min(zero) if zero else None


def _check_accuracy(results: list | None = None) -> int:
    """Shape gate on the accuracy campaign (no baseline file, no threshold).

    Two absolute acceptance properties of accuracy_campaign.json:
      1. nominal rows diverge exactly 0.0 — faults cannot exist above v_min,
         so any score there means the clean reference itself is broken;
      2. ileave88's zero-divergence floor < parity65's when both codecs were
         campaigned — the burst-correcting code must hold the clean output
         strictly deeper than the detect-only code.
    """
    results = [] if results is None else results
    if not os.path.exists(ACC_CURRENT):
        results.append(("accuracy campaign shape", "skipped", "no current run"))
        return 0  # accuracy gate is opt-in via running benchmarks.accuracy_campaign
    with open(ACC_CURRENT) as f:
        rows = [r for r in json.load(f) if "divergence" in r]
    if not rows:
        print("FAIL: accuracy_campaign.json has no scored rows", file=sys.stderr)
        results.append(("accuracy campaign shape", "error", "no scored rows"))
        return 2
    rc = 0
    bad_nominal = [
        r for r in rows if r.get("nominal") and r["divergence"] != 0.0
    ]
    if bad_nominal:
        worst = max(bad_nominal, key=lambda r: r["divergence"])
        print(
            f"FAIL: {len(bad_nominal)} nominal rows diverged from the clean "
            f"run (worst: {worst['codec']}@{worst['voltage']}V = "
            f"{worst['divergence']:.4f})",
            file=sys.stderr,
        )
        rc = 1
    floors = {c: _zero_floor(rows, c) for c in ("parity65", "ileave88")}
    ordered = None
    if all(f is not None for f in floors.values()):
        ordered = floors["ileave88"] < floors["parity65"]
        print(
            f"accuracy zero-divergence floors: parity65 {floors['parity65']}V, "
            f"ileave88 {floors['ileave88']}V (interleaved must reach deeper)"
        )
        if not ordered:
            print(
                "FAIL: ileave88 does not hold zero divergence deeper than "
                "parity65",
                file=sys.stderr,
            )
            rc = 1
    detail = (
        f"{len(rows)} rows; nominal clean: {not bad_nominal}"
        + (f"; ileave88<parity65 floor: {ordered}" if ordered is not None else "")
    )
    results.append(("accuracy campaign shape", "fail" if rc else "pass", detail))
    return rc


def _default_remeasure() -> None:
    """Re-run the measured benchmarks in a fresh process (clean jit caches)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(HERE, "..", "src"), env.get("PYTHONPATH")) if p
    )
    for mod in (
        "benchmarks.kernel_micro",
        "benchmarks.serve_throughput",
        "benchmarks.sharded_scrub",
    ):
        if mod.endswith("serve_throughput") and not os.path.exists(SERVE_BASELINE):
            continue
        if mod.endswith("sharded_scrub") and not os.path.exists(MESH_CURRENT):
            continue  # mesh gate is opt-in; don't start measuring it on retry
        subprocess.run(
            [sys.executable, "-m", mod],
            check=True,
            cwd=os.path.join(HERE, ".."),
            env=env,
        )


def write_step_summary(results: list, path: str) -> None:
    """Append the per-benchmark pass/fail table as GitHub-flavoured markdown.

    ``results``: (benchmark, status, detail) triples from the final gate
    attempt. Written to ``path`` ($GITHUB_STEP_SUMMARY in Actions) so an
    advisory (continue-on-error) failure is visible on the run page without
    opening the log.
    """
    icon = {"pass": "✅ pass", "fail": "❌ FAIL", "error": "⚠️ error",
            "skipped": "➖ skipped"}
    lines = [
        "### Benchmark regression gate",
        "",
        "| benchmark | status | detail |",
        "| --- | --- | --- |",
    ]
    for name, status, detail in results:
        lines.append(f"| {name} | {icon.get(status, status)} | {detail} |")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


GATES = ("kernel", "serve", "mesh", "accuracy")


def check(
    threshold: float = 0.20, retries: int = 0, remeasure=None,
    summary_path: str | None = None, mesh_floor: float = 0.97,
    only: tuple = GATES,
) -> int:
    """Run the selected gates; on failure, re-measure and re-check up to
    ``retries`` times. ``remeasure`` is injectable for tests (defaults to
    re-running the benchmark modules in a subprocess). The final attempt's
    per-benchmark results are appended to ``summary_path`` as a markdown
    table when set. ``only`` restricts which gates run — lanes that produce
    only one artifact (the mesh smoke job emits just sharded_scrub.json)
    must not crash on the benchmarks they never measured."""
    unknown = set(only) - set(GATES)
    assert not unknown, (sorted(unknown), GATES)
    remeasure = _default_remeasure if remeasure is None else remeasure
    retries = max(0, int(retries))  # a negative flag must not skip the gate
    rc, results = 1, []
    for attempt in range(retries + 1):
        results = []
        # Run every selected gate even when the first fails: the summary
        # table should show every benchmark's state, not stop at the first
        # trip.
        rc = 0
        if "kernel" in only:
            rc = _check_kernel(threshold, results) or rc
        if "serve" in only:
            rc = _check_serve(threshold, results) or rc
        if "mesh" in only:
            rc = _check_mesh(mesh_floor, results) or rc
        if "accuracy" in only:
            rc = _check_accuracy(results) or rc
        if rc == 0:
            break
        if attempt < retries:
            print(
                f"::warning::regression gate tripped (rc={rc}), "
                f"re-measuring (retry {attempt + 1}/{retries})"
            )
            remeasure()
    if summary_path:
        write_step_summary(results, summary_path)
    return rc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.20)
    ap.add_argument("--retries", type=int, default=0)
    ap.add_argument(
        "--mesh-floor",
        type=float,
        default=0.97,
        help="min words/s ratio allowed per device-count step up "
        "(sharded_scrub.json; 0.97 tolerates timer noise, fails real "
        "shrinkage — the steady-state donated step holds this on 1 core; "
        "CI smoke geometry passes a lower explicit floor)",
    )
    ap.add_argument(
        "--only",
        action="append",
        choices=GATES,
        default=None,
        help="restrict to one gate (repeatable); default runs all",
    )
    ap.add_argument(
        "--summary",
        default=os.environ.get("GITHUB_STEP_SUMMARY"),
        help="append a pass/fail markdown table here "
        "(default: $GITHUB_STEP_SUMMARY when set)",
    )
    args = ap.parse_args()
    sys.exit(
        check(
            args.threshold,
            retries=args.retries,
            summary_path=args.summary,
            mesh_floor=args.mesh_floor,
            only=tuple(args.only) if args.only else GATES,
        )
    )


if __name__ == "__main__":
    main()
