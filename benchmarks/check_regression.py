"""CI benchmark-regression gate for the fused inject+scrub kernel.

Compares the fresh ``benchmarks/out/kernel_micro.json`` against the
checked-in ``benchmarks/baseline/kernel_micro.json`` and exits non-zero when
the fused kernel slowed down by more than the threshold (default 20%).

Raw wall-clocks are useless across runners (CI machines differ 3-5x), so the
gated metric is ``fused_over_pair``: the fused inject+scrub time divided by
the separate inject->decode pair measured in the same process. The pair is
the workload the fused kernel replaced, touches the same planes through the
same Pallas machinery, and so cancels machine speed, interpret-mode overhead
and BLAS/thread noise — what's left is the fused kernel's relative cost,
which is what a code change can regress.

Usage: python -m benchmarks.check_regression [--threshold 0.20]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

HERE = os.path.dirname(__file__)
BASELINE = os.path.join(HERE, "baseline", "kernel_micro.json")
CURRENT = os.path.join(HERE, "out", "kernel_micro.json")


def _gated_rows(rows: list[dict]) -> dict:
    return {
        r["words"]: r["fused_over_pair"]
        for r in rows
        if r.get("kernel") == "inject_scrub"
    }


def check(threshold: float = 0.20) -> int:
    with open(BASELINE) as f:
        base = _gated_rows(json.load(f))
    with open(CURRENT) as f:
        cur = _gated_rows(json.load(f))
    if not base:
        print("FAIL: baseline has no inject_scrub rows", file=sys.stderr)
        return 2
    missing = sorted(set(base) - set(cur))
    if missing:
        print(f"FAIL: current run lacks inject_scrub rows for {missing}", file=sys.stderr)
        return 2
    # Per-size ratios are reported for debugging; the gate is the geometric
    # mean across sizes — residual timer noise per size is uncorrelated, so
    # the pooled metric is ~sqrt(n) tighter than any single row.
    logs = 0.0
    for words, ref in sorted(base.items()):
        now = cur[words]
        logs += math.log(now / ref)
        print(
            f"inject_scrub {words}w: fused_over_pair {now:.3f} "
            f"(baseline {ref:.3f}, {now / ref - 1.0:+.1%})"
        )
    rel = math.exp(logs / len(base)) - 1.0
    print(f"inject_scrub pooled: {rel:+.1%} vs baseline (gate at +{threshold:.0%})")
    if rel > threshold:
        print(
            f"FAIL: fused inject+scrub slowed down > {threshold:.0%} vs baseline",
            file=sys.stderr,
        )
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.20)
    args = ap.parse_args()
    sys.exit(check(args.threshold))


if __name__ == "__main__":
    main()
