"""Accuracy-under-undervolt campaign: the paper's headline curve, measured.

Drives core/campaign.run_campaign — for each codec (and optional environment
scenario) an inline ServingEngine walks the campaign voltage grid and every
point's output is scored against the clean nominal rollout (greedy-match
prefix, teacher-forced logit KL, perplexity delta; see DESIGN.md §15). The
emitted rows are the accuracy-vs-voltage trajectory `benchmarks/run.py`
publishes as BENCH_accuracy.json and `check_regression.py --only accuracy`
gates on shape: zero divergence at nominal, and ileave88's zero-divergence
region reaching strictly deeper than parity65's.

CLI:
  python -m benchmarks.accuracy_campaign                  # full default grid
  python -m benchmarks.accuracy_campaign --smoke          # 1 voltage, 1 codec
  python -m benchmarks.accuracy_campaign \
      --codecs secded72,ileave88 --voltages 1.0,0.59,0.55 # nightly lane
"""

from __future__ import annotations

import argparse

from benchmarks.common import csv_line, emit
from repro.core import campaign


def run(spec: campaign.CampaignSpec | None = None) -> list[dict]:
    rows = campaign.run_campaign(spec or campaign.CampaignSpec())
    emit(rows, "accuracy_campaign")
    return rows


def _parse_spec(args) -> campaign.CampaignSpec:
    kw = {}
    if args.smoke:
        # cheapest harness exercise that still scores a faulty point:
        # one codec, nominal + one deep-undervolt voltage
        kw.update(
            codecs=("secded72",), voltages=(1.0, 0.55), n_prompts=2,
            n_tokens=12, proxy_words=1 << 12,
        )
    if args.model:
        kw["model"] = args.model
    if args.codecs:
        kw["codecs"] = tuple(args.codecs.split(","))
    if args.voltages:
        kw["voltages"] = tuple(float(v) for v in args.voltages.split(","))
    if args.env:
        kw["environments"] = tuple(
            None if e in ("", "none") else e for e in args.env.split(",")
        )
    if args.prompts:
        kw["n_prompts"] = args.prompts
    if args.tokens:
        kw["n_tokens"] = args.tokens
    if args.seed is not None:
        kw["seed"] = args.seed
    return campaign.CampaignSpec(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default=None, help="tiny | <arch>-smoke | <arch>")
    ap.add_argument("--codecs", default=None, help="comma-separated codec names")
    ap.add_argument("--voltages", default=None, help="comma-separated volts")
    ap.add_argument("--env", default=None,
                    help="comma-separated scenario names ('none' = baseline)")
    ap.add_argument("--prompts", type=int, default=None)
    ap.add_argument("--tokens", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="1 codec x {nominal, 0.55V} harness smoke (CI)")
    # parse_known_args: benchmarks.run passes its section name through argv
    args, _ = ap.parse_known_args(argv)

    rows = run(_parse_spec(args))
    for r in rows:
        env = f"/{r['environment']}" if r["environment"] else ""
        print(
            csv_line(
                f"accuracy/{r['model']}{env}/{r['codec']}@{r['voltage']:.2f}V",
                r["us"],
                f"divergence={r['divergence']:.4f};match_len={r['match_len']:.1f}"
                f"/{r['n_tokens']};kl={r['kl']:.4f};ppl_delta={r['ppl_delta']:.3f};"
                f"faulty_words={r['faulty_words']};detected={r['detected']}",
            )
        )
    # per-codec deepest voltage still bit-identical to the clean run — the
    # number the paper's "negligible accuracy loss down to V_min-ish" claim
    # becomes at LM scale
    for codec in dict.fromkeys(r["codec"] for r in rows):
        zero = [
            r["voltage"] for r in rows
            if r["codec"] == codec and r["divergence"] == 0.0
        ]
        floor = min(zero) if zero else None
        print(f"# {codec}: zero-divergence floor {floor} V over {len(zero)} points")

    smoke_ok = all(r["divergence"] == 0.0 for r in rows if r["nominal"])
    print(f"# nominal rows bit-identical to clean reference: {smoke_ok}")
    if not smoke_ok:
        raise SystemExit("nominal campaign rows diverged from the clean run")


if __name__ == "__main__":
    main()
