"""Render EXPERIMENTS.md roofline tables from dryrun.json/hillclimb.json."""

from __future__ import annotations

import json
import sys


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "benchmarks/out/dryrun.json"
    with open(path) as f:
        rows = [r for r in json.load(f) if r["status"] == "ok"]
    print("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | bound | useful | GiB/chip | fits16G |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    arch_order = [
        "qwen3-0.6b", "qwen1.5-4b", "minitron-8b", "qwen2-7b",
        "llama-3.2-vision-11b", "rwkv6-3b", "musicgen-medium",
        "llama4-scout-17b-a16e", "mixtral-8x22b", "jamba-1.5-large-398b",
    ]
    shape_order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    def key(r):
        return (
            arch_order.index(r["arch"]) if r["arch"] in arch_order else 99,
            shape_order.index(r["shape"]) if r["shape"] in shape_order else 9,
            r["mesh"],
            r.get("label") or "",
        )

    for r in sorted(rows, key=key):
        label = f" ({r['label']})" if r.get("label") else ""
        print(
            f"| {r['arch']}{label} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio'] or 0:.2f} "
            f"| {r['memory']['peak_est_gib']:.1f} "
            f"| {'yes' if r['memory']['fits_16g'] else 'NO'} |"
        )


if __name__ == "__main__":
    main()
