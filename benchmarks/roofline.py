"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/out/dryrun.json (produced by repro.launch.dryrun) and
prints, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-chip memory.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import OUT_DIR, csv_line

DRYRUN = os.path.join(OUT_DIR, "dryrun.json")


def run() -> list[dict]:
    if not os.path.exists(DRYRUN):
        return []
    with open(DRYRUN) as f:
        return json.load(f)


def main():
    rows = run()
    if not rows:
        print("# no dryrun.json yet — run: python -m repro.launch.dryrun")
        return
    ok = [r for r in rows if r["status"] == "ok"]
    # memory-only cells (the CI mesh smoke) have no roofline terms: report
    # the compile/memory proof instead of KeyError'ing the whole suite
    partial = [r for r in ok if "t_compute_s" not in r]
    ok = [r for r in ok if "t_compute_s" in r]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(
            csv_line(
                f"roofline/{r['arch']}/{r['shape']}@{r['mesh']}",
                r["seconds"]["total"] * 1e6,
                f"t_comp={r['t_compute_s']:.3e};t_mem={r['t_memory_s']:.3e};"
                f"t_coll={r['t_collective_s']:.3e};bound={r['bottleneck']};"
                f"useful={r['useful_flops_ratio']:.2f};"
                f"mem_gib={r['memory']['peak_est_gib']:.1f}",
            )
        )
    for r in sorted(partial, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(
            csv_line(
                f"roofline/{r['arch']}/{r['shape']}@{r['mesh']}",
                r["seconds"]["total"] * 1e6,
                f"memory_only;mem_gib={r['memory']['peak_est_gib']:.1f}"
                + (";smoke" if r.get("smoke") else ""),
            )
        )
    fails = [r for r in rows if r["status"] != "ok"]
    print(f"# {len(ok)} ok / {len(partial)} memory-only / {len(fails)} failed cells")
    for r in fails:
        print(f"# FAIL {r['arch']}/{r['shape']}@{r['mesh']}: {r.get('error', '?')}")


if __name__ == "__main__":
    main()
