"""Serving throughput: continuous batching (paged SECDED KV cache) vs the
fixed-batch decode loop, on a mixed-length request stream.

The fixed-batch baseline is what `ServingEngine.generate` does: pad every
prompt to the longest, decode the *longest* token budget for everyone, and
run the stream in rectangular waves of ``n_lanes`` requests — short requests
burn lane-steps padding out each wave's longest budget. Continuous batching
(`ServingEngine.serve`) admits a request the moment a lane frees up and
retires it the moment its budget is done, so lane-steps track useful tokens;
multi-step blocks keep its dispatch count in the same league as the
baseline's `lax.scan` rollout. The stream below is the adversarial-but-
typical serving mix: one long generation per wave of four, so the fixed
path wastes ~2/3 of its lane-steps.

The continuous path pays its full reliability freight in the measurement:
every token's KV is SECDED-encoded into pages and the scrub-on-read pass
runs on cadence. The fixed baseline does neither (dense unprotected cache).

The gated metric is ``cont_over_fixed`` — continuous tokens/s over fixed
tokens/s in the same process — which cancels machine speed and interpret
overhead exactly like the fused/pair kernel ratio; both are gated by
benchmarks/check_regression.py against the checked-in baseline. Samples are
interleaved and the minimum taken (scheduler noise is strictly additive).

The second experiment measures prefix sharing (DESIGN.md §16): the same
serve loop over a stream whose prompts share a long common prefix, with the
copy-on-write trie on vs off. ``shared_over_private`` is the tokens/s ratio
(> 1.0 gated absolutely: sharing must never cost throughput on a
shared-heavy stream). The win is structural — shared pages prefill through
the model once and are scrubbed once per interval instead of once per
reader — and the outputs stay bit-identical (tested, not benchmarked).

Usage: PYTHONPATH=src python -m benchmarks.serve_throughput
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import csv_line, emit

N_LANES = 4
MAX_LEN = 72
SCRUB_INTERVAL = 16
# the overlap experiment scrubs on a tight cadence so the scrub launch is a
# real fraction of the loop (at 16 it is amortized into the noise): the
# serialized path blocks on counters inside every interval, the overlapped
# path (DESIGN.md #18) defers the harvest one interval and lets decode run
OVERLAP_SCRUB_INTERVAL = 4
# one long generation per wave of four: budgets 48 / 5, prompts 8 tokens
STREAM = [(8, 48 if i % 4 == 0 else 5) for i in range(16)]
# prefix-sharing stream: a 48-token common prompt prefix (6 full pages at
# page_tokens=8) + 4 private suffix tokens, 12 new tokens each. The first
# wave of N_LANES seeds the trie (registration happens after commit, so
# same-wave requests cannot share); every later wave hits all 6 pages.
SHARED_PREFIX = 48
SHARED_SUFFIX = 4
SHARED_NEW = 12
N_SHARED = 16


def _setup():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serving import ServingEngine

    # serving-shaped config: big enough that per-step compute, not Python
    # dispatch, is the cost being scheduled (the smoke config is dispatch-
    # bound and would benchmark the interpreter, not the scheduler)
    cfg = dataclasses.replace(
        get_smoke_config("qwen3-0.6b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        (rng.integers(0, cfg.vocab, size=(s0,)).astype(np.int32), n)
        for s0, n in STREAM
    ]
    prefix = rng.integers(0, cfg.vocab, size=(SHARED_PREFIX,)).astype(np.int32)
    shared_reqs = [
        (
            np.concatenate(
                [prefix, rng.integers(0, cfg.vocab, size=(SHARED_SUFFIX,)).astype(np.int32)]
            ),
            SHARED_NEW,
        )
        for _ in range(N_SHARED)
    ]
    return ServingEngine(cfg, params, rel=None, max_len=MAX_LEN), reqs, shared_reqs


def _run_fixed(eng, reqs) -> None:
    """Rectangular waves of N_LANES: pad prompts to the wave max, decode the
    wave-max token budget for every lane."""
    for w in range(0, len(reqs), N_LANES):
        wave = reqs[w : w + N_LANES]
        s_max = max(len(p) for p, _ in wave)
        n_max = max(n for _, n in wave)
        prompts = np.zeros((len(wave), s_max), np.int32)
        for i, (p, _) in enumerate(wave):
            prompts[i, : len(p)] = p  # right-pad; timing-only baseline
        eng.generate(prompts, n_tokens=n_max)


def run(samples: int = 3) -> list[dict]:
    eng, reqs, shared_reqs = _setup()
    useful_tokens = sum(n for _, n in reqs)
    run_cont = lambda: eng.serve(
        reqs, n_lanes=N_LANES, scrub_interval=SCRUB_INTERVAL
    )
    run_shared = lambda on: eng.serve(
        shared_reqs,
        n_lanes=N_LANES,
        scrub_interval=SCRUB_INTERVAL,
        share_prefix=on,
    )
    run_overlap = lambda on: eng.serve(
        reqs,
        n_lanes=N_LANES,
        scrub_interval=OVERLAP_SCRUB_INTERVAL,
        scrub_overlap=on,
    )

    from repro.obs import TraceRecorder

    _run_fixed(eng, reqs)  # warmup / compile
    rep = run_cont()
    run_shared(False), run_shared(True)  # warm both trie states' shapes
    run_overlap(False), run_overlap(True)  # warm the tight-cadence shapes
    tf, tc = [], []
    tp, ts = [], []
    tt, n_events = [], 0
    tser, tovl = [], []
    for _ in range(samples):
        t0 = time.perf_counter()
        _run_fixed(eng, reqs)
        tf.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rep = run_cont()
        tc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_shared(False)
        tp.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        srep = run_shared(True)
        ts.append(time.perf_counter() - t0)
        # traced sample: same serve loop with the flight recorder attached
        # (fresh per sample so the event list never amortizes across runs)
        eng.recorder = TraceRecorder()
        t0 = time.perf_counter()
        run_cont()
        tt.append(time.perf_counter() - t0)
        n_events = len(eng.recorder.events)
        eng.recorder = None
        t0 = time.perf_counter()
        run_overlap(False)
        tser.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_overlap(True)
        tovl.append(time.perf_counter() - t0)

    tps_fixed = useful_tokens / min(tf)
    tps_cont = useful_tokens / min(tc)
    tps_traced = useful_tokens / min(tt)
    shared_tokens = sum(n for _, n in shared_reqs)
    tps_private = shared_tokens / min(tp)
    tps_shared = shared_tokens / min(ts)
    tps_serialized = useful_tokens / min(tser)
    tps_overlapped = useful_tokens / min(tovl)
    rows = [
        {
            "kernel": "serve_throughput",
            "n_requests": len(reqs),
            "n_lanes": N_LANES,
            "useful_tokens": useful_tokens,
            "scrub_interval": SCRUB_INTERVAL,
            "steps_cont": rep.steps,
            "preemptions": rep.preemptions,
            "tokens_s_fixed": tps_fixed,
            "tokens_s_cont": tps_cont,
            "cont_over_fixed": tps_cont / tps_fixed,
        },
        {
            "kernel": "serve_shared_prefix",
            "n_requests": len(shared_reqs),
            "n_lanes": N_LANES,
            "useful_tokens": shared_tokens,
            "scrub_interval": SCRUB_INTERVAL,
            "prefix_tokens": SHARED_PREFIX,
            "prefix_hit_tokens": srep.prefix_hit_tokens,
            "tokens_s_private": tps_private,
            "tokens_s_shared": tps_shared,
            "shared_over_private": tps_shared / tps_private,
        },
        {
            # observability overhead: the same continuous-batching serve with
            # the flight recorder on. Gated absolutely (>= 0.95): tracing must
            # stay in the noise, never a tax on serving throughput.
            "kernel": "serve_traced",
            "n_requests": len(reqs),
            "n_lanes": N_LANES,
            "useful_tokens": useful_tokens,
            "scrub_interval": SCRUB_INTERVAL,
            "trace_events": n_events,
            "tokens_s_untraced": tps_cont,
            "tokens_s_traced": tps_traced,
            "traced_over_untraced": tps_traced / tps_cont,
        },
        {
            # async scrub off the decode critical path (DESIGN.md #18):
            # identical stream and cadence, scrub_overlap forced off vs on.
            # Gated absolutely in check_regression: overlapping a launch the
            # serialized path blocks on must never cost throughput.
            "kernel": "serve_scrub_overlap",
            "n_requests": len(reqs),
            "n_lanes": N_LANES,
            "useful_tokens": useful_tokens,
            "scrub_interval": OVERLAP_SCRUB_INTERVAL,
            "tokens_s_serialized": tps_serialized,
            "tokens_s_overlapped": tps_overlapped,
            "overlapped_over_serialized": tps_overlapped / tps_serialized,
        },
    ]
    emit(rows, "serve_throughput")
    return rows


def main():
    rows = run()
    r = rows[0]
    print(
        csv_line(
            f"serve/throughput_{r['n_requests']}req_{r['n_lanes']}lane",
            1e6 / r["tokens_s_cont"],
            f"cont_over_fixed={r['cont_over_fixed']:.2f};"
            f"tokens_s_cont={r['tokens_s_cont']:.1f};"
            f"tokens_s_fixed={r['tokens_s_fixed']:.1f};"
            f"preemptions={r['preemptions']}",
        )
    )
    s = rows[1]
    print(
        csv_line(
            f"serve/shared_prefix_{s['n_requests']}req_{s['prefix_tokens']}tok",
            1e6 / s["tokens_s_shared"],
            f"shared_over_private={s['shared_over_private']:.2f};"
            f"tokens_s_shared={s['tokens_s_shared']:.1f};"
            f"tokens_s_private={s['tokens_s_private']:.1f};"
            f"prefix_hit_tokens={s['prefix_hit_tokens']}",
        )
    )
    t = rows[2]
    print(
        csv_line(
            f"serve/traced_{t['n_requests']}req_{t['n_lanes']}lane",
            1e6 / t["tokens_s_traced"],
            f"traced_over_untraced={t['traced_over_untraced']:.2f};"
            f"tokens_s_traced={t['tokens_s_traced']:.1f};"
            f"trace_events={t['trace_events']}",
        )
    )
    o = rows[3]
    print(
        csv_line(
            f"serve/scrub_overlap_{o['n_requests']}req_si{o['scrub_interval']}",
            1e6 / o["tokens_s_overlapped"],
            f"overlapped_over_serialized={o['overlapped_over_serialized']:.2f};"
            f"tokens_s_overlapped={o['tokens_s_overlapped']:.1f};"
            f"tokens_s_serialized={o['tokens_s_serialized']:.1f}",
        )
    )


if __name__ == "__main__":
    main()
