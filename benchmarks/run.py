"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark, mirroring the
paper's result set plus the kernel and roofline sections.

  fig1    fault rate vs voltage, 3 platforms, ECC on/off      (paper Fig. 1)
  fig2    fault-type histogram + FIP                          (paper Fig. 2b/2c)
  table1  ECC area/power overhead + derived savings           (paper Table I)
  fig3    NN accelerator error vs voltage, ECC on/off         (paper Fig. 3)
  kernels Pallas kernel micro + fused-vs-naive roofline model
  codecs  ECC scheme comparison: coverage vs overhead vs scrub throughput
  roofline dry-run roofline table (reads benchmarks/out/dryrun.json)
"""

from __future__ import annotations

import sys
import time

from benchmarks import (
    codec_compare,
    fig1_fault_rate,
    fig2_fault_types,
    fig3_nn_accuracy,
    kernel_micro,
    roofline,
    table1_overhead,
)

SECTIONS = [
    ("fig1", fig1_fault_rate),
    ("fig2", fig2_fault_types),
    ("table1", table1_overhead),
    ("fig3", fig3_nn_accuracy),
    ("kernels", kernel_micro),
    ("codecs", codec_compare),
    ("roofline", roofline),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in SECTIONS:
        if only and name != only:
            continue
        t0 = time.time()
        print(f"# === {name} ===")
        mod.main()
        print(f"# {name} finished in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
