"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark, mirroring the
paper's result set plus the kernel, mesh, and roofline sections, and writes
one ``BENCH_<name>.json`` trajectory file at the repo root per suite — the
perf trajectory consumed between PRs (each file carries the parsed rows, so
a regression is a one-line diff against the previous commit's file).

  fig1    fault rate vs voltage, 3 platforms, ECC on/off      (paper Fig. 1)
  fig2    fault-type histogram + FIP                          (paper Fig. 2b/2c)
  table1  ECC area/power overhead + derived savings           (paper Table I)
  fig3    NN accelerator error vs voltage, ECC on/off         (paper Fig. 3)
  kernels Pallas kernel micro + fused-vs-naive roofline model
  codecs  ECC scheme comparison: coverage vs overhead vs scrub throughput
  mesh    sharded-scrub throughput vs host-device count (DESIGN.md §13)
  accuracy LM output divergence vs voltage per codec (DESIGN.md §15)
  roofline dry-run roofline table (reads benchmarks/out/dryrun.json)
"""

from __future__ import annotations

import contextlib
import io
import json
import os
import sys
import time

from benchmarks import (
    accuracy_campaign,
    codec_compare,
    fig1_fault_rate,
    fig2_fault_types,
    fig3_nn_accuracy,
    kernel_micro,
    roofline,
    sharded_scrub,
    table1_overhead,
)

SECTIONS = [
    ("fig1", fig1_fault_rate),
    ("fig2", fig2_fault_types),
    ("table1", table1_overhead),
    ("fig3", fig3_nn_accuracy),
    ("kernels", kernel_micro),
    ("codecs", codec_compare),
    ("mesh", sharded_scrub),
    ("accuracy", accuracy_campaign),
    ("roofline", roofline),
]

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def parse_rows(text: str) -> list[dict]:
    """CSV lines (``name,us_per_call,derived``) -> row dicts; comment lines
    (``# ...``) and the header are dropped."""
    rows = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#") or line.startswith("name,"):
            continue
        parts = line.split(",", 2)
        if len(parts) != 3:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": us, "derived": parts[2]})
    return rows


def write_trajectory(name: str, rows: list[dict], seconds: float,
                     root: str = REPO_ROOT) -> str:
    """Write one suite's ``BENCH_<name>.json`` at the repo root."""
    path = os.path.join(root, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {"suite": name, "rows": rows, "seconds": round(seconds, 1)},
            f, indent=1,
        )
        f.write("\n")
    return path


def run_section(name: str, mod) -> list[dict]:
    """Run one section, tee its CSV output, write its trajectory file."""
    t0 = time.time()
    print(f"# === {name} ===")
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            mod.main()
    finally:
        # echo even when the section dies: its CSV rows and diagnostics are
        # the only record of what happened before the crash
        sys.stdout.write(buf.getvalue())
    rows = parse_rows(buf.getvalue())
    seconds = time.time() - t0
    path = write_trajectory(name, rows, seconds)
    print(f"# {name}: {len(rows)} rows -> {os.path.relpath(path, REPO_ROOT)} "
          f"({seconds:.1f}s)")
    return rows


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in SECTIONS:
        if only and name != only:
            continue
        run_section(name, mod)


if __name__ == "__main__":
    main()
