"""Pallas kernel micro-benchmarks (interpret mode on CPU) + roofline model.

Wall-times here are CPU-interpret numbers (NOT TPU performance); the derived
column reports the *kernel roofline model* for TPU v5e — the quantity used in
EXPERIMENTS.md §Perf to compare the fused ECC-matmul read path against the
naive decode-then-matmul baseline:

  naive  HBM bytes = planes(9B/8w) + int8 W write + int8 W read + x + out
  fused  HBM bytes = planes(9B/8w) + x + out          (decode lives in VMEM)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, emit, timed
from repro.kernels import ops, ref

HBM_BW = 819e9
PEAK = 197e12


def _roofline(m, k, n, fused: bool):
    planes = (k // 8) * n * 9  # lo+hi (8B) + parity (1B) per 8 int8 weights
    x_io = m * k * 4 + m * n * 4
    w_rt = 0 if fused else 2 * k * n  # int8 W write + read for naive
    t_mem = (planes + x_io + w_rt) / HBM_BW
    t_comp = 2 * m * k * n / PEAK
    return t_mem, t_comp


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    # encode/decode planes
    for n_words in (1 << 14, 1 << 17):
        lo = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        hi = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        par, us_e = timed(lambda: jax.block_until_ready(ops.encode(lo, hi)))
        _, us_d = timed(lambda: jax.block_until_ready(ops.decode(lo, hi, par)))
        rows.append({"kernel": "secded_encode", "words": n_words, "us": us_e})
        rows.append({"kernel": "secded_decode", "words": n_words, "us": us_d})
    # fused vs naive ecc_matmul
    for (m, k, n) in ((128, 1024, 512), (256, 2048, 1024)):
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
        ew = ops.pack_ecc_weights(w)
        _, us_f = timed(lambda: jax.block_until_ready(ops.ecc_matmul(x, ew, fuse=True)), repeat=2)
        _, us_n = timed(lambda: jax.block_until_ready(ops.ecc_matmul(x, ew, fuse=False)), repeat=2)
        tm_f, tc = _roofline(m, k, n, fused=True)
        tm_n, _ = _roofline(m, k, n, fused=False)
        rows.append(
            {
                "kernel": "ecc_matmul", "mkn": [m, k, n],
                "us_fused_interp": us_f, "us_naive_interp": us_n,
                "tpu_model_mem_fused_s": tm_f, "tpu_model_mem_naive_s": tm_n,
                "tpu_model_compute_s": tc,
                "fused_traffic_saving": 1 - tm_f / tm_n,
            }
        )
    emit(rows, "kernel_micro")
    return rows


def main():
    rows = run()
    for r in rows:
        if r["kernel"] == "ecc_matmul":
            m, k, n = r["mkn"]
            print(
                csv_line(
                    f"kernel/ecc_matmul_{m}x{k}x{n}", r["us_fused_interp"],
                    f"fused_vs_naive_hbm_saving={100 * r['fused_traffic_saving']:.1f}%;"
                    f"model_mem_fused={r['tpu_model_mem_fused_s']:.2e}s",
                )
            )
        else:
            print(csv_line(f"kernel/{r['kernel']}_{r['words']}w", r["us"], "interpret"))


if __name__ == "__main__":
    main()
