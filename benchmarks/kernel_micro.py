"""Pallas kernel micro-benchmarks + roofline model.

Every row is tagged with the kernel backend in force (``compiled`` where the
platform lowers Pallas for real — TPU Mosaic / GPU Triton — ``interpret``
elsewhere; kernels/backend.py). On a CPU runner the wall-times are
interpret-lane numbers (NOT TPU performance); the derived
column reports the *kernel roofline model* for TPU v5e — the quantity used in
EXPERIMENTS.md §Perf to compare the fused ECC-matmul read path against the
naive decode-then-matmul baseline:

  naive  HBM bytes = planes(9B/8w) + int8 W write + int8 W read + x + out
  fused  HBM bytes = planes(9B/8w) + x + out          (decode lives in VMEM)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, emit, timed
from repro.kernels import backend as kbackend
from repro.kernels import ops, ref

HBM_BW = 819e9
PEAK = 197e12


def _roofline(m, k, n, fused: bool):
    planes = (k // 8) * n * 9  # lo+hi (8B) + parity (1B) per 8 int8 weights
    x_io = m * k * 4 + m * n * 4
    w_rt = 0 if fused else 2 * k * n  # int8 W write + read for naive
    t_mem = (planes + x_io + w_rt) / HBM_BW
    t_comp = 2 * m * k * n / PEAK
    return t_mem, t_comp


def voltage_sweep(n_steps: int = 10) -> dict:
    """Wall time + Pallas launch count for an N-step undervolt sweep on the
    paper NN config: historical per-leaf loop vs the batched arena path
    (one fused inject_scrub launch per step)."""
    import time

    from repro.configs import get_config
    from repro.core.nn_accel import EccMLP

    cfg = get_config("paper-nn")
    volts = np.linspace(0.60, 0.54, n_steps)
    out = {"kernel": "voltage_sweep", "steps": n_steps,
           "arch": cfg.name, "layer_sizes": list(cfg.layer_sizes)}
    # perleaf/batched share host (oracle) masks: pure kernel-count comparison;
    # "device" is the fully device-resident path (jax.random masks, no host
    # mask materialisation) — the production voltage-sweep configuration.
    for label, mask_source, batched in (
        ("perleaf", "host", False),
        ("batched", "host", True),
        ("device", "device", True),
    ):
        mlp = EccMLP(cfg.layer_sizes, platform=cfg.platform, seed=0,
                     mask_source=mask_source)
        mlp.store()  # untrained weights: we time the rail loop, not accuracy

        def sweep():
            for v in volts:
                mlp.set_voltage(float(v), batched=batched)

        sweep()  # warmup / compile
        ops.reset_launch_count()
        t0 = time.perf_counter()
        sweep()
        out[f"us_{label}"] = (time.perf_counter() - t0) * 1e6
        out[f"launches_{label}"] = ops.launch_count()
    out["launch_ratio"] = out["launches_perleaf"] / max(out["launches_batched"], 1)
    out["speedup"] = out["us_perleaf"] / out["us_batched"]
    out["speedup_device"] = out["us_perleaf"] / out["us_device"]
    return out


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    # encode/decode planes
    for n_words in (1 << 14, 1 << 17):
        lo = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        hi = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        par, us_e = timed(lambda: jax.block_until_ready(ops.encode(lo, hi)))
        _, us_d = timed(lambda: jax.block_until_ready(ops.decode(lo, hi, par)))
        rows.append({"kernel": "secded_encode", "words": n_words, "us": us_e})
        rows.append({"kernel": "secded_decode", "words": n_words, "us": us_d})
    # fused inject+scrub vs the separate inject->decode pair it replaced.
    # `fused_over_pair` is the machine-independent metric the CI regression
    # gate tracks (benchmarks/check_regression.py): wall-clocks vary with the
    # runner, the fused/unfused ratio on the same process does not. Samples
    # are interleaved and the minimum taken — scheduler noise is strictly
    # additive, so min-of-n estimates the true cost where mean/median of a
    # few runs on a shared CI runner jitter by 2x.
    import time as _time

    def _interleaved_min(fa, fb, n=7, inner=3):
        fa(), fb()  # warmup / compile
        ta, tb = [], []
        for _ in range(n):
            t0 = _time.perf_counter()
            for _ in range(inner):
                fa()
            ta.append(_time.perf_counter() - t0)
            t0 = _time.perf_counter()
            for _ in range(inner):
                fb()
            tb.append(_time.perf_counter() - t0)
        return min(ta) / inner * 1e6, min(tb) / inner * 1e6

    for n_words in (1 << 14, 1 << 17):
        lo = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        hi = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        par = ops.encode(lo, hi)
        mlo = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        mhi = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
        mpar = jnp.asarray(rng.integers(0, 256, n_words).astype(np.uint8))

        def fused():
            return jax.block_until_ready(
                ops.inject_scrub(lo, hi, par, mlo, mhi, mpar)[3]
            )

        def pair():
            flo, fhi, fpar = ops.inject(lo, hi, par, mlo, mhi, mpar)
            return jax.block_until_ready(ops.decode(flo, fhi, fpar)[2])

        us_f, us_p = _interleaved_min(fused, pair)
        rows.append(
            {
                "kernel": "inject_scrub", "words": n_words,
                "us": us_f, "us_pair": us_p,
                "fused_over_pair": us_f / us_p,
            }
        )
    # compiled-vs-interpret ratio on the flagship fused kernel (DESIGN.md
    # §18): `lane` is whatever backend.resolve() picks (compiled where a
    # Pallas lowering exists, interpret elsewhere), `interp` is forced
    # interpret. On an interpret-only host the two lanes are the same code
    # path and the ratio sits at ~1.0 — the trajectory row exists so a host
    # WITH a compiled lowering fails loudly if compiled ever regresses past
    # interpret (check_regression --only kernel).

    def lane():
        return jax.block_until_ready(
            ops.inject_scrub(lo, hi, par, mlo, mhi, mpar)[3]
        )

    def interp():
        return jax.block_until_ready(
            ops.inject_scrub(lo, hi, par, mlo, mhi, mpar, interpret=True)[3]
        )

    us_l, us_i = _interleaved_min(lane, interp)
    rows.append(
        {
            "kernel": "backend_ratio", "words": n_words,
            "us": us_l, "us_interpret": us_i,
            "compiled_over_interpret": us_l / us_i,
        }
    )
    # fused vs naive ecc_matmul
    for (m, k, n) in ((128, 1024, 512), (256, 2048, 1024)):
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
        ew = ops.pack_ecc_weights(w)
        _, us_f = timed(lambda: jax.block_until_ready(ops.ecc_matmul(x, ew, fuse=True)), repeat=2)
        _, us_n = timed(lambda: jax.block_until_ready(ops.ecc_matmul(x, ew, fuse=False)), repeat=2)
        tm_f, tc = _roofline(m, k, n, fused=True)
        tm_n, _ = _roofline(m, k, n, fused=False)
        rows.append(
            {
                "kernel": "ecc_matmul", "mkn": [m, k, n],
                "us_fused_interp": us_f, "us_naive_interp": us_n,
                "tpu_model_mem_fused_s": tm_f, "tpu_model_mem_naive_s": tm_n,
                "tpu_model_compute_s": tc,
                "fused_traffic_saving": 1 - tm_f / tm_n,
            }
        )
    rows.append(voltage_sweep())
    for r in rows:  # every row carries the lowering it was measured under
        r.setdefault("backend", kbackend.tag())
    emit(rows, "kernel_micro")
    return rows


def main():
    rows = run()
    for r in rows:
        if r["kernel"] == "voltage_sweep":
            print(
                csv_line(
                    f"kernel/voltage_sweep_{r['steps']}step", r["us_batched"],
                    f"speedup_vs_perleaf={r['speedup']:.2f}x;"
                    f"device_resident={r['speedup_device']:.2f}x;"
                    f"launches={r['launches_batched']}vs{r['launches_perleaf']}"
                    f" ({r['launch_ratio']:.0f}x fewer)",
                )
            )
        elif r["kernel"] == "inject_scrub":
            print(
                csv_line(
                    f"kernel/inject_scrub_{r['words']}w", r["us"],
                    f"fused_over_pair={r['fused_over_pair']:.2f};"
                    f"pair_us={r['us_pair']:.1f};backend={r['backend']}",
                )
            )
        elif r["kernel"] == "backend_ratio":
            print(
                csv_line(
                    f"kernel/backend_ratio_{r['words']}w", r["us"],
                    f"compiled_over_interpret={r['compiled_over_interpret']:.2f};"
                    f"backend={r['backend']}",
                )
            )
        elif r["kernel"] == "ecc_matmul":
            m, k, n = r["mkn"]
            print(
                csv_line(
                    f"kernel/ecc_matmul_{m}x{k}x{n}", r["us_fused_interp"],
                    f"fused_vs_naive_hbm_saving={100 * r['fused_traffic_saving']:.1f}%;"
                    f"model_mem_fused={r['tpu_model_mem_fused_s']:.2e}s",
                )
            )
        else:
            print(csv_line(
                f"kernel/{r['kernel']}_{r['words']}w", r["us"],
                f"backend={r['backend']}",
            ))


if __name__ == "__main__":
    main()
