"""Sharded-scrub throughput: one fixed arena, sharded over 1 -> 8 devices.

Benchmarks the shard_map'd paged scrub-on-read step (distributed/meshrel.py):
every reliability shard gathers its own page rows from its slice of the
stacked KV planes, runs the Hsiao scrub kernel, and writes corrected planes
back — no plane word crosses a shard. The sweep is *strong scaling*: the
total arena (``--pages`` x ``--page-words`` words) is held fixed and split
evenly across the forced host devices, so every sweep point streams the
identical working set and the curve isolates what the gate exists to catch —
per-shard step overhead (an in-step collective, a materialized payload
output, per-shard dispatch bookkeeping) that grows with the shard count.
Weak scaling (fixed per-shard slice) is the wrong experiment on a
shared-cache host: total footprint then grows with the device count and the
curve measures which sweep points happen to fit the cache hierarchy, not the
scrub step. Each device count runs in its own subprocess
(``--xla_force_host_platform_device_count`` is locked at jax init).

Timing is *steady state* (DESIGN.md §18): the step is built payload-free
(``with_payload=False`` — the scrub soak never reads the gathered page
payload, so the two largest outputs are dropped) and collective-free (no
in-step psum); after a compile warmup AND one dropped warm call, ``repeat``
calls are chain-dispatched — each feeds the previous call's corrected planes
forward — with a single ``block_until_ready`` at the end. That is exactly how
the serving scheduler drives the step (async dispatch, deferred harvest), and
it keeps per-call host dispatch overhead from polluting the high-device
points, where forced host devices multiply launch bookkeeping but not cores.

CSV rows: ``mesh_scrub_d<N>,us_per_call,words_per_s=...`` (tagged with the
kernel backend in force) plus the scaling summary row the nightly trajectory
tracks.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import csv_line, emit

DEFAULT_DEVICES = (1, 2, 4, 8)


def _worker(
    n_devices: int, total_pages: int, page_words: int, repeat: int,
    groups: int = 5,
) -> None:
    """Runs inside a subprocess with ``n_devices`` forced host devices."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed import meshrel
    from repro.launch.mesh import make_reliability_mesh

    assert len(jax.devices()) == n_devices, (len(jax.devices()), n_devices)
    assert total_pages % n_devices == 0, (total_pages, n_devices)
    # strong scaling: the arena is fixed, each shard owns total/n of it
    n_pages = total_pages // n_devices
    mesh = make_reliability_mesh(n_devices)
    sharding = meshrel.arena_sharding(mesh)
    local_words = n_pages * page_words
    total = n_devices * local_words
    rng = np.random.default_rng(0)
    lo = jax.device_put(
        jnp.asarray(rng.integers(0, 1 << 32, size=total, dtype=np.uint32)), sharding
    )
    hi = jax.device_put(
        jnp.asarray(rng.integers(0, 1 << 32, size=total, dtype=np.uint32)), sharding
    )
    from repro.kernels import ops as kops

    par = jax.device_put(kops.encode(lo, hi), sharding)
    # every shard scrubs all of its local pages each call
    table = jax.device_put(
        jnp.tile(jnp.arange(n_pages, dtype=jnp.int32)[None], (n_devices, 1)),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")),
    )
    from repro.kernels import backend as kbackend

    base = meshrel.make_kv_scrub_step(
        mesh, page_words, local_words, n_pages, with_payload=False
    )
    # donate the incoming planes: the chain feeds corrected planes forward
    # and never rereads old ones, so XLA reuses the buffers in place instead
    # of allocating (and page-faulting) fresh multi-MB outputs every call —
    # the same §18 donation contract the serving PlaneStore uses
    step = jax.jit(lambda l, h, p, t: base(l, h, p, t), donate_argnums=(0, 1, 2))
    olo, ohi, opar, cnt = step(lo, hi, par, table)
    jax.block_until_ready(cnt)  # warmup: compile
    # one more dropped call: the first post-compile dispatch still pays
    # executable/dispatch-cache population, which would otherwise dominate
    # repeat=1 smoke runs and the high-device points
    olo, ohi, opar, cnt = step(olo, ohi, opar, table)
    jax.block_until_ready(cnt)
    # steady state: chain-dispatch `repeat` calls (planes feed forward, as
    # the scheduler's async scrub does) and synchronize once per group.
    # min over groups: scheduler noise on a shared host is strictly
    # additive, so the fastest group estimates the true steady-state cost
    # (same rationale as kernel_micro's interleaved-min)
    best = float("inf")
    for _ in range(max(groups, 1)):
        t0 = time.perf_counter()
        for _ in range(repeat):
            olo, ohi, opar, cnt = step(olo, ohi, opar, table)
        jax.block_until_ready(cnt)
        best = min(best, time.perf_counter() - t0)
    us = best / repeat * 1e6
    print(json.dumps({
        "devices": n_devices,
        "us_per_call": us,
        "words_scrubbed": total,
        "words_per_s": total / (us / 1e6),
        "clean_words": int(np.asarray(cnt)[..., 0].sum()),
        "backend": kbackend.tag(),
    }))


def run_points(
    devices, n_pages: int, page_words: int, repeat: int, groups: int = 5,
    trials: int = 1,
) -> list[dict]:
    """One subprocess per (device count, trial); trials are interleaved
    round-robin across device counts and the per-point minimum taken, so a
    slow patch on a shared host hits every sweep point fairly instead of
    sinking whichever point it coincided with."""
    best: dict[int, dict] = {}
    for _ in range(max(trials, 1)):
        for n in devices:
            env = dict(os.environ)
            # preserve unrelated XLA flags; only the forced count is ours
            kept = [
                f for f in env.get("XLA_FLAGS", "").split()
                if not f.startswith("--xla_force_host_platform_device_count")
            ]
            env["XLA_FLAGS"] = " ".join(
                kept + [f"--xla_force_host_platform_device_count={n}"]
            )
            env["PYTHONPATH"] = os.pathsep.join(
                p for p in (
                    os.path.join(os.path.dirname(__file__), "..", "src"),
                    os.path.join(os.path.dirname(__file__), ".."),
                    env.get("PYTHONPATH", ""),
                ) if p
            )
            out = subprocess.run(
                [
                    sys.executable, "-m", "benchmarks.sharded_scrub",
                    "--worker", "--devices", str(n), "--pages", str(n_pages),
                    "--page-words", str(page_words), "--repeat", str(repeat),
                    "--groups", str(groups),
                ],
                capture_output=True, text=True, env=env, timeout=900,
            )
            assert out.returncode == 0, out.stderr[-2000:]
            row = json.loads(out.stdout.strip().splitlines()[-1])
            if n not in best or row["us_per_call"] < best[n]["us_per_call"]:
                best[n] = row
    return [best[n] for n in devices]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=0,
                    help="single device count (worker / one-point mode)")
    ap.add_argument("--max-devices", type=int, default=8)
    # TOTAL arena pages, split across shards (strong scaling; must divide by
    # every sweep device count). 256 x 4096 words ~ 9.4 MB of planes: past
    # L2 so the steady state is LLC-bound at every point, identical at every
    # point so the curve measures the scrub step, not the cache hierarchy
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-words", type=int, default=4096)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--groups", type=int, default=5,
                    help="timing groups per point (min taken)")
    ap.add_argument("--trials", type=int, default=3,
                    help="interleaved subprocess trials per point (min taken)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI: exercise the path, not the clock)")
    # parse_known_args: benchmarks.run passes its section name through argv
    args, _ = ap.parse_known_args(argv)
    if args.smoke:
        # chained dispatch makes extra repeats nearly free; 4 of them keep
        # the tiny-geometry points from being one-dispatch noise. The arena
        # must stay big enough that the d8 point (pages/8 per shard) is not
        # pure dispatch bookkeeping, or the smoke floor turns into a
        # dispatch-overhead lottery
        args.pages, args.page_words, args.repeat, args.groups = 128, 512, 4, 2
        args.trials = 1
    if args.worker:
        _worker(args.devices, args.pages, args.page_words, args.repeat,
                args.groups)
        return
    devices = [n for n in DEFAULT_DEVICES if n <= args.max_devices]
    if args.devices:
        devices = [args.devices]
    rows = run_points(devices, args.pages, args.page_words, args.repeat,
                      args.groups, args.trials)
    for r in rows:
        print(csv_line(
            f"mesh_scrub_d{r['devices']}", r["us_per_call"],
            f"words_per_s={r['words_per_s']:.3e};"
            f"backend={r.get('backend', 'interpret')}",
        ))
    if len(rows) > 1:
        scale = rows[-1]["words_per_s"] / rows[0]["words_per_s"]
        print(csv_line(
            f"mesh_scrub_scaling_{rows[0]['devices']}to{rows[-1]['devices']}",
            0.0, f"throughput_ratio={scale:.2f}",
        ))
    emit(rows, "sharded_scrub")


if __name__ == "__main__":
    main()
